"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    hash_encode,
    hamming_score,
    ref,
    sparse_attention_fused,
    sparse_attention_simple,
)

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=dtype)


# ---------------------------------------------------------------- hash encode
class TestHashEncode:
    @settings(**SETTINGS)
    @given(
        s=st.integers(1, 513),
        d=st.sampled_from([16, 32, 64, 128]),
        words=st.sampled_from([1, 2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
        tile=st.sampled_from([8, 64, 256]),
    )
    def test_matches_ref(self, s, d, words, seed, tile):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (s, d))
        w = _rand(rng, (d, 32 * words))
        got = hash_encode(x, w, tile_s=tile)
        want = ref.hash_encode(x, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_bf16_input(self, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (65, 32), dtype=jnp.bfloat16)
        w = _rand(rng, (32, 64))
        got = hash_encode(x, w)
        want = ref.hash_encode(x.astype(jnp.float32), w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bit_order_known_vector(self):
        # One-hot projections let us place each bit deliberately.
        d, rbit = 4, 32
        w = np.zeros((d, rbit), dtype=np.float32)
        w[0, 0] = 1.0   # bit 0 set iff x[0] >= 0
        w[1, 5] = 1.0   # bit 5 set iff x[1] >= 0
        w[2, 31] = -1.0  # bit 31 set iff x[2] < 0 (sign flip)
        x = np.array([[1.0, 1.0, 1.0, 0.0]], dtype=np.float32)
        code = np.asarray(hash_encode(jnp.asarray(x), jnp.asarray(w)))[0, 0]
        # zero-columns of W produce y == 0 -> bit set (>= 0 convention)
        zero_cols = [b for b in range(rbit) if b not in (0, 5, 31)]
        expect = (1 << 0) | (1 << 5) | sum(1 << b for b in zero_cols)
        assert code == expect

    def test_sign_convention_zero_is_positive(self):
        x = jnp.zeros((3, 8), dtype=jnp.float32)
        w = jnp.ones((8, 32), dtype=jnp.float32)
        code = np.asarray(hash_encode(x, w))
        assert (code == np.uint32(0xFFFFFFFF)).all()

    def test_deterministic(self):
        rng = np.random.default_rng(7)
        x, w = _rand(rng, (50, 16)), _rand(rng, (16, 64))
        a = np.asarray(hash_encode(x, w))
        b = np.asarray(hash_encode(x, w))
        np.testing.assert_array_equal(a, b)

    def test_rejects_bad_rbit(self):
        x = jnp.zeros((2, 8))
        w = jnp.zeros((8, 33))
        with pytest.raises(AssertionError):
            hash_encode(x, w)


# ------------------------------------------------------------------- hamming
class TestHammingScore:
    @settings(**SETTINGS)
    @given(
        h=st.integers(1, 16),
        s=st.integers(1, 700),
        words=st.sampled_from([1, 2, 4]),
        seed=st.integers(0, 2**31 - 1),
        tile=st.sampled_from([16, 128, 1024]),
    )
    def test_matches_ref(self, h, s, words, seed, tile):
        rng = np.random.default_rng(seed)
        rbit = 32 * words
        qc = jnp.asarray(rng.integers(0, 2**32, size=(h, words), dtype=np.uint32))
        kc = jnp.asarray(rng.integers(0, 2**32, size=(s, words), dtype=np.uint32))
        got = hamming_score(qc, kc, rbit, tile_k=tile)
        want = ref.hamming_score(qc, kc, rbit)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_identical_codes_score_rbit(self):
        c = jnp.asarray(np.arange(12, dtype=np.uint32).reshape(3, 4))
        s = np.asarray(hamming_score(c, c, 128))
        assert (np.diag(s) == 128).all()

    def test_complement_scores_zero(self):
        rng = np.random.default_rng(3)
        qc = rng.integers(0, 2**32, size=(2, 4), dtype=np.uint32)
        kc = ~qc
        s = np.asarray(hamming_score(jnp.asarray(qc), jnp.asarray(kc), 128))
        assert (np.diag(s) == 0).all()

    def test_score_range(self):
        rng = np.random.default_rng(5)
        qc = jnp.asarray(rng.integers(0, 2**32, size=(4, 2), dtype=np.uint32))
        kc = jnp.asarray(rng.integers(0, 2**32, size=(99, 2), dtype=np.uint32))
        s = np.asarray(hamming_score(qc, kc, 64))
        assert s.min() >= 0 and s.max() <= 64

    def test_symmetry(self):
        """score(a,b) == score(b,a) elementwise-transposed."""
        rng = np.random.default_rng(9)
        a = jnp.asarray(rng.integers(0, 2**32, size=(5, 4), dtype=np.uint32))
        b = jnp.asarray(rng.integers(0, 2**32, size=(7, 4), dtype=np.uint32))
        s_ab = np.asarray(hamming_score(a, b, 128))
        s_ba = np.asarray(hamming_score(b, a, 128))
        np.testing.assert_array_equal(s_ab, s_ba.T)


# ---------------------------------------------------------- sparse attention
class TestSparseAttention:
    @settings(**SETTINGS)
    @given(
        h=st.integers(1, 8),
        dh=st.sampled_from([16, 32, 64]),
        s=st.integers(8, 600),
        seed=st.integers(0, 2**31 - 1),
        frac=st.floats(0.05, 1.0),
        tile=st.sampled_from([16, 64, 128]),
    )
    def test_fused_matches_ref(self, h, dh, s, seed, frac, tile):
        rng = np.random.default_rng(seed)
        n = max(1, int(s * frac))
        q = _rand(rng, (h, dh))
        k = _rand(rng, (s, dh))
        v = _rand(rng, (s, dh))
        idx = jnp.asarray(rng.choice(s, size=n, replace=False))
        got = sparse_attention_fused(q, k, v, idx, tile_n=tile)
        want = ref.sparse_attention(q, k, v, idx)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(
        h=st.integers(1, 8),
        s=st.integers(8, 400),
        seed=st.integers(0, 2**31 - 1),
        tile=st.sampled_from([8, 32, 128]),
    )
    def test_simple_matches_ref(self, h, s, seed, tile):
        rng = np.random.default_rng(seed)
        dh = 32
        n = max(1, s // 3)
        q = _rand(rng, (h, dh))
        k = _rand(rng, (s, dh))
        v = _rand(rng, (s, dh))
        idx = jnp.asarray(rng.choice(s, size=n, replace=False))
        got = sparse_attention_simple(q, k, v, idx, tile_n=tile)
        want = ref.sparse_attention(q, k, v, idx)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_full_index_set_equals_dense(self):
        rng = np.random.default_rng(11)
        q, k, v = _rand(rng, (4, 32)), _rand(rng, (128, 32)), _rand(rng, (128, 32))
        idx = jnp.arange(128)
        got = sparse_attention_fused(q, k, v, idx)
        want = ref.dense_attention(q, k, v)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_single_selected_token(self):
        """k=1 sparse attention returns exactly that value row."""
        rng = np.random.default_rng(13)
        q, k, v = _rand(rng, (2, 16)), _rand(rng, (64, 16)), _rand(rng, (64, 16))
        idx = jnp.asarray([17])
        got = np.asarray(sparse_attention_fused(q, k, v, idx))
        want = np.broadcast_to(np.asarray(v)[17], (2, 16))
        assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_permutation_invariance(self):
        """Attention over a set of tokens is order-independent."""
        rng = np.random.default_rng(17)
        q, k, v = _rand(rng, (4, 32)), _rand(rng, (256, 32)), _rand(rng, (256, 32))
        idx = rng.choice(256, size=48, replace=False)
        a = np.asarray(sparse_attention_fused(q, k, v, jnp.asarray(idx)))
        b = np.asarray(sparse_attention_fused(q, k, v, jnp.asarray(idx[::-1].copy())))
        assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def test_large_logits_stable(self):
        """Online softmax must not overflow with large-magnitude scores."""
        rng = np.random.default_rng(19)
        q = _rand(rng, (2, 32), scale=30.0)
        k = _rand(rng, (128, 32), scale=30.0)
        v = _rand(rng, (128, 32))
        idx = jnp.asarray(rng.choice(128, size=32, replace=False))
        got = np.asarray(sparse_attention_fused(q, k, v, idx))
        assert np.isfinite(got).all()
        want = np.asarray(ref.sparse_attention(q, k, v, idx))
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- end-to-end
class TestHataSelectionPipeline:
    """Glue the three kernels: encode -> score -> topk -> sparse attention."""

    def test_pipeline_recall_beats_random(self):
        """Trained-free sanity: even a RANDOM hash preserves enough relative
        order on clustered data that recall@k beats uniform chance."""
        rng = np.random.default_rng(23)
        d, rbit, s, k = 64, 128, 512, 64
        w = jnp.asarray(rng.normal(size=(d, rbit)), dtype=jnp.float32)
        key_dirs = rng.normal(size=(s, d))
        keys = jnp.asarray(key_dirs, dtype=jnp.float32)
        q = keys[37:38] + 0.1 * jnp.asarray(rng.normal(size=(1, d)), dtype=jnp.float32)
        true_scores = (q @ keys.T)[0]
        true_top = set(np.argsort(-np.asarray(true_scores))[:k].tolist())
        qc = hash_encode(q, w)
        kc = hash_encode(keys, w)
        sc = hamming_score(qc, kc, rbit)
        hash_top = set(np.argsort(-np.asarray(sc)[0])[:k].tolist())
        recall = len(true_top & hash_top) / k
        assert recall > 3 * (k / s), f"recall {recall} not above chance"

    def test_gqa_aggregation_shapes(self):
        rng = np.random.default_rng(29)
        scores = jnp.asarray(rng.integers(0, 128, size=(8, 100)), dtype=jnp.int32)
        agg = ref.gqa_aggregate(scores, group=4)
        assert agg.shape == (2, 100)
        np.testing.assert_array_equal(
            np.asarray(agg[0]), np.asarray(scores[:4].sum(axis=0))
        )
