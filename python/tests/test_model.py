"""L2 model tests: shapes, dense/prefill/decode consistency, bucketed
static graphs vs the dynamic model, HATA decode correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.aot import (
    decode_step_bucketed,
    flat_weights,
    param_order,
    prefill_bucketed,
    unflat_weights,
)
from compile.model import (
    CONFIGS,
    decode_step,
    forward_train,
    generate,
    init_hash_params,
    init_params,
    prefill,
)


@pytest.fixture(scope="module", params=["hata-mha", "hata-gqa"])
def setup(request):
    cfg = CONFIGS[request.param]
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    hash_w = init_hash_params(cfg, key)
    return cfg, params, hash_w


def test_forward_train_shapes(setup):
    cfg, params, _ = setup
    tokens = jnp.zeros((2, 17), dtype=jnp.int32)
    logits = forward_train(params, cfg, tokens)
    assert logits.shape == (2, 17, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_prefill_cache_shapes(setup):
    cfg, params, hash_w = setup
    toks = jnp.arange(23) % cfg.vocab
    logits, caches = prefill(params, hash_w, cfg, toks)
    assert logits.shape == (cfg.vocab,)
    assert caches["k"].shape == (cfg.n_layers, cfg.n_kv_heads, 23, cfg.head_dim)
    assert caches["kcode"].shape == (cfg.n_layers, cfg.n_kv_heads, 23, cfg.rbit // 32)


def test_prefill_matches_forward_train(setup):
    """Last-position logits of prefill == forward_train at that position."""
    cfg, params, hash_w = setup
    toks = (jnp.arange(19) * 7 + 3) % cfg.vocab
    logits, _ = prefill(params, hash_w, cfg, toks)
    full = forward_train(params, cfg, toks[None, :])[0, -1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full), rtol=1e-4, atol=1e-4)


def test_decode_step_matches_prefill(setup):
    """Decoding token t+1 after prefill(0..t) == prefill(0..t+1)."""
    cfg, params, hash_w = setup
    toks = (jnp.arange(16) * 5 + 2) % cfg.vocab
    _, caches = prefill(params, hash_w, cfg, toks[:-1])
    logits_step, _ = decode_step(
        params, hash_w, cfg, toks[-1], jnp.asarray(15), caches, budget=0
    )
    logits_full, _ = prefill(params, hash_w, cfg, toks)
    np.testing.assert_allclose(
        np.asarray(logits_step), np.asarray(logits_full), rtol=2e-4, atol=2e-4
    )


def test_hata_budget_full_equals_dense(setup):
    """budget >= s falls back to dense: identical logits."""
    cfg, params, hash_w = setup
    toks = (jnp.arange(12) * 3 + 1) % cfg.vocab
    _, caches = prefill(params, hash_w, cfg, toks)
    d, _ = decode_step(params, hash_w, cfg, jnp.asarray(5), jnp.asarray(12), caches, budget=0)
    h, _ = decode_step(params, hash_w, cfg, jnp.asarray(5), jnp.asarray(12), caches, budget=999)
    np.testing.assert_allclose(np.asarray(d), np.asarray(h), rtol=1e-5, atol=1e-5)


def test_bucketed_graphs_match_dynamic(setup):
    cfg, params, hash_w = setup
    ws = flat_weights(params, cfg)
    assert len(ws) == len(param_order(cfg))
    toks = (jnp.arange(20) * 11 + 4) % cfg.vocab
    B = 32
    logits, caches = prefill(params, hash_w, cfg, toks)
    padded = jnp.zeros(B, jnp.int32).at[:20].set(toks)
    bl, kc, vc, cc = prefill_bucketed(cfg, B, ws, hash_w, padded, jnp.asarray(20))
    np.testing.assert_allclose(np.asarray(bl), np.asarray(logits), rtol=2e-4, atol=2e-4)
    # one hata decode step
    tok = jnp.argmax(logits).astype(jnp.int32)
    want, _ = decode_step(params, hash_w, cfg, tok, jnp.asarray(20), caches, budget=8)
    got, *_ = decode_step_bucketed(cfg, B, 8, ws, hash_w, tok, jnp.asarray(20), kc, vc, cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_unflat_roundtrip(setup):
    cfg, params, _ = setup
    back = unflat_weights(flat_weights(params, cfg), cfg)
    np.testing.assert_array_equal(np.asarray(back["embed"]), np.asarray(params["embed"]))
    np.testing.assert_array_equal(
        np.asarray(back["layers"][1]["wq"]), np.asarray(params["layers"][1]["wq"])
    )


def test_generate_deterministic(setup):
    cfg, params, hash_w = setup
    prompt = jnp.asarray(data.encode("&ab=CD; filler text ?ab="))
    a = generate(params, hash_w, cfg, prompt, 4, budget=8)
    b = generate(params, hash_w, cfg, prompt, 4, budget=8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
