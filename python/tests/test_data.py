"""Task-grammar tests for the synthetic suite (shared contract with
rust/src/bench/tasks.rs)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data


@pytest.fixture(scope="module")
def corpus():
    return data.MarkovCorpus(seed=0)


@settings(max_examples=20, deadline=None)
@given(
    kind=st.sampled_from(data.TASK_KINDS),
    ctx=st.integers(120, 800),
    seed=st.integers(0, 10_000),
)
def test_task_invariants(kind, ctx, seed):
    corpus = data.MarkovCorpus(seed=0)
    rng = np.random.default_rng(seed)
    prompt, ans = data.make_task(kind, corpus, rng, ctx)
    assert len(prompt) == ctx
    assert prompt.isascii()
    assert ans.endswith(";")
    # query suffix is "?<key>=" (fwe uses the literal 3-char key "fwe")
    assert prompt[-1] == "="
    assert "?" in prompt[-6:]


def test_ns_answer_recoverable(corpus):
    rng = np.random.default_rng(1)
    for _ in range(10):
        prompt, ans = data.make_task("ns", corpus, rng, 300)
        key = prompt[-data.KEY_LEN - 1 : -1]
        assert f"&{key}={ans}" in prompt


def test_vt_chain_consistent(corpus):
    rng = np.random.default_rng(2)
    prompt, ans = data.make_task("vt", corpus, rng, 300)
    k1 = ans[:-1]
    # alias target k1 must itself be bound
    assert f"&{k1}=" in prompt


def test_encode_decode_roundtrip():
    s = "&ab=CD;?ab="
    assert data.decode(data.encode(s)) == s
    assert data.encode(s).dtype == np.int32
    assert data.encode(s).max() < data.VOCAB


def test_training_batch_shapes_and_mask(corpus):
    rng = np.random.default_rng(3)
    xs, mask = data.training_batch(corpus, rng, batch=4, seq=128)
    assert xs.shape == (4, 129)
    assert mask.shape == (4, 128)
    assert mask.min() >= 0.0 and mask.max() <= 1.0
    # at least one row upweights answers
    assert (mask == 1.0).any()


def test_recall_sequence_answer_positions(corpus):
    rng = np.random.default_rng(4)
    text, answers = data.recall_sequence(corpus, rng, 256)
    assert len(text) == 256
    for a in answers:
        # each answer position is an uppercase value char or ';'
        assert text[a].isupper() or text[a] == ";", (a, text[a - 4 : a + 2])


def test_markov_corpus_deterministic():
    a = data.MarkovCorpus(seed=5)
    b = data.MarkovCorpus(seed=5)
    r1 = np.random.default_rng(1)
    r2 = np.random.default_rng(1)
    assert a.text(r1, 100) == b.text(r2, 100)
