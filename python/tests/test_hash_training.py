"""Learning-to-hash training tests (paper Sec 3.1 / Appendix B): the loss
decreases, the uncorrelation term regularizes W, and trained codes beat
random codes at recalling true top-scoring keys on structured data."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.train_hash import (
    EPOCHS,
    ITERS,
    build_triplets,
    hash_loss,
    hash_recall,
    train_head,
)


def synthetic_triplets(rng, n=128, m=64, dh=16):
    """Clustered q/k pairs hard enough that a random projection is NOT
    already perfect: positives = query + strong noise, negatives scaled
    wider (random-hash recall ~0.65, leaving headroom for training)."""
    qs = rng.normal(size=(n, dh)).astype(np.float32)
    keys = np.zeros((n, m, dh), dtype=np.float32)
    labels = np.full((n, m), -1.0, dtype=np.float32)
    n_pos = m // 10
    for i in range(n):
        keys[i, :n_pos] = qs[i] + 1.3 * rng.normal(size=(n_pos, dh))
        keys[i, n_pos:] = rng.normal(size=(m - n_pos, dh)) * 1.6
        labels[i, :n_pos] = np.linspace(20.0, 1.0, n_pos)
    return qs, keys, labels


@pytest.fixture(scope="module")
def trained():
    rng = np.random.default_rng(0)
    q, keys, labels = synthetic_triplets(rng)
    dh, rbit = q.shape[1], 64
    w0 = jax.random.normal(jax.random.PRNGKey(1), (dh, rbit)) / np.sqrt(dh)
    w, hist = train_head(w0, q, keys, labels, rng)
    return q, keys, labels, w0, w, hist


def test_loss_decreases(trained):
    _, _, _, _, _, hist = trained
    assert len(hist) == EPOCHS * ITERS
    first = np.mean(hist[:10])
    last = np.mean(hist[-10:])
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_trained_recall_beats_random(trained):
    q, keys, labels, w0, w, _ = trained
    r_trained = hash_recall(w, q, keys, labels)
    r_random = hash_recall(np.asarray(w0), q, keys, labels)
    assert r_trained > r_random + 0.03, f"{r_random} -> {r_trained}"
    assert r_trained > 0.5


def test_uncorrelation_term_bounded(trained):
    w = np.asarray(trained[4])
    # no duplicated bit directions: max cosine between distinct hash
    # hyperplanes stays well below 1
    norms = np.linalg.norm(w, axis=0, keepdims=True)
    cos = (w / np.maximum(norms, 1e-9)).T @ (w / np.maximum(norms, 1e-9))
    np.fill_diagonal(cos, 0.0)
    assert np.abs(cos).max() < 0.995, np.abs(cos).max()


def test_loss_components_signs():
    """Positive-label pairs pull codes together: moving a positive key
    closer to its query must lower the loss."""
    rng = np.random.default_rng(3)
    dh, rbit = 8, 32
    w = jnp.asarray(rng.normal(size=(dh, rbit)).astype(np.float32)) / np.sqrt(dh)
    q = jnp.asarray(rng.normal(size=(1, dh)).astype(np.float32))
    far = jnp.asarray(rng.normal(size=(1, 1, dh)).astype(np.float32))
    near = q[None, :, :] + 0.01
    labels = jnp.asarray([[20.0]], dtype=jnp.float32)
    l_near = hash_loss(w, q, near, labels)
    l_far = hash_loss(w, q, far, labels)
    assert float(l_near) < float(l_far)


def test_build_triplets_shapes():
    from compile.model import CONFIGS, init_params
    from compile.train_hash import harvest_qk

    cfg = CONFIGS["hata-gqa"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    all_q, all_k = harvest_qk(params, cfg, n_seqs=2, ctx=160, seed=0)
    assert len(all_q) == 2
    assert all_q[0].shape[0] == cfg.n_layers
    rng = np.random.default_rng(1)
    q, keys, labels = build_triplets(all_q, all_k, cfg, layer=1, kv=0, rng=rng, n_queries=8)
    assert q.shape == (8, cfg.head_dim)
    assert keys.shape[0] == 8 and keys.shape[2] == cfg.head_dim
    assert labels.shape == keys.shape[:2]
    assert (labels > 0).any() and (labels < 0).any()
