"""L2: JAX transformer with HATA top-k attention (build-time Python).

The model family mirrors Llama-style blocks (RMSNorm -> attention with RoPE
-> RMSNorm -> SwiGLU), with MHA or GQA head layouts, scaled to train on one
CPU core (see DESIGN.md §4).  Two decode paths are defined:

* ``decode_step`` with ``budget == 0`` — vanilla full attention over the KV
  cache.
* ``decode_step`` with ``budget > 0``  — paper Alg. 3: hash-encode q/k (L1
  kernel), Hamming scores vs the key-code cache, GQA aggregation, top-k,
  fused sparse attention (L1 kernel).

Both are pure functions over explicit cache arrays so ``aot.py`` can lower
them to static-shape HLO (bucketed max_len) for the Rust PJRT runtime.

Hash weights are per (layer, kv_head): query heads sharing a KV head share
its W_H so that one key-code cache serves the whole group (paper Sec 3.2
trains per attention head for MHA; for GQA a single code cache per KV head
is the only layout consistent with Alg. 1, and score aggregation over the
group recovers the per-query-head signal).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.hash_encode import hash_encode
from .kernels.hamming import hamming_score
from .kernels.sparse_attention import sparse_attention_fused

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters. `name` keys the artifact manifest."""

    name: str = "hata-mha"
    vocab: int = 128
    d_model: int = 128
    n_layers: int = 3
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 16
    ffn_hidden: int = 256
    rope_theta: float = 10000.0
    rbit: int = 128
    # first `dense_layers` layers always run full attention (paper Sec 5.1
    # follows Quest: the first two of 32 layers are outliers; scaled here).
    dense_layers: int = 1

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def code_words(self) -> int:
        return self.rbit // 32


# Model zoo: tiny trained models. Scale mirrors for perf sweeps live on the
# Rust side (rust/src/config) since they are never trained.
CONFIGS = {
    "hata-mha": ModelConfig(name="hata-mha", n_kv_heads=8),
    "hata-gqa": ModelConfig(name="hata-gqa", n_kv_heads=2),
}


# ----------------------------------------------------------------- params


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Xavier-ish init; layout mirrors rust/src/model/weights.rs."""

    def dense(key, fan_in, fan_out):
        scale = math.sqrt(2.0 / (fan_in + fan_out))
        return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale

    keys = iter(jax.random.split(key, 8 + 16 * cfg.n_layers))
    p: Params = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model)) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,)),
        "lm_head": dense(next(keys), cfg.d_model, cfg.vocab),
        "layers": [],
    }
    qd = cfg.n_heads * cfg.head_dim
    kvd = cfg.n_kv_heads * cfg.head_dim
    for _ in range(cfg.n_layers):
        p["layers"].append(
            {
                "attn_norm": jnp.ones((cfg.d_model,)),
                "wq": dense(next(keys), cfg.d_model, qd),
                "wk": dense(next(keys), cfg.d_model, kvd),
                "wv": dense(next(keys), cfg.d_model, kvd),
                "wo": dense(next(keys), qd, cfg.d_model),
                "mlp_norm": jnp.ones((cfg.d_model,)),
                "w_gate": dense(next(keys), cfg.d_model, cfg.ffn_hidden),
                "w_up": dense(next(keys), cfg.d_model, cfg.ffn_hidden),
                "w_down": dense(next(keys), cfg.ffn_hidden, cfg.d_model),
            }
        )
    return p


def init_hash_params(cfg: ModelConfig, key: jax.Array, rbit: int | None = None) -> jax.Array:
    """Random-projection init for W_H [L, n_kv, head_dim, rbit]."""
    shape = (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, rbit or cfg.rbit)
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(cfg.head_dim)


# ------------------------------------------------------------------ layers


def rms_norm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [s, h, dh]; positions: [s]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [s, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    cos, sin = cos[:, None, :], sin[:, None, :]  # broadcast over heads
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x: jax.Array, layer: Params) -> jax.Array:
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


def _qkv(x: jax.Array, layer: Params, cfg: ModelConfig, positions: jax.Array):
    s = x.shape[0]
    q = (x @ layer["wq"]).reshape(s, cfg.n_heads, cfg.head_dim)
    k = (x @ layer["wk"]).reshape(s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ layer["wv"]).reshape(s, cfg.n_kv_heads, cfg.head_dim)
    return rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta), v


# ------------------------------------------------------------- full forward


def forward_train(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Causal LM forward for training: tokens [b, s] -> logits [b, s, vocab]."""

    def one(seq):
        s = seq.shape[0]
        pos = jnp.arange(s)
        x = params["embed"][seq]
        for layer in params["layers"]:
            h = rms_norm(x, layer["attn_norm"])
            q, k, v = _qkv(h, layer, cfg, pos)
            kr = jnp.repeat(k, cfg.group, axis=1)
            vr = jnp.repeat(v, cfg.group, axis=1)
            outs = jax.vmap(ref.prefill_attention, in_axes=(1, 1, 1), out_axes=1)(
                q, kr, vr
            )
            x = x + outs.reshape(s, -1) @ layer["wo"]
            h = rms_norm(x, layer["mlp_norm"])
            x = x + swiglu(h, layer)
        x = rms_norm(x, params["final_norm"])
        return x @ params["lm_head"]

    return jax.vmap(one)(tokens)


# -------------------------------------------------------------- prefill/decode


def prefill(
    params: Params,
    hash_w: jax.Array,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Paper Alg. 1: full attention + fill KV cache AND key-code cache.

    tokens: [s] -> (logits_last [vocab], caches)
    caches: k/v [L, n_kv, s, dh], kcode [L, n_kv, s, words]
    """
    s = tokens.shape[0]
    pos = jnp.arange(s)
    x = params["embed"][tokens]
    ks, vs, codes = [], [], []
    for li, layer in enumerate(params["layers"]):
        h = rms_norm(x, layer["attn_norm"])
        q, k, v = _qkv(h, layer, cfg, pos)
        kr = jnp.repeat(k, cfg.group, axis=1)
        vr = jnp.repeat(v, cfg.group, axis=1)
        outs = jax.vmap(ref.prefill_attention, in_axes=(1, 1, 1), out_axes=1)(
            q, kr, vr
        )
        x = x + outs.reshape(s, -1) @ layer["wo"]
        h = rms_norm(x, layer["mlp_norm"])
        x = x + swiglu(h, layer)
        ks.append(jnp.transpose(k, (1, 0, 2)))  # [n_kv, s, dh]
        vs.append(jnp.transpose(v, (1, 0, 2)))
        codes.append(
            jnp.stack(
                [
                    hash_encode(k[:, kv, :], hash_w[li, kv], interpret=interpret)
                    for kv in range(cfg.n_kv_heads)
                ]
            )
        )  # [n_kv, s, words]
    x = rms_norm(x, params["final_norm"])
    logits = x[-1] @ params["lm_head"]
    caches = {"k": jnp.stack(ks), "v": jnp.stack(vs), "kcode": jnp.stack(codes)}
    return logits, caches


def _decode_attn_dense(q, k_cache, v_cache, cfg):
    """q [h, dh]; caches [n_kv, s, dh] -> [h, dh]."""
    outs = []
    for kv in range(cfg.n_kv_heads):
        qs = q[kv * cfg.group : (kv + 1) * cfg.group]
        outs.append(ref.dense_attention(qs, k_cache[kv], v_cache[kv]))
    return jnp.concatenate(outs, axis=0)


def _decode_attn_hata(
    q, k_cache, v_cache, code_cache, hash_w_layer, cfg, budget, interpret
):
    """Paper Alg. 3 steps 2-3: Hamming score, GQA-aggregate, top-k, sparse."""
    outs = []
    for kv in range(cfg.n_kv_heads):
        qs = q[kv * cfg.group : (kv + 1) * cfg.group]  # [g, dh]
        qc = hash_encode(qs, hash_w_layer[kv], interpret=interpret)
        scores = hamming_score(qc, code_cache[kv], cfg.rbit, interpret=interpret)
        agg = ref.gqa_aggregate(scores, cfg.group)[0]  # [s]
        idx = ref.topk_indices(agg, budget)
        outs.append(
            sparse_attention_fused(
                qs, k_cache[kv], v_cache[kv], idx, interpret=interpret
            )
        )
    return jnp.concatenate(outs, axis=0)


def decode_step(
    params: Params,
    hash_w: jax.Array,
    cfg: ModelConfig,
    token: jax.Array,
    position: jax.Array,
    caches: dict[str, jax.Array],
    *,
    budget: int = 0,
    interpret: bool = True,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One decode step (paper Alg. 3). ``budget == 0`` -> dense attention.

    Returns (logits [vocab], caches grown by one token).
    """
    x = params["embed"][token]
    new_k, new_v, new_c = [], [], []
    for li, layer in enumerate(params["layers"]):
        h = rms_norm(x, layer["attn_norm"])
        pos = position[None]
        q = (h[None, :] @ layer["wq"]).reshape(1, cfg.n_heads, cfg.head_dim)
        k = (h[None, :] @ layer["wk"]).reshape(1, cfg.n_kv_heads, cfg.head_dim)
        v = (h[None, :] @ layer["wv"]).reshape(1, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, pos, cfg.rope_theta)[0]  # [h, dh]
        k = rope(k, pos, cfg.rope_theta)[0]  # [n_kv, dh]
        v = v[0]
        k_cache = jnp.concatenate([caches["k"][li], k[:, None, :]], axis=1)
        v_cache = jnp.concatenate([caches["v"][li], v[:, None, :]], axis=1)
        kc = jnp.stack(
            [
                hash_encode(k[kv : kv + 1], hash_w[li, kv], interpret=interpret)[0]
                for kv in range(cfg.n_kv_heads)
            ]
        )
        code_cache = jnp.concatenate([caches["kcode"][li], kc[:, None, :]], axis=1)
        new_k.append(k_cache)
        new_v.append(v_cache)
        new_c.append(code_cache)
        s_now = int(k_cache.shape[1])
        use_dense = budget == 0 or li < cfg.dense_layers or budget >= s_now
        if use_dense:
            attn = _decode_attn_dense(q, k_cache, v_cache, cfg)
        else:
            attn = _decode_attn_hata(
                q, k_cache, v_cache, code_cache, hash_w[li], cfg, budget, interpret
            )
        x = x + attn.reshape(-1) @ layer["wo"]
        h = rms_norm(x, layer["mlp_norm"])
        x = x + swiglu(h, layer)
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    caches = {"k": jnp.stack(new_k), "v": jnp.stack(new_v), "kcode": jnp.stack(new_c)}
    return logits, caches


def generate(
    params: Params,
    hash_w: jax.Array,
    cfg: ModelConfig,
    prompt: jax.Array,
    n_new: int,
    *,
    budget: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Greedy generation used by python-side evals and golden files."""
    logits, caches = prefill(params, hash_w, cfg, prompt, interpret=interpret)
    out = []
    tok = jnp.argmax(logits)
    pos = prompt.shape[0]
    for _ in range(n_new):
        out.append(tok)
        logits, caches = decode_step(
            params, hash_w, cfg, tok, jnp.asarray(pos), caches,
            budget=budget, interpret=interpret,
        )
        tok = jnp.argmax(logits)
        pos += 1
    return jnp.stack(out)
