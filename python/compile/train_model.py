"""Train the tiny LMs on the synthetic corpus + retrieval-task mixture.

Build-time only.  Own AdamW (no optax in the image).  Checkpoints are
saved as .npz with flat dotted keys ("layers.0.wq", ...) — the layout the
Rust weight loader (rust/src/model/weights.rs) and aot.py both consume.

Usage:  python -m compile.train_model --config hata-mha --steps 320 \
            --out ../artifacts/
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import CONFIGS, ModelConfig, forward_train, init_params

SEQ = 384
BATCH = 8


# ------------------------------------------------------------------ AdamW


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2**t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mh, vh,
    )
    return new, {"m": m, "v": v, "t": t}


# ------------------------------------------------------------------- loss


def lm_loss(params, cfg: ModelConfig, tokens, mask):
    """Weighted next-token cross-entropy. tokens [b, s+1], mask [b, s]."""
    logits = forward_train(params, cfg, tokens[:, :-1])  # [b, s, vocab]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.sum(mask)


# ---------------------------------------------------------------- flatten


def flatten_params(params) -> dict[str, np.ndarray]:
    flat = {}
    for k, v in params.items():
        if k == "layers":
            for i, layer in enumerate(v):
                for lk, lv in layer.items():
                    flat[f"layers.{i}.{lk}"] = np.asarray(lv)
        else:
            flat[k] = np.asarray(v)
    return flat


def unflatten_params(flat: dict[str, np.ndarray], cfg: ModelConfig):
    params = {"layers": [dict() for _ in range(cfg.n_layers)]}
    for k, v in flat.items():
        if k.startswith("layers."):
            _, i, name = k.split(".")
            params["layers"][int(i)][name] = jnp.asarray(v)
        else:
            params[k] = jnp.asarray(v)
    return params


def load_params(path: str, cfg: ModelConfig):
    return unflatten_params(dict(np.load(path)), cfg)


# ------------------------------------------------------------------ train


def train(cfg: ModelConfig, steps: int, seed: int = 0, log_every: int = 20,
          lr: float = 3e-3):
    corpus = data.MarkovCorpus(seed=0)
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, tokens, mask, lr_now):
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens, mask)
        params, opt = adamw_update(params, grads, opt, lr_now)
        return params, opt, loss

    history = []
    t0 = time.time()
    for i in range(steps):
        tokens, mask = data.training_batch(corpus, rng, BATCH, SEQ)
        warm = min(1.0, (i + 1) / 30)
        decay = 0.5 * (1 + np.cos(np.pi * i / steps))
        lr_now = jnp.asarray(lr * warm * (0.1 + 0.9 * decay), jnp.float32)
        params, opt, loss = step(params, opt, jnp.asarray(tokens),
                                 jnp.asarray(mask), lr_now)
        history.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            print(f"[{cfg.name}] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    return params, history


def eval_recall_accuracy(params, cfg: ModelConfig, n: int = 20, ctx: int = 256,
                         seed: int = 1) -> float:
    """Greedy answer-exact-match on held-out single-needle tasks (dense)."""
    from .model import generate, init_hash_params

    corpus = data.MarkovCorpus(seed=0)
    rng = np.random.default_rng(seed)
    hash_w = init_hash_params(cfg, jax.random.PRNGKey(0))
    hits = 0
    for _ in range(n):
        prompt, ans = data.make_task("ns", corpus, rng, ctx)
        out = generate(params, hash_w, cfg, jnp.asarray(data.encode(prompt)),
                       len(ans), budget=0)
        hits += int(data.decode(np.asarray(out)) == ans)
    return hits / n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="hata-mha", choices=sorted(CONFIGS))
    ap.add_argument("--steps", type=int, default=320)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--eval", action="store_true")
    args = ap.parse_args()
    cfg = CONFIGS[args.config]
    params, history = train(cfg, args.steps, seed=args.seed)
    out = f"{args.out}/{cfg.name}.weights.npz"
    np.savez(out, **flatten_params(params))
    np.save(f"{args.out}/{cfg.name}.losscurve.npy", np.asarray(history))
    print(f"saved {out}")
    if args.eval:
        acc = eval_recall_accuracy(params, cfg)
        print(f"[{cfg.name}] needle-recall accuracy (dense, ctx=256): {acc:.2f}")


if __name__ == "__main__":
    main()
