"""L1 Pallas kernel: fused hash encoding (paper Alg. 2 + Sec. 4 "Kernel
fusion for hash encoding").

The paper fuses linear projection -> sign -> BitPack -> cache update into a
single CUDA kernel to kill dispatch overhead and intermediate HBM traffic.
The TPU/Pallas adaptation fuses the same chain into one ``pallas_call``:
the projection tile runs on the MXU, sign+bitpack run on the VPU, and the
packed words are written straight to the output block — the f32 projection
matrix never round-trips through HBM.

BlockSpec schedule (documented for the real-TPU target; we execute with
``interpret=True`` on CPU — see DESIGN.md §3):

  grid = (ceil(s / TS),)
  x    [s, d]     -> block (TS, d)      VMEM: TS*d*4 B
  w_h  [d, rbit]  -> block (d, rbit)    VMEM-resident across grid steps
  out  [s, rbit/32] -> block (TS, rbit/32)

For d=128, rbit=128, TS=256: ~193 KiB VMEM, far under the ~16 MiB budget, so
TS can grow until the MXU is saturated; the matmul is (TS,d)x(d,rbit) which
keeps the 128x128 systolic array busy for d,rbit >= 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD_BITS = 32
DEFAULT_TILE_S = 256


def _hash_encode_kernel(x_ref, w_ref, out_ref, *, rbit: int):
    """One seq-tile: project, sign, bitpack. All fused, one pass."""
    x = x_ref[...].astype(jnp.float32)          # (ts, d)
    w = w_ref[...].astype(jnp.float32)          # (d, rbit)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)  # MXU
    bits = (y >= 0).astype(jnp.uint32)          # (ts, rbit)
    ts = bits.shape[0]
    bits = bits.reshape(ts, rbit // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("tile_s", "interpret"))
def hash_encode(
    x: jax.Array,
    w_h: jax.Array,
    *,
    tile_s: int = DEFAULT_TILE_S,
    interpret: bool = True,
) -> jax.Array:
    """Packed hash codes for a batch of vectors.

    Args:
      x:   [s, d] queries or keys (any float dtype).
      w_h: [d, rbit] trained hash weights, rbit % 32 == 0.

    Returns:
      [s, rbit // 32] uint32 packed codes (see ref.py for bit order).
    """
    s, d = x.shape
    rbit = w_h.shape[1]
    assert rbit % WORD_BITS == 0, "rbit must be a multiple of 32"
    words = rbit // WORD_BITS
    ts = min(tile_s, s)
    # Pad seq to a tile multiple; padded rows are garbage and sliced off.
    s_pad = (s + ts - 1) // ts * ts
    if s_pad != s:
        x = jnp.pad(x, ((0, s_pad - s), (0, 0)))
    grid = (s_pad // ts,)
    out = pl.pallas_call(
        functools.partial(_hash_encode_kernel, rbit=rbit),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ts, d), lambda i: (i, 0)),
            pl.BlockSpec((d, rbit), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ts, words), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, words), jnp.uint32),
        interpret=interpret,
    )(x, w_h)
    return out[:s]
