"""L1 Pallas kernels for HATA + pure-jnp reference oracles.

Public surface:
  hash_encode.hash_encode          fused projection+sign+bitpack
  hamming.hamming_score            XOR+popcount match scores
  sparse_attention.sparse_attention_{simple,fused}
  ref.*                            oracles used by pytest and Rust goldens
"""
from . import ref  # noqa: F401
from .hash_encode import hash_encode  # noqa: F401
from .hamming import hamming_score  # noqa: F401
from .sparse_attention import (  # noqa: F401
    sparse_attention_fused,
    sparse_attention_simple,
)
