"""L1 Pallas kernel: high-performance Hamming score (paper Sec. 4).

The paper's CUDA operator loads packed codes as integers, XORs, applies
``popc``, and tree-reduces, with coalesced HBM->SRAM transfers.  The
TPU/Pallas adaptation tiles the key-code cache into VMEM blocks and uses the
VPU's ``population_count``; the per-word partial counts are reduced in
registers before a single store per (head, key-tile).

Score convention: **matching bits** (= rbit - Hamming distance), so TopK on
the score selects the most similar keys (paper Alg. 3 l.11-13).

BlockSpec schedule (real-TPU target; executed with interpret=True on CPU):

  grid = (ceil(s / TK),)
  q_codes [h, w]   -> block (h, w)     VMEM-resident across steps
  k_codes [s, w]   -> block (TK, w)    streamed HBM->VMEM, coalesced
  out     [h, s]   -> block (h, TK)

For h=8, w=4 (rbit=128), TK=2048: ~96 KiB VMEM per step; the kernel is
bandwidth-bound on the k-code stream at rbit/ (8*d_model) of the raw-key
traffic — the 32x reduction (d=128 f32 -> 128 bits) the paper exploits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_K = 2048


def _hamming_kernel(q_ref, k_ref, out_ref, *, rbit: int):
    q = q_ref[...]                     # (h, w) uint32
    k = k_ref[...]                     # (tk, w) uint32
    x = jnp.bitwise_xor(q[:, None, :], k[None, :, :])       # (h, tk, w)
    mismatch = jnp.sum(jax.lax.population_count(x), axis=-1, dtype=jnp.int32)
    out_ref[...] = rbit - mismatch     # (h, tk)


@functools.partial(jax.jit, static_argnames=("rbit", "tile_k", "interpret"))
def hamming_score(
    q_codes: jax.Array,
    k_codes: jax.Array,
    rbit: int,
    *,
    tile_k: int = DEFAULT_TILE_K,
    interpret: bool = True,
) -> jax.Array:
    """Match-count scores between query codes and all cached key codes.

    Args:
      q_codes: [h, rbit // 32] uint32.
      k_codes: [s, rbit // 32] uint32.
      rbit:    number of hash bits.

    Returns:
      [h, s] int32 scores in [0, rbit]; higher = more similar.
    """
    h, w = q_codes.shape
    s, wk = k_codes.shape
    assert w == wk and w * 32 == rbit
    tk = min(tile_k, s)
    s_pad = (s + tk - 1) // tk * tk
    if s_pad != s:
        k_codes = jnp.pad(k_codes, ((0, s_pad - s), (0, 0)))
    grid = (s_pad // tk,)
    out = pl.pallas_call(
        functools.partial(_hamming_kernel, rbit=rbit),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, w), lambda i: (0, 0)),
            pl.BlockSpec((tk, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((h, tk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((h, s_pad), jnp.int32),
        interpret=interpret,
    )(q_codes, k_codes)
    return out[:, :s]
