"""L1 Pallas kernel: top-k sparse attention with fused gather
(paper Sec. 4, "Fuse gather with FlashAttention").

The paper's problem: a separate ``Gather`` materializes the selected K/V
rows in HBM before FlashAttention re-reads them — double traffic.  Their fix
drives the FlashAttention K/V block loads directly by the top-k index list.

Two variants are provided, mirroring the paper's Fig. 9 'Simple' vs
'+FusedAttn' ablation (the Rust engine has the same pair on the request
path):

* ``sparse_attention_simple`` — gather with ``jnp.take`` (its own HBM
  round-trip), then a tiled flash-decode Pallas kernel over the gathered
  rows.
* ``sparse_attention_fused``  — one ``pallas_call``: the index list rides
  into the kernel and K/V rows are pulled tile-by-tile inside the online-
  softmax loop; no gathered copy is ever materialized outside the kernel.

Real-TPU note: the fused variant's tile loads would be expressed with a
``PrefetchScalarGridSpec`` whose index_map reads the top-k list, making the
HBM->VMEM DMA itself the gather (the TPU analog of the paper's fused CUDA
loads).  Under ``interpret=True`` the same kernel body executes with jnp
semantics on CPU, which is what we test.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_TILE_N = 128


def _flash_decode_kernel(q_ref, k_ref, v_ref, out_ref, *, tile_n: int):
    """Online-softmax attention of q (h, dh) over k/v (n, dh), tiled."""
    q = q_ref[...].astype(jnp.float32)
    h, dh = q.shape
    n = k_ref.shape[0]
    scale = dh ** -0.5
    n_tiles = n // tile_n

    def body(t, carry):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k_ref[...], t * tile_n, tile_n)
        vs = jax.lax.dynamic_slice_in_dim(v_ref[...], t * tile_n, tile_n)
        s = jnp.dot(q, ks.astype(jnp.float32).T) * scale       # (h, tn)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, vs.astype(jnp.float32))
        return m_new, l_new, acc_new

    m0 = jnp.full((h,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((h,), dtype=jnp.float32)
    acc0 = jnp.zeros((h, dh), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
    out_ref[...] = acc / l[:, None]


def _fused_kernel(idx_ref, q_ref, k_ref, v_ref, out_ref, *, tile_n: int):
    """Fused gather + online-softmax: K/V rows pulled by index per tile."""
    q = q_ref[...].astype(jnp.float32)
    h, dh = q.shape
    n = idx_ref.shape[0]
    scale = dh ** -0.5
    n_tiles = n // tile_n

    def body(t, carry):
        m, l, acc = carry
        ids = jax.lax.dynamic_slice_in_dim(idx_ref[...], t * tile_n, tile_n)
        # The gather IS the load: on TPU this is the scalar-prefetch DMA.
        ks = jnp.take(k_ref[...], ids, axis=0).astype(jnp.float32)
        vs = jnp.take(v_ref[...], ids, axis=0).astype(jnp.float32)
        s = jnp.dot(q, ks.T) * scale
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, vs)
        return m_new, l_new, acc_new

    m0 = jnp.full((h,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((h,), dtype=jnp.float32)
    acc0 = jnp.zeros((h, dh), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
    out_ref[...] = acc / l[:, None]


def _pad_to_tile(n: int, tile: int) -> int:
    return (n + tile - 1) // tile * tile


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def sparse_attention_simple(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    idx: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = True,
) -> jax.Array:
    """Gather-then-attend ('Simple' in Fig. 9). q [h,dh], k/v [s,dh], idx [n]."""
    h, dh = q.shape
    n = idx.shape[0]
    # Tile must divide n exactly: padded K rows would still receive softmax
    # weight (a zero K row has logit 0, not -inf), so instead of padding we
    # shrink the tile to the largest divisor of n.
    tn = _largest_divisor_tile(n, tile_n)
    n_pad = n
    ks = jnp.take(k, idx, axis=0)
    vs = jnp.take(v, idx, axis=0)
    out = pl.pallas_call(
        functools.partial(_flash_decode_kernel, tile_n=tn),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((h, dh), lambda i: (0, 0)),
            pl.BlockSpec((n_pad, dh), lambda i: (0, 0)),
            pl.BlockSpec((n_pad, dh), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((h, dh), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), jnp.float32),
        interpret=interpret,
    )(q, ks, vs)
    return out


def _largest_divisor_tile(n: int, max_tile: int) -> int:
    """Largest t <= max_tile with n % t == 0 (>=1)."""
    t = min(max_tile, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def sparse_attention_fused(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    idx: jax.Array,
    *,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = True,
) -> jax.Array:
    """Fused gather + FlashAttention ('+FusedAttn' in Fig. 9).

    Args:
      q:   [h, dh] query heads sharing this KV head.
      k:   [s, dh] full key cache (never copied).
      v:   [s, dh] full value cache.
      idx: [n] selected positions; n need not divide tile_n.

    Returns:
      [h, dh] float32 attention output.
    """
    h, dh = q.shape
    s = k.shape[0]
    n = idx.shape[0]
    tn = _largest_divisor_tile(n, tile_n)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, tile_n=tn),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((h, dh), lambda i: (0, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((h, dh), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), jnp.float32),
        interpret=interpret,
    )(idx, q, k, v)
    return out
