"""Pure-jnp reference oracles for the HATA Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package is
pytest-compared against the function of the same name here, and the Rust
native engine is compared against goldens generated from these functions.

Bit-packing convention (shared with Rust, little-endian words):
  hash bit ``b`` of a token lives in word ``b // 32`` at bit position
  ``b % 32``.  Two consecutive u32 words reinterpret as one u64 word on a
  little-endian host, which is exactly how the Rust engine consumes them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def hash_encode(x: jax.Array, w_h: jax.Array) -> jax.Array:
    """Encode vectors into packed binary hash codes (paper Alg. 2).

    Args:
      x:   [s, d] float vectors (queries or keys).
      w_h: [d, rbit] trained hash projection.

    Returns:
      [s, rbit // 32] uint32 packed codes. Bit = 1 iff (x @ w_h) >= 0.
    """
    s, _ = x.shape
    rbit = w_h.shape[1]
    assert rbit % WORD_BITS == 0, "rbit must be a multiple of 32"
    y = x.astype(jnp.float32) @ w_h.astype(jnp.float32)
    bits = (y >= 0).astype(jnp.uint32)  # [s, rbit]
    bits = bits.reshape(s, rbit // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def hamming_score(q_codes: jax.Array, k_codes: jax.Array, rbit: int) -> jax.Array:
    """Hash similarity score = number of MATCHING bits (paper Alg. 3 l.11).

    Higher is more similar; equals ``rbit - hamming_distance``.

    Args:
      q_codes: [h, rbit // 32] uint32 query codes.
      k_codes: [s, rbit // 32] uint32 cached key codes.

    Returns:
      [h, s] int32 match counts.
    """
    x = jnp.bitwise_xor(q_codes[:, None, :], k_codes[None, :, :])
    mismatch = jnp.sum(jax.lax.population_count(x), axis=-1, dtype=jnp.int32)
    return rbit - mismatch


def gqa_aggregate(scores: jax.Array, group: int) -> jax.Array:
    """Sum scores over query heads sharing one KV head (paper Sec 3.2).

    Args:
      scores: [h, s] per-query-head scores.
      group:  query heads per KV head (h % group == 0).

    Returns:
      [h // group, s] aggregated scores.
    """
    h, s = scores.shape
    assert h % group == 0
    return scores.reshape(h // group, group, s).sum(axis=1)


def topk_indices(scores: jax.Array, k: int) -> jax.Array:
    """Indices of the k highest scores, per row. [..., s] -> [..., k]."""
    _, idx = jax.lax.top_k(scores, k)
    return idx


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Single-token dense attention: q [h, dh], k/v [s, dh] per KV head.

    For MHA call per head with matching shapes; scale = dh ** -0.5.
    """
    dh = q.shape[-1]
    logits = (q @ k.T) * (dh ** -0.5)  # [h, s]
    p = jax.nn.softmax(logits, axis=-1)
    return p @ v  # [h, dh]


def sparse_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, idx: jax.Array
) -> jax.Array:
    """Top-k sparse attention (paper Alg. 3 l.14-17), gather-then-attend.

    Args:
      q:   [h, dh] query heads sharing this KV head.
      k:   [s, dh] full key cache.
      v:   [s, dh] full value cache.
      idx: [n] selected token positions (any order, no duplicates).

    Returns:
      [h, dh] attention output.
    """
    ks = jnp.take(k, idx, axis=0)
    vs = jnp.take(v, idx, axis=0)
    return dense_attention(q, ks, vs)


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal prefill attention for one head: q/k/v [s, dh] -> [s, dh]."""
    s, dh = q.shape
    logits = (q @ k.T) * (dh ** -0.5)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask, logits, -jnp.inf)
    return jax.nn.softmax(logits, axis=-1) @ v
