"""Learning-to-hash training for HATA (paper Sec 3.1 + Appendix B).

Pipeline (Appendix B.1, reproduced faithfully at our scale):
  1. Prefill held-out task sequences through the trained LM; harvest per
     (layer, kv-head) queries and keys (post-RoPE — the vectors actually
     compared at decode time).
  2. For each sampled query q_m (m in [n/2, n)), score against causal keys
     k_1..k_m; top 10 % are positives with linearly decayed labels in
     [1, 20], the rest get label -1.
  3. Train W_H per (layer, kv_head) with the relaxed objective (Eq. 9):

         min  eps * sum_ji s_ji ||h(q_j) - h(k_ji)||^2
            + eta * sum_j ||sum_i h(k_ji)||^2          (bit balance)
            + lam * ||W^T W - I||_F                     (bit uncorrelation)
         h(x) = 2*sigmoid(sigma * x W) - 1

     with sigma=0.1, eps=0.01, lam=1.0, eta=2.0 and SGD(lr=0.1,
     momentum=0.9, weight_decay=1e-6) for 15 epochs x 20 iterations
     (Table 11).

GQA: queries from every head in the group are paired with the shared KV
head's keys, so one W_H serves the whole group (see model.py docstring).

Usage: python -m compile.train_hash --config hata-mha --rbits 32,64,128,256
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import CONFIGS, ModelConfig, prefill, init_hash_params
from .train_model import load_params

# Table 11 hyper-parameters, adapted to our head_dim (DESIGN.md §4):
# sigma is scaled 10x (head_dim=16 projections have ~1/8 the magnitude of
# the paper's d=128 heads; sigma=0.1 leaves the sigmoid in its linear
# dead-zone); the uncorrelation penalty acts on W W^T (dh x dh) since
# W^T W (rbit x rbit) has rank <= dh << rbit and can never approach I_r;
# and the balance/uncorrelation weights are scaled down ~100x — at this
# scale the paper's eta=2, lam=1 overwhelm the similarity term and push
# recall BELOW a random projection (measured in EXPERIMENTS.md Fig-8
# notes); eta=0.02, lam=0.01 keep the regularizers without the damage.
SIGMA = 1.0
EPS = 0.01
LAM = 0.01
ETA = 0.02
LR = 0.1
WEIGHT_DECAY = 1e-6
MOMENTUM = 0.9
EPOCHS = 15
ITERS = 20

KEYS_PER_QUERY = 192  # subsampled key set per query triplet group
QUERIES_PER_BATCH = 32


# ------------------------------------------------------------- harvesting


def harvest_qk(params, cfg: ModelConfig, n_seqs: int, ctx: int, seed: int):
    """Prefill task sequences; return per-(layer, kv) query/key arrays.

    Returns q_all, k_all: [L, n_kv, n_seqs, s, dh] with queries of all heads
    in a group concatenated along the seq axis (paper pairs (q, k) within a
    head; the group's queries share the kv head's W_H).
    """
    corpus = data.MarkovCorpus(seed=0)
    rng = np.random.default_rng(seed)
    hash_w = init_hash_params(cfg, jax.random.PRNGKey(0))

    # capture q/k by re-running the projection pieces of prefill
    from .model import rms_norm, _qkv, swiglu, ref

    all_q, all_k = [], []
    for si in range(n_seqs):
        kind = data.TASK_KINDS[si % len(data.TASK_KINDS)]
        prompt, _ = data.make_task(kind, corpus, rng, ctx)
        tokens = jnp.asarray(data.encode(prompt))
        s = tokens.shape[0]
        pos = jnp.arange(s)
        x = params["embed"][tokens]
        seq_q, seq_k = [], []
        for layer in params["layers"]:
            h = rms_norm(x, layer["attn_norm"])
            q, k, v = _qkv(h, layer, cfg, pos)
            kr = jnp.repeat(k, cfg.group, axis=1)
            vr = jnp.repeat(v, cfg.group, axis=1)
            outs = jax.vmap(ref.prefill_attention, in_axes=(1, 1, 1), out_axes=1)(
                q, kr, vr
            )
            x = x + outs.reshape(s, -1) @ layer["wo"]
            h2 = rms_norm(x, layer["mlp_norm"])
            x = x + swiglu(h2, layer)
            seq_q.append(np.asarray(q))  # [s, H, dh]
            seq_k.append(np.asarray(k))  # [s, KV, dh]
        all_q.append(np.stack(seq_q))  # [L, s, H, dh]
        all_k.append(np.stack(seq_k))
    return all_q, all_k


def build_triplets(
    all_q, all_k, cfg: ModelConfig, layer: int, kv: int,
    rng: np.random.Generator, n_queries: int,
):
    """Appendix B.1 steps 2-4 -> fixed-shape arrays.

    Returns q [n, dh], keys [n, KEYS_PER_QUERY, dh], labels [n, KPQ].
    """
    qs, ks, ls = [], [], []
    n_seqs = len(all_q)
    while len(qs) < n_queries:
        si = int(rng.integers(0, n_seqs))
        Lq = all_q[si][layer]  # [s, H, dh]
        Lk = all_k[si][layer]  # [s, KV, dh]
        s = Lq.shape[0]
        m = int(rng.integers(s // 2, s))
        qh = kv * cfg.group + int(rng.integers(0, cfg.group))
        q = Lq[m, qh]                     # [dh]
        keys = Lk[: m + 1, kv]            # [m+1, dh]
        score = keys @ q                  # [m+1]
        order = np.argsort(-score)
        n_pos = max(1, (m + 1) // 10)
        labels = np.full(m + 1, -1.0, dtype=np.float32)
        # linearly decayed labels in [1, 20], best key -> 20
        labels[order[:n_pos]] = np.linspace(20.0, 1.0, n_pos)
        # subsample to fixed size: all positives + random negatives
        pos_idx = order[:n_pos]
        neg_idx = order[n_pos:]
        pick_pos = pos_idx[: KEYS_PER_QUERY // 2]
        n_neg = KEYS_PER_QUERY - len(pick_pos)
        # short sequences may not have enough distinct negatives; sample
        # with replacement rather than looping forever
        pick_neg = rng.choice(neg_idx, size=n_neg,
                              replace=len(neg_idx) < n_neg)
        pick = np.concatenate([pick_pos, pick_neg])
        qs.append(q)
        ks.append(keys[pick])
        ls.append(labels[pick])
    return (np.stack(qs).astype(np.float32),
            np.stack(ks).astype(np.float32),
            np.stack(ls).astype(np.float32))


# ---------------------------------------------------------------- training


def hash_loss(w, q, keys, labels):
    """Eq. 9. w [dh, r]; q [n, dh]; keys [n, m, dh]; labels [n, m]."""
    h_q = 2.0 * jax.nn.sigmoid(SIGMA * (q @ w)) - 1.0          # [n, r]
    h_k = 2.0 * jax.nn.sigmoid(SIGMA * (keys @ w)) - 1.0       # [n, m, r]
    d2 = jnp.sum((h_q[:, None, :] - h_k) ** 2, axis=-1)        # [n, m]
    sim_term = EPS * jnp.sum(labels * d2)
    balance = ETA * jnp.sum(jnp.sum(h_k, axis=1) ** 2) / h_k.shape[1]
    dh, r = w.shape
    gram = (dh / r) * (w @ w.T) - jnp.eye(dh, dtype=w.dtype)
    uncorr = LAM * jnp.sqrt(jnp.sum(gram**2) + 1e-12)
    n = q.shape[0]
    return (sim_term + balance) / n + uncorr


def train_head(w0, q, keys, labels, rng):
    """SGD+momentum per Table 11; EPOCHS x ITERS on reshuffled minibatches."""
    loss_grad = jax.jit(jax.value_and_grad(hash_loss))
    w = w0
    vel = jnp.zeros_like(w)
    n = q.shape[0]
    hist = []
    for _ in range(EPOCHS):
        perm = rng.permutation(n)
        for it in range(ITERS):
            lo = (it * QUERIES_PER_BATCH) % n
            sel = perm[lo : lo + QUERIES_PER_BATCH]
            if len(sel) == 0:
                sel = perm[:QUERIES_PER_BATCH]
            loss, g = loss_grad(w, jnp.asarray(q[sel]), jnp.asarray(keys[sel]),
                                jnp.asarray(labels[sel]))
            vel = MOMENTUM * vel - LR * (g + WEIGHT_DECAY * w)
            w = w + vel
            hist.append(float(loss))
    return w, hist


def hash_recall(w, q, keys, labels, k_frac: float = 0.1) -> float:
    """recall@top-10%: do hash scores recover the true positive keys?"""
    from .kernels import ref

    hits, total = 0, 0
    for i in range(min(64, q.shape[0])):
        qc = ref.hash_encode(jnp.asarray(q[i : i + 1]), w)
        kc = ref.hash_encode(jnp.asarray(keys[i]), w)
        rbit = int(w.shape[1])
        sc = np.asarray(ref.hamming_score(qc, kc, rbit))[0]
        true_pos = set(np.where(labels[i] > 0)[0].tolist())
        if not true_pos:
            continue
        k = len(true_pos)
        pred = set(np.argsort(-sc)[:k].tolist())
        hits += len(true_pos & pred)
        total += k
    return hits / max(total, 1)


def train_all(cfg: ModelConfig, params, rbits, n_seqs: int, ctx: int, seed: int):
    """Train W_H for every (layer, kv_head) and every rbit. Returns dict."""
    rng = np.random.default_rng(seed)
    t0 = time.time()
    print(f"[hash:{cfg.name}] harvesting q/k from {n_seqs} seqs @ctx={ctx}",
          flush=True)
    all_q, all_k = harvest_qk(params, cfg, n_seqs, ctx, seed)
    out = {}
    for rbit in rbits:
        ws = np.zeros((cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, rbit),
                      dtype=np.float32)
        recalls = []
        for layer in range(cfg.n_layers):
            for kv in range(cfg.n_kv_heads):
                q, keys, labels = build_triplets(all_q, all_k, cfg, layer, kv,
                                                 rng, n_queries=256)
                key0 = jax.random.PRNGKey(seed + layer * 37 + kv)
                w0 = jax.random.normal(key0, (cfg.head_dim, rbit)) / np.sqrt(
                    cfg.head_dim
                )
                w, _ = train_head(w0, q, keys, labels, rng)
                r = hash_recall(w, q, keys, labels)
                r0 = hash_recall(w0, q, keys, labels)
                # keep-better selection: at rbit >> head_dim a random
                # projection is near-ceiling and training can overfit the
                # per-head sample; ship whichever weights rank better
                # (EXPERIMENTS.md Fig-8 notes).
                if r0 > r:
                    w, r = w0, r0
                recalls.append((r0, r))
                ws[layer, kv] = np.asarray(w)
        r0m = float(np.mean([a for a, _ in recalls]))
        rm = float(np.mean([b for _, b in recalls]))
        print(f"[hash:{cfg.name}] rbit={rbit:4d} recall@10% "
              f"random={r0m:.3f} trained={rm:.3f} ({time.time()-t0:.0f}s)",
              flush=True)
        out[rbit] = ws
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="hata-mha", choices=sorted(CONFIGS))
    ap.add_argument("--rbits", default="128")
    ap.add_argument("--n-seqs", type=int, default=24)
    ap.add_argument("--ctx", type=int, default=320)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--weights", default=None)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    cfg = CONFIGS[args.config]
    wpath = args.weights or f"{args.out}/{cfg.name}.weights.npz"
    params = load_params(wpath, cfg)
    rbits = [int(r) for r in args.rbits.split(",")]
    trained = train_all(cfg, params, rbits, args.n_seqs, args.ctx, args.seed)
    for rbit, ws in trained.items():
        path = f"{args.out}/{cfg.name}.hash_r{rbit}.npz"
        np.savez(path, hash_w=ws)
        print(f"saved {path}")


if __name__ == "__main__":
    main()
