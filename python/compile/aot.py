"""AOT export: lower the L2 model to HLO *text* + export weights/goldens.

This is the only bridge between the Python build path and the Rust request
path.  Interchange is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts written to ``artifacts/`` (all consumed by rust/src/runtime):

  <model>.weights.npz        trained LM parameters (train_model.py)
  <model>.hash_r<r>.npz      trained hash weights  (train_hash.py)
  <model>.prefill.b<B>.hlo.txt
  <model>.decode_dense.b<B>.hlo.txt
  <model>.decode_hata.b<B>.k<K>.hlo.txt
  <model>.goldens.npz        parity vectors for Rust tests
  manifest.json              index of everything above + param ordering

Static-shape strategy: caches are padded to a bucket length B with a
``cur_len`` scalar; invalid positions are masked out of both the dense
softmax and the Hamming top-k (score -1 < the valid minimum of 0).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .kernels import ref
from .kernels.hash_encode import hash_encode
from .kernels.hamming import hamming_score
from .model import CONFIGS, ModelConfig, generate, prefill, rms_norm, rope, swiglu
from .train_model import load_params

WEIGHT_ORDER_GLOBAL = ["embed", "final_norm", "lm_head"]
WEIGHT_ORDER_LAYER = [
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
]


def param_order(cfg: ModelConfig) -> list[str]:
    """Canonical flat weight ordering shared with the Rust runtime."""
    names = list(WEIGHT_ORDER_GLOBAL)
    for i in range(cfg.n_layers):
        names += [f"layers.{i}.{w}" for w in WEIGHT_ORDER_LAYER]
    return names


def flat_weights(params, cfg: ModelConfig) -> list[jax.Array]:
    out = []
    for name in param_order(cfg):
        if name.startswith("layers."):
            _, i, w = name.split(".")
            out.append(params["layers"][int(i)][w])
        else:
            out.append(params[name])
    return out


def unflat_weights(ws: list[jax.Array], cfg: ModelConfig):
    params = {"layers": [dict() for _ in range(cfg.n_layers)]}
    for name, w in zip(param_order(cfg), ws):
        if name.startswith("layers."):
            _, i, k = name.split(".")
            params["layers"][int(i)][k] = w
        else:
            params[name] = w
    return params


# ----------------------------------------------------- bucketed step graphs


def decode_step_bucketed(
    cfg: ModelConfig, bucket: int, budget: int,
    ws: list[jax.Array], hash_w: jax.Array,
    token: jax.Array, cur_len: jax.Array,
    k_cache: jax.Array, v_cache: jax.Array, code_cache: jax.Array,
):
    """One decode step over fixed-size caches (budget=0 -> dense).

    caches: k/v [L, KV, B, dh], code [L, KV, B, words]; the new token's
    K/V/code are written at row ``cur_len``; rows > cur_len are masked.
    Returns (logits, k_cache, v_cache, code_cache).
    """
    params = unflat_weights(ws, cfg)
    B = bucket
    positions = jnp.arange(B)
    x = params["embed"][token]
    scale = cfg.head_dim ** -0.5
    for li, layer in enumerate(params["layers"]):
        h = rms_norm(x, layer["attn_norm"])
        pos = cur_len[None]
        q = (h[None, :] @ layer["wq"]).reshape(1, cfg.n_heads, cfg.head_dim)
        k = (h[None, :] @ layer["wk"]).reshape(1, cfg.n_kv_heads, cfg.head_dim)
        v = (h[None, :] @ layer["wv"]).reshape(1, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, pos, cfg.rope_theta)[0]   # [H, dh]
        k = rope(k, pos, cfg.rope_theta)[0]   # [KV, dh]
        v = v[0]
        # write new K/V/code at row cur_len (paper Alg. 3 l.3-9)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None, :, None, :], (li, 0, cur_len, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None, :, None, :], (li, 0, cur_len, 0)
        )
        kc = jnp.stack(
            [
                hash_encode(k[kv : kv + 1], hash_w[li, kv])[0]
                for kv in range(cfg.n_kv_heads)
            ]
        )  # [KV, words]
        code_cache = jax.lax.dynamic_update_slice(
            code_cache, kc[None, :, None, :].astype(jnp.uint32),
            (li, 0, cur_len, 0),
        )
        valid = positions <= cur_len  # [B]
        outs = []
        for kv in range(cfg.n_kv_heads):
            qs = q[kv * cfg.group : (kv + 1) * cfg.group]   # [g, dh]
            kc_full = k_cache[li, kv]                        # [B, dh]
            vc_full = v_cache[li, kv]
            use_dense = budget == 0 or li < cfg.dense_layers
            if use_dense:
                logits = (qs @ kc_full.T) * scale            # [g, B]
                logits = jnp.where(valid[None, :], logits, -jnp.inf)
                p = jax.nn.softmax(logits, axis=-1)
                outs.append(p @ vc_full)
            else:
                qcode = hash_encode(qs, hash_w[li, kv])      # [g, words]
                sc = hamming_score(qcode, code_cache[li, kv], cfg.rbit)
                agg = ref.gqa_aggregate(sc, cfg.group)[0]    # [B]
                agg = jnp.where(valid, agg, -1)
                # NOT jax.lax.top_k: it lowers to sort(..., largest=true),
                # an attribute xla_extension 0.5.1's HLO-text parser
                # rejects; argsort lowers to a plain comparator sort.
                idx = jnp.argsort(-agg)[:budget]             # [K]
                ks = jnp.take(kc_full, idx, axis=0)          # [K, dh]
                vs = jnp.take(vc_full, idx, axis=0)
                ok = jnp.take(valid, idx)                    # [K]
                logits = (qs @ ks.T) * scale
                logits = jnp.where(ok[None, :], logits, -jnp.inf)
                p = jax.nn.softmax(logits, axis=-1)
                outs.append(p @ vs)
        attn = jnp.concatenate(outs, axis=0)                 # [H, dh]
        x = x + attn.reshape(-1) @ layer["wo"]
        h = rms_norm(x, layer["mlp_norm"])
        x = x + swiglu(h, layer)
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    return logits, k_cache, v_cache, code_cache


def prefill_bucketed(
    cfg: ModelConfig, bucket: int,
    ws: list[jax.Array], hash_w: jax.Array,
    tokens: jax.Array, length: jax.Array,
):
    """Padded prefill: tokens [B] (garbage past `length`), returns
    (last_logits, k_cache, v_cache, code_cache) with caches [L, KV, B, dh]."""
    params = unflat_weights(ws, cfg)
    B = bucket
    pos = jnp.arange(B)
    x = params["embed"][tokens]
    row_valid = pos[:, None] >= pos[None, :]          # causal
    col_valid = (pos[None, :] < length)               # padding
    mask = row_valid & col_valid
    scale = cfg.head_dim ** -0.5
    ks, vs, codes = [], [], []
    for layer in params["layers"]:
        h = rms_norm(x, layer["attn_norm"])
        q = (h @ layer["wq"]).reshape(B, cfg.n_heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(B, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(B, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        outs = []
        for hh in range(cfg.n_heads):
            kv = hh // cfg.group
            logits = (q[:, hh, :] @ k[:, kv, :].T) * scale  # [B, B]
            logits = jnp.where(mask, logits, -jnp.inf)
            p = jax.nn.softmax(logits, axis=-1)
            outs.append(p @ v[:, kv, :])
        attn = jnp.stack(outs, axis=1).reshape(B, -1)
        x = x + attn @ layer["wo"]
        h = rms_norm(x, layer["mlp_norm"])
        x = x + swiglu(h, layer)
        ks.append(jnp.transpose(k, (1, 0, 2)))
        vs.append(jnp.transpose(v, (1, 0, 2)))
        codes.append(
            jnp.stack(
                [
                    hash_encode(k[:, kvh, :], hash_w[len(ks) - 1, kvh])
                    for kvh in range(cfg.n_kv_heads)
                ]
            )
        )
    x = rms_norm(x, params["final_norm"])
    last = jnp.take(x, length - 1, axis=0)
    logits = last @ params["lm_head"]
    return logits, jnp.stack(ks), jnp.stack(vs), jnp.stack(codes)


# ----------------------------------------------------------------- lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(cfg: ModelConfig, bucket: int, rbit: int):
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    L, KV, dh, w = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, rbit // 32
    sd = jax.ShapeDtypeStruct
    hash_spec = sd((L, KV, dh, rbit), f32)
    token = sd((), i32)
    cur_len = sd((), i32)
    kc = sd((L, KV, bucket, dh), f32)
    vc = sd((L, KV, bucket, dh), f32)
    cc = sd((L, KV, bucket, w), u32)
    return hash_spec, token, cur_len, kc, vc, cc


def lower_decode(cfg, params, bucket, budget, rbit):
    ws = flat_weights(params, cfg)
    w_specs = [jax.ShapeDtypeStruct(w.shape, w.dtype) for w in ws]
    hash_spec, token, cur_len, kc, vc, cc = _specs(cfg, bucket, rbit)

    def fn(*args):
        ws_in = list(args[: len(w_specs)])
        hw, tok, cl, k, v, c = args[len(w_specs):]
        return decode_step_bucketed(cfg, bucket, budget, ws_in, hw, tok, cl, k, v, c)

    lowered = jax.jit(fn).lower(*w_specs, hash_spec, token, cur_len, kc, vc, cc)
    return to_hlo_text(lowered)


def lower_prefill(cfg, params, bucket, rbit):
    ws = flat_weights(params, cfg)
    w_specs = [jax.ShapeDtypeStruct(w.shape, w.dtype) for w in ws]
    hash_spec, _, _, _, _, _ = _specs(cfg, bucket, rbit)
    tokens = jax.ShapeDtypeStruct((bucket,), jnp.int32)
    length = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(*args):
        ws_in = list(args[: len(w_specs)])
        hw, toks, ln = args[len(w_specs):]
        return prefill_bucketed(cfg, bucket, ws_in, hw, toks, ln)

    lowered = jax.jit(fn).lower(*w_specs, hash_spec, tokens, length)
    return to_hlo_text(lowered)


# ------------------------------------------------------------------ goldens


def make_goldens(cfg: ModelConfig, params, hash_w, seed: int = 0):
    """Cross-language parity vectors consumed by rust/tests/."""
    rng = np.random.default_rng(seed)
    g = {}
    # kernel-level goldens
    x = rng.normal(size=(9, cfg.head_dim)).astype(np.float32)
    g["hash_in"] = x
    g["hash_w0"] = np.asarray(hash_w[0, 0])
    g["hash_codes"] = np.asarray(ref.hash_encode(jnp.asarray(x), hash_w[0, 0])).view(np.int32)
    qc = g["hash_codes"][:2].view(np.uint32)
    kc = g["hash_codes"][2:].view(np.uint32)
    g["hamming_scores"] = np.asarray(
        ref.hamming_score(jnp.asarray(qc), jnp.asarray(kc), cfg.rbit)
    ).astype(np.int32)
    # model-level goldens: prefill logits + greedy continuations
    corpus = data.MarkovCorpus(seed=0)
    prompt, ans = data.make_task("ns", corpus, rng, 192)
    tokens = jnp.asarray(data.encode(prompt))
    g["prompt_tokens"] = np.asarray(tokens).astype(np.int32)
    logits, caches = prefill(params, hash_w, cfg, tokens)
    g["prefill_logits"] = np.asarray(logits)
    g["prefill_kcache"] = np.asarray(caches["k"])      # [L, KV, s, dh]
    g["prefill_codecache"] = np.asarray(caches["kcode"]).view(np.int32)
    gen_dense = generate(params, hash_w, cfg, tokens, 6, budget=0)
    gen_hata = generate(params, hash_w, cfg, tokens, 6, budget=48)
    g["gen_dense"] = np.asarray(gen_dense).astype(np.int32)
    g["gen_hata"] = np.asarray(gen_hata).astype(np.int32)
    g["task_answer"] = data.encode(ans)
    return g


# --------------------------------------------------------------------- main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="hata-mha,hata-gqa")
    ap.add_argument("--buckets", default="256,1024")
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--skip-hlo", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    buckets = [int(b) for b in args.buckets.split(",")]
    manifest = {"models": {}}
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        params = load_params(f"{args.out}/{cfg.name}.weights.npz", cfg)
        hash_path = f"{args.out}/{cfg.name}.hash_r{cfg.rbit}.npz"
        hash_w = jnp.asarray(np.load(hash_path)["hash_w"])
        entry = {
            "config": {
                "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
                "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
                "ffn_hidden": cfg.ffn_hidden, "rope_theta": cfg.rope_theta,
                "rbit": cfg.rbit, "dense_layers": cfg.dense_layers,
            },
            "weights": f"{cfg.name}.weights.npz",
            "hash_weights": {str(cfg.rbit): f"{cfg.name}.hash_r{cfg.rbit}.npz"},
            "param_order": param_order(cfg),
            "hlo": [],
        }
        # extra rbit variants if train_hash exported them
        for rbit in (32, 64, 256):
            p = f"{args.out}/{cfg.name}.hash_r{rbit}.npz"
            if os.path.exists(p):
                entry["hash_weights"][str(rbit)] = os.path.basename(p)
        print(f"[aot:{cfg.name}] goldens", flush=True)
        g = make_goldens(cfg, params, hash_w)
        np.savez(f"{args.out}/{cfg.name}.goldens.npz", **g)
        if not args.skip_hlo:
            for bucket in buckets:
                print(f"[aot:{cfg.name}] lowering bucket={bucket}", flush=True)
                hlo = lower_prefill(cfg, params, bucket, cfg.rbit)
                path = f"{cfg.name}.prefill.b{bucket}.hlo.txt"
                open(f"{args.out}/{path}", "w").write(hlo)
                entry["hlo"].append({"kind": "prefill", "bucket": bucket,
                                     "path": path})
                hlo = lower_decode(cfg, params, bucket, 0, cfg.rbit)
                path = f"{cfg.name}.decode_dense.b{bucket}.hlo.txt"
                open(f"{args.out}/{path}", "w").write(hlo)
                entry["hlo"].append({"kind": "decode_dense", "bucket": bucket,
                                     "path": path})
                hlo = lower_decode(cfg, params, bucket, args.budget, cfg.rbit)
                path = f"{cfg.name}.decode_hata.b{bucket}.k{args.budget}.hlo.txt"
                open(f"{args.out}/{path}", "w").write(hlo)
                entry["hlo"].append({"kind": "decode_hata", "bucket": bucket,
                                     "budget": args.budget, "path": path})
        manifest["models"][cfg.name] = entry
    with open(f"{args.out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
