#!/usr/bin/env bash
# Check that relative markdown links resolve to real files/directories.
# Usage: scripts/check_md_links.sh README.md docs/*.md
# External (http/mailto) links and pure in-page anchors are skipped;
# anchors on relative links are stripped before the existence check.
set -u

fail=0
for f in "$@"; do
  [ -f "$f" ] || { echo "missing markdown file: $f"; fail=1; continue; }
  dir=$(dirname "$f")
  # inline links: ](target) — capture the target up to the closing paren
  links=$(grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//' || true)
  while IFS= read -r link; do
    [ -n "$link" ] || continue
    # strip an optional markdown link title: [x](target "title")
    link=$(printf '%s' "$link" | sed -E 's/[[:space:]]+"[^"]*"$//')
    # strip optional angle brackets: [x](<target>)
    case "$link" in
      '<'*'>') link=${link#<}; link=${link%>} ;;
    esac
    case "$link" in
      http://*|https://*|mailto:*) continue ;;  # external
      '#'*) continue ;;                          # in-page anchor
    esac
    target=${link%%#*}                           # strip anchor
    [ -n "$target" ] || continue
    if [ ! -e "$dir/$target" ] && [ ! -e "$target" ]; then
      echo "$f: broken relative link: $link"
      fail=1
    fi
  done <<EOF
$links
EOF
done
if [ "$fail" -ne 0 ]; then
  echo "markdown link check failed"
  exit 1
fi
echo "markdown link check passed"
