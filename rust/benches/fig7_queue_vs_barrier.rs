//! Queue-vs-barrier executor sweep: decode-step wall time of the
//! dependency-driven work queue (`--exec queue`) against the
//! barrier-per-stage scatter baseline (`--exec barrier`) across
//! batch × threads, plus one prefill column at the largest batch.
//!
//! Both executors are bit-identical by construction — every cell
//! asserts exact equality of the whole per-step logits trace against
//! the barrier baseline before reporting its speedup, so a regression
//! in either executor fails the bench instead of skewing it.
//!
//! Env: HATA_BENCH_ITERS (default 1), HATA_FIG7_CTX (default 256),
//! HATA_FIG7_STEPS (default 32), HATA_FIG7_BATCHES (default 1,2,4,8).

use std::time::Instant;

use hata::config::{preset, ExecMode, Method, ServeConfig};
use hata::kvcache::{MethodAux, SeqKvCache};
use hata::model::{
    make_selector, sel_ref, weights::Weights, DecodeGraphCache, DecodeItem, DecodeScratch, Model,
    PrefillItem, SeqState, WorkerScratch,
};
use hata::tensor::ops::argmax;
use hata::util::rng::Rng;
use hata::util::threadpool::ThreadPool;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_list(key: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

/// Run `steps` decode steps for a batch of `prompts` under `serve`;
/// returns (wall seconds, flattened per-step logits trace).
#[allow(clippy::too_many_arguments)]
fn run_decode(
    model: &Model,
    serve: &ServeConfig,
    prompts: &[Vec<u32>],
    steps: usize,
    pool: &ThreadPool,
    workers: &mut [WorkerScratch],
) -> (f64, Vec<f32>) {
    let sel = make_selector(serve);
    let mut caches: Vec<SeqKvCache> =
        prompts.iter().map(|_| SeqKvCache::new(&model.cfg, serve)).collect();
    let mut states: Vec<SeqState> = prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
    let mut scratches: Vec<DecodeScratch> =
        prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
    // identical prefill for both executors: batched tiled path
    {
        let mut items: Vec<PrefillItem> = prompts
            .iter()
            .zip(caches.iter_mut())
            .zip(states.iter_mut())
            .zip(scratches.iter_mut())
            .map(|(((p, cache), state), scratch)| PrefillItem {
                tokens: p,
                start: 0,
                prompt_len: p.len(),
                is_final: false,
                tile: serve.prefill_tile,
                cache,
                state,
                scratch,
            })
            .collect();
        model.prefill_batch(&mut items, serve, pool, workers);
    }
    let mut next: Vec<u32> = scratches.iter().map(|sc| argmax(&sc.logits) as u32).collect();
    let mut graph_cache = DecodeGraphCache::new();
    let mut trace: Vec<f32> = Vec::new();
    let t0 = Instant::now();
    for step in 0..steps {
        let mut items: Vec<DecodeItem> = caches
            .iter_mut()
            .zip(states.iter_mut())
            .zip(scratches.iter_mut())
            .enumerate()
            .map(|(i, ((cache, state), scratch))| DecodeItem {
                token: next[i],
                pos: prompts[i].len() + step,
                cache,
                state,
                scratch,
            })
            .collect();
        model.decode_batch(&mut items, serve, sel_ref(&sel), pool, workers, &mut graph_cache);
        drop(items);
        for (i, n) in next.iter_mut().enumerate() {
            *n = argmax(&scratches[i].logits) as u32;
        }
        for sc in &scratches {
            trace.extend_from_slice(&sc.logits);
        }
    }
    (t0.elapsed().as_secs_f64(), trace)
}

/// One long-prompt batched prefill under `serve`; returns (seconds,
/// final logits of every sequence).
fn run_prefill(
    model: &Model,
    serve: &ServeConfig,
    prompts: &[Vec<u32>],
    pool: &ThreadPool,
    workers: &mut [WorkerScratch],
) -> (f64, Vec<f32>) {
    let mut caches: Vec<SeqKvCache> =
        prompts.iter().map(|_| SeqKvCache::new(&model.cfg, serve)).collect();
    let mut states: Vec<SeqState> = prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
    let mut scratches: Vec<DecodeScratch> =
        prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
    let t0 = Instant::now();
    {
        let mut items: Vec<PrefillItem> = prompts
            .iter()
            .zip(caches.iter_mut())
            .zip(states.iter_mut())
            .zip(scratches.iter_mut())
            .map(|(((p, cache), state), scratch)| PrefillItem {
                tokens: p,
                start: 0,
                prompt_len: p.len(),
                is_final: false,
                tile: serve.prefill_tile,
                cache,
                state,
                scratch,
            })
            .collect();
        model.prefill_batch(&mut items, serve, pool, workers);
    }
    let secs = t0.elapsed().as_secs_f64();
    let mut logits = Vec::new();
    for sc in &scratches {
        logits.extend_from_slice(&sc.logits);
    }
    (secs, logits)
}

fn main() {
    let iters = env_usize("HATA_BENCH_ITERS", 1).max(1);
    let ctx = env_usize("HATA_FIG7_CTX", 256);
    let steps = env_usize("HATA_FIG7_STEPS", 32);
    let batches = env_list("HATA_FIG7_BATCHES", &[1, 2, 4, 8]);
    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    let mut thread_counts = vec![1usize];
    if max_threads > 1 {
        thread_counts.push(max_threads);
    }
    let cfg = preset("hata-gqa").unwrap();
    let serve_base = ServeConfig { method: Method::Hata, budget: 64, ..Default::default() };
    let mut rng = Rng::new(11);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve_base, None, 1);
    let model = Model::new(cfg, weights, aux);

    let mut table = hata::bench::report::Table::new(
        &format!(
            "Fig 7 queue-vs-barrier: {steps} decode steps after a {ctx}-token prefill \
             (hata-gqa, min of {iters})"
        ),
        &["phase", "batch", "threads", "barrier_s", "queue_s", "speedup", "bitwise_equal"],
    );
    for &batch in &batches {
        let prompts: Vec<Vec<u32>> = (0..batch)
            .map(|s| (0..ctx).map(|i| 32 + ((i + s * 7) as u32 % 64)).collect())
            .collect();
        for &threads in &thread_counts {
            let pool = ThreadPool::new(threads);
            let mut workers: Vec<WorkerScratch> =
                (0..threads).map(|_| WorkerScratch::default()).collect();
            let mut cell = |exec_mode: ExecMode| -> (f64, Vec<f32>) {
                let serve = ServeConfig { threads, exec_mode, ..serve_base.clone() };
                let mut best = f64::INFINITY;
                let mut trace = Vec::new();
                for _ in 0..iters {
                    let (secs, t) =
                        run_decode(&model, &serve, &prompts, steps, &pool, &mut workers);
                    best = best.min(secs);
                    trace = t;
                }
                (best, trace)
            };
            let (bs, bt) = cell(ExecMode::Barrier);
            let (qs, qt) = cell(ExecMode::Queue);
            assert_eq!(
                bt, qt,
                "queue decode diverged from barrier (batch={batch}, threads={threads})"
            );
            table.row(vec![
                "decode".into(),
                batch.to_string(),
                threads.to_string(),
                hata::bench::report::fmt(bs),
                hata::bench::report::fmt(qs),
                hata::bench::report::fmt(bs / qs),
                "yes".into(),
            ]);
            eprintln!("[fig7] decode batch={batch} threads={threads} done");
        }
    }
    // one prefill row per thread count at the largest batch
    let batch = *batches.last().unwrap_or(&4);
    let prompts: Vec<Vec<u32>> = (0..batch)
        .map(|s| (0..4 * ctx).map(|i| 32 + ((i + s * 13) as u32 % 64)).collect())
        .collect();
    for &threads in &thread_counts {
        let pool = ThreadPool::new(threads);
        let mut workers: Vec<WorkerScratch> =
            (0..threads).map(|_| WorkerScratch::default()).collect();
        let mut cell = |exec_mode: ExecMode| -> (f64, Vec<f32>) {
            let serve = ServeConfig { threads, exec_mode, ..serve_base.clone() };
            let mut best = f64::INFINITY;
            let mut logits = Vec::new();
            for _ in 0..iters {
                let (secs, l) = run_prefill(&model, &serve, &prompts, &pool, &mut workers);
                best = best.min(secs);
                logits = l;
            }
            (best, logits)
        };
        let (bs, bl) = cell(ExecMode::Barrier);
        let (qs, ql) = cell(ExecMode::Queue);
        assert_eq!(bl, ql, "queue prefill diverged from barrier (threads={threads})");
        table.row(vec![
            "prefill".into(),
            batch.to_string(),
            threads.to_string(),
            hata::bench::report::fmt(bs),
            hata::bench::report::fmt(qs),
            hata::bench::report::fmt(bs / qs),
            "yes".into(),
        ]);
        eprintln!("[fig7] prefill batch={batch} threads={threads} done");
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig7_queue_vs_barrier").unwrap();
}
