//! Fig 4 reproduction: end-to-end inference time (prefill + decode bars)
//! per method, through the full serving engine.
//!
//! Paper: Llama2/Llama3.1, 1.56% token selection; dense vs Loki vs Quest
//! vs HATA. Here: the trained tiny models (or random weights when
//! artifacts are absent) with scaled contexts; the bar *shape* — similar
//! prefill, decode ordered dense > loki > quest/hata — is the target.

use std::sync::Arc;

use hata::bench::report::{fmt, Table};
use hata::bench::tasks::{make_task, Corpus, TaskKind};
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::Request;
use hata::kvcache::MethodAux;
use hata::model::{tokenizer, weights::Weights, Model};
use hata::util::rng::Rng;

fn main() {
    let ctx: usize =
        std::env::var("HATA_FIG4_CTX").ok().and_then(|v| v.parse().ok()).unwrap_or(1024);
    let decode_len = 32;
    let n_requests = 2;
    let budget = ((ctx as f64) * 0.0156).max(16.0) as usize;
    let mut table = Table::new(
        &format!("Fig 4 proxy: end-to-end time (ctx={ctx}, decode={decode_len}, budget={budget})"),
        &["method", "prefill_s", "decode_s", "total_s", "decode_tok_s", "speedup_vs_dense"],
    );
    let corpus = Corpus::new(0);
    let mut dense_decode = None;
    for method in [Method::Dense, Method::Loki, Method::Quest, Method::Hata] {
        let serve = ServeConfig {
            method,
            budget: if method == Method::Dense { 0 } else { budget },
            max_batch: n_requests,
            prefill_chunk: 4096,
            ..Default::default()
        };
        let cfg = preset("hata-mha").unwrap();
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        let model = Arc::new(Model::new(cfg, weights, aux));
        let mut engine = Engine::new(model, serve);
        let mut rng = Rng::new(9);
        for id in 0..n_requests {
            let (prompt, _) = make_task(TaskKind::Ns, &corpus, &mut rng, ctx, None);
            engine.submit(Request {
                id: id as u64,
                prompt: tokenizer::encode(&prompt),
                max_new_tokens: decode_len,
                stop_token: None,
                arrival: 0.0,
            });
        }
        // prefill phase: run until every sequence produced its 1st token
        let t0 = std::time::Instant::now();
        let responses = engine.run_to_completion();
        let total = t0.elapsed().as_secs_f64();
        let ttft_max = responses.iter().map(|r| r.ttft).fold(0.0, f64::max);
        let decode_s = total - ttft_max;
        let gen: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let tok_s = gen as f64 / decode_s.max(1e-9);
        let base = *dense_decode.get_or_insert(decode_s);
        table.row(vec![
            method.name().to_string(),
            fmt(ttft_max),
            fmt(decode_s),
            fmt(total),
            fmt(tok_s),
            fmt(base / decode_s),
        ]);
        eprintln!("[fig4] {} done", method.name());
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig4").unwrap();
}
