//! Fig 4 reproduction: end-to-end inference time (prefill + decode bars)
//! per method, through the full serving engine.
//!
//! Paper: Llama2/Llama3.1, 1.56% token selection; dense vs Loki vs Quest
//! vs HATA. Here: the trained tiny models (or random weights when
//! artifacts are absent) with scaled contexts; the bar *shape* — similar
//! prefill, decode ordered dense > loki > quest/hata — is the target.
//!
//! A second table sweeps the engine's `--threads` knob (batched parallel
//! decode) at batch >= 4, emitting decode tokens/s per thread count so
//! the threadpool fan-out's scaling lands in the BENCH trajectory.

use std::sync::Arc;

use hata::bench::report::{fmt, Table};
use hata::bench::tasks::{make_task, Corpus, TaskKind};
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::Request;
use hata::kvcache::MethodAux;
use hata::model::{tokenizer, weights::Weights, Model};
use hata::util::rng::Rng;

struct RunStats {
    prefill_s: f64,
    decode_s: f64,
    total_s: f64,
    decode_tok_s: f64,
}

/// Build a fresh engine, serve `n_requests` synthetic NS tasks, return
/// the timing split (prefill ~= max TTFT, decode = remainder).
fn run_once(serve: ServeConfig, ctx: usize, decode_len: usize, n_requests: usize) -> RunStats {
    let corpus = Corpus::new(0);
    let cfg = preset("hata-mha").unwrap();
    let mut rng = Rng::new(0);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let model = Arc::new(Model::new(cfg, weights, aux));
    let mut engine = Engine::new(model, serve);
    let mut rng = Rng::new(9);
    for id in 0..n_requests {
        let (prompt, _) = make_task(TaskKind::Ns, &corpus, &mut rng, ctx, None);
        engine.submit(Request {
            id: id as u64,
            prompt: tokenizer::encode(&prompt),
            max_new_tokens: decode_len,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let t0 = std::time::Instant::now();
    let responses = engine.run_to_completion();
    let total_s = t0.elapsed().as_secs_f64();
    let ttft_max = responses.iter().map(|r| r.ttft).fold(0.0, f64::max);
    let decode_s = total_s - ttft_max;
    let gen: usize = responses.iter().map(|r| r.tokens.len()).sum();
    RunStats {
        prefill_s: ttft_max,
        decode_s,
        total_s,
        decode_tok_s: gen as f64 / decode_s.max(1e-9),
    }
}

fn main() {
    let ctx: usize =
        std::env::var("HATA_FIG4_CTX").ok().and_then(|v| v.parse().ok()).unwrap_or(1024);
    let decode_len = 32;
    let n_requests = 2;
    let budget = ((ctx as f64) * 0.0156).max(16.0) as usize;
    let mut table = Table::new(
        &format!("Fig 4 proxy: end-to-end time (ctx={ctx}, decode={decode_len}, budget={budget})"),
        &["method", "prefill_s", "decode_s", "total_s", "decode_tok_s", "speedup_vs_dense"],
    );
    let mut dense_decode = None;
    for method in [Method::Dense, Method::Loki, Method::Quest, Method::Hata] {
        let serve = ServeConfig {
            method,
            budget: if method == Method::Dense { 0 } else { budget },
            max_batch: n_requests,
            prefill_chunk: 4096,
            ..Default::default()
        };
        let r = run_once(serve, ctx, decode_len, n_requests);
        let base = *dense_decode.get_or_insert(r.decode_s);
        table.row(vec![
            method.name().to_string(),
            fmt(r.prefill_s),
            fmt(r.decode_s),
            fmt(r.total_s),
            fmt(r.decode_tok_s),
            fmt(base / r.decode_s),
        ]);
        eprintln!("[fig4] {} done", method.name());
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig4").unwrap();

    // ---- thread sweep: batched parallel decode scaling at batch >= 4
    let sweep_batch = 4;
    let thread_counts = [1usize, 2, 4, 8];
    let mut tsweep = Table::new(
        &format!(
            "Fig 4 thread sweep: decode tokens/s (ctx={ctx}, batch={sweep_batch}, \
             decode={decode_len}, budget={budget})"
        ),
        &["method", "threads=1", "threads=2", "threads=4", "threads=8"],
    );
    for method in [Method::Dense, Method::Hata] {
        let mut row = vec![method.name().to_string()];
        for &threads in &thread_counts {
            let serve = ServeConfig {
                method,
                budget: if method == Method::Dense { 0 } else { budget },
                max_batch: sweep_batch,
                prefill_chunk: 4096,
                threads,
                ..Default::default()
            };
            let r = run_once(serve, ctx, decode_len, sweep_batch);
            row.push(fmt(r.decode_tok_s));
            eprintln!("[fig4] threads sweep {} t={} done", method.name(), threads);
        }
        tsweep.row(row);
    }
    println!("{}", tsweep.render());
    tsweep.write_csv("bench_results", "fig4_threads").unwrap();
}
