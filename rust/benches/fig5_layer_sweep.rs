//! Fig 5 reproduction: single-transformer-layer decode latency across
//! batch sizes and context lengths, per method.
//!
//! Paper setup: Llama2 (MHA) b=1 x {32K..256K} and b={1..8} x 32K; Llama3.1
//! (GQA). Unit = one decode step of one attention layer (one KV head;
//! heads scale linearly). We measure CPU wall time AND report the modeled
//! bandwidth-bound speedup (simulator/hbm.rs) that translates the shape to
//! GPU-class hardware.

use hata::attention::compute::{dense_attention, sparse_attention_fused};
use hata::attention::methods::{ExactTopK, HataSelector, LokiSelector, QuestSelector};
use hata::attention::{MethodState, Scratch, Selector};
use hata::bench::harness::{bench, LayerFixture};
use hata::bench::report::{fmt, Table};
use hata::config::{preset, Method, ServeConfig};
use hata::simulator::hbm::modeled_speedup;
use hata::tensor::simd::KernelMode;
use hata::util::threadpool::ThreadPool;

fn step_sparse(
    f: &LayerFixture,
    sel: &dyn Selector,
    budget: usize,
    sc: &mut Scratch,
    out: &mut [f32],
) {
    let inp = f.inputs();
    let mut st = MethodState::default();
    sel.select(&inp, &mut st, budget, sc);
    let idx = std::mem::take(&mut sc.indices);
    sparse_attention_fused(KernelMode::default(), &inp, &idx, &mut sc.probs, out);
    sc.indices = idx;
}

fn main() {
    let iters: usize =
        std::env::var("HATA_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    // head_dim 128 mirrors the paper models (Fig 5's unit is per-head
    // memory traffic).
    let dh = 128;
    let mut table = Table::new(
        "Fig 5 proxy: single-layer decode latency (one KV head, dh=128)",
        &[
            "config",
            "ctx",
            "budget",
            "dense_ms",
            "topk_ms",
            "loki_ms",
            "quest_ms",
            "hata_ms",
            "hata_speedup_meas",
            "hata_speedup_model",
        ],
    );
    let sweeps: &[(&str, usize, &[usize])] = &[
        ("mha-b1", 1, &[8_192, 32_768, 131_072, 262_144]),
        ("gqa-g4", 4, &[8_192, 32_768, 131_072]),
    ];
    for &(label, group, ctxs) in sweeps {
        for &s in ctxs {
            let budget = ((s as f64) * 0.0156) as usize;
            let f = LayerFixture::new(s, dh, group, 128, 42);
            let mut sc = Scratch::default();
            let mut out = vec![0.0f32; group * dh];
            let dense = bench("dense", 1, iters, || {
                dense_attention(KernelMode::default(), &f.inputs(), &mut sc.probs, &mut out);
            });
            let topk = bench("topk", 1, iters, || {
                step_sparse(&f, &ExactTopK, budget, &mut sc, &mut out);
            });
            let loki = bench("loki", 1, iters, || {
                step_sparse(&f, &LokiSelector { channels: dh / 4 }, budget, &mut sc, &mut out);
            });
            let quest = bench("quest", 1, iters, || {
                step_sparse(&f, &QuestSelector, budget, &mut sc, &mut out);
            });
            let hata = bench("hata", 1, iters, || {
                step_sparse(&f, &HataSelector, budget, &mut sc, &mut out);
            });
            let cfg = preset(if group == 1 { "mirror-llama2-7b" } else { "mirror-llama31-8b" })
                .unwrap();
            let serve = ServeConfig { method: Method::Hata, ..Default::default() };
            let modeled = modeled_speedup(&cfg, &serve, s, budget);
            table.row(vec![
                label.to_string(),
                s.to_string(),
                budget.to_string(),
                fmt(dense.mean_s * 1e3),
                fmt(topk.mean_s * 1e3),
                fmt(loki.mean_s * 1e3),
                fmt(quest.mean_s * 1e3),
                fmt(hata.mean_s * 1e3),
                fmt(dense.mean_s / hata.mean_s),
                fmt(modeled),
            ]);
            eprintln!("[fig5] {label} ctx={s} done");
        }
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig5").unwrap();

    // ---- threadpool fan-out: a batch of per-(sequence, head) HATA
    // select+attend items scattered across pool workers, the same work
    // unit the engine's batched decode path fans out per layer.
    let b = 4;
    let s = 32_768;
    let budget = ((s as f64) * 0.0156) as usize;
    let fixtures: Vec<LayerFixture> =
        (0..b).map(|i| LayerFixture::new(s, dh, 1, 128, 100 + i as u64)).collect();
    let mut outs: Vec<Vec<f32>> = (0..b).map(|_| vec![0.0f32; dh]).collect();
    let mut t2 = Table::new(
        &format!("Fig 5 thread fan-out: batched HATA select+attend (b={b}, ctx={s}, one head each)"),
        &["threads", "step_ms", "speedup_vs_1"],
    );
    let mut base = None;
    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads);
        let mut workers: Vec<Scratch> = (0..threads).map(|_| Scratch::default()).collect();
        let r = bench("fanout", 1, iters, || {
            pool.scatter(&mut outs, &mut workers, |i, out, ws| {
                step_sparse(&fixtures[i], &HataSelector, budget, ws, out);
            });
        });
        let base_s = *base.get_or_insert(r.mean_s);
        t2.row(vec![threads.to_string(), fmt(r.mean_s * 1e3), fmt(base_s / r.mean_s)]);
        eprintln!("[fig5] fanout threads={threads} done");
    }
    println!("{}", t2.render());
    t2.write_csv("bench_results", "fig5_threads").unwrap();
}
