//! Fig 1 reproduction: the accuracy-vs-decoding-speed scatter.
//!
//! Per method: (x) decode tokens/s through the engine at a long context,
//! (y) task accuracy on the synthetic suite (trained model if artifacts
//! exist, else selection recall on random weights as the y-axis).

use std::sync::Arc;

use hata::bench::eval::{fidelity, task_accuracy};
use hata::bench::report::{fmt, Table};
use hata::bench::tasks::{make_task, Corpus, TaskKind};
use hata::config::manifest::Manifest;
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::Request;
use hata::kvcache::MethodAux;
use hata::model::{tokenizer, weights::Weights, Model};
use hata::util::rng::Rng;

fn load(serve: &ServeConfig) -> (Model, bool) {
    if let Ok(m) = Manifest::load("artifacts") {
        if let Ok(arts) = m.model("hata-mha") {
            if let Ok(mut w) = Weights::load(&arts.weights, &arts.config) {
                if let Some(hw) = arts.hash_weights_for(arts.config.rbit) {
                    if w.load_hash(hw, &arts.config).is_ok() {
                        let aux = MethodAux::build(&arts.config, serve, None, 7);
                        return (Model::new(arts.config.clone(), w, aux), true);
                    }
                }
            }
        }
    }
    let cfg = preset("hata-mha").unwrap();
    let mut rng = Rng::new(0);
    let w = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, serve, None, 7);
    (Model::new(cfg, w, aux), false)
}

fn main() {
    let ctx = 768;
    let budget = 32;
    let samples: usize =
        std::env::var("HATA_FIG1_SAMPLES").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let corpus = Corpus::new(0);
    let mut table = Table::new(
        &format!("Fig 1 proxy: accuracy vs decode speed (ctx={ctx}, budget={budget})"),
        &["method", "tok_s", "accuracy_pct", "recall", "trained"],
    );
    for method in [
        Method::Dense,
        Method::Loki,
        Method::Quest,
        Method::MagicPig,
        Method::StreamingLlm,
        Method::Hata,
    ] {
        let serve = ServeConfig {
            method,
            budget: if method == Method::Dense { 0 } else { budget },
            max_batch: 2,
            prefill_chunk: 4096,
            ..Default::default()
        };
        let (model, trained) = load(&serve);
        // speed: decode throughput over 2 requests
        let model = Arc::new(model);
        let mut engine = Engine::new(Arc::clone(&model), serve.clone());
        let mut rng = Rng::new(4);
        for id in 0..2u64 {
            let (prompt, _) = make_task(TaskKind::Ns, &corpus, &mut rng, ctx, None);
            engine.submit(Request {
                id,
                prompt: tokenizer::encode(&prompt),
                max_new_tokens: 24,
                stop_token: None,
                arrival: 0.0,
            });
        }
        let t0 = std::time::Instant::now();
        let rs = engine.run_to_completion();
        let total = t0.elapsed().as_secs_f64();
        let ttft = rs.iter().map(|r| r.ttft).fold(0.0, f64::max);
        let gen: usize = rs.iter().map(|r| r.tokens.len()).sum();
        let tok_s = gen as f64 / (total - ttft).max(1e-9);
        // accuracy (trained) / recall (any)
        let acc = if trained {
            task_accuracy(&model, &serve, TaskKind::Ns, ctx, samples, 1, None)
        } else {
            f64::NAN
        };
        let rec = if method == Method::Dense {
            1.0
        } else {
            fidelity(&model, &serve, ctx.min(512), 2, 3).recall
        };
        table.row(vec![
            method.name().to_string(),
            fmt(tok_s),
            fmt(100.0 * acc),
            fmt(rec),
            trained.to_string(),
        ]);
        eprintln!("[fig1] {} done", method.name());
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig1").unwrap();
}
