//! Fig 10 reproduction: serving goodput vs offered load under the
//! streaming front door.
//!
//! An **open-loop** load generator fires requests at the router on a
//! seeded Poisson schedule (exponential inter-arrival gaps at each
//! offered load λ, mixed prompt lengths), submits through the
//! non-blocking admission gate ([`Router::try_submit_stream`]; a full
//! gate sheds the request, as an open-loop client must), and polls every
//! live [`ResponseStream`] for tokens. All latency is measured
//! **client-side against the scheduled arrival time**, so queueing and
//! admission delay count toward TTFT exactly as a user would see them.
//!
//! Per cell the bench reports goodput — completed requests that met both
//! SLOs (TTFT ≤ `--slo-ttft-ms`, mean TPOT ≤ `--slo-tpot-ms`) per
//! second of makespan — alongside shed count and client-side
//! TTFT/TPOT percentiles, plus a per-request record array. Everything
//! lands in `<out>/fig10_serving.json` (schema below) and a rendered
//! table on stdout.
//!
//! ```text
//! { "cells": [ { "offered_load": .., "goodput": .., "completed": ..,
//!                "shed": .., "ttft_p50_ms": .., "ttft_p99_ms": ..,
//!                "tpot_p50_ms": .., "tpot_p99_ms": .., "makespan_s": ..,
//!                "requests": [ { "id", "arrival_s", "prompt_len",
//!                                "tokens", "ttft_ms", "tpot_mean_ms",
//!                                "slo_ok", "outcome" } ] } ] }
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use hata::bench::report::{fmt, Table};
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::request::Request;
use hata::coordinator::router::{Policy, Router};
use hata::coordinator::stream::{ResponseStream, StreamEvent};
use hata::kvcache::MethodAux;
use hata::model::{weights::Weights, Model};
use hata::util::cli::Args;
use hata::util::json::Json;
use hata::util::rng::Rng;
use hata::util::stats::Summary;

const FLAGS: &[&str] = &[
    "offered-load", "requests", "method", "budget", "max-batch", "threads",
    "workers", "max-concurrent", "waiting-served-ratio",
    "prefill-chunk-budget", "kv-block", "paged!", "offload!",
    "offload-budget", "seed", "max-new", "prompt-lens", "out", "slo-ttft-ms",
    "slo-tpot-ms",
];

/// One request on the open-loop schedule.
struct Planned {
    id: u64,
    /// scheduled arrival, seconds after cell start
    at: f64,
    prompt: Vec<u32>,
    max_new: usize,
}

/// Client-side observation of one request's stream.
struct ClientRec {
    id: u64,
    arrival: f64,
    prompt_len: usize,
    /// seconds after cell start the client saw the first token
    first_token: Option<f64>,
    /// seconds after cell start the client saw the latest token
    last_token: f64,
    tokens: usize,
    outcome: &'static str,
}

/// A finite number, or JSON null (empty-summary percentiles are NaN).
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Seeded Poisson schedule: exponential gaps at rate `lambda` req/s,
/// prompt lengths drawn uniformly from `lens`.
fn plan(lambda: f64, n: usize, lens: &[usize], max_new: usize, seed: u64) -> Vec<Planned> {
    let mut rng = Rng::new(seed);
    let mut at = 0.0f64;
    (0..n as u64)
        .map(|id| {
            at += -(1.0 - rng.f64()).ln() / lambda;
            let plen = lens[rng.below(lens.len())];
            Planned {
                id,
                at,
                prompt: (0..plen).map(|_| 32 + rng.below(64) as u32).collect(),
                max_new,
            }
        })
        .collect()
}

struct CellResult {
    offered: f64,
    goodput: f64,
    completed: usize,
    shed: usize,
    makespan: f64,
    ttft_ms: Summary,
    tpot_ms: Summary,
    requests: Vec<Json>,
}

/// Drive one offered-load cell against a fresh router.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    model: &Arc<Model>,
    serve: &ServeConfig,
    workers: usize,
    lambda: f64,
    planned: Vec<Planned>,
    slo_ttft_s: f64,
    slo_tpot_s: f64,
) -> CellResult {
    let total = planned.len();
    let mut router =
        Router::new(Arc::clone(model), serve.clone(), workers, Policy::LeastLoaded);
    let mut recs: Vec<ClientRec> = Vec::with_capacity(total);
    let mut active: Vec<(usize, ResponseStream)> = Vec::new();
    let mut pending = planned.into_iter().peekable();
    let mut shed = 0usize;
    let mut completed = 0usize;
    let mut last_done_at = 0.0f64;
    let t0 = Instant::now();
    while pending.peek().is_some() || !active.is_empty() {
        let now = t0.elapsed().as_secs_f64();
        let mut progressed = false;
        // fire every arrival whose scheduled time has passed; a full
        // admission gate sheds the request (open-loop: no retry)
        while pending.peek().is_some_and(|p| p.at <= now) {
            let p = pending.next().unwrap();
            let rec = ClientRec {
                id: p.id,
                arrival: p.at,
                prompt_len: p.prompt.len(),
                first_token: None,
                last_token: 0.0,
                tokens: 0,
                outcome: "shed",
            };
            let req = Request {
                id: p.id,
                prompt: p.prompt,
                max_new_tokens: p.max_new,
                stop_token: None,
                arrival: 0.0,
            };
            recs.push(rec);
            let slot = recs.len() - 1;
            match router.try_submit_stream(req) {
                Ok(stream) => {
                    recs[slot].outcome = "completed";
                    active.push((slot, stream));
                }
                Err(_) => shed += 1,
            }
            progressed = true;
        }
        // poll every live stream; client-side clock stamps each event
        let mut i = 0;
        while i < active.len() {
            let (slot, stream) = &active[i];
            let slot = *slot;
            let mut done = false;
            while let Some(ev) = stream.try_recv() {
                progressed = true;
                let at = t0.elapsed().as_secs_f64();
                match ev {
                    StreamEvent::Token { .. } => {
                        let rec = &mut recs[slot];
                        rec.tokens += 1;
                        rec.last_token = at;
                        if rec.first_token.is_none() {
                            rec.first_token = Some(at);
                        }
                    }
                    StreamEvent::Done(resp) => {
                        let rec = &mut recs[slot];
                        if resp.reason == hata::coordinator::request::FinishReason::Preempted {
                            rec.outcome = "preempted";
                        }
                        completed += 1;
                        last_done_at = at;
                        done = true;
                        break;
                    }
                }
            }
            if done {
                active.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let makespan = last_done_at.max(t0.elapsed().as_secs_f64());
    let mut ttft_ms = Summary::new();
    let mut tpot_ms = Summary::new();
    let mut slo_ok_count = 0usize;
    let mut requests = Vec::with_capacity(recs.len());
    for rec in &recs {
        let ttft = rec.first_token.map(|t| t - rec.arrival);
        let tpot = (rec.tokens > 1)
            .then(|| (rec.last_token - rec.first_token.unwrap()) / (rec.tokens - 1) as f64);
        let slo_ok = rec.outcome == "completed"
            && ttft.is_some_and(|t| t <= slo_ttft_s)
            && tpot.map_or(rec.tokens >= 1, |t| t <= slo_tpot_s);
        if slo_ok {
            slo_ok_count += 1;
        }
        if let Some(t) = ttft {
            ttft_ms.add(t * 1e3);
        }
        if let Some(t) = tpot {
            tpot_ms.add(t * 1e3);
        }
        requests.push(Json::obj(vec![
            ("id", Json::num(rec.id as f64)),
            ("arrival_s", Json::num(rec.arrival)),
            ("prompt_len", Json::num(rec.prompt_len as f64)),
            ("tokens", Json::num(rec.tokens as f64)),
            ("ttft_ms", ttft.map(|t| Json::num(t * 1e3)).unwrap_or(Json::Null)),
            ("tpot_mean_ms", tpot.map(|t| Json::num(t * 1e3)).unwrap_or(Json::Null)),
            ("slo_ok", Json::Bool(slo_ok)),
            ("outcome", Json::str(rec.outcome)),
        ]));
    }
    CellResult {
        offered: lambda,
        goodput: slo_ok_count as f64 / makespan.max(1e-9),
        completed,
        shed,
        makespan,
        ttft_ms,
        tpot_ms,
        requests,
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // cargo bench passes its own flags (e.g. --bench) before ours; drop
    // everything up to the first flag we know
    let argv: Vec<String> =
        argv.into_iter().filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv, FLAGS, false).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let loads = args.f64_list("offered-load", &[10.0, 30.0, 90.0]).unwrap();
    let n_requests = args.usize("requests", 24).unwrap();
    let method = Method::parse(&args.str("method", "hata")).expect("bad --method");
    let lens = args.usize_list("prompt-lens", &[24, 48, 96]).unwrap();
    let max_new = args.usize("max-new", 8).unwrap();
    let workers = args.usize("workers", 1).unwrap();
    let seed = args.u64("seed", 0).unwrap();
    let slo_ttft_s = args.f64("slo-ttft-ms", 2000.0).unwrap() / 1e3;
    let slo_tpot_s = args.f64("slo-tpot-ms", 500.0).unwrap() / 1e3;
    let out_dir = args.str("out", "bench_results");
    let serve = ServeConfig {
        method,
        budget: args.usize("budget", 16).unwrap(),
        max_batch: args.usize("max-batch", 4).unwrap(),
        threads: args.usize("threads", 1).unwrap(),
        max_concurrent: args.usize("max-concurrent", 8).unwrap(),
        waiting_served_ratio: args.f64("waiting-served-ratio", 0.0).unwrap(),
        prefill_chunk: args.usize("prefill-chunk-budget", 48).unwrap(),
        kv_block: args.usize("kv-block", ServeConfig::default().kv_block).unwrap(),
        paged: args.flag("paged") || args.flag("offload"),
        offload: args.flag("offload"),
        offload_budget: args
            .usize("offload-budget", ServeConfig::default().offload_budget)
            .unwrap(),
        seed,
        ..Default::default()
    };
    let cfg = preset("hata-gqa").unwrap();
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    let model = Arc::new(model);

    let mut table = Table::new(
        &format!(
            "Fig 10 proxy: goodput vs offered load (method={}, max_concurrent={}, \
             chunk={}, workers={})",
            method.name(),
            serve.max_concurrent,
            serve.prefill_chunk,
            workers
        ),
        &[
            "offered", "goodput", "completed", "shed", "ttft_p50_ms", "ttft_p99_ms",
            "tpot_p50_ms", "tpot_p99_ms",
        ],
    );
    let mut cells = Vec::new();
    for (i, &lambda) in loads.iter().enumerate() {
        let planned = plan(lambda, n_requests, &lens, max_new, seed ^ ((i as u64 + 1) << 32));
        let cell = run_cell(&model, &serve, workers, lambda, planned, slo_ttft_s, slo_tpot_s);
        eprintln!(
            "[fig10] load={lambda:.1} req/s -> goodput {:.2} req/s, completed {}, shed {}",
            cell.goodput, cell.completed, cell.shed
        );
        table.row(vec![
            fmt(cell.offered),
            fmt(cell.goodput),
            cell.completed.to_string(),
            cell.shed.to_string(),
            fmt(cell.ttft_ms.p50()),
            fmt(cell.ttft_ms.p99()),
            fmt(cell.tpot_ms.p50()),
            fmt(cell.tpot_ms.p99()),
        ]);
        cells.push(Json::obj(vec![
            ("offered_load", Json::num(cell.offered)),
            ("goodput", Json::num(cell.goodput)),
            ("completed", Json::num(cell.completed as f64)),
            ("shed", Json::num(cell.shed as f64)),
            ("makespan_s", Json::num(cell.makespan)),
            ("ttft_p50_ms", num_or_null(cell.ttft_ms.p50())),
            ("ttft_p99_ms", num_or_null(cell.ttft_ms.p99())),
            ("tpot_p50_ms", num_or_null(cell.tpot_ms.p50())),
            ("tpot_p99_ms", num_or_null(cell.tpot_ms.p99())),
            ("requests", Json::Arr(cell.requests)),
        ]));
    }
    println!("{}", table.render());
    let doc = Json::obj(vec![
        ("method", Json::str(method.name())),
        ("max_concurrent", Json::num(serve.max_concurrent as f64)),
        ("prefill_chunk", Json::num(serve.prefill_chunk as f64)),
        ("slo_ttft_ms", Json::num(slo_ttft_s * 1e3)),
        ("slo_tpot_ms", Json::num(slo_tpot_s * 1e3)),
        ("cells", Json::Arr(cells)),
    ]);
    std::fs::create_dir_all(&out_dir).unwrap();
    let path = format!("{out_dir}/fig10_serving.json");
    std::fs::write(&path, doc.to_string_pretty()).unwrap();
    println!("wrote {path}");
}
