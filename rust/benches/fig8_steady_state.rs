//! Steady-state decode fast path: graph-cache amortization sweep.
//!
//! The queue executor's task graph used to be rebuilt every token —
//! one `children` vec per edge, ~batch × layers × (2 + kv_heads) nodes
//! per step. `--graph-cache` (on by default) builds it once per batch
//! shape and only rebinds payloads per step. This bench sweeps
//! layers × batch and reports per-step decode latency with the cache
//! off (rebuild per token, the pre-cache reference) vs on, plus the
//! graph-builds-per-step accounting: cached mode must show builds/step
//! → 0 after the first step, and every cell asserts the full per-step
//! logits trace is bit-identical between the two modes.
//!
//! The rebuild cost scales with the graph size (layers × batch), so
//! the speedup column grows toward real model layer counts — the
//! "orchestration must be nearly free" argument from the paper's
//! overhead analysis, applied to our own executor.
//!
//! Env: HATA_BENCH_ITERS (default 1), HATA_FIG8_CTX (default 128),
//! HATA_FIG8_STEPS (default 32), HATA_FIG8_LAYERS (default 2,4,8,16),
//! HATA_FIG8_BATCHES (default 1,4,8).

use std::time::Instant;

use hata::config::{preset, Method, ServeConfig};
use hata::kvcache::{MethodAux, SeqKvCache};
use hata::model::{
    make_selector, sel_ref, weights::Weights, DecodeGraphCache, DecodeItem, DecodeScratch, Model,
    SeqState, WorkerScratch,
};
use hata::tensor::ops::argmax;
use hata::util::rng::Rng;
use hata::util::threadpool::ThreadPool;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_list(key: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

/// `steps` decode steps after a shared prefill; returns (wall seconds,
/// graph builds, flattened per-step logits trace).
fn run_decode(
    model: &Model,
    serve: &ServeConfig,
    prompts: &[Vec<u32>],
    steps: usize,
    pool: &ThreadPool,
    workers: &mut [WorkerScratch],
) -> (f64, u64, Vec<f32>) {
    let sel = make_selector(serve);
    let mut caches: Vec<SeqKvCache> = prompts
        .iter()
        .map(|p| {
            let mut c = SeqKvCache::new(&model.cfg, serve);
            c.reserve(p.len() + steps + 1);
            c
        })
        .collect();
    let mut states: Vec<SeqState> = prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
    let mut scratches: Vec<DecodeScratch> =
        prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
    for (i, p) in prompts.iter().enumerate() {
        model.prefill(p, &mut caches[i], &mut states[i], serve, &mut scratches[i]);
    }
    let mut next: Vec<u32> = scratches.iter().map(|sc| argmax(&sc.logits) as u32).collect();
    let mut graph_cache = DecodeGraphCache::new();
    let mut builds = 0u64;
    let mut trace: Vec<f32> = Vec::new();
    let t0 = Instant::now();
    for step in 0..steps {
        let mut items: Vec<DecodeItem> = caches
            .iter_mut()
            .zip(states.iter_mut())
            .zip(scratches.iter_mut())
            .enumerate()
            .map(|(i, ((cache, state), scratch))| DecodeItem {
                token: next[i],
                pos: prompts[i].len() + step,
                cache,
                state,
                scratch,
            })
            .collect();
        let stats =
            model.decode_batch(&mut items, serve, sel_ref(&sel), pool, workers, &mut graph_cache);
        builds += stats.graph_builds;
        drop(items);
        for (i, n) in next.iter_mut().enumerate() {
            *n = argmax(&scratches[i].logits) as u32;
        }
        for sc in &scratches {
            trace.extend_from_slice(&sc.logits);
        }
    }
    (t0.elapsed().as_secs_f64(), builds, trace)
}

fn main() {
    let iters = env_usize("HATA_BENCH_ITERS", 1).max(1);
    let ctx = env_usize("HATA_FIG8_CTX", 128);
    let steps = env_usize("HATA_FIG8_STEPS", 32);
    let layer_counts = env_list("HATA_FIG8_LAYERS", &[2, 4, 8, 16]);
    let batches = env_list("HATA_FIG8_BATCHES", &[1, 4, 8]);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4);
    let base_cfg = preset("hata-gqa").unwrap();
    let serve_base =
        ServeConfig { method: Method::Hata, budget: 32, threads, ..Default::default() };

    let mut table = hata::bench::report::Table::new(
        &format!(
            "Fig 8 steady-state: {steps} decode steps after a {ctx}-token prefill, \
             graph cache off vs on (hata-gqa shape × layers, threads={threads}, min of {iters})"
        ),
        &[
            "layers",
            "batch",
            "off_ms_per_step",
            "on_ms_per_step",
            "speedup",
            "builds_per_step_off",
            "builds_per_step_on",
            "bitwise_equal",
        ],
    );
    for &n_layers in &layer_counts {
        let mut cfg = base_cfg.clone();
        cfg.name = format!("hata-gqa-l{n_layers}");
        cfg.n_layers = n_layers;
        let mut rng = Rng::new(13);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve_base, None, 1);
        let model = Model::new(cfg, weights, aux);
        for &batch in &batches {
            let prompts: Vec<Vec<u32>> = (0..batch)
                .map(|s| (0..ctx).map(|i| 32 + ((i + s * 7) as u32 % 64)).collect())
                .collect();
            let pool = ThreadPool::new(threads);
            let mut workers: Vec<WorkerScratch> =
                (0..threads).map(|_| WorkerScratch::default()).collect();
            let mut cell = |graph_cache: bool| -> (f64, u64, Vec<f32>) {
                let serve = ServeConfig { graph_cache, ..serve_base.clone() };
                let mut best = f64::INFINITY;
                let mut builds = 0;
                let mut trace = Vec::new();
                for _ in 0..iters {
                    let (secs, b, t) =
                        run_decode(&model, &serve, &prompts, steps, &pool, &mut workers);
                    best = best.min(secs);
                    builds = b;
                    trace = t;
                }
                (best, builds, trace)
            };
            let (off_s, off_builds, off_trace) = cell(false);
            let (on_s, on_builds, on_trace) = cell(true);
            assert_eq!(
                off_trace, on_trace,
                "graph cache changed decode logits (layers={n_layers}, batch={batch})"
            );
            assert_eq!(
                off_builds, steps as u64,
                "cache-off must rebuild every step (layers={n_layers}, batch={batch})"
            );
            assert_eq!(
                on_builds, 1,
                "cache-on must build exactly once (layers={n_layers}, batch={batch})"
            );
            table.row(vec![
                n_layers.to_string(),
                batch.to_string(),
                hata::bench::report::fmt(off_s / steps as f64 * 1e3),
                hata::bench::report::fmt(on_s / steps as f64 * 1e3),
                hata::bench::report::fmt(off_s / on_s),
                hata::bench::report::fmt(off_builds as f64 / steps as f64),
                hata::bench::report::fmt(on_builds as f64 / steps as f64),
                "yes".into(),
            ]);
            eprintln!("[fig8] layers={n_layers} batch={batch} done");
        }
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig8_steady_state").unwrap();
}
