//! Microbenchmarks of the HATA hot-path primitives — the §Perf working
//! set (EXPERIMENTS.md §Perf records before/after from this bench).

use hata::attention::hamming::{scores_group, scores_scalar, scores_word};
use hata::attention::hashenc::{encode_fused, encode_fused_blocked, encode_unfused};
use hata::attention::topk::{topk_counting, topk_heap, topk_quickselect};
use hata::bench::harness::bench;
use hata::bench::report::{fmt, Table};
use hata::util::rng::Rng;

fn main() {
    let iters: usize =
        std::env::var("HATA_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let s = 1 << 18; // 262144 tokens
    let rbit = 128;
    let words = rbit / 64;
    let dh = 128;
    let mut rng = Rng::new(0);
    let codes: Vec<u64> = (0..s * words).map(|_| rng.next_u64()).collect();
    let q: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let q4: Vec<u64> = (0..4 * words).map(|_| rng.next_u64()).collect();
    let x = rng.normal_vec(dh);
    let w = rng.normal_vec(dh * rbit);
    let fscores: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
    let budget = (s as f64 * 0.0156) as usize;

    let mut table = Table::new(
        &format!("microbench (s={s}, rbit={rbit}, dh={dh}, k={budget})"),
        &["primitive", "ms", "GB/s or Melem/s"],
    );
    let mut iscores = Vec::new();
    let bytes = (s * words * 8) as f64;

    let r = bench("hamming scalar", 1, iters.min(2), || {
        scores_scalar(&q, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_scalar".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let r = bench("hamming word", 2, iters, || {
        scores_word(&q, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_word".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let r = bench("hamming group4", 2, iters, || {
        scores_group(&q4, 4, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_group4".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let mut out = Vec::new();
    let r = bench("encode unfused", 2, iters, || {
        out.clear();
        encode_unfused(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_unfused".into(), fmt(r.mean_s * 1e3), "-".into()]);
    let r = bench("encode fused", 2, iters, || {
        out.clear();
        encode_fused(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_fused".into(), fmt(r.mean_s * 1e3), "-".into()]);
    let r = bench("encode fused blocked", 2, iters, || {
        out.clear();
        encode_fused_blocked(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_fused_blocked".into(), fmt(r.mean_s * 1e3), "-".into()]);

    let mut idx = Vec::new();
    let mut perm = Vec::new();
    let mut hist = Vec::new();
    scores_word(&q, &codes, rbit, &mut iscores);
    let r = bench("topk heap (f32)", 2, iters, || {
        topk_heap(&fscores, budget, &mut idx);
    });
    table.row(vec!["topk_heap".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);
    let r = bench("topk quickselect (f32)", 2, iters, || {
        topk_quickselect(&fscores, budget, &mut perm, &mut idx);
    });
    table.row(vec!["topk_quickselect".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);
    let r = bench("topk counting (i32 hamming)", 2, iters, || {
        topk_counting(&iscores, rbit as i32, budget, &mut hist, &mut idx);
    });
    table.row(vec!["topk_counting".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);

    println!("{}", table.render());
    table.write_csv("bench_results", "microbench").unwrap();
}
