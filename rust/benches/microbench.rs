//! Microbenchmarks of the HATA hot-path primitives — the §Perf working
//! set (EXPERIMENTS.md §Perf records before/after from this bench).
//!
//! Two tables: the integer/code primitives (hamming, encode, top-k), and
//! the float kernel layer swept across the `--kernels` tiers (Reference /
//! Simd / SimdFma, tensor/simd.rs) with measured GB/s and GFLOP/s next
//! to the `simulator::roofline` CPU bound for the same traffic and work.

use hata::attention::compute::{
    dense_attention, prefill_tile_attention, sparse_attention_fused, PrefillTile,
};
use hata::attention::hamming::{scores_group, scores_scalar, scores_word};
use hata::attention::hashenc::{encode_fused, encode_fused_blocked, encode_unfused};
use hata::attention::topk::{topk_counting, topk_heap, topk_quickselect};
use hata::bench::harness::{bench, LayerFixture};
use hata::bench::report::{fmt, roofline_cells, ROOFLINE_HEADER, Table};
use hata::simulator::roofline::{float_kernel, Device, KernelEstimate};
use hata::tensor::simd::{self, backend_name, KernelMode};
use hata::util::rng::Rng;

/// The seed-era vecmat with the `xi == 0.0` skip branch, kept here so the
/// branch-removal win stays measurable against the branch-free kernels.
fn vecmat_branchy(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &a[i * m..(i + 1) * m];
        for (yy, &aij) in y.iter_mut().zip(row) {
            *yy += xi * aij;
        }
    }
}

/// Bench one float kernel in all three `--kernels` modes and append a row
/// per mode: ms, speedup vs Reference, and the shared roofline columns.
fn run_modes(
    table: &mut Table,
    name: &str,
    est: &KernelEstimate,
    iters: usize,
    mut f: impl FnMut(KernelMode),
) {
    let mut ref_s = None;
    for mode in KernelMode::all() {
        let r = bench(name, 1, iters, || f(mode));
        let base = *ref_s.get_or_insert(r.mean_s);
        let mut row = vec![
            name.to_string(),
            mode.name().to_string(),
            fmt(r.mean_s * 1e3),
            fmt(base / r.mean_s),
        ];
        row.extend(roofline_cells(est, r.mean_s));
        table.row(row);
    }
    eprintln!("[microbench] {name} done");
}

fn main() {
    let iters: usize =
        std::env::var("HATA_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let s = 1 << 18; // 262144 tokens
    let rbit = 128;
    let words = rbit / 64;
    let dh = 128;
    let mut rng = Rng::new(0);
    let codes: Vec<u64> = (0..s * words).map(|_| rng.next_u64()).collect();
    let q: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let q4: Vec<u64> = (0..4 * words).map(|_| rng.next_u64()).collect();
    let x = rng.normal_vec(dh);
    let w = rng.normal_vec(dh * rbit);
    let fscores: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
    let budget = (s as f64 * 0.0156) as usize;

    let mut table = Table::new(
        &format!("microbench (s={s}, rbit={rbit}, dh={dh}, k={budget})"),
        &["primitive", "ms", "GB/s or Melem/s"],
    );
    let mut iscores = Vec::new();
    let bytes = (s * words * 8) as f64;

    let r = bench("hamming scalar", 1, iters.min(2), || {
        scores_scalar(&q, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_scalar".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let r = bench("hamming word", 2, iters, || {
        scores_word(&q, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_word".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let r = bench("hamming group4", 2, iters, || {
        scores_group(&q4, 4, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_group4".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let mut out = Vec::new();
    let r = bench("encode unfused", 2, iters, || {
        out.clear();
        encode_unfused(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_unfused".into(), fmt(r.mean_s * 1e3), "-".into()]);
    let r = bench("encode fused", 2, iters, || {
        out.clear();
        encode_fused(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_fused".into(), fmt(r.mean_s * 1e3), "-".into()]);
    let r = bench("encode fused blocked", 2, iters, || {
        out.clear();
        encode_fused_blocked(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_fused_blocked".into(), fmt(r.mean_s * 1e3), "-".into()]);

    let mut idx = Vec::new();
    let mut perm = Vec::new();
    let mut hist = Vec::new();
    scores_word(&q, &codes, rbit, &mut iscores);
    let r = bench("topk heap (f32)", 2, iters, || {
        topk_heap(&fscores, budget, &mut idx);
    });
    table.row(vec!["topk_heap".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);
    let r = bench("topk quickselect (f32)", 2, iters, || {
        topk_quickselect(&fscores, budget, &mut perm, &mut idx);
    });
    table.row(vec!["topk_quickselect".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);
    let r = bench("topk counting (i32 hamming)", 2, iters, || {
        topk_counting(&iscores, rbit as i32, budget, &mut hist, &mut idx);
    });
    table.row(vec!["topk_counting".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);

    println!("{}", table.render());
    table.write_csv("bench_results", "microbench").unwrap();

    // ---- float kernel layer x --kernels modes, with roofline columns
    let dev = Device::cpu();
    let mut header: Vec<&str> = vec!["kernel", "mode", "ms", "speedup_vs_ref"];
    header.extend_from_slice(&ROOFLINE_HEADER);
    let mut ft = Table::new(
        &format!("float kernels x --kernels mode (simd backend: {})", backend_name()),
        &header,
    );

    // vecmat at the decode projection shape (hidden x hidden row-major)
    let (n, m) = (1024usize, 1024usize);
    let xv = rng.normal_vec(n);
    let wv = rng.normal_vec(n * m);
    let mut yv = vec![0.0f32; m];
    let est = float_kernel(&dev, ((n * m + n + m) * 4) as f64, (2 * n * m) as f64);
    let r = bench("vecmat branchy", 1, iters, || {
        vecmat_branchy(&xv, &wv, m, &mut yv);
    });
    let mut row =
        vec!["vecmat_1024x1024".into(), "branchy-seed".into(), fmt(r.mean_s * 1e3), "-".into()];
    row.extend(roofline_cells(&est, r.mean_s));
    ft.row(row);
    run_modes(&mut ft, "vecmat_1024x1024", &est, iters, |mode| {
        simd::vecmat(mode, &xv, &wv, m, &mut yv);
    });

    // long dot product (memory-streaming shape)
    let nbig = 1 << 20;
    let av = rng.normal_vec(nbig);
    let bv = rng.normal_vec(nbig);
    let est = float_kernel(&dev, (2 * nbig * 4) as f64, (2 * nbig) as f64);
    run_modes(&mut ft, "dot_1M", &est, iters, |mode| {
        std::hint::black_box(simd::dot(mode, &av, &bv));
    });

    // decode attention kernels at dh=128 over a 4K context
    let sa = 4096usize;
    let fx = LayerFixture::new(sa, dh, 1, rbit, 11);
    let mut probs = Vec::new();
    let mut aout = vec![0.0f32; dh];
    let est = float_kernel(&dev, (2 * sa * dh * 4) as f64, (4 * sa * dh) as f64);
    run_modes(&mut ft, "dense_attn_s4096", &est, iters, |mode| {
        dense_attention(mode, &fx.inputs(), &mut probs, &mut aout);
    });

    let k = 256usize;
    let sel: Vec<u32> = (0..sa as u32).step_by(sa / k).collect();
    let est = float_kernel(&dev, (2 * k * dh * 4) as f64, (4 * k * dh) as f64);
    run_modes(&mut ft, "sparse_fused_k256", &est, iters, |mode| {
        sparse_attention_fused(mode, &fx.inputs(), &sel, &mut probs, &mut aout);
    });

    // prefill tile: 32 query rows attending causally over a ~4K prefix
    let rows = 32usize;
    let start = sa - rows;
    let qt = rng.normal_vec(rows * dh);
    let mut tout = vec![0.0f32; rows * dh];
    let macs: usize = (0..rows).map(|r| start + r + 1).sum();
    let est = float_kernel(&dev, (2 * macs * dh * 4) as f64, (4 * macs * dh) as f64);
    run_modes(&mut ft, "prefill_tile_32rows", &est, iters, |mode| {
        let tile = PrefillTile {
            q: &qt,
            k: &fx.k,
            v: &fx.v,
            group: 1,
            dh,
            qstride: dh,
            qoff: 0,
            t0: 0,
            start,
            bt: &[],
            block_tokens: 0,
            kernels: mode,
        };
        prefill_tile_attention(&tile, &mut probs, &mut tout);
    });

    // elementwise kernels at the model hidden width, batched x64
    let gn = rng.normal_vec(1024);
    let xr = rng.normal_vec(1024);
    let mut yr = vec![0.0f32; 1024];
    let est = float_kernel(&dev, (64 * 3 * 1024 * 4) as f64, (64 * 3 * 1024) as f64);
    run_modes(&mut ft, "rms_norm_1024x64", &est, iters, |mode| {
        for _ in 0..64 {
            simd::rms_norm(mode, &xr, &gn, &mut yr, 1e-5);
        }
    });

    let mut sm = rng.normal_vec(4096);
    let est = float_kernel(&dev, (16 * 4096 * 8) as f64, (16 * 4096 * 8) as f64);
    run_modes(&mut ft, "softmax_4096x16", &est, iters, |mode| {
        for _ in 0..16 {
            simd::softmax(mode, &mut sm);
        }
    });

    let upv = rng.normal_vec(1024);
    let mut gate = rng.normal_vec(1024);
    let est = float_kernel(&dev, (64 * 3 * 1024 * 4) as f64, (64 * 6 * 1024) as f64);
    run_modes(&mut ft, "silu_mul_1024x64", &est, iters, |mode| {
        for _ in 0..64 {
            simd::silu_mul(mode, &mut gate, &upv);
        }
    });

    // in-bench guarantees: Simd is bit-identical to Reference; SimdFma
    // stays within fast-math tolerance (tensor/simd.rs tests bound ULPs)
    let mut o_ref = vec![0.0f32; dh];
    let mut o_simd = vec![0.0f32; dh];
    let mut o_fma = vec![0.0f32; dh];
    dense_attention(KernelMode::Reference, &fx.inputs(), &mut probs, &mut o_ref);
    dense_attention(KernelMode::Simd, &fx.inputs(), &mut probs, &mut o_simd);
    dense_attention(KernelMode::SimdFma, &fx.inputs(), &mut probs, &mut o_fma);
    assert!(
        o_ref.iter().zip(&o_simd).all(|(a, b)| a.to_bits() == b.to_bits()),
        "Simd must be bit-identical to Reference"
    );
    assert!(
        o_ref.iter().zip(&o_fma).all(|(a, b)| (a - b).abs() <= 1e-4 * a.abs().max(1.0)),
        "SimdFma drifted past fast-math tolerance"
    );

    println!("{}", ft.render());
    ft.write_csv("bench_results", "microbench_kernels").unwrap();
}
