//! Microbenchmarks of the HATA hot-path primitives — the §Perf working
//! set (EXPERIMENTS.md §Perf records before/after from this bench).
//!
//! Two tables: the integer/code primitives (hamming, encode, top-k), and
//! the float kernel layer swept across the `--kernels` tiers (Reference /
//! Simd / SimdFma, tensor/simd.rs) with measured GB/s and GFLOP/s next
//! to the `simulator::roofline` CPU bound for the same traffic and work.

use hata::attention::compute::{
    dense_attention, prefill_tile_attention, sparse_attention_fused, PrefillTile,
};
use hata::attention::hamming::{scores_group, scores_scalar, scores_word};
use hata::attention::hashenc::{encode_fused, encode_fused_blocked, encode_unfused};
use hata::attention::topk::{topk_counting, topk_heap, topk_quickselect};
use hata::bench::harness::{bench, LayerFixture};
use hata::bench::report::{
    fmt, int_roofline_cells, roofline_cells, Table, INT_ROOFLINE_HEADER, ROOFLINE_HEADER,
};
use hata::simulator::roofline::{
    float_kernel, float_kernel_dtype, int_kernel, Device, KernelEstimate,
};
use hata::tensor::simd::{self, backend_name, KernelMode, KvDtype};
use hata::util::rng::Rng;

/// The seed-era vecmat with the `xi == 0.0` skip branch, kept here so the
/// branch-removal win stays measurable against the branch-free kernels.
fn vecmat_branchy(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &a[i * m..(i + 1) * m];
        for (yy, &aij) in y.iter_mut().zip(row) {
            *yy += xi * aij;
        }
    }
}

/// Bench one float kernel in all three `--kernels` modes and append a row
/// per mode: ms, speedup vs Reference, and the shared roofline columns.
fn run_modes(
    table: &mut Table,
    name: &str,
    est: &KernelEstimate,
    iters: usize,
    mut f: impl FnMut(KernelMode),
) {
    let mut ref_s = None;
    for mode in KernelMode::all() {
        let r = bench(name, 1, iters, || f(mode));
        let base = *ref_s.get_or_insert(r.mean_s);
        let mut row = vec![
            name.to_string(),
            mode.name().to_string(),
            fmt(r.mean_s * 1e3),
            fmt(base / r.mean_s),
        ];
        row.extend(roofline_cells(est, r.mean_s));
        table.row(row);
    }
    eprintln!("[microbench] {name} done");
}

fn main() {
    let iters: usize =
        std::env::var("HATA_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let s = 1 << 18; // 262144 tokens
    let rbit = 128;
    let words = rbit / 64;
    let dh = 128;
    let mut rng = Rng::new(0);
    let codes: Vec<u64> = (0..s * words).map(|_| rng.next_u64()).collect();
    let q: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let q4: Vec<u64> = (0..4 * words).map(|_| rng.next_u64()).collect();
    let x = rng.normal_vec(dh);
    let w = rng.normal_vec(dh * rbit);
    let fscores: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
    let budget = (s as f64 * 0.0156) as usize;

    let mut table = Table::new(
        &format!("microbench (s={s}, rbit={rbit}, dh={dh}, k={budget})"),
        &["primitive", "ms", "GB/s or Melem/s"],
    );
    let mut iscores = Vec::new();
    let bytes = (s * words * 8) as f64;

    let r = bench("hamming scalar", 1, iters.min(2), || {
        scores_scalar(&q, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_scalar".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let r = bench("hamming word", 2, iters, || {
        scores_word(&q, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_word".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let r = bench("hamming group4", 2, iters, || {
        scores_group(KernelMode::Reference, &q4, 4, &codes, rbit, &mut iscores);
    });
    table.row(vec!["hamming_group4".into(), fmt(r.mean_s * 1e3), fmt(bytes / r.mean_s / 1e9)]);

    let mut out = Vec::new();
    let r = bench("encode unfused", 2, iters, || {
        out.clear();
        encode_unfused(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_unfused".into(), fmt(r.mean_s * 1e3), "-".into()]);
    let r = bench("encode fused", 2, iters, || {
        out.clear();
        encode_fused(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_fused".into(), fmt(r.mean_s * 1e3), "-".into()]);
    let r = bench("encode fused blocked", 2, iters, || {
        out.clear();
        encode_fused_blocked(&x, &w, rbit, &mut out);
    });
    table.row(vec!["encode_fused_blocked".into(), fmt(r.mean_s * 1e3), "-".into()]);

    let mut idx = Vec::new();
    let mut perm = Vec::new();
    let mut hist = Vec::new();
    scores_word(&q, &codes, rbit, &mut iscores);
    let r = bench("topk heap (f32)", 2, iters, || {
        topk_heap(&fscores, budget, &mut idx);
    });
    table.row(vec!["topk_heap".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);
    let r = bench("topk quickselect (f32)", 2, iters, || {
        topk_quickselect(&fscores, budget, &mut perm, &mut idx);
    });
    table.row(vec!["topk_quickselect".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);
    let r = bench("topk counting (i32 hamming)", 2, iters, || {
        topk_counting(&iscores, rbit as i32, budget, &mut hist, &mut idx);
    });
    table.row(vec!["topk_counting".into(), fmt(r.mean_s * 1e3), fmt(s as f64 / r.mean_s / 1e6)]);

    println!("{}", table.render());
    table.write_csv("bench_results", "microbench").unwrap();

    let dev = Device::cpu();

    // ---- vectorized Hamming scorer x --kernels mode (GOP/s roofline)
    let mut hh: Vec<&str> = vec!["primitive", "mode", "ms", "speedup_vs_ref"];
    hh.extend_from_slice(&INT_ROOFLINE_HEADER);
    let mut ht = Table::new(
        &format!("hamming scorer x --kernels mode (simd backend: {})", backend_name()),
        &hh,
    );
    // traffic: s key-code rows streamed once plus the i32 score column;
    // work: XOR + popcount + add per (query head, word) pair
    let hest = int_kernel(&dev, (s * words * 8 + s * 4) as f64, (4 * s * words * 3) as f64);
    let mut href = Vec::new();
    scores_group(KernelMode::Reference, &q4, 4, &codes, rbit, &mut href);
    let mut ref_ms = None;
    for mode in KernelMode::all() {
        let r = bench("hamming group4", 2, iters, || {
            scores_group(mode, &q4, 4, &codes, rbit, &mut iscores);
        });
        assert_eq!(iscores, href, "vectorized scorer diverged from scores_group reference");
        let base = *ref_ms.get_or_insert(r.mean_s);
        let mut row = vec![
            "hamming_group4".to_string(),
            mode.name().to_string(),
            fmt(r.mean_s * 1e3),
            fmt(base / r.mean_s),
        ];
        row.extend(int_roofline_cells(&hest, r.mean_s));
        ht.row(row);
    }
    println!("{}", ht.render());
    ht.write_csv("bench_results", "microbench_hamming").unwrap();

    // ---- float kernel layer x --kernels modes, with roofline columns
    let mut header: Vec<&str> = vec!["kernel", "mode", "ms", "speedup_vs_ref"];
    header.extend_from_slice(&ROOFLINE_HEADER);
    let mut ft = Table::new(
        &format!("float kernels x --kernels mode (simd backend: {})", backend_name()),
        &header,
    );

    // vecmat at the decode projection shape (hidden x hidden row-major)
    let (n, m) = (1024usize, 1024usize);
    let xv = rng.normal_vec(n);
    let wv = rng.normal_vec(n * m);
    let mut yv = vec![0.0f32; m];
    let est = float_kernel(&dev, ((n * m + n + m) * 4) as f64, (2 * n * m) as f64);
    let r = bench("vecmat branchy", 1, iters, || {
        vecmat_branchy(&xv, &wv, m, &mut yv);
    });
    let mut row =
        vec!["vecmat_1024x1024".into(), "branchy-seed".into(), fmt(r.mean_s * 1e3), "-".into()];
    row.extend(roofline_cells(&est, r.mean_s));
    ft.row(row);
    run_modes(&mut ft, "vecmat_1024x1024", &est, iters, |mode| {
        simd::vecmat(mode, &xv, &wv, m, &mut yv);
    });

    // long dot product (memory-streaming shape)
    let nbig = 1 << 20;
    let av = rng.normal_vec(nbig);
    let bv = rng.normal_vec(nbig);
    let est = float_kernel(&dev, (2 * nbig * 4) as f64, (2 * nbig) as f64);
    run_modes(&mut ft, "dot_1M", &est, iters, |mode| {
        std::hint::black_box(simd::dot(mode, &av, &bv));
    });

    // widening dot/axpy over packed half rows: the streamed operand is
    // widened in-register, so traffic (and the roofline bound) drops to
    // the dtype's width. Reference and Simd share the canonical
    // reduction order (bit-identical); SimdFma fuses and stays within
    // fast-math tolerance, mirroring the f32 tiers.
    for dtype in [KvDtype::Bf16, KvDtype::F16] {
        let mut pk = Vec::new();
        simd::pack_extend(dtype, &bv, &mut pk);
        let d_ref = simd::dot_wide(KernelMode::Reference, dtype, &av, &pk);
        let d_simd = simd::dot_wide(KernelMode::Simd, dtype, &av, &pk);
        assert_eq!(
            d_ref.to_bits(),
            d_simd.to_bits(),
            "dot_wide Simd diverged from reference ({})",
            dtype.name()
        );
        let est = float_kernel(&dev, (nbig * 4 + nbig * dtype.bytes()) as f64, (2 * nbig) as f64);
        run_modes(&mut ft, &format!("dot_wide_1M_{}", dtype.name()), &est, iters, |mode| {
            std::hint::black_box(simd::dot_wide(mode, dtype, &av, &pk));
        });

        let mut y_ref = av.clone();
        let mut y_fma = av.clone();
        simd::axpy_wide(KernelMode::Reference, dtype, 0.5, &pk, &mut y_ref);
        simd::axpy_wide(KernelMode::Simd, dtype, 0.5, &pk, &mut y_fma);
        assert!(
            y_ref.iter().zip(&y_fma).all(|(a, b)| a.to_bits() == b.to_bits()),
            "axpy_wide Simd diverged from reference ({})",
            dtype.name()
        );
        y_fma.copy_from_slice(&av);
        simd::axpy_wide(KernelMode::SimdFma, dtype, 0.5, &pk, &mut y_fma);
        assert!(
            y_ref.iter().zip(&y_fma).all(|(a, b)| (a - b).abs() <= 1e-5 * a.abs().max(1e-3)),
            "axpy_wide SimdFma drifted past fast-math tolerance ({})",
            dtype.name()
        );
        let mut yw = vec![0.0f32; nbig];
        let est = float_kernel(&dev, (nbig * 8 + nbig * dtype.bytes()) as f64, (2 * nbig) as f64);
        run_modes(&mut ft, &format!("axpy_wide_1M_{}", dtype.name()), &est, iters, |mode| {
            simd::axpy_wide(mode, dtype, 0.5, &pk, &mut yw);
        });
    }

    // decode attention kernels at dh=128 over a 4K context
    let sa = 4096usize;
    let fx = LayerFixture::new(sa, dh, 1, rbit, 11);
    let mut probs = Vec::new();
    let mut aout = vec![0.0f32; dh];
    let est = float_kernel(&dev, (2 * sa * dh * 4) as f64, (4 * sa * dh) as f64);
    run_modes(&mut ft, "dense_attn_s4096", &est, iters, |mode| {
        dense_attention(mode, &fx.inputs(), &mut probs, &mut aout);
    });

    // decode attention across --kv-dtype widths: packed half K/V rows
    // halve the streamed bytes, so at the bandwidth roof the same
    // KernelMode runs up to 2x faster (the perf gate asks >= 1.5x for
    // bf16 vs f32 at a fixed mode). Selection is dtype-independent; the
    // packed run must match attention over the widened-f32 copy bit for
    // bit at Reference and Simd.
    for dtype in [KvDtype::Bf16, KvDtype::F16] {
        let mut kp = Vec::new();
        let mut vp = Vec::new();
        simd::pack_extend(dtype, &fx.k, &mut kp);
        simd::pack_extend(dtype, &fx.v, &mut vp);
        let mut wk = Vec::new();
        let mut wv = Vec::new();
        simd::widen_extend(dtype, &kp, &mut wk);
        simd::widen_extend(dtype, &vp, &mut wv);
        let mut inp = fx.inputs();
        inp.k = &kp;
        inp.v = &vp;
        inp.kv_dtype = dtype;
        let mut winp = fx.inputs();
        winp.k = &wk;
        winp.v = &wv;
        for mode in [KernelMode::Reference, KernelMode::Simd] {
            let mut o_packed = vec![0.0f32; dh];
            let mut o_wide = vec![0.0f32; dh];
            dense_attention(mode, &inp, &mut probs, &mut o_packed);
            dense_attention(mode, &winp, &mut probs, &mut o_wide);
            assert!(
                o_packed.iter().zip(&o_wide).all(|(a, b)| a.to_bits() == b.to_bits()),
                "packed {} attention diverged from widened f32 ({mode:?})",
                dtype.name()
            );
        }
        let est = float_kernel_dtype(&dev, dtype, (2 * sa * dh) as f64, (4 * sa * dh) as f64);
        let name = format!("dense_attn_s4096_{}", dtype.name());
        run_modes(&mut ft, &name, &est, iters, |mode| {
            dense_attention(mode, &inp, &mut probs, &mut aout);
        });
        if dtype == KvDtype::Bf16 {
            let t32 = bench("dense_attn f32 simd", 1, iters, || {
                dense_attention(KernelMode::Simd, &fx.inputs(), &mut probs, &mut aout);
            })
            .mean_s;
            let t16 = bench("dense_attn bf16 simd", 1, iters, || {
                dense_attention(KernelMode::Simd, &inp, &mut probs, &mut aout);
            })
            .mean_s;
            eprintln!("[microbench] decode-attention bf16 vs f32 at Simd: {:.2}x", t32 / t16);
        }
    }

    let k = 256usize;
    let sel: Vec<u32> = (0..sa as u32).step_by(sa / k).collect();
    let est = float_kernel(&dev, (2 * k * dh * 4) as f64, (4 * k * dh) as f64);
    run_modes(&mut ft, "sparse_fused_k256", &est, iters, |mode| {
        sparse_attention_fused(mode, &fx.inputs(), &sel, &mut probs, &mut aout);
    });

    // prefill tile: 32 query rows attending causally over a ~4K prefix
    let rows = 32usize;
    let start = sa - rows;
    let qt = rng.normal_vec(rows * dh);
    let mut tout = vec![0.0f32; rows * dh];
    let macs: usize = (0..rows).map(|r| start + r + 1).sum();
    let est = float_kernel(&dev, (2 * macs * dh * 4) as f64, (4 * macs * dh) as f64);
    run_modes(&mut ft, "prefill_tile_32rows", &est, iters, |mode| {
        let tile = PrefillTile {
            q: &qt,
            k: &fx.k,
            v: &fx.v,
            group: 1,
            dh,
            qstride: dh,
            qoff: 0,
            t0: 0,
            start,
            bt: &[],
            block_tokens: 0,
            kv_dtype: KvDtype::F32,
            kernels: mode,
        };
        prefill_tile_attention(&tile, &mut probs, &mut tout);
    });

    // elementwise kernels at the model hidden width, batched x64
    let gn = rng.normal_vec(1024);
    let xr = rng.normal_vec(1024);
    let mut yr = vec![0.0f32; 1024];
    let est = float_kernel(&dev, (64 * 3 * 1024 * 4) as f64, (64 * 3 * 1024) as f64);
    run_modes(&mut ft, "rms_norm_1024x64", &est, iters, |mode| {
        for _ in 0..64 {
            simd::rms_norm(mode, &xr, &gn, &mut yr, 1e-5);
        }
    });

    let mut sm = rng.normal_vec(4096);
    let est = float_kernel(&dev, (16 * 4096 * 8) as f64, (16 * 4096 * 8) as f64);
    run_modes(&mut ft, "softmax_4096x16", &est, iters, |mode| {
        for _ in 0..16 {
            simd::softmax(mode, &mut sm);
        }
    });

    let upv = rng.normal_vec(1024);
    let mut gate = rng.normal_vec(1024);
    let est = float_kernel(&dev, (64 * 3 * 1024 * 4) as f64, (64 * 6 * 1024) as f64);
    run_modes(&mut ft, "silu_mul_1024x64", &est, iters, |mode| {
        for _ in 0..64 {
            simd::silu_mul(mode, &mut gate, &upv);
        }
    });

    // in-bench guarantees: Simd is bit-identical to Reference; SimdFma
    // stays within fast-math tolerance (tensor/simd.rs tests bound ULPs)
    let mut o_ref = vec![0.0f32; dh];
    let mut o_simd = vec![0.0f32; dh];
    let mut o_fma = vec![0.0f32; dh];
    dense_attention(KernelMode::Reference, &fx.inputs(), &mut probs, &mut o_ref);
    dense_attention(KernelMode::Simd, &fx.inputs(), &mut probs, &mut o_simd);
    dense_attention(KernelMode::SimdFma, &fx.inputs(), &mut probs, &mut o_fma);
    assert!(
        o_ref.iter().zip(&o_simd).all(|(a, b)| a.to_bits() == b.to_bits()),
        "Simd must be bit-identical to Reference"
    );
    assert!(
        o_ref.iter().zip(&o_fma).all(|(a, b)| (a - b).abs() <= 1e-4 * a.abs().max(1.0)),
        "SimdFma drifted past fast-math tolerance"
    );

    println!("{}", ft.render());
    ft.write_csv("bench_results", "microbench_kernels").unwrap();
}
