//! Table 3 reproduction: KV-offloading — HATA-off vs MagicPIG-style.
//!
//! Paper testbed: PCIe 4.0, 48 CPU threads; Llama2 @36K prefill + 500
//! decode and Llama3.1 @72K + 500 decode, budgets 1.56% (HATA-off) and
//! 2-3% sampled (MagicPIG). Cost models in kvcache/offload.rs (the
//! substitution ledger is documented in DESIGN.md §4).

use hata::bench::report::{fmt, Table};
use hata::config::preset;
use hata::kvcache::offload::{hata_off, magicpig_off, OffloadRates};

fn main() {
    let rates = OffloadRates::paper_testbed();
    let mut table = Table::new(
        "Table 3 proxy: offloading time (modeled, PCIe 4.0 testbed)",
        &["model", "method", "prefill_s", "decode_s", "total_s", "pcie_GB"],
    );
    for (model, prefill_len) in [("mirror-llama2-7b", 36_000), ("mirror-llama31-8b", 72_000)] {
        let cfg = preset(model).unwrap();
        let decode_len = 500;
        let hb = ((prefill_len as f64) * 0.0156) as usize;
        let mb = ((prefill_len as f64) * 0.025) as usize; // MagicPIG ~2-3%
        let h = hata_off(&cfg, &rates, prefill_len, decode_len, hb);
        let m = magicpig_off(&cfg, &rates, prefill_len, decode_len, mb);
        for (name, rep) in [("HATA-off", h), ("MagicPIG", m)] {
            table.row(vec![
                model.to_string(),
                name.to_string(),
                fmt(rep.prefill_seconds),
                fmt(rep.decode_seconds),
                fmt(rep.total()),
                fmt(rep.ledger.bytes as f64 / 1e9),
            ]);
        }
        let speed_p = m.prefill_seconds / h.prefill_seconds;
        let speed_d = m.decode_seconds / h.decode_seconds;
        eprintln!("[table3] {model}: HATA-off speedup prefill {speed_p:.2}x decode {speed_d:.2}x");
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "table3").unwrap();
}
