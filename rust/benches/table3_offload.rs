//! Table 3 reproduction: KV-offloading — HATA-off vs MagicPIG-style —
//! now in two halves:
//!
//! 1. **Analytical** (paper scale): the fixed cost models in
//!    kvcache/offload.rs priced on the paper's PCIe 4.0 testbed, Llama2
//!    @36K prefill + 500 decode and Llama3.1 @72K + 500 decode.
//! 2. **Live** (scaled down): the real residency tier (`--offload`)
//!    running inside the serving engine on the hata-gqa preset under
//!    maximum offload pressure (budget 0). Every fetch pass is metered
//!    twice — a modeled ledger priced by the same fixed PCIe model the
//!    analytical half uses, and measured wall-clock seconds of the
//!    actual slow-tier copies — and the figure reports the prediction
//!    error between them.
//!
//! **Stated bound**: in-process slow-tier copies are strictly faster
//! than a real PCIe link, so measured seconds must land in the sandwich
//! `0.25 * bytes/calibrated_memcpy_bw <= measured <= modeled`, where
//! the ceiling is the fixed analytical model (its 10 µs per-descriptor
//! DMA latency dominates small-block gathers) and the floor is the
//! machine's own measured copy bandwidth with 4x slack for scattered
//! sub-block copies. The run asserts this sandwich and prints the error.

use std::sync::Arc;
use std::time::Instant;

use hata::bench::report::{fmt, Table};
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::Request;
use hata::kvcache::offload::{hata_off, magicpig_off, OffloadRates};
use hata::kvcache::tier::OffloadStats;
use hata::kvcache::MethodAux;
use hata::model::{weights::Weights, Model};
use hata::util::rng::Rng;

/// Best-case in-process copy bandwidth (bytes/s), measured on a few
/// contiguous 8 MB memcpys — the floor of the live sandwich bound.
fn calibrate_memcpy_bw() -> f64 {
    let src = vec![1.0f32; 2 << 20];
    let mut dst = vec![0.0f32; 2 << 20];
    let bytes = src.len() * 4;
    // warm up, then take the best of 5 (least-disturbed) passes
    dst.copy_from_slice(&src);
    let mut best = f64::MAX;
    for _ in 0..5 {
        let t0 = Instant::now();
        dst.copy_from_slice(&src);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(&dst);
    bytes as f64 / best.max(1e-12)
}

/// Run the live tiered engine on a small trace; returns the final tier
/// counters and total wall seconds of the run.
fn run_live(method: Method) -> (OffloadStats, f64) {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 4,
        prefill_chunk: 48,
        threads: 2,
        kv_block: 4,
        offload: true,
        offload_budget: 0,
        prefetch_depth: 1,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let model = Model::new(cfg, weights, aux);
    let mut engine = Engine::new(Arc::new(model), serve);
    let mut rng = Rng::new(7);
    for id in 0..6u64 {
        let plen = 48 + rng.below(32) as usize;
        engine.submit(Request {
            id,
            prompt: (0..plen).map(|_| 32 + rng.below(64) as u32).collect(),
            max_new_tokens: 12,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let t0 = Instant::now();
    let responses = engine.run_to_completion();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), 6, "{method:?}: every request must complete");
    let stats = engine.metrics.offload.expect("offload run reports tier stats");
    eprintln!("[table3] live {method:?}: {}", engine.metrics.report());
    (stats, wall)
}

fn main() {
    // ---- analytical half: the paper's testbed, paper-scale contexts
    let rates = OffloadRates::paper_testbed();
    let mut table = Table::new(
        "Table 3 proxy: offloading time (modeled, PCIe 4.0 testbed)",
        &["model", "method", "prefill_s", "decode_s", "total_s", "pcie_GB"],
    );
    for (model, prefill_len) in [("mirror-llama2-7b", 36_000), ("mirror-llama31-8b", 72_000)] {
        let cfg = preset(model).unwrap();
        let decode_len = 500;
        let hb = ((prefill_len as f64) * 0.0156) as usize;
        let mb = ((prefill_len as f64) * 0.025) as usize; // MagicPIG ~2-3%
        let h = hata_off(&cfg, &rates, prefill_len, decode_len, hb);
        let m = magicpig_off(&cfg, &rates, prefill_len, decode_len, mb);
        for (name, rep) in [("HATA-off", h), ("MagicPIG", m)] {
            table.row(vec![
                model.to_string(),
                name.to_string(),
                fmt(rep.prefill_seconds),
                fmt(rep.decode_seconds),
                fmt(rep.total()),
                fmt(rep.ledger.bytes as f64 / 1e9),
            ]);
        }
        let speed_p = m.prefill_seconds / h.prefill_seconds;
        let speed_d = m.decode_seconds / h.decode_seconds;
        eprintln!("[table3] {model}: HATA-off speedup prefill {speed_p:.2}x decode {speed_d:.2}x");
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "table3").unwrap();

    // ---- live half: the residency tier under the real engine
    let memcpy_bw = calibrate_memcpy_bw();
    eprintln!("[table3] calibrated in-process copy bandwidth: {:.1} GB/s", memcpy_bw / 1e9);
    let cols = [
        "method", "fetches", "prefetch", "evicts", "fetch_MB", "model_s", "floor_s", "wall_s",
        "wall/model",
    ];
    let mut live = Table::new(
        "Table 3 live: residency tier, modeled vs measured fetch seconds (budget 0)",
        &cols,
    );
    for method in [Method::Hata, Method::Dense, Method::Quest] {
        let (o, _wall) = run_live(method);
        let fetched = o.demand_fetches + o.prefetch_fetches;
        assert!(fetched > 0 && o.evictions > 0, "{method:?}: tier must actually run");
        let modeled = o.fetch.seconds;
        let floor = 0.25 * o.fetch.bytes as f64 / memcpy_bw;
        let measured = o.measured_fetch_s;
        // the stated bound: fixed-PCIe model is a ceiling, calibrated
        // copy bandwidth (with 4x scatter slack) a floor
        assert!(
            measured <= modeled,
            "{method:?}: measured {measured:.6}s exceeded the PCIe-model ceiling {modeled:.6}s"
        );
        assert!(
            measured >= floor,
            "{method:?}: measured {measured:.9}s under the copy-bandwidth floor {floor:.9}s"
        );
        live.row(vec![
            format!("{method:?}"),
            fetched.to_string(),
            o.prefetch_fetches.to_string(),
            o.evictions.to_string(),
            fmt(o.fetch.bytes as f64 / 1e6),
            fmt(modeled),
            fmt(floor),
            fmt(measured),
            fmt(measured / modeled),
        ]);
        eprintln!(
            "[table3] live {method:?}: error {:.1}% (measured {:.3} ms, modeled {:.3} ms)",
            100.0 * (modeled - measured) / modeled,
            measured * 1e3,
            modeled * 1e3,
        );
    }
    println!("{}", live.render());
    live.write_csv("bench_results", "table3_live").unwrap();
}
