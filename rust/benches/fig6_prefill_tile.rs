//! Prefill-tiling sweep: wall time of a long-prompt prefill under the
//! block-tiled threadpool path (`Model::prefill_batch`, engine chunking
//! emulated) across tile sizes and thread counts, against the token-serial
//! baseline (`Model::prefill_serial`).
//!
//! The tiled path is bit-identical to the baseline for every
//! (tile, threads) cell — the sweep only moves wall time — and the last
//! column asserts it by comparing final logits exactly.
//!
//! Env: HATA_BENCH_ITERS (default 1), HATA_PREFILL_LEN (default 4096),
//! HATA_PREFILL_CHUNK (default 512).

use std::time::Instant;

use hata::bench::report::{fmt, Table};
use hata::config::{preset, Method, ServeConfig};
use hata::kvcache::{MethodAux, SeqKvCache};
use hata::model::{weights::Weights, DecodeScratch, Model, PrefillItem, SeqState, WorkerScratch};
use hata::util::rng::Rng;
use hata::util::threadpool::ThreadPool;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let iters = env_usize("HATA_BENCH_ITERS", 1).max(1);
    let s = env_usize("HATA_PREFILL_LEN", 4096);
    let chunk = env_usize("HATA_PREFILL_CHUNK", 512).max(1);
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method: Method::Hata,
        budget: 64,
        prefill_chunk: chunk,
        ..Default::default()
    };
    let mut rng = Rng::new(9);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let model = Model::new(cfg, weights, aux);
    let prompt: Vec<u32> = (0..s as u32).map(|i| 32 + (i % 64)).collect();
    let mut scratch = DecodeScratch::new(&model.cfg);

    // ---- token-serial baseline
    let mut serial_secs = f64::INFINITY;
    let mut serial_logits = Vec::new();
    for _ in 0..iters {
        let mut cache = SeqKvCache::new(&model.cfg, &serve);
        let mut state = SeqState::new(&model.cfg);
        let t0 = Instant::now();
        model.prefill_serial(&prompt, &mut cache, &mut state, &serve, &mut scratch);
        serial_secs = serial_secs.min(t0.elapsed().as_secs_f64());
        serial_logits = scratch.logits.clone();
    }
    eprintln!("[fig6] serial baseline done ({serial_secs:.3}s)");

    // ---- tiled path: engine-shaped chunking, PrefillItem per chunk
    let run_tiled = |threads: usize, tile: usize, scratch: &mut DecodeScratch| -> f64 {
        let pool = ThreadPool::new(threads);
        let mut workers: Vec<WorkerScratch> =
            (0..threads).map(|_| WorkerScratch::default()).collect();
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let t0 = Instant::now();
            let mut start = 0usize;
            while start < prompt.len() {
                let end = (start + chunk).min(prompt.len());
                let mut items = vec![PrefillItem {
                    tokens: &prompt[start..end],
                    start,
                    prompt_len: prompt.len(),
                    is_final: end == prompt.len(),
                    tile,
                    cache: &mut cache,
                    state: &mut state,
                    scratch: &mut *scratch,
                }];
                model.prefill_batch(&mut items, &serve, &pool, &mut workers);
                start = end;
            }
            best = best.min(t0.elapsed().as_secs_f64());
            assert_eq!(
                scratch.logits, serial_logits,
                "tiled prefill (threads={threads}, tile={tile}) diverged from serial"
            );
        }
        best
    };

    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    let mut table = Table::new(
        &format!(
            "Fig 6 prefill-tile sweep: {s}-token prompt, chunk={chunk} (hata-gqa, min of {iters})"
        ),
        &["path", "threads", "tile", "seconds", "speedup_vs_serial", "bitwise_equal"],
    );
    table.row(vec![
        "token-serial".into(),
        "1".into(),
        "-".into(),
        fmt(serial_secs),
        "1.00".into(),
        "-".into(),
    ]);
    let mut thread_counts = vec![1usize];
    if max_threads > 1 {
        thread_counts.push(max_threads);
    }
    for &threads in &thread_counts {
        for &tile in &[16usize, 32, 64, 128, 2 * chunk.max(1)] {
            let secs = run_tiled(threads, tile, &mut scratch);
            table.row(vec![
                "tiled".into(),
                threads.to_string(),
                tile.to_string(),
                fmt(secs),
                fmt(serial_secs / secs),
                "yes".into(),
            ]);
            eprintln!("[fig6] threads={threads} tile={tile} done ({secs:.3}s)");
        }
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig6_prefill_tile").unwrap();
}
