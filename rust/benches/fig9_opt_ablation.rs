//! Fig 9 reproduction: the hardware-optimization ablation.
//!
//! Paper: Simple -> +Score (hamming operator) -> +FusedAttn (gather fused
//! into FlashAttention) -> +Encode (fused hash encoding), Llama2 attention
//! at 128K ctx, 1.56% budget. CPU analogs (DESIGN.md §3):
//!   Simple     = scalar-popcount scoring + separate gather + unfused encode
//!   +Score     = packed-u64 POPCNT scoring
//!   +FusedAttn = gather folded into the attention pass
//!   +Encode    = fused projection+sign+bitpack
//!
//! The four paper rows run the float loops in `KernelMode::Reference` so
//! the ablation isolates the paper's optimizations; two extra rows then
//! switch the winning variant to the `Simd` and `SimdFma` kernel tiers
//! (`--kernels`, docs/PERFORMANCE.md). Every row reports measured GB/s
//! and GFLOP/s against the `simulator::roofline` CPU bound.

use hata::attention::compute::{sparse_attention_fused, sparse_attention_gather};
use hata::attention::hamming::{scores_scalar, scores_word};
use hata::attention::hashenc::{encode_fused_blocked, encode_unfused};
use hata::attention::topk::topk_counting;
use hata::bench::harness::{bench, LayerFixture};
use hata::bench::report::{fmt, roofline_cells, ROOFLINE_HEADER, Table};
use hata::simulator::roofline::{float_kernel, Device};
use hata::tensor::simd::{backend_name, KernelMode};

fn main() {
    let iters: usize =
        std::env::var("HATA_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let s = 131_072;
    let dh = 128;
    let rbit = 128;
    let budget = (s as f64 * 0.0156) as usize;
    let f = LayerFixture::new(s, dh, 1, rbit, 7);
    let mut iscores: Vec<i32> = Vec::new();
    let mut idx: Vec<u32> = Vec::new();
    let mut hist: Vec<u32> = Vec::new();
    let (mut kb, mut vb, mut probs) = (Vec::new(), Vec::new(), Vec::new());
    let mut out = vec![0.0f32; dh];
    let mut qc: Vec<u64> = Vec::new();

    // Step traffic/work for the roofline columns: the code stream and the
    // score write/re-read dominate bytes; the sparse qk+pv pass and the
    // one-row query encode dominate flops.
    let words = rbit / 64;
    let hbm = (s * words * 8 + s * 8 + 2 * budget * dh * 4) as f64;
    let flops = (4 * budget * dh + 2 * dh * rbit) as f64;
    let est = float_kernel(&Device::cpu(), hbm, flops);

    let variants: &[(&str, bool, bool, bool, KernelMode)] = &[
        ("Simple", false, false, false, KernelMode::Reference),
        ("+Score", false, true, false, KernelMode::Reference),
        ("+Score+FusedAttn", false, true, true, KernelMode::Reference),
        ("+Score+FusedAttn+Encode (HATA)", true, true, true, KernelMode::Reference),
        ("+Simd kernels", true, true, true, KernelMode::Simd),
        ("+SimdFma kernels", true, true, true, KernelMode::SimdFma),
    ];
    let mut header: Vec<&str> = vec!["variant", "ms/step", "speedup_vs_simple"];
    header.extend_from_slice(&ROOFLINE_HEADER);
    let mut table = Table::new(
        &format!("Fig 9 proxy: optimization ablation (ctx={s}, budget={budget}, dh={dh})"),
        &header,
    );
    eprintln!("[fig9] simd backend: {}", backend_name());
    let mut base = None;
    for &(name, enc, score, attn, mode) in variants {
        let r = bench(name, 1, iters, || {
            qc.clear();
            if enc {
                encode_fused_blocked(&f.q, &f.hash_w, rbit, &mut qc);
            } else {
                encode_unfused(&f.q, &f.hash_w, rbit, &mut qc);
            }
            if score {
                scores_word(&qc, &f.codes, rbit, &mut iscores);
            } else {
                scores_scalar(&qc, &f.codes, rbit, &mut iscores);
            }
            topk_counting(&iscores, rbit as i32, budget, &mut hist, &mut idx);
            let inp = f.inputs();
            if attn {
                sparse_attention_fused(mode, &inp, &idx, &mut probs, &mut out);
            } else {
                sparse_attention_gather(mode, &inp, &idx, &mut kb, &mut vb, &mut probs, &mut out);
            }
        });
        let b = *base.get_or_insert(r.mean_s);
        let mut row = vec![name.to_string(), fmt(r.mean_s * 1e3), fmt(b / r.mean_s)];
        row.extend(roofline_cells(&est, r.mean_s));
        table.row(row);
        eprintln!("[fig9] {name} done");
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig9").unwrap();
}
