//! Fig 9 reproduction: the hardware-optimization ablation.
//!
//! Paper: Simple -> +Score (hamming operator) -> +FusedAttn (gather fused
//! into FlashAttention) -> +Encode (fused hash encoding), Llama2 attention
//! at 128K ctx, 1.56% budget. CPU analogs (DESIGN.md §3):
//!   Simple     = scalar-popcount scoring + separate gather + unfused encode
//!   +Score     = packed-u64 POPCNT scoring
//!   +FusedAttn = gather folded into the attention pass
//!   +Encode    = fused projection+sign+bitpack

use hata::attention::compute::{sparse_attention_fused, sparse_attention_gather};
use hata::attention::hamming::{scores_scalar, scores_word};
use hata::attention::hashenc::{encode_fused_blocked, encode_unfused};
use hata::attention::topk::topk_counting;
use hata::bench::harness::{bench, LayerFixture};
use hata::bench::report::{fmt, Table};

fn main() {
    let iters: usize =
        std::env::var("HATA_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let s = 131_072;
    let dh = 128;
    let rbit = 128;
    let budget = (s as f64 * 0.0156) as usize;
    let f = LayerFixture::new(s, dh, 1, rbit, 7);
    let mut iscores: Vec<i32> = Vec::new();
    let mut idx: Vec<u32> = Vec::new();
    let mut hist: Vec<u32> = Vec::new();
    let (mut kb, mut vb, mut probs) = (Vec::new(), Vec::new(), Vec::new());
    let mut out = vec![0.0f32; dh];
    let mut qc: Vec<u64> = Vec::new();

    let variants: &[(&str, bool, bool, bool)] = &[
        ("Simple", false, false, false),
        ("+Score", false, true, false),
        ("+Score+FusedAttn", false, true, true),
        ("+Score+FusedAttn+Encode (HATA)", true, true, true),
    ];
    let mut table = Table::new(
        &format!("Fig 9 proxy: optimization ablation (ctx={s}, budget={budget}, dh={dh})"),
        &["variant", "ms/step", "speedup_vs_simple"],
    );
    let mut base = None;
    for &(name, enc, score, attn) in variants {
        let r = bench(name, 1, iters, || {
            qc.clear();
            if enc {
                encode_fused_blocked(&f.q, &f.hash_w, rbit, &mut qc);
            } else {
                encode_unfused(&f.q, &f.hash_w, rbit, &mut qc);
            }
            if score {
                scores_word(&qc, &f.codes, rbit, &mut iscores);
            } else {
                scores_scalar(&qc, &f.codes, rbit, &mut iscores);
            }
            topk_counting(&iscores, rbit as i32, budget, &mut hist, &mut idx);
            let inp = f.inputs();
            if attn {
                sparse_attention_fused(&inp, &idx, &mut probs, &mut out);
            } else {
                sparse_attention_gather(&inp, &idx, &mut kb, &mut vb, &mut probs, &mut out);
            }
        });
        let b = *base.get_or_insert(r.mean_s);
        table.row(vec![name.to_string(), fmt(r.mean_s * 1e3), fmt(b / r.mean_s)]);
        eprintln!("[fig9] {name} done");
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "fig9").unwrap();
}
