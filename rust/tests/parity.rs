//! Cross-language parity: Rust native engine vs JAX goldens, and the PJRT
//! runtime vs both. Gated on `artifacts/` (skips cleanly before
//! `make artifacts`).

use hata::config::manifest::Manifest;
use hata::config::{Method, ServeConfig};
use hata::kvcache::{MethodAux, SeqKvCache};
use hata::model::{make_selector, sel_ref, weights::Weights, DecodeScratch, Model, SeqState};
use hata::tensor::io::TensorStore;

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

fn load_model(name: &str, serve: &ServeConfig) -> Option<(Model, TensorStore)> {
    let m = manifest()?;
    let arts = m.model(name).ok()?;
    let mut w = Weights::load(&arts.weights, &arts.config).ok()?;
    w.load_hash(arts.hash_weights_for(arts.config.rbit)?, &arts.config).ok()?;
    let goldens = TensorStore::load(m.root.join(format!("{name}.goldens.npz"))).ok()?;
    let aux = MethodAux::build(&arts.config, serve, None, 7);
    Some((Model::new(arts.config.clone(), w, aux), goldens))
}

/// Hash-encode bit-parity: Rust packed u64 words vs Python uint32 pairs.
#[test]
fn hash_codes_match_python() {
    let serve = ServeConfig::default();
    let Some((model, g)) = load_model("hata-mha", &serve) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let x = g.f32("hash_in").unwrap();
    let w = g.f32("hash_w0").unwrap();
    let want_u32 = g.get("hash_codes").unwrap().as_u32().unwrap();
    let rbit = w.shape()[1];
    let rows = x.shape()[0];
    let mut got = Vec::new();
    for r in 0..rows {
        hata::attention::hashenc::encode_fused_blocked(x.row(r), w.data(), rbit, &mut got);
    }
    // little-endian: two u32 words per u64
    let words32 = rbit / 32;
    for r in 0..rows {
        for wd in 0..rbit / 64 {
            let lo = want_u32[r * words32 + 2 * wd] as u64;
            let hi = want_u32[r * words32 + 2 * wd + 1] as u64;
            assert_eq!(got[r * (rbit / 64) + wd], lo | (hi << 32), "row {r} word {wd}");
        }
    }
    let _ = model;
}

/// Hamming scores equal the Python oracle's.
#[test]
fn hamming_scores_match_python() {
    let serve = ServeConfig::default();
    let Some((_, g)) = load_model("hata-mha", &serve) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let codes_u32 = g.get("hash_codes").unwrap().as_u32().unwrap();
    let want = g.i32("hamming_scores").unwrap();
    let rbit = 128;
    let w64 = rbit / 64;
    let to_u64 = |row: &[u32]| -> Vec<u64> {
        (0..w64).map(|i| row[2 * i] as u64 | ((row[2 * i + 1] as u64) << 32)).collect()
    };
    let words32 = rbit / 32;
    let rows: Vec<Vec<u64>> =
        (0..codes_u32.len() / words32).map(|r| to_u64(&codes_u32[r * words32..(r + 1) * words32])).collect();
    let qn = 2; // goldens: first 2 rows are queries
    let kn = rows.len() - qn;
    let mut kflat = Vec::new();
    for k in &rows[qn..] {
        kflat.extend_from_slice(k);
    }
    let mut out = Vec::new();
    for (qi, q) in rows[..qn].iter().enumerate() {
        hata::attention::hamming::scores_word(q, &kflat, rbit, &mut out);
        for ki in 0..kn {
            assert_eq!(out[ki], want[qi * kn + ki], "q{qi} k{ki}");
        }
    }
}

/// Native Rust prefill reproduces the JAX prefill: last-token logits,
/// K cache and code cache.
#[test]
fn native_prefill_matches_jax() {
    let serve = ServeConfig { method: Method::Hata, budget: 48, ..Default::default() };
    let Some((model, g)) = load_model("hata-mha", &serve) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let prompt: Vec<u32> = g.i32("prompt_tokens").unwrap().iter().map(|&t| t as u32).collect();
    let want_logits = g.f32("prefill_logits").unwrap();
    let want_k = g.f32("prefill_kcache").unwrap(); // [L, KV, s, dh]
    let want_codes = g.get("prefill_codecache").unwrap().as_u32().unwrap();
    let mut cache = SeqKvCache::new(&model.cfg, &serve);
    let mut state = SeqState::new(&model.cfg);
    let mut scratch = DecodeScratch::new(&model.cfg);
    model.prefill(&prompt, &mut cache, &mut state, &serve, &mut scratch);
    // logits
    let max_err = scratch
        .logits
        .iter()
        .zip(want_logits.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 2e-3, "prefill logits max err {max_err}");
    // K cache rows
    let (dh, s) = (model.cfg.head_dim, prompt.len());
    for li in 0..model.cfg.n_layers {
        for kv in 0..model.cfg.n_kv_heads {
            let got = cache.k_slice(li, kv);
            for t in (0..s).step_by(37) {
                let want_row = want_k.slice4(li, kv, t);
                for i in 0..dh {
                    assert!(
                        (got[t * dh + i] - want_row[i]).abs() < 2e-3,
                        "kcache l{li} kv{kv} t{t}"
                    );
                }
            }
            // code cache: compare packed bits (u32 pairs vs u64)
            let words32 = model.cfg.rbit / 32;
            let gotc = cache.codes_slice(li, kv);
            for t in (0..s).step_by(53) {
                let base = ((li * model.cfg.n_kv_heads + kv) * s + t) * words32;
                for wd in 0..model.cfg.rbit / 64 {
                    let lo = want_codes[base + 2 * wd] as u64;
                    let hi = want_codes[base + 2 * wd + 1] as u64;
                    let want = lo | (hi << 32);
                    let got = gotc[t * (model.cfg.rbit / 64) + wd];
                    let diff = (want ^ got).count_ones();
                    // borderline sign(0^-) flips tolerated on <=2 bits
                    assert!(diff <= 2, "codecache l{li} kv{kv} t{t}: {diff} bits differ");
                }
            }
        }
    }
}

/// Greedy generations (dense and HATA) match JAX end-to-end.
#[test]
fn native_generation_matches_jax() {
    for (budget, key) in [(0usize, "gen_dense"), (48, "gen_hata")] {
        let serve = ServeConfig {
            method: if budget == 0 { Method::Dense } else { Method::Hata },
            budget,
            ..Default::default()
        };
        let Some((model, g)) = load_model("hata-mha", &serve) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let prompt: Vec<u32> =
            g.i32("prompt_tokens").unwrap().iter().map(|&t| t as u32).collect();
        let want: Vec<u32> = g.i32(key).unwrap().iter().map(|&t| t as u32).collect();
        let selector = make_selector(&serve);
        let mut cache = SeqKvCache::new(&model.cfg, &serve);
        let mut state = SeqState::new(&model.cfg);
        let mut scratch = DecodeScratch::new(&model.cfg);
        let out = model.generate(
            &prompt,
            want.len(),
            &serve,
            sel_ref(&selector),
            &mut cache,
            &mut state,
            &mut scratch,
        );
        assert_eq!(out, want, "budget {budget}");
    }
}

/// PJRT runtime executes the AOT graphs and agrees with the native engine.
#[test]
fn pjrt_generation_matches_native_and_jax() {
    let serve = ServeConfig { method: Method::Hata, budget: 64, ..Default::default() };
    let Some((_, g)) = load_model("hata-mha", &serve) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let m = manifest().unwrap();
    let arts = m.model("hata-mha").unwrap();
    let prompt: Vec<u32> = g.i32("prompt_tokens").unwrap().iter().map(|&t| t as u32).collect();
    let want_dense: Vec<u32> = g.i32("gen_dense").unwrap().iter().map(|&t| t as u32).collect();
    let pm = match hata::runtime::PjrtModel::load(arts, prompt.len() + want_dense.len()) {
        Ok(pm) => pm,
        Err(e) => {
            eprintln!("skipping: no usable PJRT artifacts ({e})");
            return;
        }
    };
    let dense = pm.generate(&prompt, want_dense.len(), 0).unwrap();
    assert_eq!(dense, want_dense, "pjrt dense vs jax golden");
    // HATA decode graph compiled with budget fixed by aot.py (64)
    let hata_out = pm.generate(&prompt, want_dense.len(), pm.hata_budget).unwrap();
    assert_eq!(hata_out.len(), want_dense.len());
}
