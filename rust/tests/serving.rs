//! Deterministic serving-path test harness: streaming, chunked prefill,
//! admission control, and router liveness.
//!
//! The streaming serve path ([`Engine::submit_stream`] /
//! `Router::submit_stream`) must be **bitwise-identical** to the
//! closed-loop `submit`/`drain` path — per-token events are a different
//! delivery mechanism, never a different computation. Likewise chunked
//! prefill (`--prefill-chunk-budget`) must leave bit-identical
//! K/V/codes/logits and method state (SnapKV keep-sets included) for
//! any chunk size, both at the model layer and through the engine.
//!
//! On top of the differentials, the admission-control properties:
//! in-flight never exceeds `--max-concurrent` under randomized
//! submitter interleavings, nobody starves, preempted requests resume
//! without recompute (`prefill_tokens` stays equal to the sum of
//! prompt lengths), and an idle or stalled router parks its workers
//! instead of burning CPU (bounded `idle_waits`, `drain` always
//! returns).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use hata::config::{preset, ExecMode, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::{FinishReason, Request};
use hata::coordinator::router::{Policy, Router};
use hata::coordinator::stream::{ResponseStream, StreamEvent};
use hata::kvcache::pool::PAGE_TOKENS;
use hata::kvcache::{MethodAux, SeqKvCache};
use hata::model::{
    make_selector, sel_ref, weights::Weights, DecodeScratch, Model, PrefillItem, SeqState,
    WorkerScratch,
};
use hata::tensor::ops::argmax;
use hata::tensor::simd::KernelMode;
use hata::util::rng::Rng;
use hata::util::threadpool::ThreadPool;

const METHODS: [Method; 9] = [
    Method::Dense,
    Method::ExactTopK,
    Method::Hata,
    Method::Loki,
    Method::Quest,
    Method::MagicPig,
    Method::StreamingLlm,
    Method::H2o,
    Method::SnapKv,
];

/// Physical block size under test: `HATA_KV_BLOCK` or a tiny default.
fn kv_block() -> usize {
    std::env::var("HATA_KV_BLOCK").ok().and_then(|s| s.parse().ok()).unwrap_or(4)
}

/// One request of a trace: prompt, generation budget, arrival step.
struct TraceReq {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    arrive: usize,
}

/// A deterministic multi-request schedule; `preempts` are (step, id)
/// events applied before that step runs.
struct Trace {
    reqs: Vec<TraceReq>,
    preempts: Vec<(usize, u64)>,
    last_event: usize,
}

impl Trace {
    fn prompt_tokens_total(&self) -> u64 {
        self.reqs.iter().map(|r| r.prompt.len() as u64).sum()
    }
}

/// Staggered-arrival trace with the given prompt lengths.
fn build_trace(seed: u64, lens: &[usize], preempts: Vec<(usize, u64)>) -> Trace {
    let mut rng = Rng::new(seed);
    let reqs: Vec<TraceReq> = lens
        .iter()
        .enumerate()
        .map(|(i, &len)| TraceReq {
            id: i as u64,
            prompt: (0..len).map(|_| 32 + rng.below(64) as u32).collect(),
            max_new: 3 + i % 3,
            arrive: i / 2,
        })
        .collect();
    let last_event = reqs
        .iter()
        .map(|r| r.arrive)
        .chain(preempts.iter().map(|p| p.0))
        .max()
        .unwrap_or(0);
    Trace { reqs, preempts, last_event }
}

/// An engine build for one differential cell; the model is seeded
/// identically every call so runs differ only in the axes passed here.
#[allow(clippy::too_many_arguments)]
fn mk_engine(
    method: Method,
    threads: usize,
    tile: usize,
    exec_mode: ExecMode,
    graph_cache: bool,
    kernels: KernelMode,
    paged: bool,
    prefill_chunk: usize,
) -> Engine {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 4,
        prefill_chunk,
        prefill_tile: tile,
        threads,
        exec_mode,
        graph_cache,
        kernels,
        kv_block: kv_block(),
        paged,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    Engine::new(Arc::new(model), serve)
}

/// What one trace replay produced: per-request token streams (sorted by
/// id) and the prefill-work counter.
struct Run {
    streams: Vec<(u64, Vec<u32>)>,
    prefill_tokens: u64,
}

/// Closed-loop replay: `submit` + `take_responses`.
fn run_closed(trace: &Trace, mut engine: Engine) -> Run {
    let mut streams: Vec<(u64, Vec<u32>)> = Vec::new();
    let mut step = 0usize;
    loop {
        for r in trace.reqs.iter().filter(|r| r.arrive == step) {
            engine.submit(Request {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new,
                stop_token: None,
                arrival: 0.0,
            });
        }
        for &(_, id) in trace.preempts.iter().filter(|(s, _)| *s == step) {
            engine.preempt(id);
        }
        engine.step();
        for resp in engine.take_responses() {
            assert_eq!(resp.reason, FinishReason::MaxTokens, "request {} must finish", resp.id);
            streams.push((resp.id, resp.tokens));
        }
        step += 1;
        if step > trace.last_event && !engine.has_work() {
            break;
        }
        assert!(step < 10_000, "trace did not converge");
    }
    streams.sort_by_key(|(id, _)| *id);
    Run { streams, prefill_tokens: engine.metrics.prefill_tokens }
}

/// Streaming replay: `submit_stream`, polling every live stream after
/// each step — tokens must arrive incrementally (gapless indices, at
/// commit time) and the terminal `Done` must repeat exactly the
/// streamed tokens.
fn run_streaming(trace: &Trace, mut engine: Engine) -> Run {
    let mut handles: Vec<(u64, ResponseStream)> = Vec::new();
    let mut live: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut done: Vec<(u64, Vec<u32>)> = Vec::new();
    let mut step = 0usize;
    loop {
        for r in trace.reqs.iter().filter(|r| r.arrive == step) {
            let h = engine.submit_stream(Request {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new,
                stop_token: None,
                arrival: 0.0,
            });
            assert_eq!(h.id(), r.id);
            handles.push((r.id, h));
            live.insert(r.id, Vec::new());
        }
        for &(_, id) in trace.preempts.iter().filter(|(s, _)| *s == step) {
            engine.preempt(id);
        }
        engine.step();
        // the closed-loop copies still accumulate (worker bookkeeping);
        // this path consumes the streams, so just discard them
        engine.take_responses();
        let mut i = 0;
        while i < handles.len() {
            let id = handles[i].0;
            let mut finished = false;
            while let Some(ev) = handles[i].1.try_recv() {
                match ev {
                    StreamEvent::Token { token, index } => {
                        let buf = live.get_mut(&id).unwrap();
                        assert_eq!(index, buf.len(), "req {id}: stream indices must be gapless");
                        buf.push(token);
                    }
                    StreamEvent::Done(resp) => {
                        assert_eq!(resp.id, id);
                        assert_eq!(
                            resp.reason,
                            FinishReason::MaxTokens,
                            "request {id} must finish"
                        );
                        assert_eq!(
                            resp.tokens, live[&id],
                            "req {id}: Done must repeat the streamed tokens"
                        );
                        done.push((id, resp.tokens));
                        finished = true;
                        break;
                    }
                }
            }
            if finished {
                handles.swap_remove(i);
            } else {
                i += 1;
            }
        }
        step += 1;
        if step > trace.last_event && !engine.has_work() && handles.is_empty() {
            break;
        }
        assert!(step < 10_000, "trace did not converge");
    }
    done.sort_by_key(|(id, _)| *id);
    Run { streams: done, prefill_tokens: engine.metrics.prefill_tokens }
}

// ------------------------------------------------------------ streaming

/// Tentpole differential, widest axis: for every method in the zoo the
/// streaming path must emit exactly the closed-loop token streams.
#[test]
fn streaming_bitwise_identical_for_every_method() {
    let trace = build_trace(17, &[40, 55, 33, 61, 28, 47], Vec::new());
    for method in METHODS {
        let mk = || mk_engine(method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, false, 48);
        let closed = run_closed(&trace, mk());
        let streamed = run_streaming(&trace, mk());
        assert_eq!(closed.streams, streamed.streams, "{method:?}: streaming diverged");
    }
}

/// The remaining axes: threads × tile × executor × graph-cache × kernel
/// tier × paged, on the most layout-sensitive methods.
#[test]
fn streaming_identical_across_axes() {
    let trace = build_trace(29, &[40, 55, 33, 61], Vec::new());
    let cells: &[(usize, usize, ExecMode, bool, KernelMode, bool)] = &[
        (1, 1, ExecMode::Barrier, true, KernelMode::Reference, false),
        (2, 16, ExecMode::Queue, true, KernelMode::Simd, true),
        (4, 7, ExecMode::Queue, false, KernelMode::Simd, true),
        (2, 16, ExecMode::Barrier, false, KernelMode::Reference, false),
    ];
    for method in [Method::Dense, Method::Hata, Method::SnapKv] {
        for &(threads, tile, exec, gc, kernels, paged) in cells {
            let mk = || mk_engine(method, threads, tile, exec, gc, kernels, paged, 48);
            let closed = run_closed(&trace, mk());
            let streamed = run_streaming(&trace, mk());
            assert_eq!(
                closed.streams, streamed.streams,
                "{method:?} threads={threads} tile={tile} {exec:?} gc={gc} {kernels:?} \
                 paged={paged}"
            );
        }
    }
}

/// Preempt/resume through the streaming path: a preempt storm must not
/// change the streams relative to a quiet closed-loop run, and resumed
/// requests must recompute nothing (`prefill_tokens` equals the sum of
/// prompt lengths — a re-prefilled chunk would exceed it).
#[test]
fn streaming_preempt_storm_resumes_without_recompute() {
    let lens = [40, 55, 33, 61, 28, 47];
    let quiet = build_trace(43, &lens, Vec::new());
    let stormy = build_trace(43, &lens, vec![(2, 0), (3, 1), (5, 3), (6, 2)]);
    for method in [Method::Dense, Method::Hata] {
        let closed = run_closed(
            &quiet,
            mk_engine(method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, false, 48),
        );
        let streamed = run_streaming(
            &stormy,
            mk_engine(method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, true, 48),
        );
        assert_eq!(
            closed.streams, streamed.streams,
            "{method:?}: preempted streaming run diverged from quiet closed-loop run"
        );
        assert_eq!(
            streamed.prefill_tokens,
            stormy.prompt_tokens_total(),
            "{method:?}: a resumed sequence re-prefilled a chunk (recompute)"
        );
    }
}

// ------------------------------------------------------- chunked prefill

/// Model-level chunked prefill: drive `prefill_batch` one chunk at a
/// time and compare against the canonical whole-prompt [`Model::prefill`]
/// — logits, every K/V/code row, SnapKV keep-sets, and four subsequent
/// decode steps (which read H2O/method state, so hidden state drift
/// would surface) must all be bit-identical.
#[test]
fn chunked_prefill_model_equivalence_for_every_method() {
    let prompt: Vec<u32> = {
        let mut rng = Rng::new(5);
        (0..75).map(|_| 32 + rng.below(64) as u32).collect()
    };
    for method in METHODS {
        let serve = ServeConfig { method, budget: 16, ..Default::default() };
        let cfg = preset("hata-gqa").unwrap();
        let mut rng = Rng::new(7);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        let model = Model::new(cfg, weights, aux);
        let selector = make_selector(&serve);
        let sel = sel_ref(&selector);

        // one tile, prompt/3, and a chunk overlapping the SnapKV window
        // boundary mid-chunk
        for chunk in [7usize, 25, 32] {
            // whole-prompt reference (rebuilt per chunk value: the
            // decode continuation below mutates it)
            let mut c1 = SeqKvCache::new(&model.cfg, &serve);
            let mut s1 = SeqState::new(&model.cfg);
            let mut sc1 = DecodeScratch::new(&model.cfg);
            model.prefill(&prompt, &mut c1, &mut s1, &serve, &mut sc1);

            let pool = ThreadPool::new(1);
            let mut workers = [WorkerScratch::default()];
            let mut c2 = SeqKvCache::new(&model.cfg, &serve);
            let mut s2 = SeqState::new(&model.cfg);
            let mut sc2 = DecodeScratch::new(&model.cfg);
            let mut start = 0usize;
            while start < prompt.len() {
                let end = (start + chunk).min(prompt.len());
                let mut items = vec![PrefillItem {
                    tokens: &prompt[start..end],
                    start,
                    prompt_len: prompt.len(),
                    is_final: end == prompt.len(),
                    tile: serve.prefill_tile,
                    cache: &mut c2,
                    state: &mut s2,
                    scratch: &mut sc2,
                }];
                model.prefill_batch(&mut items, &serve, &pool, &mut workers);
                start = end;
            }
            assert_eq!(
                sc1.logits, sc2.logits,
                "{method:?} chunk={chunk}: prefill logits diverged"
            );
            for (i, (a, b)) in s1.per_head.iter().zip(s2.per_head.iter()).enumerate() {
                assert_eq!(
                    a.snapkv_keep, b.snapkv_keep,
                    "{method:?} chunk={chunk}: SnapKV keep-set diverged at head {i}"
                );
            }
            assert!(
                s2.snapkv_qwin.is_empty(),
                "{method:?} chunk={chunk}: observation window must be consumed by the final chunk"
            );
            for li in 0..model.cfg.n_layers {
                for kv in 0..model.cfg.n_kv_heads {
                    assert_eq!(
                        c1.k_slice(li, kv),
                        c2.k_slice(li, kv),
                        "{method:?} chunk={chunk}: K rows diverged l{li} kv{kv}"
                    );
                    assert_eq!(
                        c1.v_slice(li, kv),
                        c2.v_slice(li, kv),
                        "{method:?} chunk={chunk}: V rows diverged l{li} kv{kv}"
                    );
                    assert_eq!(
                        c1.codes_slice(li, kv),
                        c2.codes_slice(li, kv),
                        "{method:?} chunk={chunk}: codes diverged l{li} kv{kv}"
                    );
                }
            }
            // decode continues from the chunked cache bit-identically
            // (reads H2O cumulative mass, SnapKV keep-sets, etc.)
            let mut next1 = argmax(&sc1.logits) as u32;
            let mut next2 = argmax(&sc2.logits) as u32;
            for step in 0..4 {
                let pos = prompt.len() + step;
                model.decode_step(next1, pos, &mut c1, &mut s1, &serve, sel, &mut sc1);
                model.decode_step(next2, pos, &mut c2, &mut s2, &serve, sel, &mut sc2);
                assert_eq!(
                    sc1.logits, sc2.logits,
                    "{method:?} chunk={chunk}: decode step {step} after prefill diverged"
                );
                next1 = argmax(&sc1.logits) as u32;
                next2 = argmax(&sc2.logits) as u32;
            }
        }
    }
}

/// Engine-level chunked prefill: for every method, token streams are
/// identical whether prompts prefill in one-tile chunks, thirds, or a
/// single whole-prompt pass — interleaved with decode in the same
/// continuous batch.
#[test]
fn chunked_prefill_engine_equivalence_for_every_method() {
    let trace = build_trace(37, &[70, 85, 96, 60], Vec::new());
    for method in METHODS {
        let runs: Vec<Run> = [16usize, 30, 4096]
            .into_iter()
            .map(|chunk| {
                run_closed(
                    &trace,
                    mk_engine(method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, false, chunk),
                )
            })
            .collect();
        assert_eq!(
            runs[0].streams, runs[2].streams,
            "{method:?}: one-tile chunks diverged from whole-prompt prefill"
        );
        assert_eq!(
            runs[1].streams, runs[2].streams,
            "{method:?}: prompt/3 chunks diverged from whole-prompt prefill"
        );
    }
}

// ------------------------------------------------------------ admission

/// Randomized submitter interleavings: in-flight never exceeds
/// `--max-concurrent`, every request eventually completes (no
/// starvation), and the gate settles back to zero.
#[test]
fn admission_bounds_in_flight_under_interleaved_submitters() {
    let cfg = preset("hata-gqa").unwrap();
    let mut rng = Rng::new(0);
    let weights = Weights::random(&cfg, &mut rng);
    let model = Arc::new(Model::new(cfg, weights, MethodAux::default()));
    let serve = ServeConfig {
        method: Method::Hata,
        budget: 16,
        max_batch: 2,
        max_concurrent: 3,
        ..Default::default()
    };
    let router = Arc::new(Mutex::new(Router::new(model, serve, 2, Policy::LeastLoaded)));
    let (tx, rx) = std::sync::mpsc::channel::<ResponseStream>();
    let mut joins = Vec::new();
    for t in 0..3u64 {
        let router = Arc::clone(&router);
        let tx = tx.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..4u64 {
                let mut req = Request {
                    id: t * 4 + i,
                    prompt: (0..24 + (i as usize) * 5).map(|j| 32 + (j as u32 % 64)).collect(),
                    max_new_tokens: 3,
                    stop_token: None,
                    arrival: 0.0,
                };
                loop {
                    let attempt = router.lock().unwrap().try_submit_stream(req);
                    match attempt {
                        Ok(stream) => {
                            tx.send(stream).unwrap();
                            break;
                        }
                        Err(back) => {
                            req = back;
                            std::thread::sleep(Duration::from_micros(100 + rng.below(900) as u64));
                        }
                    }
                }
            }
        }));
    }
    drop(tx);
    let mut completed = 0usize;
    for stream in rx {
        let out = stream.wait();
        assert!(out.response.is_some(), "an admitted request must complete (no starvation)");
        completed += 1;
    }
    assert_eq!(completed, 12, "every submitted request must complete");
    for j in joins {
        j.join().unwrap();
    }
    let router = router.lock().unwrap();
    let peak = router.admission().peak();
    assert!(peak <= 3, "in-flight peak {peak} exceeded max_concurrent=3");
    assert!(peak > 0, "the gate must have actually been exercised");
    for _ in 0..1000 {
        if router.admission().in_flight() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(router.admission().in_flight(), 0, "gate must settle to zero");
}

// -------------------------------------------------------- router liveness

/// Regression: an idle router parks its workers on their channels. After
/// drain settles, further wall-clock time must add zero engine steps and
/// zero wakeups — a busy-spinning worker would rack both up.
#[test]
fn idle_router_burns_no_cpu() {
    let cfg = preset("hata-gqa").unwrap();
    let mut rng = Rng::new(0);
    let weights = Weights::random(&cfg, &mut rng);
    let model = Arc::new(Model::new(cfg, weights, MethodAux::default()));
    let serve =
        ServeConfig { method: Method::Hata, budget: 16, max_batch: 2, ..Default::default() };
    let mut router = Router::new(model, serve, 2, Policy::RoundRobin);
    for i in 0..4u64 {
        router.submit(Request {
            id: i,
            prompt: (0..30).map(|j| 32 + (j % 64)).collect(),
            max_new_tokens: 3,
            stop_token: None,
            arrival: 0.0,
        });
    }
    assert_eq!(router.drain().len(), 4);
    std::thread::sleep(Duration::from_millis(50)); // let workers park
    let before = router.worker_stats();
    std::thread::sleep(Duration::from_millis(150));
    let after = router.worker_stats();
    for (i, (b, a)) in before.iter().zip(after.iter()).enumerate() {
        assert_eq!(a.steps, b.steps, "worker {i}: idle router executed engine steps");
        assert_eq!(a.idle_waits, b.idle_waits, "worker {i}: idle router woke without a message");
        assert!(
            a.idle_waits <= 16,
            "worker {i}: {} wakeups for a handful of messages — busy-spin",
            a.idle_waits
        );
    }
}

/// A request that can never be admitted used to spin its worker at 100%
/// CPU forever and hang `drain`. The worker loop now applies
/// `STALL_LIMIT` and preempts, so drain returns the request as
/// `Preempted` and the worker parks afterwards.
#[test]
fn stalled_router_drain_returns_preempted() {
    let cfg = preset("hata-gqa").unwrap();
    let mut rng = Rng::new(0);
    let weights = Weights::random(&cfg, &mut rng);
    let model = Arc::new(Model::new(cfg, weights, MethodAux::default()));
    let serve = ServeConfig {
        method: Method::Dense,
        budget: 0,
        max_batch: 2,
        kv_capacity: 2 * PAGE_TOKENS,
        ..Default::default()
    };
    let mut router = Router::new(model, serve, 1, Policy::RoundRobin);
    router.submit(Request {
        id: 1,
        prompt: (0..10 * PAGE_TOKENS).map(|j| 32 + (j as u32 % 64)).collect(),
        max_new_tokens: 4,
        stop_token: None,
        arrival: 0.0,
    });
    let rs = router.drain(); // must return, not hang
    assert_eq!(rs.len(), 1);
    assert_eq!(rs[0].reason, FinishReason::Preempted);
    // and the worker parks instead of continuing to spin
    std::thread::sleep(Duration::from_millis(50));
    let before = router.worker_stats();
    std::thread::sleep(Duration::from_millis(100));
    let after = router.worker_stats();
    assert_eq!(before[0].steps, after[0].steps, "stalled worker kept stepping");
    // a streamed inadmissible request still gets a terminal event
    let stream = router.submit_stream(Request {
        id: 2,
        prompt: (0..10 * PAGE_TOKENS).map(|j| 32 + (j as u32 % 64)).collect(),
        max_new_tokens: 4,
        stop_token: None,
        arrival: 0.0,
    });
    let out = stream.wait();
    let resp = out.response.expect("stalled stream must terminate with Done");
    assert_eq!(resp.reason, FinishReason::Preempted);
    assert!(out.tokens.is_empty());
}
