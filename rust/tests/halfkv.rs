//! Differential gate for `--kv-dtype` (half-precision KV storage).
//!
//! The contract under test (docs/PERFORMANCE.md §--kv-dtype):
//!
//! 1. **f32 is the historical layout**: an explicit `--kv-dtype f32` run
//!    is bit-identical across the thread / layout / kernel axes the
//!    parallel and paged harnesses pin down.
//! 2. **Selection parity**: hash codes and every selector side structure
//!    are computed from the *pre-quantization* f32 keys, so feeding the
//!    same rows into caches of every dtype yields exactly the same codes
//!    and exactly the same top-k selection for the code/summary-driven
//!    selectors (Hata, Quest, Loki, MagicPIG, StreamingLLM).
//! 3. **Bounded value error, invariant layout**: model logits under
//!    bf16/f16 stay within a documented relative bound of the f32 run
//!    for every method in the zoo and every kernel tier, while paged and
//!    contiguous runs at the *same* half dtype remain bit-identical
//!    (quantize-once on append + exact widening on read). The offload
//!    engine at bf16 is bitwise the resident paged bf16 engine, so the
//!    paged bound transitively covers the tier.
//! 4. **Traffic halves**: with a dtype-independent access pattern
//!    (Dense), the offload ledger's evict/fetch byte counts for bf16 are
//!    exactly half the f32 run's, at identical eviction/fetch counts.
//! 5. **CoW is lossless**: forking a half-precision paged sequence and
//!    decoding on the child never perturbs a parent bit, and the shared
//!    prefix round-trips into the child unchanged.

use std::sync::Arc;

use hata::attention::{AttnInputs, MethodState, Scratch, Selector};
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::Request;
use hata::kvcache::pool::KvPool;
use hata::kvcache::tier::OffloadStats;
use hata::kvcache::{BlockStore, MethodAux, SeqKvCache};
use hata::model::{make_selector, sel_ref, weights::Weights, DecodeScratch, Model, SeqState};
use hata::tensor::simd::{KernelMode, KvDtype};
use hata::util::rng::Rng;

const METHODS: [Method; 9] = [
    Method::Dense,
    Method::ExactTopK,
    Method::Hata,
    Method::Loki,
    Method::Quest,
    Method::MagicPig,
    Method::StreamingLlm,
    Method::H2o,
    Method::SnapKv,
];

/// Replay a fixed 5-request workload through one engine build and return
/// the per-request token streams plus the tier ledger (offload runs).
fn run_engine(
    method: Method,
    dtype: KvDtype,
    threads: usize,
    paged: bool,
    offload: bool,
    kernels: KernelMode,
) -> (Vec<(u64, Vec<u32>)>, Option<OffloadStats>) {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 4,
        prefill_chunk: 48,
        prefill_tile: 16,
        threads,
        kernels,
        kv_dtype: dtype,
        kv_block: 4,
        paged,
        offload,
        offload_budget: 0,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    let mut engine = Engine::new(Arc::new(model), serve);
    for id in 0..5u64 {
        engine.submit(Request {
            id,
            prompt: (0..(24 + id as usize * 9)).map(|i| 32 + (i as u32 % 64)).collect(),
            max_new_tokens: 4,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let mut out: Vec<(u64, Vec<u32>)> =
        engine.run_to_completion().into_iter().map(|r| (r.id, r.tokens)).collect();
    out.sort_by_key(|(id, _)| *id);
    assert_eq!(out.len(), 5, "all requests must complete ({method:?}, {dtype:?})");
    (out, engine.metrics.offload)
}

/// An explicit `--kv-dtype f32` engine must be bit-identical to itself
/// across the thread / layout / offload / kernel axes — the seed-era
/// parallel.rs matrix, replayed with the dtype threaded through.
#[test]
fn f32_dtype_bit_identical_across_parallel_matrix() {
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        let base = run_engine(method, KvDtype::F32, 1, false, false, KernelMode::Simd).0;
        for threads in [2usize, 4] {
            let r = run_engine(method, KvDtype::F32, threads, false, false, KernelMode::Simd).0;
            assert_eq!(base, r, "{method:?}: threads={threads} diverged");
        }
        let paged = run_engine(method, KvDtype::F32, 2, true, false, KernelMode::Simd).0;
        assert_eq!(base, paged, "{method:?}: paged diverged");
        let tiered = run_engine(method, KvDtype::F32, 2, true, true, KernelMode::Simd).0;
        assert_eq!(base, tiered, "{method:?}: offload diverged");
        let refk = run_engine(method, KvDtype::F32, 1, false, false, KernelMode::Reference).0;
        assert_eq!(base, refk, "{method:?}: reference kernels diverged");
    }
}

/// Append the same f32 K/V rows into caches of every storage dtype: the
/// hash codes must be exactly equal (they hash the pre-quantization
/// rows) and every code/summary-driven selector must pick exactly the
/// f32 run's top-k indices.
#[test]
fn half_cache_selection_identical_to_f32() {
    let cfg = preset("hata-gqa").unwrap();
    let dh = cfg.head_dim;
    let rbit = cfg.rbit;
    let methods =
        [Method::Hata, Method::Quest, Method::Loki, Method::MagicPig, Method::StreamingLlm];
    for method in methods {
        let serve32 = ServeConfig { method, budget: 8, ..Default::default() };
        let aux = MethodAux::build(&cfg, &serve32, None, 1);
        let mut rng = Rng::new(3);
        let hash_w: Vec<f32> = (0..dh * rbit).map(|_| rng.normal()).collect();
        let rows = 37usize;
        let krows: Vec<Vec<f32>> =
            (0..rows).map(|_| (0..dh).map(|_| rng.normal()).collect()).collect();
        let vrows: Vec<Vec<f32>> =
            (0..rows).map(|_| (0..dh).map(|_| rng.normal()).collect()).collect();
        let q: Vec<f32> = (0..cfg.group() * dh).map(|_| rng.normal()).collect();
        let selector = make_selector(&serve32).expect("sparse method has a selector");
        let mut base: Option<(Vec<u64>, Vec<u32>)> = None;
        for dtype in KvDtype::all() {
            let serve = ServeConfig { kv_dtype: dtype, ..serve32.clone() };
            let mut cache = SeqKvCache::new(&cfg, &serve);
            for (krow, vrow) in krows.iter().zip(&vrows) {
                cache.head_mut(0, 0).append(krow, vrow, &hash_w, rbit, &aux);
                cache.advance_len();
            }
            let rd = cache.read_view(0, 0);
            let inp = AttnInputs {
                q: &q,
                group: cfg.group(),
                dh,
                k: rd.k,
                v: rd.v,
                codes: rd.codes,
                words: rbit / 64,
                rbit,
                s: cache.len(),
                pos: cache.len() - 1,
                bt: rd.bt,
                block_tokens: rd.block_tokens,
                kv_dtype: rd.kv_dtype,
                kernels: KernelMode::Simd,
                side: cache.side(0, 0, &hash_w, &aux),
            };
            let mut st = MethodState::default();
            let mut sc = Scratch::default();
            selector.select(&inp, &mut st, 8, &mut sc);
            let codes = cache.codes_logical(0, 0);
            match &base {
                None => base = Some((codes, sc.indices.clone())),
                Some((c32, i32sel)) => {
                    assert_eq!(&codes, c32, "{method:?} {dtype:?}: hash codes diverged");
                    assert_eq!(&sc.indices, i32sel, "{method:?} {dtype:?}: selection diverged");
                }
            }
        }
    }
}

/// Prefill + 4 decode steps with a fixed (logit-independent) token feed;
/// returns the final-step logits.
fn decode_logits(model: &Model, serve: &ServeConfig, paged: bool) -> Vec<f32> {
    let bt = serve.kv_block;
    let prompt: Vec<u32> = (0..44u32).map(|i| 32 + (i * 7 % 64)).collect();
    let steps = 4usize;
    let selector = make_selector(serve);
    let sel = sel_ref(&selector);
    let mut state = SeqState::new(&model.cfg);
    let mut sc = DecodeScratch::new(&model.cfg);
    let planes = model.cfg.n_layers * model.cfg.n_kv_heads;
    let mut pool = KvPool::with_block(512 * bt, bt);
    let store = Arc::new(BlockStore::new(
        planes,
        model.cfg.head_dim,
        model.cfg.rbit / 64,
        bt,
        serve.kv_dtype,
    ));
    let mut cache = if paged {
        let mut c = SeqKvCache::new_paged(&model.cfg, serve, Arc::clone(&store));
        c.reserve(prompt.len() + steps + 1);
        pool.grow(1, prompt.len()).unwrap();
        // SAFETY: single-threaded test, no live views of the store
        unsafe { store.ensure_blocks(pool.minted_pages()) };
        c.sync_table(pool.seq_blocks(1));
        c
    } else {
        SeqKvCache::new(&model.cfg, serve)
    };
    model.prefill(&prompt, &mut cache, &mut state, serve, &mut sc);
    for step in 0..steps {
        let pos = prompt.len() + step;
        if paged {
            pool.grow(1, 1).unwrap();
            // SAFETY: single-threaded test, no live views of the store
            unsafe { store.ensure_blocks(pool.minted_pages()) };
            cache.sync_table(pool.seq_blocks(1));
        }
        let tok = 32 + (step as u32 * 11) % 64;
        model.decode_step(tok, pos, &mut cache, &mut state, serve, sel, &mut sc);
    }
    sc.logits.clone()
}

/// Documented logit bound vs the same-mode f32 run. For selectors whose
/// ranking is computed from pre-quantization keys (plus Dense), the
/// selection is provably identical, so only attention-value rounding
/// compounds across layers — the tight bound applies. ExactTopK, H2O
/// and SnapKV rank by *quantized* values (stored keys or attention
/// mass), so a near-tie may legitimately select a different token; the
/// loose bound only rules out NaN/garbage-level divergence for those.
fn rel_bound(dtype: KvDtype, method: Method) -> f32 {
    if matches!(method, Method::ExactTopK | Method::H2o | Method::SnapKv) {
        return 1.5;
    }
    match dtype {
        KvDtype::F32 => 0.0,
        KvDtype::Bf16 => 0.25,
        KvDtype::F16 => 0.06,
    }
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    let scale = b.iter().fold(0.0f32, |m, x| m.max(x.abs())).max(1e-3);
    a.iter().zip(b).fold(0.0f32, |m, (x, y)| m.max((x - y).abs())) / scale
}

/// Every method x kernel tier: half-precision logits stay within the
/// documented bound of the same-tier f32 run, and the paged half run is
/// bit-identical to the contiguous half run (layout never adds error).
#[test]
fn half_logits_bounded_and_layout_invariant_all_methods() {
    for method in METHODS {
        for kernels in KernelMode::all() {
            let serve32 = ServeConfig {
                method,
                budget: 16,
                kernels,
                kv_block: 4,
                ..Default::default()
            };
            let cfg = preset("hata-gqa").unwrap();
            let mut rng = Rng::new(7);
            let weights = Weights::random(&cfg, &mut rng);
            let aux = MethodAux::build(&cfg, &serve32, None, 1);
            let mut model = Model::new(cfg, weights, aux);
            model.kernels = kernels;
            let l32 = decode_logits(&model, &serve32, false);
            for dtype in [KvDtype::Bf16, KvDtype::F16] {
                let serve = ServeConfig { kv_dtype: dtype, ..serve32.clone() };
                let flat = decode_logits(&model, &serve, false);
                let paged = decode_logits(&model, &serve, true);
                assert!(
                    flat.iter().zip(&paged).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{method:?} {kernels:?} {dtype:?}: paged diverged from contiguous"
                );
                assert!(flat.iter().all(|x| x.is_finite()), "{method:?} {dtype:?}: non-finite");
                let err = max_rel_err(&flat, &l32);
                assert!(
                    err <= rel_bound(dtype, method),
                    "{method:?} {kernels:?} {dtype:?}: logit error {err} over bound"
                );
            }
        }
    }
}

/// The offload engine at bf16 must be bitwise the resident paged bf16
/// engine (NaN poison makes a bypassed fetch fail loudly), and — with
/// Dense's dtype-independent block access pattern — the tier ledger's
/// evict/fetch bytes must be exactly half the f32 run's at identical
/// eviction and fetch counts.
#[test]
fn offload_bf16_bitwise_and_ledger_bytes_halved() {
    let (s32, o32) = run_engine(Method::Dense, KvDtype::F32, 2, true, true, KernelMode::Simd);
    let (s16, o16) = run_engine(Method::Dense, KvDtype::Bf16, 2, true, true, KernelMode::Simd);
    let o32 = o32.expect("f32 offload run reports tier stats");
    let o16 = o16.expect("bf16 offload run reports tier stats");
    assert!(o16.evictions > 0, "budget 0 must evict: {o16:?}");
    assert_eq!(o32.evictions, o16.evictions, "eviction counts must match across dtypes");
    assert_eq!(
        o32.demand_fetches + o32.prefetch_fetches,
        o16.demand_fetches + o16.prefetch_fetches,
        "fetch counts must match across dtypes"
    );
    assert_eq!(o16.evict.bytes * 2, o32.evict.bytes, "bf16 evict bytes must be exactly half");
    assert_eq!(o16.fetch.bytes * 2, o32.fetch.bytes, "bf16 fetch bytes must be exactly half");
    let resident = run_engine(Method::Dense, KvDtype::Bf16, 2, true, false, KernelMode::Simd).0;
    assert_eq!(resident, s16, "bf16 offload streams diverged from resident paged");
    assert!(!s32.is_empty(), "f32 offload run produced no streams");
}

/// Property: forking a half-precision paged sequence and decoding on the
/// child is lossless — every parent packed row, code word and summary
/// survives bit for bit, and the child's shared prefix matches exactly.
/// Several geometries, always ending mid-block to force a CoW copy.
#[test]
fn half_cow_fork_round_trip_lossless() {
    let bt = 4usize;
    for dtype in [KvDtype::Bf16, KvDtype::F16] {
        for seed in [1u64, 2, 3] {
            let serve = ServeConfig {
                method: Method::Hata,
                budget: 16,
                kv_block: bt,
                kv_dtype: dtype,
                ..Default::default()
            };
            let cfg = preset("hata-gqa").unwrap();
            let mut rng = Rng::new(seed);
            let weights = Weights::random(&cfg, &mut rng);
            let aux = MethodAux::build(&cfg, &serve, None, 1);
            let model = Model::new(cfg, weights, aux);
            let selector = make_selector(&serve);
            let sel = sel_ref(&selector);
            let plen = 2 * bt + 1 + (seed as usize % (bt - 1));
            let prompt: Vec<u32> = (0..plen as u32).map(|i| 32 + (i * 5 % 64)).collect();

            let mut pool = KvPool::with_block(256 * bt, bt);
            let planes = model.cfg.n_layers * model.cfg.n_kv_heads;
            let store = Arc::new(BlockStore::new(
                planes,
                model.cfg.head_dim,
                model.cfg.rbit / 64,
                bt,
                dtype,
            ));
            let mut parent = SeqKvCache::new_paged(&model.cfg, &serve, Arc::clone(&store));
            parent.reserve(prompt.len() + 4);
            let mut ps = SeqState::new(&model.cfg);
            let mut psc = DecodeScratch::new(&model.cfg);
            pool.grow(1, prompt.len()).unwrap();
            // SAFETY: single-threaded test, no live views of the store
            unsafe { store.ensure_blocks(pool.minted_pages()) };
            parent.sync_table(pool.seq_blocks(1));
            model.prefill(&prompt, &mut parent, &mut ps, &serve, &mut psc);

            let mut snap: Vec<(Vec<f32>, Vec<f32>, Vec<u64>)> = Vec::new();
            for li in 0..model.cfg.n_layers {
                for kv in 0..model.cfg.n_kv_heads {
                    snap.push((
                        parent.k_logical(li, kv),
                        parent.v_logical(li, kv),
                        parent.codes_logical(li, kv),
                    ));
                }
            }

            let mut child = parent.fork_paged(&mut pool, 1, 2).unwrap();
            // unshare the partial tail block the child appends into
            let copied = child.make_writable(&mut pool, 2, plen / bt).unwrap();
            assert!(copied, "the shared tail block must be copied, not written in place");

            let mut cs = SeqState::new(&model.cfg);
            let mut csc = DecodeScratch::new(&model.cfg);
            child.reserve(prompt.len() + 4);
            for step in 0..2 {
                pool.grow(2, 1).unwrap();
                // SAFETY: single-threaded test, no live views of the store
                unsafe { store.ensure_blocks(pool.minted_pages()) };
                child.sync_table(pool.seq_blocks(2));
                let tok = 32 + (step as u32 * 13) % 64;
                model.decode_step(tok, plen + step, &mut child, &mut cs, &serve, sel, &mut csc);
            }

            for li in 0..model.cfg.n_layers {
                for kv in 0..model.cfg.n_kv_heads {
                    let (k, v, codes) = &snap[li * model.cfg.n_kv_heads + kv];
                    let ctx = format!("{dtype:?} seed {seed} l{li} kv{kv}");
                    assert_eq!(&parent.k_logical(li, kv), k, "parent K mutated {ctx}");
                    assert_eq!(&parent.v_logical(li, kv), v, "parent V mutated {ctx}");
                    assert_eq!(&parent.codes_logical(li, kv), codes, "parent codes mutated {ctx}");
                    assert_eq!(
                        child.k_logical(li, kv)[..k.len()],
                        k[..],
                        "child K prefix diverged {ctx}"
                    );
                    assert_eq!(
                        child.v_logical(li, kv)[..v.len()],
                        v[..],
                        "child V prefix diverged {ctx}"
                    );
                }
            }
            assert_eq!(child.len(), parent.len() + 2);
            pool.release(1).unwrap();
            pool.release(2).unwrap();
            assert_eq!(pool.free_pages(), pool.capacity_pages(), "leak after release");
        }
    }
}
