//! Parallel-vs-serial determinism: the batched threadpool decode path
//! (`serve.threads > 1`) and the block-tiled prefill path (any
//! `prefill_tile` / `prefill_chunk`) must produce byte-identical results
//! to the serial engine for every method — work items touch disjoint
//! state, per-worker scratch is fully overwritten, and tile reduction
//! order is fixed per query row, so thread count, tile geometry and item
//! placement cannot change any result.

use std::sync::Arc;

use hata::config::{preset, ExecMode, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::Request;
use hata::kvcache::{MethodAux, SeqKvCache};
use hata::model::{
    weights::Weights, DecodeGraphCache, DecodeItem, DecodeScratch, Model, PrefillItem, SeqState,
    WorkerScratch,
};
use hata::tensor::simd::KernelMode;
use hata::util::rng::Rng;
use hata::util::threadpool::ThreadPool;

/// Run a fixed workload (6 requests, mixed prompt lengths, chunked
/// prefill) and return the (id, tokens) streams sorted by id.
fn run(method: Method, threads: usize) -> Vec<(u64, Vec<u32>)> {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 4,
        prefill_chunk: 64,
        threads,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    let mut engine = Engine::new(Arc::new(model), serve);
    for id in 0..6u64 {
        engine.submit(Request {
            id,
            prompt: (0..(40 + id as usize * 13)).map(|i| 32 + (i as u32 % 64)).collect(),
            max_new_tokens: 5,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let mut out: Vec<(u64, Vec<u32>)> =
        engine.run_to_completion().into_iter().map(|r| (r.id, r.tokens)).collect();
    out.sort_by_key(|(id, _)| *id);
    assert_eq!(out.len(), 6, "all requests must complete ({method:?}, threads={threads})");
    assert!(out.iter().all(|(_, t)| t.len() == 5));
    out
}

#[test]
fn dense_tokens_identical_across_thread_counts() {
    let serial = run(Method::Dense, 1);
    assert_eq!(serial, run(Method::Dense, 2));
    assert_eq!(serial, run(Method::Dense, 4));
}

#[test]
fn hata_tokens_identical_across_thread_counts() {
    let serial = run(Method::Hata, 1);
    assert_eq!(serial, run(Method::Hata, 2));
    assert_eq!(serial, run(Method::Hata, 4));
}

#[test]
fn quest_tokens_identical_across_thread_counts() {
    let serial = run(Method::Quest, 1);
    assert_eq!(serial, run(Method::Quest, 4));
}

/// Build one random model for the prefill-equivalence tests (kernel
/// tier taken from `serve.kernels`, as `load_model` does).
fn model_for(method: Method, serve: &ServeConfig) -> Model {
    let cfg = preset("hata-gqa").unwrap();
    let mut rng = Rng::new(7);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    model
}

/// Tiled prefill must produce bit-identical caches, hash codes, side
/// structures and logits to the token-serial reference for every tile
/// size — including a tile larger than the chunk (clamped) — for the
/// Dense, Hata and Quest selectors.
#[test]
fn tiled_prefill_bit_identical_to_token_serial() {
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        let serve = ServeConfig { method, budget: 16, prefill_chunk: 128, ..Default::default() };
        let model = model_for(method, &serve);
        let prompt: Vec<u32> = (0..300u32).map(|i| 32 + (i % 64)).collect();
        // token-serial reference
        let mut c1 = SeqKvCache::new(&model.cfg, &serve);
        let mut s1 = SeqState::new(&model.cfg);
        let mut sc1 = DecodeScratch::new(&model.cfg);
        model.prefill_serial(&prompt, &mut c1, &mut s1, &serve, &mut sc1);
        for tile in [1usize, 8, 32, 1024] {
            let serve_t = ServeConfig { prefill_tile: tile, ..serve.clone() };
            let mut c2 = SeqKvCache::new(&model.cfg, &serve_t);
            let mut s2 = SeqState::new(&model.cfg);
            let mut sc2 = DecodeScratch::new(&model.cfg);
            model.prefill(&prompt, &mut c2, &mut s2, &serve_t, &mut sc2);
            assert_eq!(c1.len(), c2.len(), "{method:?} tile {tile}");
            for li in 0..model.cfg.n_layers {
                for kv in 0..model.cfg.n_kv_heads {
                    assert_eq!(
                        c1.k_slice(li, kv),
                        c2.k_slice(li, kv),
                        "{method:?} tile {tile} k l{li} kv{kv}"
                    );
                    assert_eq!(
                        c1.v_slice(li, kv),
                        c2.v_slice(li, kv),
                        "{method:?} tile {tile} v l{li} kv{kv}"
                    );
                    assert_eq!(
                        c1.codes_slice(li, kv),
                        c2.codes_slice(li, kv),
                        "{method:?} tile {tile} codes l{li} kv{kv}"
                    );
                    let a = c1.side(li, kv, &[], &model.aux);
                    let b = c2.side(li, kv, &[], &model.aux);
                    assert_eq!(a.quest_min, b.quest_min, "{method:?} tile {tile}");
                    assert_eq!(a.quest_max, b.quest_max, "{method:?} tile {tile}");
                }
            }
            assert_eq!(c1.bytes(), c2.bytes(), "{method:?} tile {tile}");
            assert_eq!(sc1.logits, sc2.logits, "{method:?} tile {tile} logits");
            assert_eq!(sc1.q, sc2.q, "{method:?} tile {tile} final-layer q");
        }
    }
}

/// SnapKV's prefill-time observation state must survive the tiling —
/// including a window that spans a chunk boundary (prompt 130, chunk 64:
/// the 16-token window covers the last two blocks).
#[test]
fn tiled_prefill_matches_serial_snapkv_state() {
    let serve =
        ServeConfig { method: Method::SnapKv, budget: 12, prefill_chunk: 64, ..Default::default() };
    let model = model_for(Method::SnapKv, &serve);
    let prompt: Vec<u32> = (0..130u32).map(|i| 32 + (i % 64)).collect();
    let mut c1 = SeqKvCache::new(&model.cfg, &serve);
    let mut s1 = SeqState::new(&model.cfg);
    let mut sc1 = DecodeScratch::new(&model.cfg);
    model.prefill_serial(&prompt, &mut c1, &mut s1, &serve, &mut sc1);
    let mut c2 = SeqKvCache::new(&model.cfg, &serve);
    let mut s2 = SeqState::new(&model.cfg);
    let mut sc2 = DecodeScratch::new(&model.cfg);
    model.prefill(&prompt, &mut c2, &mut s2, &serve, &mut sc2);
    assert_eq!(sc1.logits, sc2.logits);
    for (i, (a, b)) in s1.per_head.iter().zip(&s2.per_head).enumerate() {
        assert_eq!(a.snapkv_keep, b.snapkv_keep, "head {i}");
    }
}

/// Engine-level prefill determinism: token streams must be identical
/// across thread counts AND tile sizes (chunked prefill, long prompts).
fn run_tiled(method: Method, threads: usize, tile: usize) -> Vec<(u64, Vec<u32>)> {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 3,
        prefill_chunk: 48,
        prefill_tile: tile,
        threads,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    let mut engine = Engine::new(Arc::new(model), serve);
    for id in 0..4u64 {
        engine.submit(Request {
            id,
            prompt: (0..(90 + id as usize * 37)).map(|i| 32 + (i as u32 % 64)).collect(),
            max_new_tokens: 4,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let mut out: Vec<(u64, Vec<u32>)> =
        engine.run_to_completion().into_iter().map(|r| (r.id, r.tokens)).collect();
    out.sort_by_key(|(id, _)| *id);
    assert_eq!(out.len(), 4, "all requests must complete ({method:?}, threads={threads})");
    assert!(out.iter().all(|(_, t)| t.len() == 4));
    out
}

#[test]
fn tiled_prefill_engine_identical_across_threads_and_tiles() {
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        let base = run_tiled(method, 1, 16);
        assert_eq!(base, run_tiled(method, 4, 16), "{method:?} threads");
        assert_eq!(base, run_tiled(method, 4, 64), "{method:?} tile 64");
        assert_eq!(base, run_tiled(method, 2, 7), "{method:?} odd tile");
    }
}

/// Engine-level executor determinism: identical token streams from the
/// full serving loop (chunked prefill + batched decode) under `--exec
/// queue` and `--exec barrier`, with the decode graph cache on or off.
fn run_exec(
    method: Method,
    threads: usize,
    tile: usize,
    exec_mode: ExecMode,
    graph_cache: bool,
) -> Vec<(u64, Vec<u32>)> {
    run_exec_kernels(method, threads, tile, exec_mode, graph_cache, KernelMode::default())
}

/// [`run_exec`] with an explicit `--kernels` tier.
fn run_exec_kernels(
    method: Method,
    threads: usize,
    tile: usize,
    exec_mode: ExecMode,
    graph_cache: bool,
    kernels: KernelMode,
) -> Vec<(u64, Vec<u32>)> {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 3,
        prefill_chunk: 48,
        prefill_tile: tile,
        threads,
        exec_mode,
        graph_cache,
        kernels,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    let mut engine = Engine::new(Arc::new(model), serve);
    for id in 0..4u64 {
        engine.submit(Request {
            id,
            prompt: (0..(90 + id as usize * 37)).map(|i| 32 + (i as u32 % 64)).collect(),
            max_new_tokens: 4,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let mut out: Vec<(u64, Vec<u32>)> =
        engine.run_to_completion().into_iter().map(|r| (r.id, r.tokens)).collect();
    out.sort_by_key(|(id, _)| *id);
    assert_eq!(out.len(), 4, "all requests must complete ({method:?}, {exec_mode:?})");
    out
}

/// The acceptance matrix: `--graph-cache on|off` × `--exec queue` ≡
/// `--exec barrier` for every (threads ∈ {1, 2, 8}) × (tile ∈ {1, 16})
/// × (Dense/Hata/Quest) cell. The barrier path ignores the cache, so it
/// is the common reference both queue variants must match bit-for-bit.
#[test]
fn queue_exec_engine_identical_to_barrier() {
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        for threads in [1usize, 2, 8] {
            for tile in [1usize, 16] {
                let barrier = run_exec(method, threads, tile, ExecMode::Barrier, true);
                for graph_cache in [true, false] {
                    let queue = run_exec(method, threads, tile, ExecMode::Queue, graph_cache);
                    assert_eq!(
                        barrier, queue,
                        "{method:?} threads={threads} tile={tile} cache={graph_cache}"
                    );
                }
            }
        }
    }
}

/// H2O keeps its serial prefill under both executors (query-order
/// cumulative state), so the modes must still agree end to end — with
/// the decode graph cache on or off.
#[test]
fn queue_exec_matches_barrier_for_h2o() {
    let barrier = run_exec(Method::H2o, 4, 16, ExecMode::Barrier, true);
    for graph_cache in [true, false] {
        assert_eq!(
            barrier,
            run_exec(Method::H2o, 4, 16, ExecMode::Queue, graph_cache),
            "cache={graph_cache}"
        );
    }
}

/// SnapKV reads the final-layer queries out of `scratch.block.q` after a
/// whole-prompt batched prefill — exactly what the queue epilogue/QKV
/// tasks leave behind — so its observation state and logits must be
/// byte-identical across executors (engine streams too).
#[test]
fn queue_exec_matches_barrier_for_snapkv() {
    let barrier = run_exec(Method::SnapKv, 4, 16, ExecMode::Barrier, true);
    for graph_cache in [true, false] {
        assert_eq!(
            barrier,
            run_exec(Method::SnapKv, 4, 16, ExecMode::Queue, graph_cache),
            "cache={graph_cache}"
        );
    }
    // model level: whole-prompt prefill_batch, then compare snapkv_keep
    // rankings and logits bit-for-bit
    let mk_serve = |exec_mode: ExecMode| ServeConfig {
        method: Method::SnapKv,
        budget: 12,
        prefill_tile: 8,
        exec_mode,
        ..Default::default()
    };
    let model = model_for(Method::SnapKv, &mk_serve(ExecMode::Barrier));
    let pool = ThreadPool::new(4);
    let prompts: Vec<Vec<u32>> =
        (0..3).map(|s| (0..(60 + s * 31)).map(|i| 32 + (i as u32 % 64)).collect()).collect();
    let run = |serve: &ServeConfig| {
        let mut workers: Vec<WorkerScratch> = (0..4).map(|_| WorkerScratch::default()).collect();
        let mut caches: Vec<SeqKvCache> =
            prompts.iter().map(|_| SeqKvCache::new(&model.cfg, serve)).collect();
        let mut states: Vec<SeqState> = prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
        let mut scratches: Vec<DecodeScratch> =
            prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
        {
            let mut items: Vec<PrefillItem> = prompts
                .iter()
                .zip(caches.iter_mut())
                .zip(states.iter_mut())
                .zip(scratches.iter_mut())
                .map(|(((p, cache), state), scratch)| PrefillItem {
                    tokens: p,
                    start: 0,
                    prompt_len: p.len(),
                    is_final: true,
                    tile: serve.prefill_tile,
                    cache,
                    state,
                    scratch,
                })
                .collect();
            model.prefill_batch(&mut items, serve, &pool, &mut workers);
        }
        let logits: Vec<Vec<f32>> = scratches.iter().map(|sc| sc.logits.clone()).collect();
        let keeps: Vec<Vec<Vec<u32>>> = states
            .iter()
            .map(|st| st.per_head.iter().map(|h| h.snapkv_keep.clone()).collect())
            .collect();
        (logits, keeps)
    };
    let (l1, k1) = run(&mk_serve(ExecMode::Barrier));
    let (l2, k2) = run(&mk_serve(ExecMode::Queue));
    assert_eq!(l1, l2, "snapkv logits");
    assert_eq!(k1, k2, "snapkv observation state");
}

/// Model-level bit-identity: queue-mode `prefill_batch` + `decode_batch`
/// must leave byte-identical KV caches, hash codes, side structures and
/// logits to barrier mode — not just the same argmax tokens — with the
/// decode graph cache on and off.
#[test]
fn queue_exec_bit_identical_caches_and_logits() {
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        let mk_serve = |exec_mode: ExecMode, graph_cache: bool| ServeConfig {
            method,
            budget: 16,
            prefill_tile: 8,
            exec_mode,
            graph_cache,
            ..Default::default()
        };
        let model = model_for(method, &mk_serve(ExecMode::Barrier, true));
        let pool = ThreadPool::new(4);
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|s| (0..(70 + s * 23)).map(|i| 32 + (i as u32 % 64)).collect())
            .collect();
        let run = |serve: &ServeConfig| {
            let mut workers: Vec<WorkerScratch> =
                (0..4).map(|_| WorkerScratch::default()).collect();
            let mut caches: Vec<SeqKvCache> =
                prompts.iter().map(|_| SeqKvCache::new(&model.cfg, serve)).collect();
            let mut states: Vec<SeqState> =
                prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
            let mut scratches: Vec<DecodeScratch> =
                prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
            // batched tiled prefill, all sequences in one call
            {
                let mut items: Vec<PrefillItem> = prompts
                    .iter()
                    .zip(caches.iter_mut())
                    .zip(states.iter_mut())
                    .zip(scratches.iter_mut())
                    .map(|(((p, cache), state), scratch)| PrefillItem {
                        tokens: p,
                        start: 0,
                        prompt_len: p.len(),
                        is_final: true,
                        tile: serve.prefill_tile,
                        cache,
                        state,
                        scratch,
                    })
                    .collect();
                model.prefill_batch(&mut items, serve, &pool, &mut workers);
            }
            let sel = hata::model::make_selector(serve);
            let mut next: Vec<u32> = scratches
                .iter()
                .map(|sc| hata::tensor::ops::argmax(&sc.logits) as u32)
                .collect();
            let mut graph_cache = DecodeGraphCache::new();
            let mut logit_trace: Vec<Vec<f32>> = Vec::new();
            for step in 0..4 {
                let mut items: Vec<DecodeItem> = caches
                    .iter_mut()
                    .zip(states.iter_mut())
                    .zip(scratches.iter_mut())
                    .enumerate()
                    .map(|(i, ((cache, state), scratch))| DecodeItem {
                        token: next[i],
                        pos: prompts[i].len() + step,
                        cache,
                        state,
                        scratch,
                    })
                    .collect();
                let sel = hata::model::sel_ref(&sel);
                model.decode_batch(&mut items, serve, sel, &pool, &mut workers, &mut graph_cache);
                drop(items);
                for (i, n) in next.iter_mut().enumerate() {
                    *n = hata::tensor::ops::argmax(&scratches[i].logits) as u32;
                }
                logit_trace.extend(scratches.iter().map(|sc| sc.logits.clone()));
            }
            (caches, logit_trace)
        };
        let (c1, l1) = run(&mk_serve(ExecMode::Barrier, true));
        for graph_cache in [true, false] {
            let (c2, l2) = run(&mk_serve(ExecMode::Queue, graph_cache));
            assert_eq!(l1, l2, "{method:?} logits cache={graph_cache}");
            for (s, (a, b)) in c1.iter().zip(&c2).enumerate() {
                assert_eq!(a.len(), b.len(), "{method:?} seq {s}");
                for li in 0..model.cfg.n_layers {
                    for kv in 0..model.cfg.n_kv_heads {
                        assert_eq!(a.k_slice(li, kv), b.k_slice(li, kv), "{method:?} seq {s} k");
                        assert_eq!(a.v_slice(li, kv), b.v_slice(li, kv), "{method:?} seq {s} v");
                        assert_eq!(
                            a.codes_slice(li, kv),
                            b.codes_slice(li, kv),
                            "{method:?} seq {s} codes"
                        );
                        let sa = a.side(li, kv, &[], &model.aux);
                        let sb = b.side(li, kv, &[], &model.aux);
                        assert_eq!(sa.quest_min, sb.quest_min, "{method:?} seq {s}");
                        assert_eq!(sa.quest_max, sb.quest_max, "{method:?} seq {s}");
                    }
                }
                assert_eq!(a.bytes(), b.bytes(), "{method:?} seq {s}");
            }
        }
    }
}

/// `--kernels simd` must be bit-identical to `--kernels reference` end
/// to end: identical token streams from the full serving loop across
/// Dense/Hata/Quest × threads × tile × executor × graph cache.
/// tensor/simd.rs replays the scalar reduction order exactly, so the
/// vectorized tier may not change a single bit anywhere in the engine.
#[test]
fn simd_kernels_engine_identical_to_reference() {
    let cells: &[(usize, usize, ExecMode)] =
        &[(1, 1, ExecMode::Barrier), (2, 16, ExecMode::Queue), (2, 1, ExecMode::Queue)];
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        for &(threads, tile, exec) in cells {
            let rf = run_exec_kernels(method, threads, tile, exec, true, KernelMode::Reference);
            for gc in [true, false] {
                let simd = run_exec_kernels(method, threads, tile, exec, gc, KernelMode::Simd);
                assert_eq!(rf, simd, "{method:?} threads={threads} tile={tile} {exec:?} gc={gc}");
            }
        }
    }
}

/// Stronger than token streams: after a tiled prefill, the Reference and
/// Simd tiers must leave byte-identical KV caches, hash codes and logits.
#[test]
fn simd_kernels_bit_identical_prefill_state() {
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        let mk = |kernels: KernelMode| ServeConfig {
            method,
            budget: 16,
            prefill_tile: 8,
            kernels,
            ..Default::default()
        };
        let prompt: Vec<u32> = (0..200u32).map(|i| 32 + (i % 64)).collect();
        let run = |serve: &ServeConfig| {
            let model = model_for(method, serve);
            let mut c = SeqKvCache::new(&model.cfg, serve);
            let mut s = SeqState::new(&model.cfg);
            let mut sc = DecodeScratch::new(&model.cfg);
            model.prefill(&prompt, &mut c, &mut s, serve, &mut sc);
            (model, c, sc)
        };
        let (m1, c1, sc1) = run(&mk(KernelMode::Reference));
        let (_m2, c2, sc2) = run(&mk(KernelMode::Simd));
        assert_eq!(sc1.logits, sc2.logits, "{method:?} logits");
        for li in 0..m1.cfg.n_layers {
            for kv in 0..m1.cfg.n_kv_heads {
                assert_eq!(c1.k_slice(li, kv), c2.k_slice(li, kv), "{method:?} k l{li} kv{kv}");
                assert_eq!(c1.v_slice(li, kv), c2.v_slice(li, kv), "{method:?} v l{li} kv{kv}");
                assert_eq!(
                    c1.codes_slice(li, kv),
                    c2.codes_slice(li, kv),
                    "{method:?} codes l{li} kv{kv}"
                );
            }
        }
    }
}
