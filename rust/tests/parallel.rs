//! Parallel-vs-serial determinism: the batched threadpool decode path
//! (`serve.threads > 1`) must produce byte-identical token streams to the
//! serial engine for every method — work items touch disjoint state and
//! per-worker scratch is fully overwritten, so thread count and item
//! placement cannot change any result.

use std::sync::Arc;

use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::Request;
use hata::kvcache::MethodAux;
use hata::model::{weights::Weights, Model};
use hata::util::rng::Rng;

/// Run a fixed workload (6 requests, mixed prompt lengths, chunked
/// prefill) and return the (id, tokens) streams sorted by id.
fn run(method: Method, threads: usize) -> Vec<(u64, Vec<u32>)> {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 4,
        prefill_chunk: 64,
        threads,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut engine = Engine::new(Arc::new(Model::new(cfg, weights, aux)), serve);
    for id in 0..6u64 {
        engine.submit(Request {
            id,
            prompt: (0..(40 + id as usize * 13)).map(|i| 32 + (i as u32 % 64)).collect(),
            max_new_tokens: 5,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let mut out: Vec<(u64, Vec<u32>)> =
        engine.run_to_completion().into_iter().map(|r| (r.id, r.tokens)).collect();
    out.sort_by_key(|(id, _)| *id);
    assert_eq!(out.len(), 6, "all requests must complete ({method:?}, threads={threads})");
    assert!(out.iter().all(|(_, t)| t.len() == 5));
    out
}

#[test]
fn dense_tokens_identical_across_thread_counts() {
    let serial = run(Method::Dense, 1);
    assert_eq!(serial, run(Method::Dense, 2));
    assert_eq!(serial, run(Method::Dense, 4));
}

#[test]
fn hata_tokens_identical_across_thread_counts() {
    let serial = run(Method::Hata, 1);
    assert_eq!(serial, run(Method::Hata, 2));
    assert_eq!(serial, run(Method::Hata, 4));
}

#[test]
fn quest_tokens_identical_across_thread_counts() {
    let serial = run(Method::Quest, 1);
    assert_eq!(serial, run(Method::Quest, 4));
}
