//! Cross-module integration tests that need no artifacts: engine + router
//! under load, method accuracy ordering on a synthetic associative model,
//! memory-pressure behaviour, and failure injection.

use std::sync::Arc;

use hata::bench::eval::fidelity;
use hata::bench::tasks::{make_task, Corpus, TaskKind};
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::{FinishReason, Request};
use hata::coordinator::router::{Policy, Router};
use hata::kvcache::MethodAux;
use hata::model::{tokenizer, weights::Weights, Model};
use hata::util::rng::Rng;

fn random_model(cfg_name: &str, serve: &ServeConfig, seed: u64) -> Arc<Model> {
    let cfg = preset(cfg_name).unwrap();
    let mut rng = Rng::new(seed);
    let w = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, serve, None, seed + 1);
    Arc::new(Model::new(cfg, w, aux))
}

#[test]
fn engine_under_oversubscription_completes_all() {
    let serve = ServeConfig {
        method: Method::Hata,
        budget: 16,
        max_batch: 2,
        prefill_chunk: 64,
        kv_capacity: 1 << 14,
        ..Default::default()
    };
    let model = random_model("hata-gqa", &serve, 0);
    let mut engine = Engine::new(model, serve);
    for id in 0..10u64 {
        engine.submit(Request {
            id,
            prompt: (32..32 + 60 + (id as u32 % 13)).collect(),
            max_new_tokens: 3,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let rs = engine.run_to_completion();
    assert_eq!(rs.len(), 10);
    assert!(rs.iter().all(|r| r.reason == FinishReason::MaxTokens));
}

#[test]
fn router_with_multiple_workers_under_mixed_kinds() {
    let serve = ServeConfig { method: Method::Hata, budget: 16, max_batch: 2, ..Default::default() };
    let model = random_model("hata-mha", &serve, 1);
    let mut router = Router::new(model, serve, 2, Policy::LeastLoaded);
    let corpus = Corpus::new(0);
    let mut rng = Rng::new(2);
    for id in 0..6u64 {
        let kind = TaskKind::all()[id as usize % TaskKind::all().len()];
        let (prompt, _) = make_task(kind, &corpus, &mut rng, 200, None);
        router.submit(Request {
            id,
            prompt: tokenizer::encode(&prompt),
            max_new_tokens: 3,
            stop_token: None,
            arrival: 0.0,
        });
    }
    let rs = router.drain();
    assert_eq!(rs.len(), 6);
}

/// The fidelity ORDERING the paper's accuracy tables rest on: exact top-k
/// >= HATA(trained-free random hash) > StreamingLLM on retrieval-shaped
/// Q/K — even on a random model, selection recall separates the families.
#[test]
fn selection_recall_ordering() {
    let budget = 24;
    let ctx = 256;
    let mut recalls = std::collections::BTreeMap::new();
    for method in [Method::ExactTopK, Method::Hata, Method::StreamingLlm] {
        let serve = ServeConfig { method, budget, ..Default::default() };
        let model = random_model("hata-mha", &serve, 3);
        let f = fidelity(&model, &serve, ctx, 3, 11);
        recalls.insert(method.name(), f.recall);
    }
    assert!(recalls["topk"] > 0.999);
    assert!(
        recalls["hata"] > recalls["streamingllm"],
        "hata {} vs streaming {}",
        recalls["hata"],
        recalls["streamingllm"]
    );
}

#[test]
fn h2o_and_snapkv_respect_budget() {
    for method in [Method::H2o, Method::SnapKv] {
        let serve = ServeConfig { method, budget: 12, max_batch: 1, ..Default::default() };
        let model = random_model("hata-mha", &serve, 4);
        let mut engine = Engine::new(Arc::clone(&model), serve);
        engine.submit(Request {
            id: 1,
            prompt: (32..120).collect(),
            max_new_tokens: 4,
            stop_token: None,
            arrival: 0.0,
        });
        let rs = engine.run_to_completion();
        assert_eq!(rs.len(), 1, "{method:?}");
        assert_eq!(rs[0].tokens.len(), 4, "{method:?}");
    }
}

#[test]
fn empty_prompt_is_survivable() {
    // degenerate request: prompt of one token (zero-length prompts are
    // rejected upstream; one token is the minimum the engine admits)
    let serve = ServeConfig { method: Method::Dense, budget: 0, ..Default::default() };
    let model = random_model("hata-mha", &serve, 5);
    let mut engine = Engine::new(model, serve);
    engine.submit(Request {
        id: 1,
        prompt: vec![65],
        max_new_tokens: 2,
        stop_token: None,
        arrival: 0.0,
    });
    let rs = engine.run_to_completion();
    assert_eq!(rs[0].tokens.len(), 2);
}

#[test]
fn max_new_zero_finishes_immediately() {
    let serve = ServeConfig { method: Method::Dense, budget: 0, ..Default::default() };
    let model = random_model("hata-mha", &serve, 6);
    let mut engine = Engine::new(model, serve);
    engine.submit(Request {
        id: 1,
        prompt: (32..64).collect(),
        max_new_tokens: 0,
        stop_token: None,
        arrival: 0.0,
    });
    let rs = engine.run_to_completion();
    assert_eq!(rs.len(), 1);
    assert!(rs[0].tokens.is_empty());
}
