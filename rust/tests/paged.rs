//! Differential trace harness for the paged KV cache.
//!
//! The paged layout (`--paged`: fixed-size physical blocks behind
//! per-sequence block tables, copy-on-write prefix sharing, block-table
//! admission) must be **bitwise-identical** to the contiguous layout —
//! not approximately equal, not token-equal-by-luck. Two kinds of proof
//! live here:
//!
//! 1. **Engine traces**: a seeded multi-request trace (staggered
//!    arrivals, shared prefixes, mid-flight preemptions) is replayed
//!    through a paged and a contiguous engine build; the per-request
//!    token streams must match exactly, across methods × threads ×
//!    tiles × executors × graph-cache × kernel tiers. Along the way the
//!    pool is audited every step: each physical block's refcount must
//!    equal the number of live block-table references to it, and every
//!    page must be back on the free list when the trace drains (no
//!    leaks, no double frees).
//! 2. **Model-level state**: prefill + decode through a paged cache
//!    (tiny blocks, real pool-managed tables) must leave bit-identical
//!    logits at every step and bit-identical logical K/V rows, hash
//!    codes and Quest min/max summaries, for every method in the zoo.
//!
//! Plus the sharing properties the tentpole claims: shared prefixes are
//! stored once (refcount > 1 while both holders live, `prefix_hits`
//! metric counts the saved blocks), preempt/resume recomputes nothing
//! (`prefill_tokens` equals the sum of prompt lengths), and
//! copy-on-write never mutates a shared block in place.
//!
//! The block size under test comes from `HATA_KV_BLOCK` (the CI paged
//! leg sets 4); the tiny default forces many blocks, boundary crossings
//! and partial tail blocks.

use std::sync::Arc;

use hata::config::{preset, ExecMode, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::{FinishReason, Request};
use hata::kvcache::pool::KvPool;
use hata::kvcache::{BlockStore, MethodAux, SeqKvCache};
use hata::model::{make_selector, sel_ref, weights::Weights, DecodeScratch, Model, SeqState};
use hata::tensor::ops::argmax;
use hata::tensor::simd::KernelMode;
use hata::util::rng::Rng;

const METHODS: [Method; 9] = [
    Method::Dense,
    Method::ExactTopK,
    Method::Hata,
    Method::Loki,
    Method::Quest,
    Method::MagicPig,
    Method::StreamingLlm,
    Method::H2o,
    Method::SnapKv,
];

/// Physical block size under test: `HATA_KV_BLOCK` or a tiny default
/// that maximizes block-boundary traffic.
fn kv_block() -> usize {
    std::env::var("HATA_KV_BLOCK").ok().and_then(|s| s.parse().ok()).unwrap_or(4)
}

/// One request of a trace: prompt, generation budget, and the engine
/// step at which it arrives.
struct TraceReq {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    arrive: usize,
}

/// A deterministic multi-request schedule. `preempts` are (step, id)
/// events applied before that step runs.
struct Trace {
    reqs: Vec<TraceReq>,
    preempts: Vec<(usize, u64)>,
    last_event: usize,
}

impl Trace {
    fn prompt_tokens_total(&self) -> u64 {
        self.reqs.iter().map(|r| r.prompt.len() as u64).sum()
    }
}

/// Six requests: two pairs share a 2-block prefix (ids 0/3 and 1/4),
/// two are unique — arrivals staggered so each pair's lifetimes
/// overlap and the second arrival's dedup lands while the first holder
/// is still decoding (that's what makes refcount > 1 observable).
fn build_trace(seed: u64, preempts: Vec<(usize, u64)>) -> Trace {
    let bt = kv_block();
    let mut rng = Rng::new(seed);
    let mut tok = |n: usize| -> Vec<u32> { (0..n).map(|_| 32 + rng.below(64) as u32).collect() };
    let prefix_a = tok(2 * bt);
    let prefix_b = tok(2 * bt);
    // (shared prefix, suffix length, max_new, arrival step)
    let specs: [(Option<&[u32]>, usize, usize, usize); 6] = [
        (Some(&prefix_a), 9, 6, 0),
        (Some(&prefix_b), 13, 6, 0),
        (None, 11 + bt, 4, 1),
        (Some(&prefix_a), 15, 4, 2),
        (Some(&prefix_b), 10 + bt, 4, 3),
        (None, 9, 3, 4),
    ];
    let mut reqs = Vec::new();
    for (id, (prefix, suffix, max_new, arrive)) in specs.into_iter().enumerate() {
        let mut prompt = prefix.map(<[u32]>::to_vec).unwrap_or_default();
        prompt.extend((0..suffix).map(|_| 32 + rng.below(64) as u32));
        reqs.push(TraceReq { id: id as u64, prompt, max_new, arrive });
    }
    let last_event = reqs
        .iter()
        .map(|r| r.arrive)
        .chain(preempts.iter().map(|p| p.0))
        .max()
        .unwrap_or(0);
    Trace { reqs, preempts, last_event }
}

/// Audit the pool against the set of sequences that could hold pages:
/// every minted block's refcount must equal the number of block-table
/// references to it, and the free-page count must match the blocks in
/// use. Returns the largest refcount seen (> 1 means a block is
/// physically shared right now).
fn check_conservation(pool: &KvPool, open_ids: &[u64]) -> u32 {
    let minted = pool.minted_pages();
    let mut counts = vec![0u32; minted];
    for &id in open_ids {
        for &b in pool.seq_blocks(id) {
            counts[b as usize] += 1;
        }
    }
    let mut in_use = 0usize;
    let mut max_rc = 0u32;
    for (b, &c) in counts.iter().enumerate() {
        assert_eq!(
            pool.refcount(b as u32),
            c,
            "block {b}: refcount diverged from live references (leak or double free)"
        );
        if c > 0 {
            in_use += 1;
        }
        max_rc = max_rc.max(c);
    }
    assert_eq!(
        pool.free_pages(),
        pool.capacity_pages() - in_use,
        "free-page accounting diverged from blocks in use"
    );
    max_rc
}

/// What one engine replay of a trace produced.
struct TraceRun {
    /// (id, generated tokens), sorted by id
    streams: Vec<(u64, Vec<u32>)>,
    prefix_hits: u64,
    prefill_tokens: u64,
    /// largest physical-block refcount observed at any step
    max_shared_rc: u32,
}

/// Replay `trace` through one engine build and collect the streams plus
/// the paged audit trail. The model is seeded identically for every
/// call, so two runs differ only in the axes passed here.
#[allow(clippy::too_many_arguments)]
fn run_trace(
    trace: &Trace,
    method: Method,
    threads: usize,
    tile: usize,
    exec_mode: ExecMode,
    graph_cache: bool,
    kernels: KernelMode,
    paged: bool,
) -> TraceRun {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 4,
        prefill_chunk: 48,
        prefill_tile: tile,
        threads,
        exec_mode,
        graph_cache,
        kernels,
        kv_block: kv_block(),
        paged,
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    let mut engine = Engine::new(Arc::new(model), serve);
    let mut open: Vec<u64> = Vec::new();
    let mut streams: Vec<(u64, Vec<u32>)> = Vec::new();
    let mut max_shared_rc = 0u32;
    let mut step = 0usize;
    loop {
        for r in trace.reqs.iter().filter(|r| r.arrive == step) {
            engine.submit(Request {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new,
                stop_token: None,
                arrival: 0.0,
            });
            open.push(r.id);
        }
        for &(_, id) in trace.preempts.iter().filter(|(s, _)| *s == step) {
            engine.preempt(id);
        }
        engine.step();
        for resp in engine.take_responses() {
            assert_eq!(resp.reason, FinishReason::MaxTokens, "request {} must finish", resp.id);
            open.retain(|&id| id != resp.id);
            streams.push((resp.id, resp.tokens));
        }
        if paged {
            max_shared_rc = max_shared_rc.max(check_conservation(engine.pool(), &open));
        }
        step += 1;
        if step > trace.last_event && !engine.has_work() {
            break;
        }
        assert!(step < 10_000, "trace did not converge");
    }
    assert!(open.is_empty(), "every request must complete");
    if paged {
        let pool = engine.pool();
        assert_eq!(pool.active_seqs(), 0, "pool leak: sequences still hold pages");
        assert_eq!(pool.free_pages(), pool.capacity_pages(), "pool leak: pages not returned");
    }
    streams.sort_by_key(|(id, _)| *id);
    TraceRun {
        streams,
        prefix_hits: engine.metrics.prefix_hits,
        prefill_tokens: engine.metrics.prefill_tokens,
        max_shared_rc,
    }
}

/// The tentpole differential, widest axis: for every method in the
/// zoo, a paged engine must emit exactly the contiguous engine's token
/// streams on a shared-prefix trace — while the step-by-step pool audit
/// inside `run_trace` proves no block ever leaks, double-frees, or
/// carries a wrong refcount. Sharing must actually happen: the paged
/// run must observe refcount > 1 and count prefix hits.
#[test]
fn paged_engine_bitwise_identical_for_every_method() {
    let trace = build_trace(11, Vec::new());
    for method in METHODS {
        let flat = run_trace(&trace, method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, false);
        let paged = run_trace(&trace, method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, true);
        assert_eq!(flat.streams, paged.streams, "{method:?}: paged streams diverged");
        assert!(paged.prefix_hits > 0, "{method:?}: shared prefixes must produce dedup hits");
        assert!(paged.max_shared_rc > 1, "{method:?}: a shared block must be refcounted > 1");
        assert_eq!(flat.prefix_hits, 0, "{method:?}: contiguous engines never dedup");
    }
}

/// The remaining parallel.rs axes: threads × tile × executor ×
/// graph-cache × kernel tier, paged vs contiguous, on the selector
/// methods with the most layout-sensitive access patterns.
#[test]
fn paged_engine_identical_across_axes() {
    let trace = build_trace(23, Vec::new());
    let cells: &[(usize, usize, ExecMode, bool, KernelMode)] = &[
        (1, 1, ExecMode::Barrier, true, KernelMode::Reference),
        (2, 16, ExecMode::Queue, true, KernelMode::Simd),
        (4, 7, ExecMode::Queue, false, KernelMode::Simd),
        (2, 16, ExecMode::Barrier, false, KernelMode::Reference),
    ];
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        for &(threads, tile, exec, gc, kernels) in cells {
            let flat = run_trace(&trace, method, threads, tile, exec, gc, kernels, false);
            let paged = run_trace(&trace, method, threads, tile, exec, gc, kernels, true);
            assert_eq!(
                flat.streams, paged.streams,
                "{method:?} threads={threads} tile={tile} {exec:?} gc={gc} {kernels:?}"
            );
        }
    }
}

/// Preempt/resume must recompute nothing: block tables make held pages
/// cheap, so a preempted sequence resumes exactly where it stopped.
/// `prefill_tokens` counts every chunk actually run — if a resume ever
/// re-prefilled, the counter would exceed the sum of prompt lengths.
/// And the token streams still match a contiguous run that was never
/// preempted at all.
#[test]
fn preempt_resume_recomputes_nothing() {
    let quiet = build_trace(31, Vec::new());
    let stormy = build_trace(31, vec![(2, 0), (3, 1), (5, 3), (6, 2)]);
    for method in [Method::Dense, Method::Hata] {
        let flat = run_trace(&quiet, method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, false);
        let paged =
            run_trace(&stormy, method, 2, 16, ExecMode::Queue, true, KernelMode::Simd, true);
        assert_eq!(
            flat.streams, paged.streams,
            "{method:?}: preempted paged run diverged from quiet contiguous run"
        );
        assert_eq!(
            paged.prefill_tokens,
            stormy.prompt_tokens_total(),
            "{method:?}: a resumed sequence re-prefilled a chunk (recompute)"
        );
    }
}

/// Model-level bitwise identity for the whole method zoo: logits after
/// prefill and after every decode step, plus logical K/V rows, hash
/// codes and Quest block summaries, must match the contiguous build
/// bit for bit — with the paged cache running on pool-managed tables
/// exactly as the engine drives them.
#[test]
fn paged_model_bitwise_state_for_every_method() {
    let bt = kv_block();
    for method in METHODS {
        let serve = ServeConfig { method, budget: 16, kv_block: bt, ..Default::default() };
        let cfg = preset("hata-gqa").unwrap();
        let mut rng = Rng::new(7);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        let model = Model::new(cfg, weights, aux);
        let selector = make_selector(&serve);
        let sel = sel_ref(&selector);
        let decode_steps = 6usize;
        // prompt crosses many block boundaries and ends mid-block
        let prompt: Vec<u32> = (0..(10 * bt + 3) as u32).map(|i| 32 + (i % 64)).collect();

        let mut c1 = SeqKvCache::new(&model.cfg, &serve);
        let mut s1 = SeqState::new(&model.cfg);
        let mut sc1 = DecodeScratch::new(&model.cfg);
        model.prefill(&prompt, &mut c1, &mut s1, &serve, &mut sc1);

        let mut pool = KvPool::with_block(1024 * bt, bt);
        let planes = model.cfg.n_layers * model.cfg.n_kv_heads;
        let store = Arc::new(BlockStore::new(
            planes,
            model.cfg.head_dim,
            model.cfg.rbit / 64,
            bt,
            serve.kv_dtype,
        ));
        let mut c2 = SeqKvCache::new_paged(&model.cfg, &serve, Arc::clone(&store));
        c2.reserve(prompt.len() + decode_steps + 1);
        let mut s2 = SeqState::new(&model.cfg);
        let mut sc2 = DecodeScratch::new(&model.cfg);
        pool.grow(1, prompt.len()).unwrap();
        // SAFETY: single-threaded test, no live views of the store
        unsafe { store.ensure_blocks(pool.minted_pages()) };
        c2.sync_table(pool.seq_blocks(1));
        model.prefill(&prompt, &mut c2, &mut s2, &serve, &mut sc2);
        assert_eq!(sc1.logits, sc2.logits, "{method:?}: prefill logits diverged");

        let mut next = argmax(&sc1.logits) as u32;
        for step in 0..decode_steps {
            let pos = prompt.len() + step;
            pool.grow(1, 1).unwrap();
            // SAFETY: single-threaded test, no live views of the store
            unsafe { store.ensure_blocks(pool.minted_pages()) };
            c2.sync_table(pool.seq_blocks(1));
            model.decode_step(next, pos, &mut c1, &mut s1, &serve, sel, &mut sc1);
            model.decode_step(next, pos, &mut c2, &mut s2, &serve, sel, &mut sc2);
            assert_eq!(sc1.logits, sc2.logits, "{method:?}: step {step} logits diverged");
            next = argmax(&sc1.logits) as u32;
        }
        for li in 0..model.cfg.n_layers {
            for kv in 0..model.cfg.n_kv_heads {
                assert_eq!(
                    c1.k_slice(li, kv),
                    c2.k_logical(li, kv),
                    "{method:?}: K rows diverged l{li} kv{kv}"
                );
                assert_eq!(
                    c1.v_slice(li, kv),
                    c2.v_logical(li, kv),
                    "{method:?}: V rows diverged l{li} kv{kv}"
                );
                if method == Method::Hata {
                    assert_eq!(
                        c1.codes_slice(li, kv),
                        c2.codes_logical(li, kv),
                        "{method:?}: hash codes diverged l{li} kv{kv}"
                    );
                }
                let hw = model.weights.hash_head(li, kv);
                let a = c1.side(li, kv, hw, &model.aux);
                let b = c2.side(li, kv, hw, &model.aux);
                assert_eq!(a.quest_min, b.quest_min, "{method:?}: quest_min l{li} kv{kv}");
                assert_eq!(a.quest_max, b.quest_max, "{method:?}: quest_max l{li} kv{kv}");
            }
        }
    }
}

/// Copy-on-write correctness as a property: fork a prefilled sequence,
/// unshare the partial tail block, decode on the child — and the
/// parent's every logical K/V/code row must be byte-identical to its
/// pre-fork snapshot. A single in-place write to a shared block would
/// flip parent bytes and fail this.
#[test]
fn cow_fork_never_mutates_parent_blocks() {
    let bt = kv_block();
    let serve =
        ServeConfig { method: Method::Hata, budget: 16, kv_block: bt, ..Default::default() };
    let cfg = preset("hata-gqa").unwrap();
    let mut rng = Rng::new(9);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let model = Model::new(cfg, weights, aux);
    let selector = make_selector(&serve);
    let sel = sel_ref(&selector);
    // ends mid-block for bt > 1, so the fork shares a partial tail
    let plen = 2 * bt + bt.div_ceil(2);
    let prompt: Vec<u32> = (0..plen as u32).map(|i| 32 + (i * 5 % 64)).collect();

    let mut pool = KvPool::with_block(256 * bt, bt);
    let planes = model.cfg.n_layers * model.cfg.n_kv_heads;
    let store = Arc::new(BlockStore::new(
        planes,
        model.cfg.head_dim,
        model.cfg.rbit / 64,
        bt,
        serve.kv_dtype,
    ));
    let mut parent = SeqKvCache::new_paged(&model.cfg, &serve, Arc::clone(&store));
    parent.reserve(prompt.len() + 4);
    let mut ps = SeqState::new(&model.cfg);
    let mut psc = DecodeScratch::new(&model.cfg);
    pool.grow(1, prompt.len()).unwrap();
    // SAFETY: single-threaded test, no live views of the store
    unsafe { store.ensure_blocks(pool.minted_pages()) };
    parent.sync_table(pool.seq_blocks(1));
    model.prefill(&prompt, &mut parent, &mut ps, &serve, &mut psc);

    // snapshot every logical row of the parent
    let snap: Vec<(Vec<f32>, Vec<f32>, Vec<u64>)> = (0..model.cfg.n_layers)
        .flat_map(|li| (0..model.cfg.n_kv_heads).map(move |kv| (li, kv)))
        .map(|(li, kv)| {
            (parent.k_logical(li, kv), parent.v_logical(li, kv), parent.codes_logical(li, kv))
        })
        .collect();

    let minted_before = pool.minted_pages();
    let mut child = parent.fork_paged(&mut pool, 1, 2).unwrap();
    assert_eq!(pool.minted_pages(), minted_before, "fork must mint zero pages");
    for &b in pool.seq_blocks(1) {
        assert_eq!(pool.refcount(b), 2, "every parent block must be shared after fork");
    }
    assert_eq!(child.block_table(), pool.seq_blocks(1), "child aliases the parent's blocks");

    // unshare the partial tail block the child is about to append into
    if plen % bt != 0 {
        let idx = plen / bt;
        let copied = child.make_writable(&mut pool, 2, idx).unwrap();
        assert!(copied, "a shared tail block must be copied, never written in place");
        assert_eq!(pool.refcount(pool.seq_blocks(1)[idx]), 1, "parent tail unshared again");
    }

    // decode two tokens on the child only
    let mut cs = SeqState::new(&model.cfg);
    let mut csc = DecodeScratch::new(&model.cfg);
    child.reserve(prompt.len() + 4);
    let mut next = argmax(&psc.logits) as u32;
    for step in 0..2 {
        pool.grow(2, 1).unwrap();
        // SAFETY: single-threaded test, no live views of the store
        unsafe { store.ensure_blocks(pool.minted_pages()) };
        child.sync_table(pool.seq_blocks(2));
        model.decode_step(next, plen + step, &mut child, &mut cs, &serve, sel, &mut csc);
        next = argmax(&csc.logits) as u32;
    }

    // the parent's bytes are untouched; the child agrees on the prefix
    for li in 0..model.cfg.n_layers {
        for kv in 0..model.cfg.n_kv_heads {
            let (k, v, codes) = &snap[li * model.cfg.n_kv_heads + kv];
            assert_eq!(&parent.k_logical(li, kv), k, "parent K mutated l{li} kv{kv}");
            assert_eq!(&parent.v_logical(li, kv), v, "parent V mutated l{li} kv{kv}");
            assert_eq!(&parent.codes_logical(li, kv), codes, "parent codes mutated l{li} kv{kv}");
            assert_eq!(
                child.k_logical(li, kv)[..k.len()],
                k[..],
                "child prefix diverged l{li} kv{kv}"
            );
        }
    }
    assert_eq!(child.len(), parent.len() + 2);

    // teardown conserves every page
    pool.release(1).unwrap();
    pool.release(2).unwrap();
    assert_eq!(pool.active_seqs(), 0);
    assert_eq!(pool.free_pages(), pool.capacity_pages(), "leak after release");
}
