//! Differential harness for the KV residency tier (`--offload`).
//!
//! Offloading moves real bytes: cold K/V blocks are written back to a
//! slow-tier store (their device rows poisoned with NaN), and decode
//! fetches back only the blocks its top-k selection touches, scoring the
//! always-resident code cache first. The proof obligations:
//!
//! 1. **Bit-identity**: an engine running with `--offload` under maximum
//!    pressure (budget 0 — only append-target tail blocks stay resident)
//!    must emit exactly the token streams of a fully-resident paged
//!    engine, for every method in the zoo and across the executor /
//!    thread / kernel / prefetch-depth axes. The NaN poison makes this a
//!    strong claim: any read that bypasses the fetch path corrupts
//!    logits and fails the comparison, so passing proves every consumed
//!    row was genuinely restored from the slow tier.
//! 2. **The tier actually ran**: fetches and evictions must be observed
//!    (> 0), and with layer-ahead prefetch enabled, prefetch-issued
//!    copies must be observed too.
//! 3. **Accounting**: the modeled transfer ledger must agree exactly
//!    with the fetch counters (`bytes == fetched_planes * plane_bytes`)
//!    and with the PCIe model's pricing, and the measured wall-clock
//!    must be populated. `benches/table3_offload.rs` carries the
//!    modeled-vs-measured prediction-error figure.
//!
//! Block size comes from `HATA_KV_BLOCK` (CI's offload leg sets 4).

use std::sync::Arc;

use hata::config::{preset, ExecMode, Method, ServeConfig};
use hata::coordinator::engine::Engine;
use hata::coordinator::request::{FinishReason, Request};
use hata::kvcache::tier::OffloadStats;
use hata::kvcache::MethodAux;
use hata::model::{weights::Weights, Model};
use hata::tensor::simd::KernelMode;
use hata::util::rng::Rng;

const METHODS: [Method; 9] = [
    Method::Dense,
    Method::ExactTopK,
    Method::Hata,
    Method::Loki,
    Method::Quest,
    Method::MagicPig,
    Method::StreamingLlm,
    Method::H2o,
    Method::SnapKv,
];

/// Physical block size under test (`HATA_KV_BLOCK` or a tiny default).
fn kv_block() -> usize {
    std::env::var("HATA_KV_BLOCK").ok().and_then(|s| s.parse().ok()).unwrap_or(4)
}

struct TraceReq {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    arrive: usize,
}

/// A deterministic multi-request schedule with staggered arrivals and
/// shared prefixes (same shape as the paged harness, so the offload run
/// also exercises dedup'd blocks spilling and refetching).
fn build_trace(seed: u64) -> Vec<TraceReq> {
    let bt = kv_block();
    let mut rng = Rng::new(seed);
    let mut tok = |n: usize| -> Vec<u32> { (0..n).map(|_| 32 + rng.below(64) as u32).collect() };
    let prefix_a = tok(2 * bt);
    let prefix_b = tok(2 * bt);
    let specs: [(Option<&[u32]>, usize, usize, usize); 6] = [
        (Some(&prefix_a), 9, 6, 0),
        (Some(&prefix_b), 13, 6, 0),
        (None, 11 + bt, 4, 1),
        (Some(&prefix_a), 15, 4, 2),
        (Some(&prefix_b), 10 + bt, 4, 3),
        (None, 9, 3, 4),
    ];
    specs
        .into_iter()
        .enumerate()
        .map(|(id, (prefix, suffix, max_new, arrive))| {
            let mut prompt = prefix.map(<[u32]>::to_vec).unwrap_or_default();
            prompt.extend((0..suffix).map(|_| 32 + rng.below(64) as u32));
            TraceReq { id: id as u64, prompt, max_new, arrive }
        })
        .collect()
}

struct TraceRun {
    /// (id, generated tokens), sorted by id
    streams: Vec<(u64, Vec<u32>)>,
    offload: Option<OffloadStats>,
}

/// Replay a trace through one engine build. `offload` is
/// `Some((budget_tokens, prefetch_depth))`; `None` runs the resident
/// paged reference.
fn run_trace(
    reqs: &[TraceReq],
    method: Method,
    threads: usize,
    exec_mode: ExecMode,
    kernels: KernelMode,
    offload: Option<(usize, usize)>,
) -> TraceRun {
    let cfg = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        max_batch: 4,
        prefill_chunk: 48,
        prefill_tile: 16,
        threads,
        exec_mode,
        graph_cache: true,
        kernels,
        kv_block: kv_block(),
        paged: true,
        offload: offload.is_some(),
        offload_budget: offload.map_or(0, |(b, _)| b),
        prefetch_depth: offload.map_or(1, |(_, d)| d),
        ..Default::default()
    };
    let mut rng = Rng::new(42);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    let mut engine = Engine::new(Arc::new(model), serve);
    let mut open: Vec<u64> = Vec::new();
    let mut streams: Vec<(u64, Vec<u32>)> = Vec::new();
    let last_arrival = reqs.iter().map(|r| r.arrive).max().unwrap_or(0);
    let mut step = 0usize;
    loop {
        for r in reqs.iter().filter(|r| r.arrive == step) {
            engine.submit(Request {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new,
                stop_token: None,
                arrival: 0.0,
            });
            open.push(r.id);
        }
        engine.step();
        for resp in engine.take_responses() {
            assert_eq!(resp.reason, FinishReason::MaxTokens, "request {} must finish", resp.id);
            open.retain(|&id| id != resp.id);
            streams.push((resp.id, resp.tokens));
        }
        step += 1;
        if step > last_arrival && !engine.has_work() {
            break;
        }
        assert!(step < 10_000, "trace did not converge");
    }
    assert!(open.is_empty(), "every request must complete");
    streams.sort_by_key(|(id, _)| *id);
    TraceRun { streams, offload: engine.metrics.offload }
}

/// The tentpole differential, widest axis: under maximum offload
/// pressure (budget 0), every method's token streams must match the
/// resident paged engine bit for bit — while evictions and fetches are
/// actually happening (NaN poison guarantees a bypassed fetch would
/// corrupt the comparison, so this cannot pass vacuously).
#[test]
fn offload_engine_bitwise_identical_for_every_method() {
    let reqs = build_trace(11);
    for method in METHODS {
        let resident = run_trace(&reqs, method, 2, ExecMode::Queue, KernelMode::Simd, None);
        let tiered = run_trace(&reqs, method, 2, ExecMode::Queue, KernelMode::Simd, Some((0, 1)));
        assert_eq!(resident.streams, tiered.streams, "{method:?}: offload streams diverged");
        let o = tiered.offload.expect("offload run reports tier stats");
        assert!(o.evictions > 0, "{method:?}: budget 0 must evict cold blocks");
        assert!(
            o.demand_fetches + o.prefetch_fetches > 0,
            "{method:?}: spilled blocks must be fetched back"
        );
        assert!(resident.offload.is_none(), "{method:?}: resident run has no tier");
    }
}

/// The remaining axes: threads × executor × kernel tier × prefetch
/// depth (0 = fetch at the layer itself, 2 = two layers of lookahead)
/// × a non-zero block budget, on the most layout-sensitive methods.
#[test]
fn offload_engine_identical_across_axes() {
    let reqs = build_trace(23);
    let bt = kv_block();
    let cells: &[(usize, ExecMode, KernelMode, usize, usize)] = &[
        (1, ExecMode::Barrier, KernelMode::Reference, 0, 1),
        (4, ExecMode::Queue, KernelMode::Simd, 0, 0),
        (2, ExecMode::Queue, KernelMode::Simd, 0, 2),
        (2, ExecMode::Queue, KernelMode::Simd, 4 * bt, 1),
        (2, ExecMode::Barrier, KernelMode::Reference, 2 * bt, 1),
    ];
    for method in [Method::Dense, Method::Hata, Method::Quest] {
        for &(threads, exec, kernels, budget, depth) in cells {
            let resident = run_trace(&reqs, method, threads, exec, kernels, None);
            let tiered = run_trace(&reqs, method, threads, exec, kernels, Some((budget, depth)));
            assert_eq!(
                resident.streams, tiered.streams,
                "{method:?} threads={threads} {exec:?} {kernels:?} budget={budget} depth={depth}"
            );
        }
    }
}

/// Layer-ahead prefetch must actually issue copies: under budget 0 a
/// head's selected blocks are evicted again after every step, so the
/// next step's prefetch task (released one layer ahead) re-fetches them
/// before the attention task runs. Depth 0 still fetches, but strictly
/// on the demand path's behalf within the same layer.
#[test]
fn prefetch_tasks_issue_fetches() {
    let reqs = build_trace(31);
    let tiered = run_trace(&reqs, Method::Hata, 2, ExecMode::Queue, KernelMode::Simd, Some((0, 1)));
    let o = tiered.offload.expect("tier stats");
    assert!(o.prefetch_fetches > 0, "layer-ahead prefetch must fetch spilled blocks: {o:?}");
    assert!(o.hits > 0, "prefetched blocks must turn later residency checks into hits");
}

/// A budget large enough to hold every block means the tier never
/// spills: zero evictions, zero fetches, and the streams still match.
#[test]
fn ample_budget_never_spills() {
    let reqs = build_trace(47);
    let resident = run_trace(&reqs, Method::Hata, 2, ExecMode::Queue, KernelMode::Simd, None);
    let tiered =
        run_trace(&reqs, Method::Hata, 2, ExecMode::Queue, KernelMode::Simd, Some((1 << 20, 1)));
    assert_eq!(resident.streams, tiered.streams);
    let o = tiered.offload.expect("tier stats");
    assert_eq!(o.evictions, 0, "ample budget must not evict: {o:?}");
    assert_eq!(o.demand_fetches + o.prefetch_fetches, 0, "nothing spilled, nothing fetched");
    assert_eq!(o.fetch.bytes, 0);
}

/// The modeled ledger must agree exactly with the counters and the PCIe
/// model: every fetched block-plane moves `2 * block_tokens * head_dim`
/// f32 rows, every pass is one priced gather, and eviction bytes mirror
/// fetch bytes for blocks that spill whole. Measured wall-clock must be
/// populated whenever modeled seconds are.
#[test]
fn ledger_accounting_is_exact() {
    let reqs = build_trace(59);
    let tiered = run_trace(&reqs, Method::Hata, 2, ExecMode::Queue, KernelMode::Simd, Some((0, 1)));
    let o = tiered.offload.expect("tier stats");
    let cfg = preset("hata-gqa").unwrap();
    let plane_bytes = (2 * kv_block() * cfg.head_dim * 4) as u64;
    let fetched = o.demand_fetches + o.prefetch_fetches;
    assert_eq!(o.fetch.bytes, fetched * plane_bytes, "fetch bytes must count fetched planes");
    assert_eq!(o.evict.bytes % plane_bytes, 0, "evict bytes are whole planes");
    assert!(o.evict.bytes > 0);
    // every pass is one gather: transfers <= fetched planes, and the
    // modeled seconds are bounded by the PCIe model's bandwidth term
    // plus per-batch descriptor latency (8 rows per batch, 2*bt rows
    // per fetched plane — see PcieModel::gather_time)
    let pcie = hata::simulator::pcie::PcieModel::gen4_x16();
    assert!(o.fetch.transfers <= fetched, "one gather per fetch pass");
    let bw_term = o.fetch.bytes as f64 / pcie.bandwidth;
    let rows = (fetched as usize) * 2 * kv_block();
    let max_latency = pcie.latency * (rows as f64 / 8.0 + o.fetch.transfers as f64);
    assert!(o.fetch.seconds >= bw_term, "modeled seconds below bandwidth floor");
    assert!(o.fetch.seconds <= bw_term + max_latency, "modeled seconds above latency ceiling");
    assert!(o.measured_fetch_s > 0.0, "measured fetch wall-clock must be populated");
    assert!(o.measured_evict_s > 0.0, "measured evict wall-clock must be populated");
}

/// `--offload` implies the paged layout even when the caller forgot
/// `--paged`: the engine forces it before building the store.
#[test]
fn offload_forces_paged_layout() {
    let cfg = preset("hata-gqa").unwrap();
    let mut rng = Rng::new(1);
    let weights = Weights::random(&cfg, &mut rng);
    let serve = ServeConfig {
        method: Method::Hata,
        budget: 16,
        offload: true,
        paged: false,
        ..Default::default()
    };
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let engine = Engine::new(Arc::new(Model::new(cfg, weights, aux)), serve);
    assert!(engine.serve.paged, "offload must imply the paged layout");
}
