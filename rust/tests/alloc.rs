//! Zero-allocation steady-state decode enforcement.
//!
//! A counting global allocator wraps `System` and tallies every
//! `alloc`/`alloc_zeroed`/`realloc` while a flag is armed. The test
//! warms up a batched decode loop (queue executor, graph cache on),
//! pre-reserves every buffer that legitimately grows with context
//! (KV caches via [`SeqKvCache::reserve`], worker scratch arenas), arms
//! the counter, runs further decode steps, and asserts the count is
//! **zero** for every method × thread-count cell.
//!
//! If any hot-path temporary (a selector `Vec::new`, a rebuilt task
//! graph, a boxed pool job) is reintroduced, this test fails — that is
//! its entire purpose. A negative control with `--graph-cache off`
//! (which intentionally rebuilds the graph every step) verifies the
//! counter actually observes the hot path.
//!
//! The matrix runs the paged KV layout too (`--paged`): block-table
//! growth and plane resizes happen on the engine thread *between*
//! steps (`KvPool::grow` + `BlockStore::ensure_blocks` + `sync_table`,
//! mirroring `Engine::step`), so the armed decode window must stay
//! allocation-free through the block-table indirection as well.
//!
//! Everything lives in ONE `#[test]` so no sibling test thread can
//! allocate while the counter is armed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use hata::config::{preset, ExecMode, Method, ModelConfig, ServeConfig};
use hata::kvcache::pool::KvPool;
use hata::kvcache::{BlockStore, MethodAux, SeqKvCache};
use hata::model::{
    make_selector, sel_ref, weights::Weights, DecodeGraphCache, DecodeItem, DecodeScratch, Model,
    SeqState, WorkerScratch,
};
use hata::tensor::ops::argmax;
use hata::util::rng::Rng;
use hata::util::threadpool::ThreadPool;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// `System` allocator wrapper that counts allocation events (from any
/// thread) while `COUNTING` is armed. Deallocations are free.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Grow a vector's capacity to at least `total` without changing its
/// length-semantics (contents are overwritten by every consumer).
fn prewarm<T>(v: &mut Vec<T>, total: usize) {
    if v.capacity() < total {
        v.reserve(total - v.len());
    }
}

/// Pre-size every selection/attention buffer a worker arena might need
/// up to context length `max_s`. Task→worker placement is
/// nondeterministic under threads > 1, so a worker may first see the
/// longest sequence inside the measured window — warming by running is
/// not deterministic, reserving explicitly is. Sizes are derived from
/// the config so raising `rbit`/`magicpig_l`/group later cannot turn a
/// harness shortfall into a false hot-path failure.
fn prewarm_worker(ws: &mut WorkerScratch, max_s: usize, cfg: &ModelConfig, serve: &ServeConfig) {
    let dh = cfg.head_dim;
    let group = cfg.group();
    let sc = &mut ws.sel;
    prewarm(&mut sc.scores, max_s);
    prewarm(&mut sc.iscores, max_s);
    prewarm(&mut sc.indices, max_s);
    prewarm(&mut sc.probs, max_s);
    prewarm(&mut sc.qcodes, max_s.max(group * (cfg.rbit / 64)));
    prewarm(&mut sc.fbuf, max_s);
    // counting-select histograms: one slot per score value — Hamming
    // scores reach group*rbit, MagicPIG collision counts reach mp_l
    prewarm(&mut sc.hist, group * cfg.rbit + 1 + serve.magicpig_l);
    prewarm(&mut sc.perm, max_s);
    prewarm(&mut sc.idxbuf, max_s);
    prewarm(&mut sc.sigbuf, serve.magicpig_l);
    prewarm(&mut ws.kgather, max_s * dh);
    prewarm(&mut ws.vgather, max_s * dh);
}

const WARM_STEPS: usize = 12;
const MEASURED_STEPS: usize = 4;

/// Run prefill + WARM_STEPS decode steps cold, then MEASURED_STEPS with
/// the allocation counter armed around each `decode_batch` call (the
/// "decode step" under test). Returns the armed-window event count.
/// With `paged`, the caches run on pool-managed block tables (tiny
/// 4-token blocks) grown between steps, exactly as the engine does.
fn steady_state_allocs(method: Method, threads: usize, graph_cache: bool, paged: bool) -> u64 {
    const BT: usize = 4;
    let cfg: ModelConfig = preset("hata-gqa").unwrap();
    let serve = ServeConfig {
        method,
        budget: 16,
        threads,
        exec_mode: ExecMode::Queue,
        graph_cache,
        kv_block: BT,
        ..Default::default()
    };
    let mut rng = Rng::new(5);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, &serve, None, 1);
    let model = Model::new(cfg, weights, aux);
    let sel = make_selector(&serve);
    let pool = ThreadPool::new(threads);
    let mut workers: Vec<WorkerScratch> = (0..threads).map(|_| WorkerScratch::default()).collect();
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|s| (0..(48 + s * 11)).map(|i| 32 + (i as u32 % 64)).collect())
        .collect();
    let total_steps = WARM_STEPS + MEASURED_STEPS;
    let max_s = prompts.iter().map(|p| p.len()).max().unwrap() + total_steps + 1;
    for w in workers.iter_mut() {
        prewarm_worker(w, max_s, &model.cfg, &serve);
    }
    let mut kv_pool = KvPool::with_block(4096 * BT, BT);
    let store = Arc::new(BlockStore::new(
        model.cfg.n_layers * model.cfg.n_kv_heads,
        model.cfg.head_dim,
        model.cfg.rbit / 64,
        BT,
        serve.kv_dtype,
    ));
    let mut caches: Vec<SeqKvCache> = prompts
        .iter()
        .map(|_| {
            let mut c = if paged {
                SeqKvCache::new_paged(&model.cfg, &serve, Arc::clone(&store))
            } else {
                SeqKvCache::new(&model.cfg, &serve)
            };
            c.reserve(max_s);
            c
        })
        .collect();
    let mut states: Vec<SeqState> = prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
    // H2O's cumulative-mass vectors grow one slot per token; pre-size
    // them so steady-state resizes stay within capacity regardless of
    // the allocator's growth policy
    for st in states.iter_mut() {
        for h in st.per_head.iter_mut() {
            prewarm(&mut h.h2o_cum, max_s);
        }
    }
    let mut scratches: Vec<DecodeScratch> =
        prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
    let mut next: Vec<u32> = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        if paged {
            kv_pool.grow(i as u64, p.len()).unwrap();
            // SAFETY: no pass is running, so no worker holds a view
            unsafe { store.ensure_blocks(kv_pool.minted_pages()) };
            caches[i].sync_table(kv_pool.seq_blocks(i as u64));
        }
        model.prefill(p, &mut caches[i], &mut states[i], &serve, &mut scratches[i]);
        next.push(argmax(&scratches[i].logits) as u32);
    }
    let mut graph_cache_state = DecodeGraphCache::new();
    ALLOCS.store(0, Ordering::SeqCst);
    for step in 0..total_steps {
        if paged {
            // engine-thread work between passes, outside the armed
            // window — exactly where Engine::step does it
            for i in 0..prompts.len() {
                kv_pool.grow(i as u64, 1).unwrap();
            }
            // SAFETY: no pass is running, so no worker holds a view
            unsafe { store.ensure_blocks(kv_pool.minted_pages()) };
            for (i, c) in caches.iter_mut().enumerate() {
                c.sync_table(kv_pool.seq_blocks(i as u64));
            }
        }
        let mut items: Vec<DecodeItem> = caches
            .iter_mut()
            .zip(states.iter_mut())
            .zip(scratches.iter_mut())
            .enumerate()
            .map(|(i, ((cache, state), scratch))| DecodeItem {
                token: next[i],
                pos: prompts[i].len() + step,
                cache,
                state,
                scratch,
            })
            .collect();
        let armed = step >= WARM_STEPS;
        if armed {
            COUNTING.store(true, Ordering::SeqCst);
        }
        model.decode_batch(
            &mut items,
            &serve,
            sel_ref(&sel),
            &pool,
            &mut workers,
            &mut graph_cache_state,
        );
        if armed {
            COUNTING.store(false, Ordering::SeqCst);
        }
        drop(items);
        for (i, n) in next.iter_mut().enumerate() {
            *n = argmax(&scratches[i].logits) as u32;
        }
    }
    ALLOCS.load(Ordering::SeqCst)
}

/// The whole matrix in one test so no sibling test thread can allocate
/// while the counter is armed.
#[test]
fn warmed_decode_step_is_allocation_free() {
    let methods = [
        Method::Dense,
        Method::ExactTopK,
        Method::Hata,
        Method::Loki,
        Method::Quest,
        Method::MagicPig,
        Method::StreamingLlm,
        Method::H2o,
        Method::SnapKv,
    ];
    for method in methods {
        for threads in [1usize, 2, 8] {
            let n = steady_state_allocs(method, threads, true, false);
            assert_eq!(
                n, 0,
                "{method:?} threads={threads}: {n} allocation(s) in a warmed \
                 steady-state decode step (queue exec, graph cache on)"
            );
        }
        // paged layout: block-table growth happens between steps, so the
        // armed decode window must stay allocation-free here too
        for threads in [1usize, 2] {
            let n = steady_state_allocs(method, threads, true, true);
            assert_eq!(
                n, 0,
                "{method:?} threads={threads}: {n} allocation(s) in a warmed \
                 steady-state PAGED decode step (queue exec, graph cache on)"
            );
        }
    }
    // negative control: with the graph cache off every step rebuilds the
    // task graph, which MUST register as allocations — proving the
    // counter actually observes the decode hot path.
    let n = steady_state_allocs(Method::Hata, 2, false, false);
    assert!(n > 0, "counter saw nothing with graph cache off — harness is broken");
}
