//! Weight loading: the `.npz` checkpoints trained by
//! `python -m compile.train_model` / `train_hash`, in the flat dotted-key
//! layout both sides share (aot.py `param_order`).

use anyhow::{ensure, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::io::TensorStore;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One transformer block's parameters.
pub struct LayerWeights {
    /// Pre-attention RMSNorm gain, [d_model].
    pub attn_norm: Tensor,
    /// Query projection, [d_model, n_heads * dh].
    pub wq: Tensor,
    /// Key projection, [d_model, n_kv_heads * dh].
    pub wk: Tensor,
    /// Value projection, [d_model, n_kv_heads * dh].
    pub wv: Tensor,
    /// Attention output projection, [n_heads * dh, d_model].
    pub wo: Tensor,
    /// Pre-MLP RMSNorm gain, [d_model].
    pub mlp_norm: Tensor,
    /// MLP gate projection, [d_model, ffn_hidden].
    pub w_gate: Tensor,
    /// MLP up projection, [d_model, ffn_hidden].
    pub w_up: Tensor,
    /// MLP down projection, [ffn_hidden, d_model].
    pub w_down: Tensor,
}

/// Full model parameters + trained hash weights.
pub struct Weights {
    /// Token embedding table, [vocab, d_model].
    pub embed: Tensor,
    /// Final RMSNorm gain, [d_model].
    pub final_norm: Tensor,
    /// LM head, [d_model, vocab].
    pub lm_head: Tensor,
    /// Per-layer block parameters.
    pub layers: Vec<LayerWeights>,
    /// Per (layer, kv-head) hash projection, each [head_dim * rbit]
    /// row-major. Empty when no hash weights were loaded.
    pub hash: Vec<Vec<f32>>,
    hash_rbit: usize,
}

impl Weights {
    /// Load LM weights from an .npz checkpoint.
    pub fn load(path: &std::path::Path, cfg: &ModelConfig) -> Result<Weights> {
        let store = TensorStore::load(path)?;
        let get = |name: &str| -> Result<Tensor> { Ok(store.f32(name)?.clone()) };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            layers.push(LayerWeights {
                attn_norm: get(&format!("layers.{i}.attn_norm"))?,
                wq: get(&format!("layers.{i}.wq"))?,
                wk: get(&format!("layers.{i}.wk"))?,
                wv: get(&format!("layers.{i}.wv"))?,
                wo: get(&format!("layers.{i}.wo"))?,
                mlp_norm: get(&format!("layers.{i}.mlp_norm"))?,
                w_gate: get(&format!("layers.{i}.w_gate"))?,
                w_up: get(&format!("layers.{i}.w_up"))?,
                w_down: get(&format!("layers.{i}.w_down"))?,
            });
        }
        let w = Weights {
            embed: get("embed")?,
            final_norm: get("final_norm")?,
            lm_head: get("lm_head")?,
            layers,
            hash: Vec::new(),
            hash_rbit: 0,
        };
        w.validate(cfg)?;
        Ok(w)
    }

    /// Load trained hash weights ([L, KV, dh, rbit] npz, key "hash_w").
    pub fn load_hash(&mut self, path: &std::path::Path, cfg: &ModelConfig) -> Result<()> {
        let store = TensorStore::load(path)?;
        let t = store.f32("hash_w")?;
        let shape = t.shape();
        ensure!(
            shape.len() == 4
                && shape[0] == cfg.n_layers
                && shape[1] == cfg.n_kv_heads
                && shape[2] == cfg.head_dim,
            "hash_w shape {shape:?} does not match config"
        );
        let rbit = shape[3];
        let per = cfg.head_dim * rbit;
        self.hash = (0..cfg.n_layers * cfg.n_kv_heads)
            .map(|h| t.data()[h * per..(h + 1) * per].to_vec())
            .collect();
        self.hash_rbit = rbit;
        Ok(())
    }

    /// Hash projection for one head ([dh * rbit] row-major), empty slice
    /// when hashes are not loaded (dense-only serving).
    pub fn hash_head(&self, layer: usize, kv: usize) -> &[f32] {
        if self.hash.is_empty() {
            &[]
        } else {
            &self.hash[layer * (self.hash.len() / self.layers.len()) + kv]
        }
    }

    /// Bit width the loaded hash weights were trained for (0 = none).
    pub fn hash_rbit(&self) -> usize {
        self.hash_rbit
    }

    fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        ensure!(self.embed.shape() == [cfg.vocab, cfg.d_model], "embed shape");
        ensure!(
            self.lm_head.shape() == [cfg.d_model, cfg.vocab],
            "lm_head shape {:?}",
            self.lm_head.shape()
        );
        for (i, l) in self.layers.iter().enumerate() {
            ensure!(
                l.wq.shape() == [cfg.d_model, cfg.n_heads * cfg.head_dim],
                "layer {i} wq shape"
            );
            ensure!(
                l.wk.shape() == [cfg.d_model, cfg.n_kv_heads * cfg.head_dim],
                "layer {i} wk shape"
            );
            ensure!(
                l.w_down.shape() == [cfg.ffn_hidden, cfg.d_model],
                "layer {i} w_down shape"
            );
        }
        Ok(())
    }

    /// Random weights for tests and synthetic perf sweeps (never trained).
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> Weights {
        let t = |rng: &mut Rng, shape: Vec<usize>, scale: f32| {
            let n = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| rng.normal() * scale).collect())
        };
        let qd = cfg.n_heads * cfg.head_dim;
        let kvd = cfg.n_kv_heads * cfg.head_dim;
        let s = 1.0 / (cfg.d_model as f32).sqrt();
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: Tensor::new(vec![cfg.d_model], vec![1.0; cfg.d_model]),
                wq: t(rng, vec![cfg.d_model, qd], s),
                wk: t(rng, vec![cfg.d_model, kvd], s),
                wv: t(rng, vec![cfg.d_model, kvd], s),
                wo: t(rng, vec![qd, cfg.d_model], s),
                mlp_norm: Tensor::new(vec![cfg.d_model], vec![1.0; cfg.d_model]),
                w_gate: t(rng, vec![cfg.d_model, cfg.ffn_hidden], s),
                w_up: t(rng, vec![cfg.d_model, cfg.ffn_hidden], s),
                w_down: t(rng, vec![cfg.ffn_hidden, cfg.d_model], s),
            })
            .collect();
        let hash = (0..cfg.n_layers * cfg.n_kv_heads)
            .map(|_| {
                let scale = 1.0 / (cfg.head_dim as f32).sqrt();
                (0..cfg.head_dim * cfg.rbit).map(|_| rng.normal() * scale).collect()
            })
            .collect();
        Weights {
            embed: t(rng, vec![cfg.vocab, cfg.d_model], 0.02),
            final_norm: Tensor::new(vec![cfg.d_model], vec![1.0; cfg.d_model]),
            lm_head: t(rng, vec![cfg.d_model, cfg.vocab], s),
            layers,
            hash,
            hash_rbit: cfg.rbit,
        }
    }

    /// Load everything from an artifact manifest entry.
    pub fn from_artifacts(
        arts: &crate::config::manifest::ModelArtifacts,
        rbit: usize,
    ) -> Result<Weights> {
        let mut w = Weights::load(&arts.weights, &arts.config)?;
        let hw = arts
            .hash_weights_for(rbit)
            .with_context(|| format!("no hash weights for rbit {rbit}"))?;
        w.load_hash(hw, &arts.config)?;
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn random_weights_validate() {
        let cfg = preset("hata-gqa").unwrap();
        let mut rng = Rng::new(0);
        let w = Weights::random(&cfg, &mut rng);
        assert!(w.validate(&cfg).is_ok());
        assert_eq!(w.hash.len(), cfg.n_layers * cfg.n_kv_heads);
        assert_eq!(w.hash_head(1, 0).len(), cfg.head_dim * cfg.rbit);
    }

    #[test]
    fn hash_head_indexing_distinct() {
        let cfg = preset("hata-mha").unwrap();
        let mut rng = Rng::new(1);
        let w = Weights::random(&cfg, &mut rng);
        assert_ne!(w.hash_head(0, 0), w.hash_head(1, 3));
    }
}
