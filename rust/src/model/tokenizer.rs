//! Byte-level tokenizer: token id == ASCII byte (vocab 128), matching
//! `python/compile/data.py` `encode`/`decode`.

/// Encode text to token ids (non-ASCII replaced with '?').
pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().map(|b| if b < 128 { b as u32 } else { b'?' as u32 }).collect()
}

/// Decode token ids to text (ids masked to 7 bits).
pub fn decode(ids: &[u32]) -> String {
    ids.iter().map(|&i| ((i & 0x7F) as u8) as char).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "&ab=CD;?ab=";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn non_ascii_replaced() {
        assert_eq!(decode(&encode("é")), "??"); // 2 utf-8 bytes
    }

    #[test]
    fn ids_in_vocab() {
        assert!(encode("hello WORLD 123 &=?;").iter().all(|&i| i < 128));
    }
}
