//! Token sampling: greedy and temperature (seeded).

use crate::tensor::ops::argmax;
use crate::util::rng::Rng;

/// Token sampling policy applied to each step's logits.
#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    /// Argmax (deterministic, the serving default).
    Greedy,
    /// Softmax sampling at the given temperature (seeded per request).
    Temperature(f32),
}

impl Sampler {
    /// Pick the next token from `logits`.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::Temperature(t) => {
                let t = t.max(1e-3);
                let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f32> = logits.iter().map(|&l| ((l - max) / t).exp()).collect();
                let total: f32 = weights.iter().sum();
                let mut r = rng.f32() * total;
                for (i, &w) in weights.iter().enumerate() {
                    if r < w {
                        return i as u32;
                    }
                    r -= w;
                }
                (weights.len() - 1) as u32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 2.0, -1.0];
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(0);
        let logits = [0.0, 5.0, 1.0];
        for _ in 0..50 {
            assert_eq!(Sampler::Temperature(0.01).sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Rng::new(7);
        let logits = [1.0, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[Sampler::Temperature(1.0).sample(&logits, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
