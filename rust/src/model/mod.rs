//! Native CPU transformer engine: the Rust request-path twin of
//! `python/compile/model.py`, numerically parity-tested against JAX
//! goldens (rust/tests/parity.rs).
//!
//! The decode step is allocation-free (all buffers live in
//! [`DecodeScratch`]) and the attention stage is pluggable: any
//! [`crate::attention::Selector`] can drive top-k sparse attention, which
//! is exactly the paper's integration story.

pub mod sampler;
pub mod tokenizer;
pub mod weights;

use crate::attention::compute::{dense_attention, sparse_attention_fused, sparse_attention_gather};
use crate::attention::methods::h2o_accumulate;
use crate::attention::{AttnInputs, MethodState, Scratch, Selector};
use crate::config::{Method, ModelConfig, ServeConfig};
use crate::kvcache::{MethodAux, SeqKvCache};
use crate::tensor::ops::{rms_norm, rope_inplace, silu, vecmat};
use weights::Weights;

/// Reusable decode-step buffers (per worker thread).
pub struct DecodeScratch {
    x: Vec<f32>,
    h: Vec<f32>,
    /// last layer's rotated queries after a step (read by eval fidelity)
    pub q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    mlp: Vec<f32>,
    kgather: Vec<f32>,
    vgather: Vec<f32>,
    pub logits: Vec<f32>,
    pub sel: Scratch,
}

impl DecodeScratch {
    pub fn new(cfg: &ModelConfig) -> Self {
        DecodeScratch {
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * cfg.head_dim],
            k: vec![0.0; cfg.n_kv_heads * cfg.head_dim],
            v: vec![0.0; cfg.n_kv_heads * cfg.head_dim],
            attn: vec![0.0; cfg.n_heads * cfg.head_dim],
            gate: vec![0.0; cfg.ffn_hidden],
            up: vec![0.0; cfg.ffn_hidden],
            mlp: vec![0.0; cfg.d_model],
            kgather: Vec::new(),
            vgather: Vec::new(),
            logits: vec![0.0; cfg.vocab],
            sel: Scratch::default(),
        }
    }
}

/// Per-sequence method state for all (layer, kv) heads.
pub struct SeqState {
    pub per_head: Vec<MethodState>,
}

impl SeqState {
    pub fn new(cfg: &ModelConfig) -> Self {
        SeqState { per_head: vec![MethodState::default(); cfg.n_layers * cfg.n_kv_heads] }
    }
}

/// Which sparse-attention compute variant the engine uses (Fig. 9
/// 'FusedAttn' ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseKernel {
    Gather,
    Fused,
}

/// The model: weights + config + per-model method constants.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: Weights,
    pub aux: MethodAux,
    pub sparse_kernel: SparseKernel,
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Weights, aux: MethodAux) -> Self {
        Model { cfg, weights, aux, sparse_kernel: SparseKernel::Fused }
    }

    /// One decode step (paper Alg. 3 embedded in the full block stack).
    ///
    /// Appends `token`'s K/V (and hash codes) to `cache`, runs the
    /// configured attention per (layer, kv-head), returns argmax-ready
    /// logits in `scratch.logits`.
    pub fn decode_step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        scratch: &mut DecodeScratch,
    ) {
        let cfg = &self.cfg;
        let w = &self.weights;
        scratch.x.copy_from_slice(w.embed.row(token as usize));
        for li in 0..cfg.n_layers {
            let lw = &w.layers[li];
            // ---- attention block
            rms_norm(&scratch.x, lw.attn_norm.data(), &mut scratch.h, 1e-5);
            vecmat(&scratch.h, lw.wq.data(), cfg.n_heads * cfg.head_dim, &mut scratch.q);
            vecmat(&scratch.h, lw.wk.data(), cfg.n_kv_heads * cfg.head_dim, &mut scratch.k);
            vecmat(&scratch.h, lw.wv.data(), cfg.n_kv_heads * cfg.head_dim, &mut scratch.v);
            for hh in 0..cfg.n_heads {
                rope_inplace(&mut scratch.q[hh * cfg.head_dim..(hh + 1) * cfg.head_dim], pos, cfg.rope_theta);
            }
            for kv in 0..cfg.n_kv_heads {
                rope_inplace(&mut scratch.k[kv * cfg.head_dim..(kv + 1) * cfg.head_dim], pos, cfg.rope_theta);
            }
            // append K/V/codes (paper Alg. 3 l.3-9)
            for kv in 0..cfg.n_kv_heads {
                cache.append(
                    li,
                    kv,
                    &scratch.k[kv * cfg.head_dim..(kv + 1) * cfg.head_dim],
                    &scratch.v[kv * cfg.head_dim..(kv + 1) * cfg.head_dim],
                    w.hash_head(li, kv),
                    cfg.rbit,
                    &self.aux,
                );
            }
            let s_now = pos + 1;
            // ---- per-KV-head attention
            for kv in 0..cfg.n_kv_heads {
                let group = cfg.group();
                let inp = AttnInputs {
                    q: &scratch.q[kv * group * cfg.head_dim..(kv + 1) * group * cfg.head_dim],
                    group,
                    dh: cfg.head_dim,
                    k: cache.k_slice(li, kv),
                    v: cache.v_slice(li, kv),
                    codes: cache.codes_slice(li, kv),
                    words: cfg.rbit / 64,
                    rbit: cfg.rbit,
                    s: s_now,
                    pos,
                    side: cache.side(li, kv, w.hash_head(li, kv), &self.aux),
                };
                let out = &mut scratch.attn[kv * group * cfg.head_dim..(kv + 1) * group * cfg.head_dim];
                let use_dense = selector.is_none()
                    || li < cfg.dense_layers
                    || serve.budget == 0
                    || serve.budget >= s_now;
                if use_dense {
                    dense_attention(&inp, &mut scratch.sel.probs, out);
                    // H2O needs cumulative mass even during dense steps
                    if serve.method == Method::H2o {
                        let st = &mut state.per_head[li * cfg.n_kv_heads + kv];
                        st.h2o_cum.resize(s_now, 0.0);
                        for (t, &p) in scratch.sel.probs.iter().enumerate().take(s_now) {
                            st.h2o_cum[t] += p;
                        }
                    }
                } else {
                    let sel = selector.unwrap();
                    let st = &mut state.per_head[li * cfg.n_kv_heads + kv];
                    sel.select(&inp, st, serve.budget, &mut scratch.sel);
                    // split borrows: take indices out, then compute
                    let indices = std::mem::take(&mut scratch.sel.indices);
                    match self.sparse_kernel {
                        SparseKernel::Fused => {
                            sparse_attention_fused(&inp, &indices, &mut scratch.sel.probs, out)
                        }
                        SparseKernel::Gather => sparse_attention_gather(
                            &inp,
                            &indices,
                            &mut scratch.kgather,
                            &mut scratch.vgather,
                            &mut scratch.sel.probs,
                            out,
                        ),
                    }
                    if serve.method == Method::H2o {
                        h2o_accumulate(st, &indices, &scratch.sel.probs, s_now);
                    }
                    scratch.sel.indices = indices;
                }
            }
            // wo projection + residual
            vecmat(&scratch.attn, lw.wo.data(), cfg.d_model, &mut scratch.h);
            for (x, &h) in scratch.x.iter_mut().zip(&scratch.h) {
                *x += h;
            }
            // ---- MLP block
            rms_norm(&scratch.x, lw.mlp_norm.data(), &mut scratch.h, 1e-5);
            vecmat(&scratch.h, lw.w_gate.data(), cfg.ffn_hidden, &mut scratch.gate);
            vecmat(&scratch.h, lw.w_up.data(), cfg.ffn_hidden, &mut scratch.up);
            for (g, &u) in scratch.gate.iter_mut().zip(&scratch.up) {
                *g = silu(*g) * u;
            }
            vecmat(&scratch.gate, lw.w_down.data(), cfg.d_model, &mut scratch.mlp);
            for (x, &m) in scratch.x.iter_mut().zip(&scratch.mlp) {
                *x += m;
            }
        }
        rms_norm(&scratch.x, w.final_norm.data(), &mut scratch.h, 1e-5);
        vecmat(&scratch.h, w.lm_head.data(), cfg.vocab, &mut scratch.logits);
    }

    /// Prefill `tokens` into `cache` with full attention (paper Alg. 1),
    /// computing SnapKV observation state when requested. Leaves the
    /// last-token logits in `scratch.logits`.
    ///
    /// Implementation: token-by-token decode steps with dense attention —
    /// O(s^2) like any causal prefill, sharing the exact step code path
    /// (the AOT/PJRT engine has the batched matmul formulation).
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        serve: &ServeConfig,
        scratch: &mut DecodeScratch,
    ) {
        let dense_serve = ServeConfig { budget: 0, ..serve.clone() };
        // SnapKV: capture final-layer observation-window queries
        let snap_window = if serve.method == Method::SnapKv { serve.snapkv_window } else { 0 };
        let s = tokens.len();
        let nheads = self.cfg.n_kv_heads;
        let mut qwin: Vec<Vec<f32>> = vec![Vec::new(); if snap_window > 0 { nheads } else { 0 }];
        for (pos, &tok) in tokens.iter().enumerate() {
            self.decode_step(tok, pos, cache, state, &dense_serve, None, scratch);
            if snap_window > 0 && pos >= s.saturating_sub(snap_window) {
                // scratch.q holds the FINAL layer's rotated queries here.
                // SnapKV observation windows are layer-local in the paper;
                // we apply the final-layer ranking to every layer — a
                // scaled-down approximation documented in DESIGN.md §4.
                let g = self.cfg.group();
                for kv in 0..nheads {
                    qwin[kv].extend_from_slice(
                        &scratch.q[kv * g * self.cfg.head_dim..(kv + 1) * g * self.cfg.head_dim],
                    );
                }
            }
        }
        if snap_window > 0 {
            let li = self.cfg.n_layers - 1;
            for kv in 0..nheads {
                let g = self.cfg.group();
                let w = qwin[kv].len() / (g * self.cfg.head_dim);
                if w == 0 {
                    continue;
                }
                let inp = AttnInputs {
                    q: &qwin[kv],
                    group: g,
                    dh: self.cfg.head_dim,
                    k: cache.k_slice(li, kv),
                    v: cache.v_slice(li, kv),
                    codes: cache.codes_slice(li, kv),
                    words: self.cfg.rbit / 64,
                    rbit: self.cfg.rbit,
                    s: cache.len(),
                    pos: cache.len() - 1,
                    side: crate::attention::Side::default(),
                };
                let mut st = MethodState::default();
                crate::attention::methods::snapkv_prefill(&mut st, &inp, w, &mut scratch.sel);
                for li2 in 0..self.cfg.n_layers {
                    state.per_head[li2 * nheads + kv].snapkv_keep = st.snapkv_keep.clone();
                }
            }
        }
    }

    /// Greedy generation helper used by evals and examples.
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        &self,
        prompt: &[u32],
        n_new: usize,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        scratch: &mut DecodeScratch,
    ) -> Vec<u32> {
        self.prefill(prompt, cache, state, serve, scratch);
        let mut out = Vec::with_capacity(n_new);
        let mut tok = crate::tensor::ops::argmax(&scratch.logits) as u32;
        let mut pos = prompt.len();
        for _ in 0..n_new {
            out.push(tok);
            self.decode_step(tok, pos, cache, state, serve, selector, scratch);
            tok = crate::tensor::ops::argmax(&scratch.logits) as u32;
            pos += 1;
        }
        out
    }
}

/// Borrow an owned selector as the trait object the engine takes.
pub fn sel_ref(sel: &Option<Box<dyn Selector + Send + Sync>>) -> Option<&dyn Selector> {
    sel.as_deref().map(|s| s as &dyn Selector)
}

/// Build the [`Selector`] instance for a method (None = dense).
pub fn make_selector(serve: &ServeConfig) -> Option<Box<dyn Selector + Send + Sync>> {
    use crate::attention::methods::*;
    Some(match serve.method {
        Method::Dense => return None,
        Method::ExactTopK => Box::new(ExactTopK),
        Method::Hata => Box::new(HataSelector),
        Method::Loki => Box::new(LokiSelector),
        Method::Quest => Box::new(QuestSelector),
        Method::MagicPig => Box::new(MagicPigSelector),
        Method::StreamingLlm => Box::new(StreamingLlm { sinks: serve.sinks }),
        Method::H2o => Box::new(H2oSelector),
        Method::SnapKv => Box::new(SnapKvSelector { window: serve.snapkv_window }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::util::rng::Rng;

    fn tiny_model(method: Method) -> (Model, ServeConfig) {
        let cfg = preset("hata-gqa").unwrap();
        let serve = ServeConfig { method, budget: 16, ..Default::default() };
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        (Model::new(cfg, weights, aux), serve)
    }

    #[test]
    fn decode_step_produces_finite_logits() {
        let (model, serve) = tiny_model(Method::Dense);
        let mut cache = SeqKvCache::new(&model.cfg, &serve);
        let mut state = SeqState::new(&model.cfg);
        let mut scratch = DecodeScratch::new(&model.cfg);
        for pos in 0..5 {
            model.decode_step(7 + pos as u32, pos, &mut cache, &mut state, &serve, None, &mut scratch);
        }
        assert_eq!(cache.len(), 5);
        assert!(scratch.logits.iter().all(|x| x.is_finite()));
        assert_eq!(scratch.logits.len(), model.cfg.vocab);
    }

    #[test]
    fn hata_with_full_budget_matches_dense() {
        // budget >= s falls back to dense per step: outputs identical
        let (model, mut serve) = tiny_model(Method::Hata);
        serve.budget = 1000;
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (40..60).collect();
        let mut c1 = SeqKvCache::new(&model.cfg, &serve);
        let mut s1 = SeqState::new(&model.cfg);
        let mut sc1 = DecodeScratch::new(&model.cfg);
        let out1 = model.generate(&prompt, 4, &serve, sel_ref(&sel), &mut c1, &mut s1, &mut sc1);
        let dense_serve = ServeConfig { method: Method::Dense, budget: 0, ..serve.clone() };
        let mut c2 = SeqKvCache::new(&model.cfg, &dense_serve);
        let mut s2 = SeqState::new(&model.cfg);
        let mut sc2 = DecodeScratch::new(&model.cfg);
        let out2 = model.generate(&prompt, 4, &dense_serve, None, &mut c2, &mut s2, &mut sc2);
        assert_eq!(out1, out2);
    }

    #[test]
    fn every_method_runs_end_to_end() {
        for &method in Method::all() {
            let (model, serve) = tiny_model(method);
            let sel = make_selector(&serve);
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            let prompt: Vec<u32> = (32..96).collect();
            let out = model.generate(&prompt, 3, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch);
            assert_eq!(out.len(), 3, "method {method:?}");
            assert!(scratch.logits.iter().all(|x| x.is_finite()), "method {method:?}");
        }
    }

    #[test]
    fn gather_and_fused_kernels_agree() {
        let (mut model, serve) = tiny_model(Method::Hata);
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (32..112).collect();
        let run = |model: &Model| {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            model.generate(&prompt, 6, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch)
        };
        let fused = run(&model);
        model.sparse_kernel = SparseKernel::Gather;
        let gathered = run(&model);
        assert_eq!(fused, gathered);
    }

    #[test]
    fn deterministic_generation() {
        let (model, serve) = tiny_model(Method::Hata);
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (32..80).collect();
        let gen = |_| {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            model.generate(&prompt, 5, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch)
        };
        assert_eq!(gen(0), gen(1));
    }
}
