//! Native CPU transformer engine: the Rust request-path twin of
//! `python/compile/model.py`, numerically parity-tested against JAX
//! goldens (rust/tests/parity.rs).
//!
//! The decode step is allocation-free (all buffers live in
//! [`DecodeScratch`]) and the attention stage is pluggable: any
//! [`crate::attention::Selector`] can drive top-k sparse attention, which
//! is exactly the paper's integration story.
//!
//! ## Batched parallel decode
//!
//! [`Model::decode_batch`] advances a whole scheduler batch one token in
//! lock-step over layers. Within each layer the per-(sequence, kv-head)
//! attention unit — hash encode + append, Hamming scoring, top-k select,
//! sparse gather/attend — is an [`AttnWork`] item fanned across
//! [`crate::util::threadpool::ThreadPool::scatter`]. Ownership:
//!
//! * weights/config ([`Model`]) — shared reads from every worker;
//! * activations ([`DecodeScratch`]) — one per *sequence*, split-borrowed
//!   per stage (`q`/`k`/`v` read, `attn` chunks written disjointly);
//! * KV regions — disjoint per (layer, head) via
//!   [`crate::kvcache::SeqKvCache::layer_heads_mut`];
//! * selection buffers ([`WorkerScratch`]) — one per *worker thread*.
//!
//! The serial [`Model::decode_step`] runs the identical per-head routine
//! ([`Model::decode_batch`] with one item degenerates to it), so
//! `threads = N` is byte-identical to `threads = 1`.

pub mod sampler;
pub mod tokenizer;
pub mod weights;

use crate::attention::compute::{dense_attention, sparse_attention_fused, sparse_attention_gather};
use crate::attention::methods::h2o_accumulate;
use crate::attention::{AttnInputs, MethodState, Scratch, Selector};
use crate::config::{Method, ModelConfig, ServeConfig};
use crate::kvcache::{HeadMut, MethodAux, SeqKvCache};
use crate::tensor::ops::{rms_norm, rope_inplace, silu, vecmat};
use crate::util::threadpool::ThreadPool;
use weights::Weights;

/// Reusable per-sequence decode buffers: activations that must persist
/// across the layer stack of one step, plus a built-in [`WorkerScratch`]
/// equivalent (`sel`/`kgather`/`vgather`) for the serial path.
pub struct DecodeScratch {
    x: Vec<f32>,
    h: Vec<f32>,
    /// last layer's rotated queries after a step (read by eval fidelity)
    pub q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    mlp: Vec<f32>,
    kgather: Vec<f32>,
    vgather: Vec<f32>,
    pub logits: Vec<f32>,
    pub sel: Scratch,
}

impl DecodeScratch {
    pub fn new(cfg: &ModelConfig) -> Self {
        DecodeScratch {
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * cfg.head_dim],
            k: vec![0.0; cfg.n_kv_heads * cfg.head_dim],
            v: vec![0.0; cfg.n_kv_heads * cfg.head_dim],
            attn: vec![0.0; cfg.n_heads * cfg.head_dim],
            gate: vec![0.0; cfg.ffn_hidden],
            up: vec![0.0; cfg.ffn_hidden],
            mlp: vec![0.0; cfg.d_model],
            kgather: Vec::new(),
            vgather: Vec::new(),
            logits: vec![0.0; cfg.vocab],
            sel: Scratch::default(),
        }
    }
}

/// Per-worker-thread selection/gather buffers for the batched decode
/// path. Per-sequence activations live in [`DecodeScratch`]; these arenas
/// are lent to whichever work item the worker picks up, and every routine
/// fully overwrites what it reads, so placement cannot affect results.
#[derive(Default)]
pub struct WorkerScratch {
    pub sel: Scratch,
    pub kgather: Vec<f32>,
    pub vgather: Vec<f32>,
}

/// Per-sequence method state for all (layer, kv) heads.
pub struct SeqState {
    pub per_head: Vec<MethodState>,
}

impl SeqState {
    pub fn new(cfg: &ModelConfig) -> Self {
        SeqState { per_head: vec![MethodState::default(); cfg.n_layers * cfg.n_kv_heads] }
    }
}

/// One sequence's slot in a batched decode step.
pub struct DecodeItem<'a> {
    /// token being fed (the previously sampled one)
    pub token: u32,
    /// absolute position of `token`
    pub pos: usize,
    pub cache: &'a mut SeqKvCache,
    pub state: &'a mut SeqState,
    pub scratch: &'a mut DecodeScratch,
}

/// One sequence's prefill chunk in a batched step.
pub struct PrefillItem<'a> {
    pub tokens: &'a [u32],
    /// absolute position of `tokens[0]`
    pub start: usize,
    /// chunk covers the entire prompt: use [`Model::prefill`] (captures
    /// SnapKV observation state); otherwise dense decode steps
    pub whole: bool,
    pub cache: &'a mut SeqKvCache,
    pub state: &'a mut SeqState,
    pub scratch: &'a mut DecodeScratch,
}

/// One (sequence, kv-head) attention work unit of a batched step: append
/// the token's K/V row to this head's disjoint cache region, then
/// select + attend into this head's slice of the sequence's `attn`.
struct AttnWork<'a> {
    head: HeadMut<'a>,
    st: &'a mut MethodState,
    q: &'a [f32],
    krow: &'a [f32],
    vrow: &'a [f32],
    out: &'a mut [f32],
    pos: usize,
    layer: usize,
    hash_w: &'a [f32],
}

/// Which sparse-attention compute variant the engine uses (Fig. 9
/// 'FusedAttn' ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseKernel {
    Gather,
    Fused,
}

/// The model: weights + config + per-model method constants.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: Weights,
    pub aux: MethodAux,
    pub sparse_kernel: SparseKernel,
}

impl Model {
    pub fn new(cfg: ModelConfig, weights: Weights, aux: MethodAux) -> Self {
        Model { cfg, weights, aux, sparse_kernel: SparseKernel::Fused }
    }

    /// Attention block input: rms-norm + q/k/v projections + RoPE, into
    /// the sequence's scratch.
    fn layer_qkv(&self, li: usize, pos: usize, sc: &mut DecodeScratch) {
        let cfg = &self.cfg;
        let lw = &self.weights.layers[li];
        rms_norm(&sc.x, lw.attn_norm.data(), &mut sc.h, 1e-5);
        vecmat(&sc.h, lw.wq.data(), cfg.n_heads * cfg.head_dim, &mut sc.q);
        vecmat(&sc.h, lw.wk.data(), cfg.n_kv_heads * cfg.head_dim, &mut sc.k);
        vecmat(&sc.h, lw.wv.data(), cfg.n_kv_heads * cfg.head_dim, &mut sc.v);
        for hh in 0..cfg.n_heads {
            let row = &mut sc.q[hh * cfg.head_dim..(hh + 1) * cfg.head_dim];
            rope_inplace(row, pos, cfg.rope_theta);
        }
        for kv in 0..cfg.n_kv_heads {
            let row = &mut sc.k[kv * cfg.head_dim..(kv + 1) * cfg.head_dim];
            rope_inplace(row, pos, cfg.rope_theta);
        }
    }

    /// Attention output projection + residual, then the MLP block.
    fn layer_mlp(&self, li: usize, sc: &mut DecodeScratch) {
        let cfg = &self.cfg;
        let lw = &self.weights.layers[li];
        vecmat(&sc.attn, lw.wo.data(), cfg.d_model, &mut sc.h);
        for (x, &h) in sc.x.iter_mut().zip(&sc.h) {
            *x += h;
        }
        rms_norm(&sc.x, lw.mlp_norm.data(), &mut sc.h, 1e-5);
        vecmat(&sc.h, lw.w_gate.data(), cfg.ffn_hidden, &mut sc.gate);
        vecmat(&sc.h, lw.w_up.data(), cfg.ffn_hidden, &mut sc.up);
        for (g, &u) in sc.gate.iter_mut().zip(&sc.up) {
            *g = silu(*g) * u;
        }
        vecmat(&sc.gate, lw.w_down.data(), cfg.d_model, &mut sc.mlp);
        for (x, &m) in sc.x.iter_mut().zip(&sc.mlp) {
            *x += m;
        }
    }

    /// Final norm + LM head into `sc.logits`.
    fn lm_head(&self, sc: &mut DecodeScratch) {
        rms_norm(&sc.x, self.weights.final_norm.data(), &mut sc.h, 1e-5);
        vecmat(&sc.h, self.weights.lm_head.data(), self.cfg.vocab, &mut sc.logits);
    }

    /// One (sequence, kv-head) attention unit (paper Alg. 3 l.3-12):
    /// append K/V/codes to this head's region, then select + attend.
    /// Runs identically on the engine thread (serial path, scratch =
    /// the sequence's own buffers) and on threadpool workers (batched
    /// path, scratch = the worker's arena).
    #[allow(clippy::too_many_arguments)]
    fn run_attn_work(
        &self,
        w: &mut AttnWork,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        sel: &mut Scratch,
        kgather: &mut Vec<f32>,
        vgather: &mut Vec<f32>,
    ) {
        let cfg = &self.cfg;
        w.head.append(w.krow, w.vrow, w.hash_w, cfg.rbit, &self.aux);
        let s_now = w.pos + 1;
        let inp = AttnInputs {
            q: w.q,
            group: cfg.group(),
            dh: cfg.head_dim,
            k: &w.head.hc.k,
            v: &w.head.hc.v,
            codes: &w.head.hc.codes,
            words: cfg.rbit / 64,
            rbit: cfg.rbit,
            s: s_now,
            pos: w.pos,
            side: w.head.side(w.hash_w, &self.aux),
        };
        let use_dense = selector.is_none()
            || w.layer < cfg.dense_layers
            || serve.budget == 0
            || serve.budget >= s_now;
        if use_dense {
            dense_attention(&inp, &mut sel.probs, &mut *w.out);
            // H2O needs cumulative mass even during dense steps
            if serve.method == Method::H2o {
                w.st.h2o_cum.resize(s_now, 0.0);
                for (t, &p) in sel.probs.iter().enumerate().take(s_now) {
                    w.st.h2o_cum[t] += p;
                }
            }
        } else {
            let chooser = selector.unwrap();
            chooser.select(&inp, &mut *w.st, serve.budget, &mut *sel);
            // split borrows: take indices out, then compute
            let indices = std::mem::take(&mut sel.indices);
            match self.sparse_kernel {
                SparseKernel::Fused => {
                    sparse_attention_fused(&inp, &indices, &mut sel.probs, &mut *w.out)
                }
                SparseKernel::Gather => sparse_attention_gather(
                    &inp,
                    &indices,
                    &mut *kgather,
                    &mut *vgather,
                    &mut sel.probs,
                    &mut *w.out,
                ),
            }
            if serve.method == Method::H2o {
                h2o_accumulate(&mut *w.st, &indices, &sel.probs, s_now);
            }
            sel.indices = indices;
        }
    }

    /// One decode step (paper Alg. 3 embedded in the full block stack).
    ///
    /// Appends `token`'s K/V (and hash codes) to `cache`, runs the
    /// configured attention per (layer, kv-head), returns argmax-ready
    /// logits in `scratch.logits`.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        scratch: &mut DecodeScratch,
    ) {
        let cfg = &self.cfg;
        let group = cfg.group();
        let dh = cfg.head_dim;
        scratch.x.copy_from_slice(self.weights.embed.row(token as usize));
        for li in 0..cfg.n_layers {
            self.layer_qkv(li, pos, scratch);
            let DecodeScratch { q, k, v, attn, sel, kgather, vgather, .. } = scratch;
            for (kv, out) in attn.chunks_mut(group * dh).enumerate() {
                let mut work = AttnWork {
                    head: cache.head_mut(li, kv),
                    st: &mut state.per_head[li * cfg.n_kv_heads + kv],
                    q: &q[kv * group * dh..(kv + 1) * group * dh],
                    krow: &k[kv * dh..(kv + 1) * dh],
                    vrow: &v[kv * dh..(kv + 1) * dh],
                    out,
                    pos,
                    layer: li,
                    hash_w: self.weights.hash_head(li, kv),
                };
                let (kg, vg) = (&mut *kgather, &mut *vgather);
                self.run_attn_work(&mut work, serve, selector, &mut *sel, kg, vg);
            }
            self.layer_mlp(li, scratch);
        }
        self.lm_head(scratch);
        cache.advance_len();
    }

    /// Advance a whole batch one token: lock-step over layers, with the
    /// per-(sequence, kv-head) attention units fanned across `pool` and
    /// one [`WorkerScratch`] arena per worker. Leaves each sequence's
    /// logits in its own `scratch.logits`.
    ///
    /// Byte-identical to running [`Model::decode_step`] per item: work
    /// items only touch disjoint state, so neither thread count nor
    /// placement can change any result.
    pub fn decode_batch(
        &self,
        items: &mut [DecodeItem],
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        pool: &ThreadPool,
        workers: &mut [WorkerScratch],
    ) {
        let cfg = &self.cfg;
        let group = cfg.group();
        let dh = cfg.head_dim;
        for it in items.iter_mut() {
            it.scratch.x.copy_from_slice(self.weights.embed.row(it.token as usize));
        }
        for li in 0..cfg.n_layers {
            // stage 1: norm + q/k/v projections + RoPE, one item per sequence
            pool.scatter(items, workers, |_, it, _| self.layer_qkv(li, it.pos, &mut *it.scratch));
            // stage 2: per-(sequence, kv-head) attention work items.
            // Built serially (cheap split-borrow bookkeeping), run on the
            // pool — this is where the step spends its time.
            let mut work: Vec<AttnWork> = Vec::with_capacity(items.len() * cfg.n_kv_heads);
            for it in items.iter_mut() {
                let pos = it.pos;
                let DecodeScratch { q, k, v, attn, .. } = &mut *it.scratch;
                let heads = it.cache.layer_heads_mut(li);
                let states = &mut it.state.per_head[li * cfg.n_kv_heads..(li + 1) * cfg.n_kv_heads];
                for (kv, ((head, st), out)) in heads
                    .into_iter()
                    .zip(states.iter_mut())
                    .zip(attn.chunks_mut(group * dh))
                    .enumerate()
                {
                    work.push(AttnWork {
                        head,
                        st,
                        q: &q[kv * group * dh..(kv + 1) * group * dh],
                        krow: &k[kv * dh..(kv + 1) * dh],
                        vrow: &v[kv * dh..(kv + 1) * dh],
                        out,
                        pos,
                        layer: li,
                        hash_w: self.weights.hash_head(li, kv),
                    });
                }
            }
            pool.scatter(&mut work, workers, |_, w, ws| {
                let (kg, vg) = (&mut ws.kgather, &mut ws.vgather);
                self.run_attn_work(w, serve, selector, &mut ws.sel, kg, vg)
            });
            drop(work);
            // stage 3: wo + residual + MLP, one item per sequence
            pool.scatter(items, workers, |_, it, _| self.layer_mlp(li, &mut *it.scratch));
        }
        pool.scatter(items, workers, |_, it, _| self.lm_head(&mut *it.scratch));
        for it in items.iter_mut() {
            it.cache.advance_len();
        }
    }

    /// Batched prefill chunks: each chunk is token-serial (causal), but
    /// chunks of different sequences are independent, so they fan across
    /// the pool at sequence granularity.
    pub fn prefill_batch(
        &self,
        items: &mut [PrefillItem],
        serve: &ServeConfig,
        pool: &ThreadPool,
        workers: &mut [WorkerScratch],
    ) {
        let dense = ServeConfig { budget: 0, ..serve.clone() };
        pool.scatter(items, workers, |_, it, _| {
            if it.whole {
                // single-chunk prompt: captures SnapKV state
                self.prefill(it.tokens, &mut *it.cache, &mut *it.state, serve, &mut *it.scratch);
            } else {
                for (i, &tok) in it.tokens.iter().enumerate() {
                    self.decode_step(
                        tok,
                        it.start + i,
                        &mut *it.cache,
                        &mut *it.state,
                        &dense,
                        None,
                        &mut *it.scratch,
                    );
                }
            }
        });
    }

    /// Prefill `tokens` into `cache` with full attention (paper Alg. 1),
    /// computing SnapKV observation state when requested. Leaves the
    /// last-token logits in `scratch.logits`.
    ///
    /// Implementation: token-by-token decode steps with dense attention —
    /// O(s^2) like any causal prefill, sharing the exact step code path
    /// (the AOT/PJRT engine has the batched matmul formulation).
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        serve: &ServeConfig,
        scratch: &mut DecodeScratch,
    ) {
        let dense_serve = ServeConfig { budget: 0, ..serve.clone() };
        // SnapKV: capture final-layer observation-window queries
        let snap_window = if serve.method == Method::SnapKv { serve.snapkv_window } else { 0 };
        let s = tokens.len();
        let nheads = self.cfg.n_kv_heads;
        let mut qwin: Vec<Vec<f32>> = vec![Vec::new(); if snap_window > 0 { nheads } else { 0 }];
        for (pos, &tok) in tokens.iter().enumerate() {
            self.decode_step(tok, pos, cache, state, &dense_serve, None, scratch);
            if snap_window > 0 && pos >= s.saturating_sub(snap_window) {
                // scratch.q holds the FINAL layer's rotated queries here.
                // SnapKV observation windows are layer-local in the paper;
                // we apply the final-layer ranking to every layer — a
                // scaled-down approximation documented in DESIGN.md §4.
                let g = self.cfg.group();
                for kv in 0..nheads {
                    qwin[kv].extend_from_slice(
                        &scratch.q[kv * g * self.cfg.head_dim..(kv + 1) * g * self.cfg.head_dim],
                    );
                }
            }
        }
        if snap_window > 0 {
            let li = self.cfg.n_layers - 1;
            for kv in 0..nheads {
                let g = self.cfg.group();
                let w = qwin[kv].len() / (g * self.cfg.head_dim);
                if w == 0 {
                    continue;
                }
                let inp = AttnInputs {
                    q: &qwin[kv],
                    group: g,
                    dh: self.cfg.head_dim,
                    k: cache.k_slice(li, kv),
                    v: cache.v_slice(li, kv),
                    codes: cache.codes_slice(li, kv),
                    words: self.cfg.rbit / 64,
                    rbit: self.cfg.rbit,
                    s: cache.len(),
                    pos: cache.len() - 1,
                    side: crate::attention::Side::default(),
                };
                let mut st = MethodState::default();
                crate::attention::methods::snapkv_prefill(&mut st, &inp, w, &mut scratch.sel);
                for li2 in 0..self.cfg.n_layers {
                    state.per_head[li2 * nheads + kv].snapkv_keep = st.snapkv_keep.clone();
                }
            }
        }
    }

    /// Greedy generation helper used by evals and examples.
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        &self,
        prompt: &[u32],
        n_new: usize,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        scratch: &mut DecodeScratch,
    ) -> Vec<u32> {
        self.prefill(prompt, cache, state, serve, scratch);
        let mut out = Vec::with_capacity(n_new);
        let mut tok = crate::tensor::ops::argmax(&scratch.logits) as u32;
        let mut pos = prompt.len();
        for _ in 0..n_new {
            out.push(tok);
            self.decode_step(tok, pos, cache, state, serve, selector, scratch);
            tok = crate::tensor::ops::argmax(&scratch.logits) as u32;
            pos += 1;
        }
        out
    }
}

/// Borrow an owned selector as the trait object the engine takes.
pub fn sel_ref(sel: &Option<Box<dyn Selector + Send + Sync>>) -> Option<&dyn Selector> {
    match sel {
        Some(b) => Some(b.as_ref()),
        None => None,
    }
}

/// Build the [`Selector`] instance for a method (None = dense).
pub fn make_selector(serve: &ServeConfig) -> Option<Box<dyn Selector + Send + Sync>> {
    use crate::attention::methods::*;
    Some(match serve.method {
        Method::Dense => return None,
        Method::ExactTopK => Box::new(ExactTopK),
        Method::Hata => Box::new(HataSelector),
        Method::Loki => Box::new(LokiSelector),
        Method::Quest => Box::new(QuestSelector),
        Method::MagicPig => Box::new(MagicPigSelector),
        Method::StreamingLlm => Box::new(StreamingLlm { sinks: serve.sinks }),
        Method::H2o => Box::new(H2oSelector),
        Method::SnapKv => Box::new(SnapKvSelector { window: serve.snapkv_window }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::tensor::ops::argmax;
    use crate::util::rng::Rng;

    fn tiny_model(method: Method) -> (Model, ServeConfig) {
        let cfg = preset("hata-gqa").unwrap();
        let serve = ServeConfig { method, budget: 16, ..Default::default() };
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        (Model::new(cfg, weights, aux), serve)
    }

    #[test]
    fn decode_step_produces_finite_logits() {
        let (model, serve) = tiny_model(Method::Dense);
        let mut cache = SeqKvCache::new(&model.cfg, &serve);
        let mut state = SeqState::new(&model.cfg);
        let mut scratch = DecodeScratch::new(&model.cfg);
        for pos in 0..5 {
            let tok = 7 + pos as u32;
            model.decode_step(tok, pos, &mut cache, &mut state, &serve, None, &mut scratch);
        }
        assert_eq!(cache.len(), 5);
        assert!(scratch.logits.iter().all(|x| x.is_finite()));
        assert_eq!(scratch.logits.len(), model.cfg.vocab);
    }

    #[test]
    fn hata_with_full_budget_matches_dense() {
        // budget >= s falls back to dense per step: outputs identical
        let (model, mut serve) = tiny_model(Method::Hata);
        serve.budget = 1000;
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (40..60).collect();
        let mut c1 = SeqKvCache::new(&model.cfg, &serve);
        let mut s1 = SeqState::new(&model.cfg);
        let mut sc1 = DecodeScratch::new(&model.cfg);
        let out1 = model.generate(&prompt, 4, &serve, sel_ref(&sel), &mut c1, &mut s1, &mut sc1);
        let dense_serve = ServeConfig { method: Method::Dense, budget: 0, ..serve.clone() };
        let mut c2 = SeqKvCache::new(&model.cfg, &dense_serve);
        let mut s2 = SeqState::new(&model.cfg);
        let mut sc2 = DecodeScratch::new(&model.cfg);
        let out2 = model.generate(&prompt, 4, &dense_serve, None, &mut c2, &mut s2, &mut sc2);
        assert_eq!(out1, out2);
    }

    #[test]
    fn every_method_runs_end_to_end() {
        for &method in Method::all() {
            let (model, serve) = tiny_model(method);
            let sel = make_selector(&serve);
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            let prompt: Vec<u32> = (32..96).collect();
            let out =
                model.generate(&prompt, 3, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch);
            assert_eq!(out.len(), 3, "method {method:?}");
            assert!(scratch.logits.iter().all(|x| x.is_finite()), "method {method:?}");
        }
    }

    #[test]
    fn gather_and_fused_kernels_agree() {
        let (mut model, serve) = tiny_model(Method::Hata);
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (32..112).collect();
        let run = |model: &Model| {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            model.generate(&prompt, 6, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch)
        };
        let fused = run(&model);
        model.sparse_kernel = SparseKernel::Gather;
        let gathered = run(&model);
        assert_eq!(fused, gathered);
    }

    #[test]
    fn deterministic_generation() {
        let (model, serve) = tiny_model(Method::Hata);
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (32..80).collect();
        let gen = |_| {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            model.generate(&prompt, 5, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch)
        };
        assert_eq!(gen(0), gen(1));
    }

    #[test]
    fn decode_batch_matches_serial_generate() {
        for method in [Method::Dense, Method::Hata, Method::Quest] {
            let (model, serve) = tiny_model(method);
            let sel = make_selector(&serve);
            let prompts: Vec<Vec<u32>> =
                vec![(32..72).collect(), (40..95).collect(), (50..76).collect()];
            let n_new = 4;
            // serial reference
            let mut want = Vec::new();
            for p in &prompts {
                let mut cache = SeqKvCache::new(&model.cfg, &serve);
                let mut state = SeqState::new(&model.cfg);
                let mut scratch = DecodeScratch::new(&model.cfg);
                want.push(model.generate(
                    p,
                    n_new,
                    &serve,
                    sel_ref(&sel),
                    &mut cache,
                    &mut state,
                    &mut scratch,
                ));
            }
            // batched path across a 3-worker pool
            let pool = ThreadPool::new(3);
            let mut workers: Vec<WorkerScratch> =
                (0..3).map(|_| WorkerScratch::default()).collect();
            let mut caches: Vec<SeqKvCache> =
                prompts.iter().map(|_| SeqKvCache::new(&model.cfg, &serve)).collect();
            let mut states: Vec<SeqState> =
                prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
            let mut scratches: Vec<DecodeScratch> =
                prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
            let mut next: Vec<u32> = Vec::with_capacity(prompts.len());
            for (i, p) in prompts.iter().enumerate() {
                model.prefill(p, &mut caches[i], &mut states[i], &serve, &mut scratches[i]);
                next.push(argmax(&scratches[i].logits) as u32);
            }
            let mut got: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
            for step in 0..n_new {
                for (i, &tok) in next.iter().enumerate() {
                    got[i].push(tok);
                }
                let mut items: Vec<DecodeItem> = caches
                    .iter_mut()
                    .zip(states.iter_mut())
                    .zip(scratches.iter_mut())
                    .enumerate()
                    .map(|(i, ((cache, state), scratch))| DecodeItem {
                        token: next[i],
                        pos: prompts[i].len() + step,
                        cache,
                        state,
                        scratch,
                    })
                    .collect();
                model.decode_batch(&mut items, &serve, sel_ref(&sel), &pool, &mut workers);
                drop(items);
                for (i, n) in next.iter_mut().enumerate() {
                    *n = argmax(&scratches[i].logits) as u32;
                }
            }
            assert_eq!(got, want, "method {method:?}");
        }
    }
}
