//! Native CPU transformer engine: the Rust request-path twin of
//! `python/compile/model.py`, numerically parity-tested against JAX
//! goldens (rust/tests/parity.rs).
//!
//! The decode step is allocation-free (all buffers live in
//! [`DecodeScratch`]) and the attention stage is pluggable: any
//! [`crate::attention::Selector`] can drive top-k sparse attention, which
//! is exactly the paper's integration story.
//!
//! ## Batched parallel decode
//!
//! [`Model::decode_batch`] advances a whole scheduler batch one token.
//! The per-(sequence, kv-head) attention unit — hash encode + append,
//! Hamming scoring, top-k select, sparse gather/attend — is one work
//! item; items reach the pool through the executor `serve.exec_mode`
//! picks (dependency task graph by default, or lock-step
//! [`crate::util::threadpool::ThreadPool::scatter`] stages per layer —
//! see the executors section below). Ownership, identical in both modes:
//!
//! * weights/config ([`Model`]) — shared reads from every worker;
//! * activations ([`DecodeScratch`]) — one per *sequence*, split-borrowed
//!   per stage (`q`/`k`/`v` read, `attn` chunks written disjointly);
//! * KV regions — disjoint per (layer, head) via
//!   [`crate::kvcache::SeqKvCache::layer_heads_mut`];
//! * selection buffers ([`WorkerScratch`]) — one per *worker thread*.
//!
//! The serial [`Model::decode_step`] runs the identical per-head routine
//! ([`Model::decode_batch`] with one item degenerates to it), so
//! `threads = N` is byte-identical to `threads = 1`.
//!
//! ## Executors: dependency-driven queue vs barrier-per-stage
//!
//! `serve.exec_mode` picks how a batch's work items reach the pool.
//! Under the default [`crate::config::ExecMode::Queue`], the whole
//! decode step (and each prefill block pass) becomes one
//! [`crate::util::workqueue::TaskGraph`]: per sequence, a chain of
//! QKV → per-head attention → MLP tasks across *all* layers, so a
//! sequence's attention starts the moment its own QKV lands and no
//! task ever waits on another sequence's straggler.
//! [`crate::config::ExecMode::Barrier`] keeps the original reference
//! path — consecutive [`crate::util::threadpool::ThreadPool::scatter`]
//! calls with a full-pool barrier between stages. Both executors run
//! the same per-item routines on the same disjoint state, so they are
//! bit-identical for every (threads, tile, method) combination
//! (rust/tests/parallel.rs; benches/fig7_queue_vs_barrier.rs).
//!
//! ## Steady-state decode fast path
//!
//! The decode graph's shape depends only on (batch size, layer count,
//! kv-head count), so the queue executor does not rebuild it per token:
//! the caller owns a [`DecodeGraphCache`] and, under `serve.graph_cache`
//! (default on), each step only *rebinds* the cached graph's payloads to
//! the step's sequences — no graph construction, and no heap allocation
//! at all once warmed (selector temporaries live in
//! [`crate::attention::Scratch`], the executor's run state lives inside
//! the cached [`TaskGraph`], and dispatch goes through the pool's
//! allocation-free broadcast). rust/tests/alloc.rs enforces the
//! zero-allocation property with a counting global allocator;
//! benches/fig8_steady_state.rs measures the rebuild amortization across
//! layers × batch. `--graph-cache off` restores the build-per-step
//! reference behavior, bit-identically.
//!
//! ## Block-tiled parallel prefill
//!
//! Prefill used to walk the prompt one token at a time through the
//! decode step path, leaving the pool idle during the O(s^2) phase that
//! dominates long-context serving. [`Model::prefill`] /
//! [`Model::prefill_batch`] now advance whole token blocks through the
//! layer stack: per layer, every block token's Q/K/V rows are computed
//! in one pass, appended block-wise to the per-head
//! [`crate::kvcache::HeadCache`] regions, and the attention runs as
//! (sequence, kv-head, query-tile)
//! work items — causally masked tiles over the already-written prefix
//! plus the intra-block lower triangle
//! ([`crate::attention::compute::prefill_tile_attention`]) — fanned
//! across the same executor / [`WorkerScratch`] machinery as decode
//! (task graph or scatter stages, per `serve.exec_mode`; see the
//! executors section below). Per-token arithmetic is never
//! reordered (each query row reduces its key prefix with the decode
//! kernel, in key order), so tiled prefill is bit-identical to the
//! token-serial reference [`Model::prefill_serial`] for every tile,
//! chunk and thread count — which keeps the Dense/Hata/Quest parity and
//! determinism suites exact. H2O is the one exception: its cumulative
//! attention mass accumulates in query order during dense prefill, so
//! H2O chunks keep the serial path.
//!
//! Ownership adds one arena to the decode story: block activations
//! ([`PrefillScratch`], inside each sequence's [`DecodeScratch`]) are
//! split-borrowed per query tile (x/q/k/v rows) and per kv-head
//! (head-major attention staging), while per-token norm/MLP temporaries
//! live in the per-worker [`WorkerScratch`].

pub mod sampler;
pub mod tokenizer;
pub mod weights;

use crate::attention::compute::{
    dense_attention, prefill_tile_attention, sparse_attention_fused, sparse_attention_gather,
    PrefillTile,
};
use crate::attention::methods::h2o_accumulate;
use crate::attention::{AttnInputs, MethodState, Scratch, Selector};
use crate::config::{ExecMode, Method, ModelConfig, ServeConfig};
use crate::kvcache::{HeadHandle, HeadMut, MethodAux, SeqKvCache};
use crate::tensor::ops::rope_inplace;
use crate::tensor::simd::{self, KernelMode};
use crate::util::threadpool::ThreadPool;
use crate::util::workqueue::{QueueStats, TaskGraph, TaskId};
use weights::Weights;

/// Reusable per-sequence decode buffers: activations that must persist
/// across the layer stack of one step, plus a built-in [`WorkerScratch`]
/// equivalent (`sel`/`kgather`/`vgather`) for the serial path.
pub struct DecodeScratch {
    x: Vec<f32>,
    h: Vec<f32>,
    /// last layer's rotated queries after a step (read by eval fidelity)
    pub q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    mlp: Vec<f32>,
    kgather: Vec<f32>,
    vgather: Vec<f32>,
    /// LM-head output of the last token fed through this scratch.
    pub logits: Vec<f32>,
    /// Selection buffers for the serial (pool-free) path.
    pub sel: Scratch,
    /// Block activations for the tiled prefill path, grown on demand.
    pub block: PrefillScratch,
}

impl DecodeScratch {
    /// Allocate all per-step buffers for `cfg`'s shapes.
    pub fn new(cfg: &ModelConfig) -> Self {
        DecodeScratch {
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.n_heads * cfg.head_dim],
            k: vec![0.0; cfg.n_kv_heads * cfg.head_dim],
            v: vec![0.0; cfg.n_kv_heads * cfg.head_dim],
            attn: vec![0.0; cfg.n_heads * cfg.head_dim],
            gate: vec![0.0; cfg.ffn_hidden],
            up: vec![0.0; cfg.ffn_hidden],
            mlp: vec![0.0; cfg.d_model],
            kgather: Vec::new(),
            vgather: Vec::new(),
            logits: vec![0.0; cfg.vocab],
            sel: Scratch::default(),
            block: PrefillScratch::default(),
        }
    }
}

/// Per-sequence block buffers for the tiled prefill path: token-major
/// activation rows plus the head-major attention staging area, resized
/// to the current chunk length before each block pass. Every row that a
/// stage reads was fully written by an earlier stage of the same block,
/// so reuse across blocks cannot leak state.
#[derive(Default)]
pub struct PrefillScratch {
    /// residual stream rows [len, d_model]
    x: Vec<f32>,
    /// rotated query rows [len, n_heads * head_dim]
    q: Vec<f32>,
    /// key rows [len, n_kv_heads * head_dim]
    k: Vec<f32>,
    /// value rows [len, n_kv_heads * head_dim]
    v: Vec<f32>,
    /// attention outputs, head-major [n_kv_heads, len, group * head_dim]
    /// so (kv-head, query-tile) work items write disjoint contiguous
    /// slices; the MLP stage re-gathers per-token rows
    attn: Vec<f32>,
}

impl PrefillScratch {
    fn ensure(&mut self, cfg: &ModelConfig, len: usize) {
        self.x.resize(len * cfg.d_model, 0.0);
        self.q.resize(len * cfg.n_heads * cfg.head_dim, 0.0);
        self.k.resize(len * cfg.n_kv_heads * cfg.head_dim, 0.0);
        self.v.resize(len * cfg.n_kv_heads * cfg.head_dim, 0.0);
        self.attn.resize(len * cfg.n_heads * cfg.head_dim, 0.0);
    }
}

/// Per-worker-thread buffers for the batched decode and tiled prefill
/// paths. Per-sequence activations live in [`DecodeScratch`]; these
/// arenas are lent to whichever work item the worker picks up, and every
/// routine fully overwrites what it reads, so placement cannot affect
/// results.
#[derive(Default)]
pub struct WorkerScratch {
    /// selection buffers (scores, indices, probs, query codes)
    pub sel: Scratch,
    /// K gather staging for [`SparseKernel::Gather`]
    pub kgather: Vec<f32>,
    /// V gather staging for [`SparseKernel::Gather`]
    pub vgather: Vec<f32>,
    /// tiled prefill: rms-norm output row (projection input)
    pub h: Vec<f32>,
    /// tiled prefill: MLP gate activations
    pub gate: Vec<f32>,
    /// tiled prefill: MLP up-projection activations
    pub up: Vec<f32>,
    /// tiled prefill: MLP down-projection row
    pub mlp: Vec<f32>,
    /// tiled prefill: one token's attention outputs gathered contiguous
    /// (head order) before the `wo` projection
    pub attn_row: Vec<f32>,
}

/// Per-sequence method state for all (layer, kv) heads.
pub struct SeqState {
    /// [`MethodState`] per (layer, kv) head, layer-major.
    pub per_head: Vec<MethodState>,
    /// SnapKV observation-window queries accumulated across prefill
    /// chunks (per kv head; empty until a chunk overlaps the window).
    /// Chunked prefill fills this incrementally and the final chunk's
    /// epilogue consumes it, so a chunked prompt ends with exactly the
    /// whole-prompt SnapKV state.
    pub snapkv_qwin: Vec<Vec<f32>>,
}

impl SeqState {
    /// Default state for every (layer, kv) head of `cfg`.
    pub fn new(cfg: &ModelConfig) -> Self {
        SeqState {
            per_head: vec![MethodState::default(); cfg.n_layers * cfg.n_kv_heads],
            snapkv_qwin: Vec::new(),
        }
    }
}

/// One sequence's slot in a batched decode step.
pub struct DecodeItem<'a> {
    /// token being fed (the previously sampled one)
    pub token: u32,
    /// absolute position of `token`
    pub pos: usize,
    /// this sequence's KV/code cache
    pub cache: &'a mut SeqKvCache,
    /// this sequence's per-head method state
    pub state: &'a mut SeqState,
    /// this sequence's activation buffers (logits land here)
    pub scratch: &'a mut DecodeScratch,
}

/// One sequence's prefill chunk in a batched step.
pub struct PrefillItem<'a> {
    /// the chunk's prompt tokens
    pub tokens: &'a [u32],
    /// absolute position of `tokens[0]`
    pub start: usize,
    /// full prompt length of the sequence this chunk belongs to (tells
    /// SnapKV where the observation window `[prompt_len - window,
    /// prompt_len)` sits relative to this chunk)
    pub prompt_len: usize,
    /// last chunk of the prompt: run the method's prefill epilogue
    /// (SnapKV keep-set ranking over the accumulated observation
    /// window) after the block pass
    pub is_final: bool,
    /// query rows per attention tile work item (`serve.prefill_tile`,
    /// surfaced per chunk by
    /// [`crate::coordinator::scheduler::PrefillWork`])
    pub tile: usize,
    /// this sequence's KV/code cache
    pub cache: &'a mut SeqKvCache,
    /// this sequence's per-head method state
    pub state: &'a mut SeqState,
    /// this sequence's activation buffers (block arenas + logits)
    pub scratch: &'a mut DecodeScratch,
}

/// One (sequence, kv-head) attention work unit of a batched step: append
/// the token's K/V row to this head's disjoint cache region, then
/// select + attend into this head's slice of the sequence's `attn`.
struct AttnWork<'a> {
    head: HeadMut<'a>,
    st: &'a mut MethodState,
    q: &'a [f32],
    krow: &'a [f32],
    vrow: &'a [f32],
    out: &'a mut [f32],
    pos: usize,
    layer: usize,
    hash_w: &'a [f32],
}

/// One sequence's token block inside a tiled prefill pass — the unit
/// `prefill_blocks` advances in lock-step over layers.
struct PrefillBlock<'a> {
    tokens: &'a [u32],
    /// absolute position of `tokens[0]`
    start: usize,
    /// query rows per attention tile (clamped to the block length)
    tile: usize,
    cache: &'a mut SeqKvCache,
    scratch: &'a mut DecodeScratch,
}

/// Stage-1 work item: rms-norm + Q/K/V projections + RoPE for one run
/// of consecutive block tokens (split-borrowed rows of the sequence's
/// `PrefillScratch`).
struct QkvTile<'a> {
    x: &'a [f32],
    q: &'a mut [f32],
    k: &'a mut [f32],
    v: &'a mut [f32],
    /// absolute position of the tile's first row
    pos0: usize,
}

/// Stage-2 work item: append one (sequence, kv-head)'s whole block of
/// K/V rows (plus codes and side structures) to its cache region.
struct AppendBlock<'a> {
    head: HeadMut<'a>,
    k: &'a [f32],
    v: &'a [f32],
    kv: usize,
    hash_w: &'a [f32],
}

/// Stage-3 work item: one causal query tile of one (sequence, kv-head),
/// writing its disjoint slice of the head-major attention staging area.
struct AttnTileItem<'a> {
    tile: PrefillTile<'a>,
    out: &'a mut [f32],
}

/// Stage-4 work item: output projection + residual + MLP for one run of
/// consecutive block tokens.
struct MlpTile<'a> {
    x: &'a mut [f32],
    /// the sequence's full head-major attention staging area
    attn: &'a [f32],
    /// block-local index of the tile's first row
    t0: usize,
    /// block length (head-major stride is `len * group * dh`)
    len: usize,
}

/// Build-time-carved raw read view into a scratch buffer, used by the
/// work-queue task payloads where reader and writer tasks of the same
/// buffer coexist in one task vector (graph edges order the accesses,
/// which plain borrows cannot express). Only materialized inside a
/// running task, after its dependencies completed.
#[derive(Clone, Copy)]
struct RawSlice {
    ptr: *const f32,
    len: usize,
}

impl RawSlice {
    /// Materialize the slice.
    ///
    /// # Safety
    /// Every task that writes this region must have completed (graph
    /// edges), and no task writing it may run until the borrow ends.
    unsafe fn get<'x>(&self) -> &'x [f32] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

/// Build-time-carved raw write view; see [`RawSlice`].
#[derive(Clone, Copy)]
struct RawSliceMut {
    ptr: *mut f32,
    len: usize,
}

impl RawSliceMut {
    /// Materialize the slice.
    ///
    /// # Safety
    /// This task must be the only live accessor of the region (graph
    /// edges: writers are exclusive).
    #[allow(clippy::mut_from_ref)]
    unsafe fn get<'x>(&self) -> &'x mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
}

/// One node's payload in the decode-step task graph (`--exec queue`).
/// Raw pointers stand in for the borrows that graph edges make
/// exclusive; every dereference site states which edge justifies it.
/// One chain per sequence: Qkv(0) → Attn(0, kv)* → Mlp(0) → Qkv(1) → …
/// → LmHead, so a fast sequence never waits on a slow one.
///
/// Deliberately lifetime-free (plain data): the payload arena lives in
/// the long-lived [`DecodeGraphCache`] and is **rebound in place every
/// step** — cleared and refilled with fresh addresses before each run —
/// so a stale pointer is never dereferenced, and rebinding within the
/// arena's warmed capacity allocates nothing.
enum DecodeTask {
    /// rms-norm + Q/K/V projections + RoPE for one (sequence, layer).
    Qkv { sc: *mut DecodeScratch, layer: usize, pos: usize },
    /// One (sequence, layer, kv-head) attention unit (append + select +
    /// attend), reading the QKV task's rows and writing its disjoint
    /// `attn` chunk.
    Attn {
        head: HeadHandle,
        st: *mut MethodState,
        q: RawSlice,
        krow: RawSlice,
        vrow: RawSlice,
        out: RawSliceMut,
        pos: usize,
        layer: usize,
        hash_w: RawSlice,
    },
    /// Output projection + residual + MLP for one (sequence, layer).
    Mlp { sc: *mut DecodeScratch, layer: usize },
    /// Final norm + LM head for one sequence.
    LmHead { sc: *mut DecodeScratch },
    /// Offload only: fetch the blocks this (sequence, layer, kv-head)
    /// selected last step from the slow tier, released `prefetch_depth`
    /// layers ahead of the head's Attn task so the transfer overlaps an
    /// earlier layer's attention (InfiniGen-style lookahead).
    Prefetch { head: HeadHandle, st: *const MethodState },
}

// SAFETY: the raw pointers reference per-sequence state whose accesses
// are ordered and made exclusive by the task graph's dependency edges
// (see the build site in `Model::bind_decode_tasks`), and are rebound
// from live `&mut` borrows at the start of every step before any task
// runs.
unsafe impl Send for DecodeTask {}

/// Cached decode-step execution structure: the [`TaskGraph`] plus its
/// payload arena, owned by the caller (the engine keeps one per serving
/// loop) and handed to every [`Model::decode_batch`] call.
///
/// The decode graph's *shape* depends only on (batch size, `n_layers`,
/// `n_kv_heads`) — per sequence, the same Qkv → per-head Attn → Mlp
/// chain across all layers plus an LM-head task. Under
/// `serve.graph_cache` (the default) the structure is therefore built
/// once and re-derived only when the batch size changes; steady-state
/// steps merely rebind the task payloads in place, which together with
/// the scratch-ified selectors makes a warmed-up decode step perform
/// **zero heap allocations** (enforced by rust/tests/alloc.rs). With
/// `graph_cache` off, every step builds a fresh graph — the PR 4
/// reference behavior the `fig8_steady_state` bench compares against.
pub struct DecodeGraphCache {
    graph: TaskGraph,
    tasks: Vec<DecodeTask>,
    /// Batch size the cached structure was built for.
    batch: usize,
    /// (n_layers, n_kv_heads) guard so a cache is never reused across
    /// models of a different shape.
    shape: (usize, usize),
    /// Prefetch-depth the structure was built with (`Some(depth)` when
    /// `--offload` added per-head Prefetch tasks, `None` otherwise) —
    /// the offload axis of the shape guard.
    prefetch: Option<usize>,
}

impl DecodeGraphCache {
    /// Empty cache; the first decode step builds the structure.
    pub fn new() -> Self {
        DecodeGraphCache {
            graph: TaskGraph::new(),
            tasks: Vec::new(),
            batch: usize::MAX,
            shape: (0, 0),
            prefetch: None,
        }
    }
}

impl Default for DecodeGraphCache {
    fn default() -> Self {
        DecodeGraphCache::new()
    }
}

/// One node's payload in the prefill-block task graph (`--exec queue`):
/// the four barrier stages of `prefill_blocks` as dependency-ordered
/// tasks, chained across layers per sequence. Tile boundaries match the
/// barrier path exactly, so every task computes the same values on the
/// same rows.
enum PrefillTask<'a> {
    /// Stage 1: norm + Q/K/V + RoPE for one (sequence, layer, tile).
    Qkv {
        x: RawSlice,
        q: RawSliceMut,
        k: RawSliceMut,
        v: RawSliceMut,
        pos0: usize,
        layer: usize,
    },
    /// Stage 2: block append for one (sequence, layer, kv-head); depends
    /// on all of the block's QKV tiles (it reads every row).
    Append {
        head: HeadHandle,
        k: RawSlice,
        v: RawSlice,
        kv: usize,
        hash_w: &'a [f32],
    },
    /// Stage 3: one causal query tile of one (sequence, layer, kv-head);
    /// depends on that head's append (reads the head's K/V through the
    /// handle at run time — the append may have reallocated the buffers).
    AttnTile {
        head: HeadHandle,
        q: RawSlice,
        out: RawSliceMut,
        qoff: usize,
        t0: usize,
        start: usize,
    },
    /// Stage 4: wo + residual + MLP for one (sequence, layer, tile);
    /// depends on that tile's attention tasks across all kv-heads.
    Mlp { x: RawSliceMut, attn: RawSlice, t0: usize, len: usize, layer: usize },
    /// Per-sequence epilogue after the last layer: bump the cache length,
    /// stage the last token's activations, run the LM head.
    Epilogue { cache: *mut SeqKvCache, sc: *mut DecodeScratch, len: usize },
}

// SAFETY: as for `DecodeTask` — all raw state is per-sequence and its
// accesses are ordered by the graph edges built in `prefill_blocks_queue`.
unsafe impl Send for PrefillTask<'_> {}

/// Execution context for the tiled prefill stages: the engine pool plus
/// per-worker arenas (batched path), or a single inline arena (the
/// serial [`Model::prefill`]). Inline runs items in index order; pooled
/// placement cannot change results (the `scatter` contract), so both
/// are bit-identical.
enum PrefillExec<'a> {
    Pool(&'a ThreadPool, &'a mut [WorkerScratch]),
    Inline(&'a mut WorkerScratch),
}

impl PrefillExec<'_> {
    /// Run one stage: `f(index, item, arena)` exactly once per item.
    fn run<T, F>(&mut self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T, &mut WorkerScratch) + Sync,
    {
        match self {
            PrefillExec::Pool(pool, workers) => pool.scatter(items, &mut **workers, f),
            PrefillExec::Inline(ws) => {
                for (i, it) in items.iter_mut().enumerate() {
                    f(i, it, &mut **ws);
                }
            }
        }
    }
}

/// Which sparse-attention compute variant the engine uses (Fig. 9
/// 'FusedAttn' ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseKernel {
    /// 'Simple': materialize gathered K/V copies, then attend.
    Gather,
    /// Gather folded into the score/accumulate loops (paper default).
    Fused,
}

/// The model: weights + config + per-model method constants.
pub struct Model {
    /// Transformer shape parameters.
    pub cfg: ModelConfig,
    /// Loaded (or random) parameters + trained hash weights.
    pub weights: Weights,
    /// Per-model method constants (Loki PCA, MagicPIG planes).
    pub aux: MethodAux,
    /// Which sparse-attention compute variant decode uses.
    pub sparse_kernel: SparseKernel,
    /// Which f32 kernel tier every float loop runs in (`--kernels`).
    /// `Simd` (the default) is bit-identical to `Reference`.
    pub kernels: KernelMode,
}

impl Model {
    /// Assemble a model (fused sparse kernel, SIMD kernels by default).
    pub fn new(cfg: ModelConfig, weights: Weights, aux: MethodAux) -> Self {
        Model {
            cfg,
            weights,
            aux,
            sparse_kernel: SparseKernel::Fused,
            kernels: KernelMode::default(),
        }
    }

    /// Attention block input: rms-norm + q/k/v projections + RoPE, into
    /// the sequence's scratch.
    fn layer_qkv(&self, li: usize, pos: usize, sc: &mut DecodeScratch) {
        let cfg = &self.cfg;
        let lw = &self.weights.layers[li];
        let km = self.kernels;
        simd::rms_norm(km, &sc.x, lw.attn_norm.data(), &mut sc.h, 1e-5);
        simd::vecmat(km, &sc.h, lw.wq.data(), cfg.n_heads * cfg.head_dim, &mut sc.q);
        simd::vecmat(km, &sc.h, lw.wk.data(), cfg.n_kv_heads * cfg.head_dim, &mut sc.k);
        simd::vecmat(km, &sc.h, lw.wv.data(), cfg.n_kv_heads * cfg.head_dim, &mut sc.v);
        for hh in 0..cfg.n_heads {
            let row = &mut sc.q[hh * cfg.head_dim..(hh + 1) * cfg.head_dim];
            rope_inplace(row, pos, cfg.rope_theta);
        }
        for kv in 0..cfg.n_kv_heads {
            let row = &mut sc.k[kv * cfg.head_dim..(kv + 1) * cfg.head_dim];
            rope_inplace(row, pos, cfg.rope_theta);
        }
    }

    /// Attention output projection + residual, then the MLP block.
    fn layer_mlp(&self, li: usize, sc: &mut DecodeScratch) {
        let cfg = &self.cfg;
        let lw = &self.weights.layers[li];
        let km = self.kernels;
        simd::vecmat(km, &sc.attn, lw.wo.data(), cfg.d_model, &mut sc.h);
        for (x, &h) in sc.x.iter_mut().zip(&sc.h) {
            *x += h;
        }
        simd::rms_norm(km, &sc.x, lw.mlp_norm.data(), &mut sc.h, 1e-5);
        simd::vecmat(km, &sc.h, lw.w_gate.data(), cfg.ffn_hidden, &mut sc.gate);
        simd::vecmat(km, &sc.h, lw.w_up.data(), cfg.ffn_hidden, &mut sc.up);
        simd::silu_mul(km, &mut sc.gate, &sc.up);
        simd::vecmat(km, &sc.gate, lw.w_down.data(), cfg.d_model, &mut sc.mlp);
        for (x, &m) in sc.x.iter_mut().zip(&sc.mlp) {
            *x += m;
        }
    }

    /// Final norm + LM head into `sc.logits`.
    fn lm_head(&self, sc: &mut DecodeScratch) {
        let km = self.kernels;
        simd::rms_norm(km, &sc.x, self.weights.final_norm.data(), &mut sc.h, 1e-5);
        simd::vecmat(km, &sc.h, self.weights.lm_head.data(), self.cfg.vocab, &mut sc.logits);
    }

    /// One (sequence, kv-head) attention unit (paper Alg. 3 l.3-12):
    /// append K/V/codes to this head's region, then select + attend.
    /// Runs identically on the engine thread (serial path, scratch =
    /// the sequence's own buffers) and on threadpool workers (batched
    /// path, scratch = the worker's arena).
    #[allow(clippy::too_many_arguments)]
    fn run_attn_work(
        &self,
        w: &mut AttnWork,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        sel: &mut Scratch,
        kgather: &mut Vec<f32>,
        vgather: &mut Vec<f32>,
    ) {
        let cfg = &self.cfg;
        w.head.append(w.krow, w.vrow, w.hash_w, cfg.rbit, &self.aux);
        let s_now = w.pos + 1;
        let rd = w.head.read();
        let inp = AttnInputs {
            q: w.q,
            group: cfg.group(),
            dh: cfg.head_dim,
            k: rd.k,
            v: rd.v,
            codes: rd.codes,
            words: cfg.rbit / 64,
            rbit: cfg.rbit,
            s: s_now,
            pos: w.pos,
            bt: rd.bt,
            block_tokens: rd.block_tokens,
            kv_dtype: rd.kv_dtype,
            kernels: self.kernels,
            side: w.head.side(w.hash_w, &self.aux),
        };
        let use_dense = selector.is_none()
            || w.layer < cfg.dense_layers
            || serve.budget == 0
            || serve.budget >= s_now;
        // Offload: dense attention and exact top-k scoring read every
        // cached row, so restore the full range from the slow tier up
        // front; every other selector scores the always-resident code
        // cache (or side structures) and fetches only after selection.
        // The recorded block list doubles as next step's prefetch list.
        let tiered = w.head.tier_active();
        let needs_full_rows = use_dense || serve.method == Method::ExactTopK;
        if tiered && needs_full_rows {
            w.head.ensure_range_resident(s_now, &mut w.st.sel_blocks);
        }
        let km = self.kernels;
        if use_dense {
            dense_attention(km, &inp, &mut sel.probs, &mut *w.out);
            // H2O needs cumulative mass even during dense steps
            if serve.method == Method::H2o {
                w.st.h2o_cum.resize(s_now, 0.0);
                for (t, &p) in sel.probs.iter().enumerate().take(s_now) {
                    w.st.h2o_cum[t] += p;
                }
            }
        } else {
            let chooser = selector.unwrap();
            chooser.select(&inp, &mut *w.st, serve.budget, &mut *sel);
            // split borrows: take indices out, then compute
            let indices = std::mem::take(&mut sel.indices);
            if tiered && !needs_full_rows {
                w.head.ensure_selected_resident(&indices, &mut w.st.sel_blocks);
            }
            match self.sparse_kernel {
                SparseKernel::Fused => {
                    sparse_attention_fused(km, &inp, &indices, &mut sel.probs, &mut *w.out)
                }
                SparseKernel::Gather => sparse_attention_gather(
                    km,
                    &inp,
                    &indices,
                    &mut *kgather,
                    &mut *vgather,
                    &mut sel.probs,
                    &mut *w.out,
                ),
            }
            if serve.method == Method::H2o {
                h2o_accumulate(&mut *w.st, &indices, &sel.probs, s_now);
            }
            sel.indices = indices;
        }
    }

    /// One decode step (paper Alg. 3 embedded in the full block stack).
    ///
    /// Appends `token`'s K/V (and hash codes) to `cache`, runs the
    /// configured attention per (layer, kv-head), returns argmax-ready
    /// logits in `scratch.logits`.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        scratch: &mut DecodeScratch,
    ) {
        let cfg = &self.cfg;
        let group = cfg.group();
        let dh = cfg.head_dim;
        scratch.x.copy_from_slice(self.weights.embed.row(token as usize));
        for li in 0..cfg.n_layers {
            self.layer_qkv(li, pos, scratch);
            let DecodeScratch { q, k, v, attn, sel, kgather, vgather, .. } = scratch;
            for (kv, out) in attn.chunks_mut(group * dh).enumerate() {
                let mut work = AttnWork {
                    head: cache.head_mut(li, kv),
                    st: &mut state.per_head[li * cfg.n_kv_heads + kv],
                    q: &q[kv * group * dh..(kv + 1) * group * dh],
                    krow: &k[kv * dh..(kv + 1) * dh],
                    vrow: &v[kv * dh..(kv + 1) * dh],
                    out,
                    pos,
                    layer: li,
                    hash_w: self.weights.hash_head(li, kv),
                };
                let (kg, vg) = (&mut *kgather, &mut *vgather);
                self.run_attn_work(&mut work, serve, selector, &mut *sel, kg, vg);
            }
            self.layer_mlp(li, scratch);
        }
        self.lm_head(scratch);
        cache.advance_len();
    }

    /// Advance a whole batch one token, leaving each sequence's logits
    /// in its own `scratch.logits`. `serve.exec_mode` picks the
    /// executor: the dependency-driven work queue (default — one task
    /// chain per sequence, no inter-stage barriers) or the
    /// barrier-per-stage scatter reference path.
    ///
    /// `graph_cache` is the caller-owned decode graph + payload arena;
    /// under `serve.graph_cache` (default on) the queue executor reuses
    /// its structure across steps and only rebinds payloads, which is
    /// what makes a warmed-up steady-state step allocation-free. With
    /// the knob off (or in barrier mode) the cache is left untouched
    /// and every step rebuilds from scratch — the reference behavior.
    ///
    /// Byte-identical to running [`Model::decode_step`] per item under
    /// either mode and either cache setting: work items only touch
    /// disjoint state and the cached graph encodes the exact same
    /// dependency structure a fresh build would, so neither thread
    /// count, executor, caching, nor placement can change any result.
    /// Returns the executor's counters (zero for the barrier path).
    pub fn decode_batch(
        &self,
        items: &mut [DecodeItem],
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        pool: &ThreadPool,
        workers: &mut [WorkerScratch],
        graph_cache: &mut DecodeGraphCache,
    ) -> QueueStats {
        match serve.exec_mode {
            ExecMode::Queue => {
                self.decode_batch_queue(items, serve, selector, pool, workers, graph_cache)
            }
            ExecMode::Barrier => {
                self.decode_batch_barrier(items, serve, selector, pool, workers);
                QueueStats::default()
            }
        }
    }

    /// Barrier-per-stage reference executor for [`Model::decode_batch`]:
    /// lock-step over layers, each layer's three stages as consecutive
    /// [`ThreadPool::scatter`] calls.
    fn decode_batch_barrier(
        &self,
        items: &mut [DecodeItem],
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        pool: &ThreadPool,
        workers: &mut [WorkerScratch],
    ) {
        let cfg = &self.cfg;
        let group = cfg.group();
        let dh = cfg.head_dim;
        for it in items.iter_mut() {
            it.scratch.x.copy_from_slice(self.weights.embed.row(it.token as usize));
        }
        for li in 0..cfg.n_layers {
            // stage 1: norm + q/k/v projections + RoPE, one item per sequence
            pool.scatter(items, workers, |_, it, _| self.layer_qkv(li, it.pos, &mut *it.scratch));
            // stage 2: per-(sequence, kv-head) attention work items.
            // Built serially (cheap split-borrow bookkeeping), run on the
            // pool — this is where the step spends its time.
            let mut work: Vec<AttnWork> = Vec::with_capacity(items.len() * cfg.n_kv_heads);
            for it in items.iter_mut() {
                let pos = it.pos;
                let DecodeScratch { q, k, v, attn, .. } = &mut *it.scratch;
                let heads = it.cache.layer_heads_mut(li);
                let states = &mut it.state.per_head[li * cfg.n_kv_heads..(li + 1) * cfg.n_kv_heads];
                for (kv, ((head, st), out)) in heads
                    .into_iter()
                    .zip(states.iter_mut())
                    .zip(attn.chunks_mut(group * dh))
                    .enumerate()
                {
                    work.push(AttnWork {
                        head,
                        st,
                        q: &q[kv * group * dh..(kv + 1) * group * dh],
                        krow: &k[kv * dh..(kv + 1) * dh],
                        vrow: &v[kv * dh..(kv + 1) * dh],
                        out,
                        pos,
                        layer: li,
                        hash_w: self.weights.hash_head(li, kv),
                    });
                }
            }
            pool.scatter(&mut work, workers, |_, w, ws| {
                let (kg, vg) = (&mut ws.kgather, &mut ws.vgather);
                self.run_attn_work(w, serve, selector, &mut ws.sel, kg, vg)
            });
            drop(work);
            // stage 3: wo + residual + MLP, one item per sequence
            pool.scatter(items, workers, |_, it, _| self.layer_mlp(li, &mut *it.scratch));
        }
        pool.scatter(items, workers, |_, it, _| self.lm_head(&mut *it.scratch));
        for it in items.iter_mut() {
            it.cache.advance_len();
        }
    }

    /// Work-queue executor for [`Model::decode_batch`]: one dependency
    /// chain per sequence across *all* layers — Qkv → per-head Attn →
    /// Mlp per layer, then the LM head — run as a single
    /// [`TaskGraph`]. No stage or layer barriers: a sequence's
    /// attention starts the moment its own QKV lands, and its layer 2
    /// can run while another sequence is still in layer 0.
    ///
    /// The graph's shape depends only on (batch size, `n_layers`,
    /// `n_kv_heads`), so under `serve.graph_cache` the structure in
    /// `cache` is reused verbatim across steps and only the payloads
    /// are rebound; the structure is re-derived (in place, reusing
    /// buffer capacity) when the batch size changes. With the knob off,
    /// a throwaway cache makes every step a cold build — the PR 4
    /// reference behavior.
    fn decode_batch_queue(
        &self,
        items: &mut [DecodeItem],
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        pool: &ThreadPool,
        workers: &mut [WorkerScratch],
        graph_cache: &mut DecodeGraphCache,
    ) -> QueueStats {
        let cfg = &self.cfg;
        let shape = (cfg.n_layers, cfg.n_kv_heads);
        let prefetch = serve.offload.then_some(serve.prefetch_depth);
        let mut throwaway;
        let cache = if serve.graph_cache {
            graph_cache
        } else {
            throwaway = DecodeGraphCache::new();
            &mut throwaway
        };
        let rebuild =
            cache.batch != items.len() || cache.shape != shape || cache.prefetch != prefetch;
        self.bind_decode_tasks(items, cache, rebuild, prefetch);
        let mut stats = cache.graph.run(pool, &mut cache.tasks, workers, |_, t, ws| {
            self.run_decode_task(t, serve, selector, ws)
        });
        if rebuild {
            stats.graph_builds = 1;
        } else {
            stats.graph_hits = 1;
        }
        for it in items.iter_mut() {
            it.cache.advance_len();
        }
        stats
    }

    /// (Re)bind the decode task graph for this step's `items`. With
    /// `rebuild` the dependency structure is re-derived (batch shape
    /// changed or the cache is cold); otherwise only the payload arena
    /// is refilled — same order, fresh addresses — which stays within
    /// warmed capacity and therefore allocates nothing.
    fn bind_decode_tasks(
        &self,
        items: &mut [DecodeItem],
        cache: &mut DecodeGraphCache,
        rebuild: bool,
        prefetch: Option<usize>,
    ) {
        let cfg = &self.cfg;
        let group = cfg.group();
        let dh = cfg.head_dim;
        let ghd = group * dh;
        if rebuild {
            let per_head = if prefetch.is_some() { 2 } else { 1 };
            let per_seq = cfg.n_layers * (2 + per_head * cfg.n_kv_heads) + 1;
            cache.graph.clear();
            cache.batch = items.len();
            cache.shape = (cfg.n_layers, cfg.n_kv_heads);
            cache.prefetch = prefetch;
            cache.tasks.reserve(items.len() * per_seq);
            let mut attn_ids: Vec<TaskId> = Vec::with_capacity(cfg.n_kv_heads);
            let mut qkv_ids: Vec<TaskId> = Vec::with_capacity(cfg.n_layers);
            for _ in 0..items.len() {
                let mut prev: Option<TaskId> = None;
                qkv_ids.clear();
                for li in 0..cfg.n_layers {
                    let qkv = match prev {
                        Some(p) => cache.graph.add(&[p]),
                        None => cache.graph.add(&[]),
                    };
                    qkv_ids.push(qkv);
                    attn_ids.clear();
                    for _kv in 0..cfg.n_kv_heads {
                        match prefetch {
                            // layer li's fetch is released once layer
                            // (li - depth)'s QKV lands — deep enough to
                            // overlap attention of the layers between —
                            // and the head's attend waits for its fetch
                            // (deterministic hit accounting, and the
                            // fetch's read of last step's selection is
                            // ordered before this step's write)
                            Some(depth) => {
                                let pf = if li >= depth {
                                    cache.graph.add(&[qkv_ids[li - depth]])
                                } else {
                                    cache.graph.add(&[])
                                };
                                attn_ids.push(cache.graph.add(&[qkv, pf]));
                            }
                            None => attn_ids.push(cache.graph.add(&[qkv])),
                        }
                    }
                    prev = Some(cache.graph.add(&attn_ids));
                }
                cache.graph.add(&[prev.expect("at least one layer")]);
            }
        }
        cache.tasks.clear();
        for it in items.iter_mut() {
            it.scratch.x.copy_from_slice(self.weights.embed.row(it.token as usize));
            let pos = it.pos;
            let scp: *mut DecodeScratch = &mut *it.scratch;
            // SAFETY: carve base pointers into the fixed-size activation
            // buffers once; decode never resizes them, and every task
            // access below is ordered by the graph edges.
            let (qp, kp, vp, ap) = unsafe {
                let s = &mut *scp;
                (s.q.as_mut_ptr(), s.k.as_mut_ptr(), s.v.as_mut_ptr(), s.attn.as_mut_ptr())
            };
            let stp = it.state.per_head.as_mut_ptr();
            for li in 0..cfg.n_layers {
                cache.tasks.push(DecodeTask::Qkv { sc: scp, layer: li, pos });
                for kv in 0..cfg.n_kv_heads {
                    let hw = self.weights.hash_head(li, kv);
                    if prefetch.is_some() {
                        cache.tasks.push(DecodeTask::Prefetch {
                            head: it.cache.head_handle(li, kv),
                            // SAFETY: same indexing as the Attn task
                            // below; the Prefetch→Attn edge orders this
                            // shared read before the exclusive write.
                            st: unsafe { stp.add(li * cfg.n_kv_heads + kv) },
                        });
                    }
                    cache.tasks.push(DecodeTask::Attn {
                        head: it.cache.head_handle(li, kv),
                        // SAFETY: li * n_kv + kv < per_head.len() by
                        // construction (SeqState is sized for cfg); each
                        // (li, kv) pair is used by exactly one task.
                        st: unsafe { stp.add(li * cfg.n_kv_heads + kv) },
                        q: RawSlice { ptr: unsafe { qp.add(kv * ghd) }, len: ghd },
                        krow: RawSlice { ptr: unsafe { kp.add(kv * dh) }, len: dh },
                        vrow: RawSlice { ptr: unsafe { vp.add(kv * dh) }, len: dh },
                        out: RawSliceMut { ptr: unsafe { ap.add(kv * ghd) }, len: ghd },
                        pos,
                        layer: li,
                        hash_w: RawSlice { ptr: hw.as_ptr(), len: hw.len() },
                    });
                }
                cache.tasks.push(DecodeTask::Mlp { sc: scp, layer: li });
            }
            cache.tasks.push(DecodeTask::LmHead { sc: scp });
        }
        debug_assert_eq!(cache.tasks.len(), cache.graph.len(), "payload arena matches graph");
    }

    /// Execute one decode-graph task. Each arm's `unsafe` materializes
    /// the views its graph edges make exclusive: Qkv/Mlp/LmHead are the
    /// only live tasks of their sequence when they run (chain order), and
    /// Attn tasks read rows their QKV dependency finished writing while
    /// owning their disjoint `attn` chunk, per-head state and (layer, kv)
    /// head region.
    fn run_decode_task(
        &self,
        t: &mut DecodeTask,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        ws: &mut WorkerScratch,
    ) {
        match t {
            DecodeTask::Qkv { sc, layer, pos } => {
                self.layer_qkv(*layer, *pos, unsafe { &mut **sc })
            }
            DecodeTask::Attn { head, st, q, krow, vrow, out, pos, layer, hash_w } => {
                let mut w = AttnWork {
                    head: unsafe { head.head_mut() },
                    // SAFETY: exactly one Attn task per (layer, kv) head
                    // exists, so this &mut aliases no other task's state.
                    st: unsafe { &mut **st },
                    q: unsafe { q.get() },
                    krow: unsafe { krow.get() },
                    vrow: unsafe { vrow.get() },
                    out: unsafe { out.get() },
                    pos: *pos,
                    layer: *layer,
                    hash_w: unsafe { hash_w.get() },
                };
                let (kg, vg) = (&mut ws.kgather, &mut ws.vgather);
                self.run_attn_work(&mut w, serve, selector, &mut ws.sel, kg, vg);
            }
            DecodeTask::Mlp { sc, layer } => self.layer_mlp(*layer, unsafe { &mut **sc }),
            DecodeTask::LmHead { sc } => self.lm_head(unsafe { &mut **sc }),
            DecodeTask::Prefetch { head, st } => {
                // SAFETY: the Prefetch→Attn edge makes this head's Attn
                // task wait for us, so this shared read of the state
                // (the block list its Attn wrote *last* step) precedes
                // this step's exclusive write; no other task touches it.
                let blocks = unsafe { &(**st).sel_blocks };
                // SAFETY: recorded ids stay owned by/shared with a live
                // sequence until its next step (HeadHandle contract).
                unsafe { head.prefetch_blocks(blocks) };
            }
        }
    }

    /// Batched prefill chunks: every chunk advances through the tiled
    /// block-forward path in lock-step over layers, with (sequence,
    /// tile) projection/MLP items and (sequence, kv-head, query-tile)
    /// attention items fanned across `pool` — the same work-item
    /// machinery as [`Model::decode_batch`], bit-identical to the
    /// token-serial reference for any tile/thread count and either
    /// `serve.exec_mode` (queue by default, barrier-per-stage scatter as
    /// the reference path). SnapKV chunks accumulate the observation
    /// window (the slice overlapping `[prompt_len - w, prompt_len)`)
    /// into the sequence's persistent [`SeqState::snapkv_qwin`] after
    /// the pass, and the final chunk runs the keep-set ranking — so a
    /// chunked prompt ends bit-identical to a whole-prompt prefill.
    /// H2O chunks keep the
    /// token-serial path (sequence-granular fan-out) under both modes:
    /// its cumulative attention mass accumulates in query order during
    /// dense prefill, which tiling would reorder. Returns the work-queue
    /// executor's counters (zero for barrier/H2O).
    pub fn prefill_batch(
        &self,
        items: &mut [PrefillItem],
        serve: &ServeConfig,
        pool: &ThreadPool,
        workers: &mut [WorkerScratch],
    ) -> QueueStats {
        if serve.method == Method::H2o {
            let dense = ServeConfig { budget: 0, ..serve.clone() };
            pool.scatter(items, workers, |_, it, _| {
                if it.start == 0 && it.is_final {
                    self.prefill_serial(
                        it.tokens,
                        &mut *it.cache,
                        &mut *it.state,
                        serve,
                        &mut *it.scratch,
                    );
                } else {
                    for (i, &tok) in it.tokens.iter().enumerate() {
                        self.decode_step(
                            tok,
                            it.start + i,
                            &mut *it.cache,
                            &mut *it.state,
                            &dense,
                            None,
                            &mut *it.scratch,
                        );
                    }
                }
            });
            return QueueStats::default();
        }
        let stats = {
            let mut blocks: Vec<PrefillBlock> = items
                .iter_mut()
                .map(|it| PrefillBlock {
                    tokens: it.tokens,
                    start: it.start,
                    tile: it.tile,
                    cache: &mut *it.cache,
                    scratch: &mut *it.scratch,
                })
                .collect();
            match serve.exec_mode {
                ExecMode::Queue => self.prefill_blocks_queue(&mut blocks, pool, workers),
                ExecMode::Barrier => {
                    self.prefill_blocks(&mut blocks, &mut PrefillExec::Pool(pool, workers));
                    QueueStats::default()
                }
            }
        };
        if serve.method == Method::SnapKv {
            for it in items.iter_mut() {
                let len = it.tokens.len();
                if len == 0 {
                    continue;
                }
                // accumulate the slice of this chunk that overlaps the
                // prompt's observation window [prompt_len - w, prompt_len)
                // into the sequence's persistent qwin, so a chunked
                // prompt finalizes with exactly the whole-prompt state
                let w0 = it.prompt_len.saturating_sub(serve.snapkv_window);
                if it.start + len > w0 {
                    if it.state.snapkv_qwin.is_empty() {
                        it.state.snapkv_qwin = vec![Vec::new(); self.cfg.n_kv_heads];
                    }
                    let lo = w0.max(it.start) - it.start;
                    let qwin = &mut it.state.snapkv_qwin;
                    self.snapkv_gather(&it.scratch.block.q, lo..len, qwin);
                }
                if it.is_final {
                    let qwin = std::mem::take(&mut it.state.snapkv_qwin);
                    self.snapkv_finalize(
                        &qwin,
                        &mut *it.cache,
                        &mut *it.state,
                        &mut it.scratch.sel,
                    );
                }
            }
        }
        stats
    }

    /// Prefill `tokens` into `cache` with full attention (paper Alg. 1),
    /// computing SnapKV observation state when requested. Leaves the
    /// last-token logits in `scratch.logits`.
    ///
    /// Implementation: the prompt walks in `serve.prefill_chunk` token
    /// blocks through the tiled block-forward path — the same stages
    /// [`Model::prefill_batch`] fans across the engine threadpool, run
    /// inline here in canonical order. Results are bit-identical to the
    /// token-serial reference [`Model::prefill_serial`] for every
    /// chunk/tile size; H2O falls back to it (query-order cumulative
    /// state).
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        serve: &ServeConfig,
        scratch: &mut DecodeScratch,
    ) {
        if serve.method == Method::H2o {
            return self.prefill_serial(tokens, cache, state, serve, scratch);
        }
        let chunk = serve.prefill_chunk.max(1);
        let snap_window = if serve.method == Method::SnapKv { serve.snapkv_window } else { 0 };
        let s = tokens.len();
        let nheads = self.cfg.n_kv_heads;
        let mut qwin: Vec<Vec<f32>> = vec![Vec::new(); if snap_window > 0 { nheads } else { 0 }];
        let mut ws = WorkerScratch::default();
        let mut start = 0usize;
        while start < s {
            let end = (start + chunk).min(s);
            {
                let mut blocks = [PrefillBlock {
                    tokens: &tokens[start..end],
                    start,
                    tile: serve.prefill_tile,
                    cache: &mut *cache,
                    scratch: &mut *scratch,
                }];
                self.prefill_blocks(&mut blocks, &mut PrefillExec::Inline(&mut ws));
            }
            if snap_window > 0 {
                // scratch.block.q holds the FINAL layer's rotated queries
                // for every block token here. SnapKV observation windows
                // are layer-local in the paper; we apply the final-layer
                // ranking to every layer — a scaled-down approximation
                // documented in DESIGN.md §4.
                let w0 = s.saturating_sub(snap_window);
                self.snapkv_gather(&scratch.block.q, start.max(w0) - start..end - start, &mut qwin);
            }
            start = end;
        }
        if snap_window > 0 {
            self.snapkv_finalize(&qwin, cache, state, &mut scratch.sel);
        }
    }

    /// Token-serial reference prefill: one [`Model::decode_step`] per
    /// prompt token, dense attention throughout — the pre-tiling
    /// baseline, kept as the equivalence oracle for the tiled path
    /// (rust/tests/parallel.rs, benches/fig6_prefill_tile.rs) and as the
    /// H2O path (its cumulative mass accumulates in query order).
    pub fn prefill_serial(
        &self,
        tokens: &[u32],
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        serve: &ServeConfig,
        scratch: &mut DecodeScratch,
    ) {
        let dense_serve = ServeConfig { budget: 0, ..serve.clone() };
        // SnapKV: capture final-layer observation-window queries
        let snap_window = if serve.method == Method::SnapKv { serve.snapkv_window } else { 0 };
        let s = tokens.len();
        let nheads = self.cfg.n_kv_heads;
        let mut qwin: Vec<Vec<f32>> = vec![Vec::new(); if snap_window > 0 { nheads } else { 0 }];
        for (pos, &tok) in tokens.iter().enumerate() {
            self.decode_step(tok, pos, cache, state, &dense_serve, None, scratch);
            if snap_window > 0 && pos >= s.saturating_sub(snap_window) {
                // scratch.q holds the FINAL layer's rotated queries here
                let g = self.cfg.group();
                for (kv, win) in qwin.iter_mut().enumerate() {
                    win.extend_from_slice(
                        &scratch.q[kv * g * self.cfg.head_dim..(kv + 1) * g * self.cfg.head_dim],
                    );
                }
            }
        }
        if snap_window > 0 {
            self.snapkv_finalize(&qwin, cache, state, &mut scratch.sel);
        }
    }

    /// Extend the per-head SnapKV observation windows with the
    /// final-layer rotated queries of block rows `rows` (read from a
    /// [`PrefillScratch`] query buffer after a block pass).
    fn snapkv_gather(
        &self,
        block_q: &[f32],
        rows: std::ops::Range<usize>,
        qwin: &mut [Vec<f32>],
    ) {
        let g = self.cfg.group();
        let dh = self.cfg.head_dim;
        let qrow = self.cfg.n_heads * dh;
        for t in rows {
            for (kv, win) in qwin.iter_mut().enumerate() {
                win.extend_from_slice(
                    &block_q[t * qrow + kv * g * dh..t * qrow + (kv + 1) * g * dh],
                );
            }
        }
    }

    /// SnapKV epilogue shared by every prefill path: rank prefix tokens
    /// by the observation-window queries' attention (final layer) and
    /// store the ranking in every layer's head state.
    fn snapkv_finalize(
        &self,
        qwin: &[Vec<f32>],
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        sel: &mut Scratch,
    ) {
        let li = self.cfg.n_layers - 1;
        let nheads = self.cfg.n_kv_heads;
        let g = self.cfg.group();
        for (kv, win) in qwin.iter().enumerate() {
            let w = win.len() / (g * self.cfg.head_dim);
            if w == 0 {
                continue;
            }
            let rd = cache.read_view(li, kv);
            let inp = AttnInputs {
                q: win.as_slice(),
                group: g,
                dh: self.cfg.head_dim,
                k: rd.k,
                v: rd.v,
                codes: rd.codes,
                words: self.cfg.rbit / 64,
                rbit: self.cfg.rbit,
                s: cache.len(),
                pos: cache.len() - 1,
                bt: rd.bt,
                block_tokens: rd.block_tokens,
                kv_dtype: rd.kv_dtype,
                kernels: self.kernels,
                side: crate::attention::Side::default(),
            };
            let mut st = MethodState::default();
            crate::attention::methods::snapkv_prefill(&mut st, &inp, w, sel);
            for li2 in 0..self.cfg.n_layers {
                state.per_head[li2 * nheads + kv].snapkv_keep = st.snapkv_keep.clone();
            }
        }
    }

    /// Advance every sequence's token block through the full layer stack
    /// with the tiled stage fan-out. Per layer: (sequence, tile) Q/K/V
    /// projection items, (sequence, kv-head) block appends, (sequence,
    /// kv-head, query-tile) causal attention items, then (sequence,
    /// tile) MLP items — each stage's work vector is built serially
    /// (cheap split-borrow bookkeeping) and run on `exec`. The epilogue
    /// bumps cache lengths and leaves last-token logits (plus the
    /// final-layer queries in `scratch.q`) exactly like the serial path.
    fn prefill_blocks(&self, items: &mut [PrefillBlock], exec: &mut PrefillExec) {
        let cfg = &self.cfg;
        let dm = cfg.d_model;
        let dh = cfg.head_dim;
        let group = cfg.group();
        let ghd = group * dh;
        let qrow = cfg.n_heads * dh;
        let krow = cfg.n_kv_heads * dh;
        for it in items.iter_mut() {
            it.scratch.block.ensure(cfg, it.tokens.len());
            for (t, &tok) in it.tokens.iter().enumerate() {
                it.scratch.block.x[t * dm..(t + 1) * dm]
                    .copy_from_slice(self.weights.embed.row(tok as usize));
            }
        }
        for li in 0..cfg.n_layers {
            // stage 1: norm + q/k/v projections + RoPE per (sequence, tile)
            let mut qkv: Vec<QkvTile> = Vec::new();
            for it in items.iter_mut() {
                let len = it.tokens.len();
                if len == 0 {
                    continue;
                }
                let tile = it.tile.clamp(1, len);
                let PrefillScratch { x, q, k, v, .. } = &mut it.scratch.block;
                let mut qi = q.chunks_mut(tile * qrow);
                let mut ki = k.chunks_mut(tile * krow);
                let mut vi = v.chunks_mut(tile * krow);
                for (ti, xs) in x.chunks(tile * dm).enumerate() {
                    qkv.push(QkvTile {
                        x: xs,
                        q: qi.next().unwrap(),
                        k: ki.next().unwrap(),
                        v: vi.next().unwrap(),
                        pos0: it.start + ti * tile,
                    });
                }
            }
            exec.run(&mut qkv, |_, t, ws| self.qkv_tile(li, t, ws));
            drop(qkv);
            // stage 2: block append per (sequence, kv-head)
            let mut appends: Vec<AppendBlock> = Vec::new();
            for it in items.iter_mut() {
                if it.tokens.is_empty() {
                    continue;
                }
                let heads = it.cache.layer_heads_mut(li);
                let PrefillScratch { k, v, .. } = &it.scratch.block;
                for (kv, head) in heads.into_iter().enumerate() {
                    appends.push(AppendBlock {
                        head,
                        k: k.as_slice(),
                        v: v.as_slice(),
                        kv,
                        hash_w: self.weights.hash_head(li, kv),
                    });
                }
            }
            exec.run(&mut appends, |_, a, _| {
                a.head.append_block(a.k, a.v, krow, a.kv * dh, a.hash_w, cfg.rbit, &self.aux)
            });
            drop(appends);
            // stage 3: causal attention per (sequence, kv-head, query-tile)
            let mut tiles: Vec<AttnTileItem> = Vec::new();
            for it in items.iter_mut() {
                let len = it.tokens.len();
                if len == 0 {
                    continue;
                }
                let tile = it.tile.clamp(1, len);
                let start = it.start;
                let PrefillScratch { q, attn, .. } = &mut it.scratch.block;
                let q = q.as_slice();
                let cache = &*it.cache;
                for (kv, ahead) in attn.chunks_mut(len * ghd).enumerate() {
                    let rd = cache.read_view(li, kv);
                    for (ti, out) in ahead.chunks_mut(tile * ghd).enumerate() {
                        tiles.push(AttnTileItem {
                            tile: PrefillTile {
                                q,
                                k: rd.k,
                                v: rd.v,
                                group,
                                dh,
                                qstride: qrow,
                                qoff: kv * ghd,
                                t0: ti * tile,
                                start,
                                bt: rd.bt,
                                block_tokens: rd.block_tokens,
                                kv_dtype: rd.kv_dtype,
                                kernels: self.kernels,
                            },
                            out,
                        });
                    }
                }
            }
            exec.run(&mut tiles, |_, t, ws| {
                prefill_tile_attention(&t.tile, &mut ws.sel.probs, &mut *t.out)
            });
            drop(tiles);
            // stage 4: wo + residual + MLP per (sequence, tile)
            let mut mlps: Vec<MlpTile> = Vec::new();
            for it in items.iter_mut() {
                let len = it.tokens.len();
                if len == 0 {
                    continue;
                }
                let tile = it.tile.clamp(1, len);
                let PrefillScratch { x, attn, .. } = &mut it.scratch.block;
                let attn = attn.as_slice();
                for (ti, xs) in x.chunks_mut(tile * dm).enumerate() {
                    mlps.push(MlpTile { x: xs, attn, t0: ti * tile, len });
                }
            }
            exec.run(&mut mlps, |_, t, ws| self.mlp_tile(li, t, ws));
            drop(mlps);
        }
        // epilogue: cache length bookkeeping + last-token logits per
        // sequence, mirroring what the serial path leaves behind
        exec.run(items, |_, it, _| {
            let len = it.tokens.len();
            if len == 0 {
                return;
            }
            it.cache.advance_len_by(len);
            {
                let DecodeScratch { x, q, block, .. } = &mut *it.scratch;
                q.copy_from_slice(&block.q[(len - 1) * qrow..len * qrow]);
                x.copy_from_slice(&block.x[(len - 1) * dm..len * dm]);
            }
            self.lm_head(&mut *it.scratch);
        });
    }

    /// Work-queue executor for the tiled prefill block pass: the same
    /// four stages as [`Model::prefill_blocks`], but as one
    /// [`TaskGraph`] per batch, chained across layers per sequence —
    /// QKV tiles → per-head block appends → per-(head, tile) causal
    /// attention → MLP tiles → next layer, then a per-sequence
    /// epilogue. Dependencies (append waits on every QKV tile of its
    /// block; an MLP tile waits on its tile's attention across all
    /// heads) also carry the write-after-read hazards: a tile's next-
    /// layer QKV overwrite is transitively ordered after every reader
    /// of the current layer's rows. Same tile boundaries, kernels and
    /// reduction orders as the barrier path — bit-identical output.
    fn prefill_blocks_queue(
        &self,
        items: &mut [PrefillBlock],
        pool: &ThreadPool,
        workers: &mut [WorkerScratch],
    ) -> QueueStats {
        let cfg = &self.cfg;
        let dm = cfg.d_model;
        let dh = cfg.head_dim;
        let group = cfg.group();
        let ghd = group * dh;
        let qrow = cfg.n_heads * dh;
        let krow = cfg.n_kv_heads * dh;
        let mut graph = TaskGraph::new();
        let mut tasks: Vec<PrefillTask> = Vec::new();
        for it in items.iter_mut() {
            let len = it.tokens.len();
            it.scratch.block.ensure(cfg, len);
            for (t, &tok) in it.tokens.iter().enumerate() {
                it.scratch.block.x[t * dm..(t + 1) * dm]
                    .copy_from_slice(self.weights.embed.row(tok as usize));
            }
            if len == 0 {
                continue;
            }
            let tile = it.tile.clamp(1, len);
            let ntiles = len.div_ceil(tile);
            let start = it.start;
            let cp: *mut SeqKvCache = &mut *it.cache;
            let scp: *mut DecodeScratch = &mut *it.scratch;
            // SAFETY: base pointers into the block arenas, which `ensure`
            // sized above and nothing resizes during the run; all task
            // accesses below are ordered by the graph edges.
            let (xp, qp, kp, vp, ap) = unsafe {
                let b = &mut (*scp).block;
                (
                    b.x.as_mut_ptr(),
                    b.q.as_mut_ptr(),
                    b.k.as_mut_ptr(),
                    b.v.as_mut_ptr(),
                    b.attn.as_mut_ptr(),
                )
            };
            // SAFETY: derive the head handles through `cp` (not a fresh
            // `&mut it.cache` reborrow) so every raw view of this cache
            // shares one derivation chain; at run time the handles are
            // used strictly before the epilogue task re-materializes the
            // whole cache from `cp` (graph edges put the epilogue last).
            let handles = unsafe { (*cp).head_handles() };
            let mut prev_mlp: Vec<TaskId> = Vec::new();
            let mut qkv_ids: Vec<TaskId> = Vec::with_capacity(ntiles);
            let mut append_ids: Vec<TaskId> = Vec::with_capacity(cfg.n_kv_heads);
            for li in 0..cfg.n_layers {
                qkv_ids.clear();
                for ti in 0..ntiles {
                    let r0 = ti * tile;
                    let rows = tile.min(len - r0);
                    let id = if li == 0 {
                        graph.add(&[])
                    } else {
                        graph.add(&[prev_mlp[ti]])
                    };
                    qkv_ids.push(id);
                    tasks.push(PrefillTask::Qkv {
                        x: RawSlice { ptr: unsafe { xp.add(r0 * dm) }, len: rows * dm },
                        q: RawSliceMut { ptr: unsafe { qp.add(r0 * qrow) }, len: rows * qrow },
                        k: RawSliceMut { ptr: unsafe { kp.add(r0 * krow) }, len: rows * krow },
                        v: RawSliceMut { ptr: unsafe { vp.add(r0 * krow) }, len: rows * krow },
                        pos0: start + r0,
                        layer: li,
                    });
                }
                append_ids.clear();
                for kv in 0..cfg.n_kv_heads {
                    append_ids.push(graph.add(&qkv_ids));
                    tasks.push(PrefillTask::Append {
                        head: handles[li * cfg.n_kv_heads + kv],
                        k: RawSlice { ptr: kp, len: len * krow },
                        v: RawSlice { ptr: vp, len: len * krow },
                        kv,
                        hash_w: self.weights.hash_head(li, kv),
                    });
                }
                let mut attn_by_tile: Vec<Vec<TaskId>> =
                    vec![Vec::with_capacity(cfg.n_kv_heads); ntiles];
                for kv in 0..cfg.n_kv_heads {
                    for (ti, by_tile) in attn_by_tile.iter_mut().enumerate() {
                        let r0 = ti * tile;
                        let rows = tile.min(len - r0);
                        by_tile.push(graph.add(&[append_ids[kv]]));
                        tasks.push(PrefillTask::AttnTile {
                            head: handles[li * cfg.n_kv_heads + kv],
                            q: RawSlice { ptr: qp, len: len * qrow },
                            out: RawSliceMut {
                                ptr: unsafe { ap.add((kv * len + r0) * ghd) },
                                len: rows * ghd,
                            },
                            qoff: kv * ghd,
                            t0: r0,
                            start,
                        });
                    }
                }
                prev_mlp.clear();
                for (ti, by_tile) in attn_by_tile.iter().enumerate() {
                    let r0 = ti * tile;
                    let rows = tile.min(len - r0);
                    prev_mlp.push(graph.add(by_tile));
                    tasks.push(PrefillTask::Mlp {
                        x: RawSliceMut { ptr: unsafe { xp.add(r0 * dm) }, len: rows * dm },
                        attn: RawSlice { ptr: ap, len: len * cfg.n_heads * dh },
                        t0: r0,
                        len,
                        layer: li,
                    });
                }
            }
            graph.add(&prev_mlp);
            tasks.push(PrefillTask::Epilogue { cache: cp, sc: scp, len });
        }
        let stats = graph.run(pool, &mut tasks, workers, |_, t, ws| self.run_prefill_task(t, ws));
        drop(tasks);
        stats
    }

    /// Execute one prefill-graph task; each arm materializes exactly the
    /// views its dependency edges make safe (see
    /// [`Model::prefill_blocks_queue`]) and calls the same per-tile
    /// routine as the barrier path.
    fn run_prefill_task(&self, t: &mut PrefillTask, ws: &mut WorkerScratch) {
        let cfg = &self.cfg;
        match t {
            PrefillTask::Qkv { x, q, k, v, pos0, layer } => {
                let mut tile = QkvTile {
                    x: unsafe { x.get() },
                    q: unsafe { q.get() },
                    k: unsafe { k.get() },
                    v: unsafe { v.get() },
                    pos0: *pos0,
                };
                self.qkv_tile(*layer, &mut tile, ws);
            }
            PrefillTask::Append { head, k, v, kv, hash_w } => {
                let mut head = unsafe { head.head_mut() };
                head.append_block(
                    unsafe { k.get() },
                    unsafe { v.get() },
                    cfg.n_kv_heads * cfg.head_dim,
                    *kv * cfg.head_dim,
                    *hash_w,
                    cfg.rbit,
                    &self.aux,
                );
            }
            PrefillTask::AttnTile { head, q, out, qoff, t0, start } => {
                // SAFETY: this head's append task completed (graph edge),
                // so its K/V buffers are stable for the whole read.
                let rd = unsafe { head.read_view() };
                let tile = PrefillTile {
                    q: unsafe { q.get() },
                    k: rd.k,
                    v: rd.v,
                    group: cfg.group(),
                    dh: cfg.head_dim,
                    qstride: cfg.n_heads * cfg.head_dim,
                    qoff: *qoff,
                    t0: *t0,
                    start: *start,
                    bt: rd.bt,
                    block_tokens: rd.block_tokens,
                    kv_dtype: rd.kv_dtype,
                    kernels: self.kernels,
                };
                prefill_tile_attention(&tile, &mut ws.sel.probs, unsafe { out.get() });
            }
            PrefillTask::Mlp { x, attn, t0, len, layer } => {
                let mut tile = MlpTile {
                    x: unsafe { x.get() },
                    attn: unsafe { attn.get() },
                    t0: *t0,
                    len: *len,
                };
                self.mlp_tile(*layer, &mut tile, ws);
            }
            PrefillTask::Epilogue { cache, sc, len } => {
                // SAFETY: every task of this sequence completed (the
                // epilogue depends on the last layer's MLP tiles, which
                // transitively cover all appends and reads).
                let cache = unsafe { &mut **cache };
                cache.advance_len_by(*len);
                let scratch = unsafe { &mut **sc };
                let dm = cfg.d_model;
                let qrow = cfg.n_heads * cfg.head_dim;
                {
                    let DecodeScratch { x, q, block, .. } = scratch;
                    q.copy_from_slice(&block.q[(*len - 1) * qrow..*len * qrow]);
                    x.copy_from_slice(&block.x[(*len - 1) * dm..*len * dm]);
                }
                self.lm_head(scratch);
            }
        }
    }

    /// Stage-1 tile worker: rms-norm + Q/K/V projections + RoPE for the
    /// tile's token rows — per-token arithmetic identical to the decode
    /// path's `layer_qkv`, with the norm temporary in the worker arena.
    fn qkv_tile(&self, li: usize, t: &mut QkvTile, ws: &mut WorkerScratch) {
        let cfg = &self.cfg;
        let lw = &self.weights.layers[li];
        let dm = cfg.d_model;
        let dh = cfg.head_dim;
        let qrow = cfg.n_heads * dh;
        let krow = cfg.n_kv_heads * dh;
        let km = self.kernels;
        ws.h.resize(dm, 0.0);
        for (r, xs) in t.x.chunks(dm).enumerate() {
            let pos = t.pos0 + r;
            simd::rms_norm(km, xs, lw.attn_norm.data(), &mut ws.h, 1e-5);
            let q = &mut t.q[r * qrow..(r + 1) * qrow];
            simd::vecmat(km, &ws.h, lw.wq.data(), qrow, q);
            simd::vecmat(km, &ws.h, lw.wk.data(), krow, &mut t.k[r * krow..(r + 1) * krow]);
            simd::vecmat(km, &ws.h, lw.wv.data(), krow, &mut t.v[r * krow..(r + 1) * krow]);
            for hh in 0..cfg.n_heads {
                rope_inplace(&mut q[hh * dh..(hh + 1) * dh], pos, cfg.rope_theta);
            }
            let k = &mut t.k[r * krow..(r + 1) * krow];
            for kv in 0..cfg.n_kv_heads {
                rope_inplace(&mut k[kv * dh..(kv + 1) * dh], pos, cfg.rope_theta);
            }
        }
    }

    /// Stage-4 tile worker: output projection + residual + MLP for the
    /// tile's token rows — per-token arithmetic identical to the decode
    /// path's `layer_mlp`. Each token's per-head attention outputs are
    /// gathered from the head-major staging area into a contiguous row
    /// first, so the `wo` reduction order matches the serial path bit
    /// for bit.
    fn mlp_tile(&self, li: usize, t: &mut MlpTile, ws: &mut WorkerScratch) {
        let cfg = &self.cfg;
        let lw = &self.weights.layers[li];
        let dm = cfg.d_model;
        let ghd = cfg.group() * cfg.head_dim;
        let arow = cfg.n_heads * cfg.head_dim;
        let km = self.kernels;
        ws.attn_row.resize(arow, 0.0);
        ws.h.resize(dm, 0.0);
        ws.gate.resize(cfg.ffn_hidden, 0.0);
        ws.up.resize(cfg.ffn_hidden, 0.0);
        ws.mlp.resize(dm, 0.0);
        for (r, xs) in t.x.chunks_mut(dm).enumerate() {
            let row = t.t0 + r;
            for kv in 0..cfg.n_kv_heads {
                let at = (kv * t.len + row) * ghd;
                ws.attn_row[kv * ghd..(kv + 1) * ghd].copy_from_slice(&t.attn[at..at + ghd]);
            }
            simd::vecmat(km, &ws.attn_row, lw.wo.data(), dm, &mut ws.h);
            for (x, &h) in xs.iter_mut().zip(&ws.h) {
                *x += h;
            }
            simd::rms_norm(km, xs, lw.mlp_norm.data(), &mut ws.h, 1e-5);
            simd::vecmat(km, &ws.h, lw.w_gate.data(), cfg.ffn_hidden, &mut ws.gate);
            simd::vecmat(km, &ws.h, lw.w_up.data(), cfg.ffn_hidden, &mut ws.up);
            simd::silu_mul(km, &mut ws.gate, &ws.up);
            simd::vecmat(km, &ws.gate, lw.w_down.data(), dm, &mut ws.mlp);
            for (x, &m) in xs.iter_mut().zip(&ws.mlp) {
                *x += m;
            }
        }
    }

    /// Greedy generation helper used by evals and examples.
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        &self,
        prompt: &[u32],
        n_new: usize,
        serve: &ServeConfig,
        selector: Option<&dyn Selector>,
        cache: &mut SeqKvCache,
        state: &mut SeqState,
        scratch: &mut DecodeScratch,
    ) -> Vec<u32> {
        self.prefill(prompt, cache, state, serve, scratch);
        let mut out = Vec::with_capacity(n_new);
        let mut tok = crate::tensor::ops::argmax(&scratch.logits) as u32;
        let mut pos = prompt.len();
        for _ in 0..n_new {
            out.push(tok);
            self.decode_step(tok, pos, cache, state, serve, selector, scratch);
            tok = crate::tensor::ops::argmax(&scratch.logits) as u32;
            pos += 1;
        }
        out
    }
}

/// Borrow an owned selector as the trait object the engine takes.
pub fn sel_ref(sel: &Option<Box<dyn Selector + Send + Sync>>) -> Option<&dyn Selector> {
    match sel {
        Some(b) => Some(b.as_ref()),
        None => None,
    }
}

/// Build the [`Selector`] instance for a method (None = dense).
pub fn make_selector(serve: &ServeConfig) -> Option<Box<dyn Selector + Send + Sync>> {
    use crate::attention::methods::*;
    Some(match serve.method {
        Method::Dense => return None,
        Method::ExactTopK => Box::new(ExactTopK),
        Method::Hata => Box::new(HataSelector),
        Method::Loki => Box::new(LokiSelector { channels: serve.loki_channels }),
        Method::Quest => Box::new(QuestSelector),
        Method::MagicPig => Box::new(MagicPigSelector),
        Method::StreamingLlm => Box::new(StreamingLlm { sinks: serve.sinks }),
        Method::H2o => Box::new(H2oSelector),
        Method::SnapKv => Box::new(SnapKvSelector { window: serve.snapkv_window }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::tensor::ops::argmax;
    use crate::util::rng::Rng;

    fn tiny_model(method: Method) -> (Model, ServeConfig) {
        let cfg = preset("hata-gqa").unwrap();
        let serve = ServeConfig { method, budget: 16, ..Default::default() };
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        (Model::new(cfg, weights, aux), serve)
    }

    #[test]
    fn decode_step_produces_finite_logits() {
        let (model, serve) = tiny_model(Method::Dense);
        let mut cache = SeqKvCache::new(&model.cfg, &serve);
        let mut state = SeqState::new(&model.cfg);
        let mut scratch = DecodeScratch::new(&model.cfg);
        for pos in 0..5 {
            let tok = 7 + pos as u32;
            model.decode_step(tok, pos, &mut cache, &mut state, &serve, None, &mut scratch);
        }
        assert_eq!(cache.len(), 5);
        assert!(scratch.logits.iter().all(|x| x.is_finite()));
        assert_eq!(scratch.logits.len(), model.cfg.vocab);
    }

    #[test]
    fn hata_with_full_budget_matches_dense() {
        // budget >= s falls back to dense per step: outputs identical
        let (model, mut serve) = tiny_model(Method::Hata);
        serve.budget = 1000;
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (40..60).collect();
        let mut c1 = SeqKvCache::new(&model.cfg, &serve);
        let mut s1 = SeqState::new(&model.cfg);
        let mut sc1 = DecodeScratch::new(&model.cfg);
        let out1 = model.generate(&prompt, 4, &serve, sel_ref(&sel), &mut c1, &mut s1, &mut sc1);
        let dense_serve = ServeConfig { method: Method::Dense, budget: 0, ..serve.clone() };
        let mut c2 = SeqKvCache::new(&model.cfg, &dense_serve);
        let mut s2 = SeqState::new(&model.cfg);
        let mut sc2 = DecodeScratch::new(&model.cfg);
        let out2 = model.generate(&prompt, 4, &dense_serve, None, &mut c2, &mut s2, &mut sc2);
        assert_eq!(out1, out2);
    }

    #[test]
    fn every_method_runs_end_to_end() {
        for &method in Method::all() {
            let (model, serve) = tiny_model(method);
            let sel = make_selector(&serve);
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            let prompt: Vec<u32> = (32..96).collect();
            let out =
                model.generate(&prompt, 3, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch);
            assert_eq!(out.len(), 3, "method {method:?}");
            assert!(scratch.logits.iter().all(|x| x.is_finite()), "method {method:?}");
        }
    }

    #[test]
    fn gather_and_fused_kernels_agree() {
        let (mut model, serve) = tiny_model(Method::Hata);
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (32..112).collect();
        let run = |model: &Model| {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            model.generate(&prompt, 6, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch)
        };
        let fused = run(&model);
        model.sparse_kernel = SparseKernel::Gather;
        let gathered = run(&model);
        assert_eq!(fused, gathered);
    }

    #[test]
    fn deterministic_generation() {
        let (model, serve) = tiny_model(Method::Hata);
        let sel = make_selector(&serve);
        let prompt: Vec<u32> = (32..80).collect();
        let gen = |_| {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            model.generate(&prompt, 5, &serve, sel_ref(&sel), &mut cache, &mut state, &mut scratch)
        };
        assert_eq!(gen(0), gen(1));
    }

    #[test]
    fn tiled_prefill_matches_serial_prefill() {
        // block/tile decomposition must not change a single bit: caches,
        // codes, final-layer queries and logits all compare exactly
        let (model, serve) = tiny_model(Method::Hata);
        let prompt: Vec<u32> = (0..90u32).map(|i| 32 + (i % 64)).collect();
        let mut c1 = SeqKvCache::new(&model.cfg, &serve);
        let mut s1 = SeqState::new(&model.cfg);
        let mut sc1 = DecodeScratch::new(&model.cfg);
        model.prefill_serial(&prompt, &mut c1, &mut s1, &serve, &mut sc1);
        for (chunk, tile) in [(32usize, 5usize), (64, 16), (1024, 7), (16, 1024)] {
            let serve_t = ServeConfig { prefill_chunk: chunk, prefill_tile: tile, ..serve.clone() };
            let mut c2 = SeqKvCache::new(&model.cfg, &serve_t);
            let mut s2 = SeqState::new(&model.cfg);
            let mut sc2 = DecodeScratch::new(&model.cfg);
            model.prefill(&prompt, &mut c2, &mut s2, &serve_t, &mut sc2);
            assert_eq!(c1.len(), c2.len(), "chunk {chunk} tile {tile}");
            for li in 0..model.cfg.n_layers {
                for kv in 0..model.cfg.n_kv_heads {
                    assert_eq!(c1.k_slice(li, kv), c2.k_slice(li, kv), "chunk {chunk} tile {tile}");
                    assert_eq!(c1.v_slice(li, kv), c2.v_slice(li, kv), "chunk {chunk} tile {tile}");
                    assert_eq!(
                        c1.codes_slice(li, kv),
                        c2.codes_slice(li, kv),
                        "chunk {chunk} tile {tile}"
                    );
                }
            }
            assert_eq!(sc1.logits, sc2.logits, "chunk {chunk} tile {tile}");
            assert_eq!(sc1.q, sc2.q, "chunk {chunk} tile {tile}");
        }
    }

    #[test]
    fn decode_batch_matches_serial_generate() {
        for method in [Method::Dense, Method::Hata, Method::Quest] {
            let (model, serve) = tiny_model(method);
            let sel = make_selector(&serve);
            let prompts: Vec<Vec<u32>> =
                vec![(32..72).collect(), (40..95).collect(), (50..76).collect()];
            let n_new = 4;
            // serial reference
            let mut want = Vec::new();
            for p in &prompts {
                let mut cache = SeqKvCache::new(&model.cfg, &serve);
                let mut state = SeqState::new(&model.cfg);
                let mut scratch = DecodeScratch::new(&model.cfg);
                want.push(model.generate(
                    p,
                    n_new,
                    &serve,
                    sel_ref(&sel),
                    &mut cache,
                    &mut state,
                    &mut scratch,
                ));
            }
            // batched path across a 3-worker pool
            let pool = ThreadPool::new(3);
            let mut workers: Vec<WorkerScratch> =
                (0..3).map(|_| WorkerScratch::default()).collect();
            let mut caches: Vec<SeqKvCache> =
                prompts.iter().map(|_| SeqKvCache::new(&model.cfg, &serve)).collect();
            let mut states: Vec<SeqState> =
                prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
            let mut scratches: Vec<DecodeScratch> =
                prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
            let mut next: Vec<u32> = Vec::with_capacity(prompts.len());
            for (i, p) in prompts.iter().enumerate() {
                model.prefill(p, &mut caches[i], &mut states[i], &serve, &mut scratches[i]);
                next.push(argmax(&scratches[i].logits) as u32);
            }
            let mut got: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
            let mut graph_cache = DecodeGraphCache::new();
            for step in 0..n_new {
                for (i, &tok) in next.iter().enumerate() {
                    got[i].push(tok);
                }
                let mut items: Vec<DecodeItem> = caches
                    .iter_mut()
                    .zip(states.iter_mut())
                    .zip(scratches.iter_mut())
                    .enumerate()
                    .map(|(i, ((cache, state), scratch))| DecodeItem {
                        token: next[i],
                        pos: prompts[i].len() + step,
                        cache,
                        state,
                        scratch,
                    })
                    .collect();
                model.decode_batch(
                    &mut items,
                    &serve,
                    sel_ref(&sel),
                    &pool,
                    &mut workers,
                    &mut graph_cache,
                );
                drop(items);
                for (i, n) in next.iter_mut().enumerate() {
                    *n = argmax(&scratches[i].logits) as u32;
                }
            }
            assert_eq!(got, want, "method {method:?}");
        }
    }

    #[test]
    fn graph_cache_survives_batch_shape_changes() {
        // one long-lived DecodeGraphCache driven through growing and
        // shrinking batches must keep producing the exact logits of the
        // serial decode path (rebuild-on-shape-change correctness)
        let (model, serve) = tiny_model(Method::Hata);
        let sel = make_selector(&serve);
        let prompts: Vec<Vec<u32>> =
            vec![(32..72).collect(), (40..95).collect(), (50..76).collect()];
        // serial reference: full generation per sequence
        let n_new = 6;
        let mut want_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        for p in &prompts {
            let mut cache = SeqKvCache::new(&model.cfg, &serve);
            let mut state = SeqState::new(&model.cfg);
            let mut scratch = DecodeScratch::new(&model.cfg);
            model.prefill(p, &mut cache, &mut state, &serve, &mut scratch);
            let mut tok = argmax(&scratch.logits) as u32;
            let mut per_step = Vec::new();
            for step in 0..n_new {
                model.decode_step(
                    tok,
                    p.len() + step,
                    &mut cache,
                    &mut state,
                    &serve,
                    sel_ref(&sel),
                    &mut scratch,
                );
                per_step.push(scratch.logits.clone());
                tok = argmax(&scratch.logits) as u32;
            }
            want_logits.push(per_step);
        }
        // batched path: batch {0,1,2} for 2 steps, then {0,1} for 2,
        // then {0,1,2} again — exercising shrink and re-grow against one
        // persistent cache
        let pool = ThreadPool::new(3);
        let mut workers: Vec<WorkerScratch> = (0..3).map(|_| WorkerScratch::default()).collect();
        let mut caches: Vec<SeqKvCache> =
            prompts.iter().map(|_| SeqKvCache::new(&model.cfg, &serve)).collect();
        let mut states: Vec<SeqState> =
            prompts.iter().map(|_| SeqState::new(&model.cfg)).collect();
        let mut scratches: Vec<DecodeScratch> =
            prompts.iter().map(|_| DecodeScratch::new(&model.cfg)).collect();
        let mut next: Vec<u32> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            model.prefill(p, &mut caches[i], &mut states[i], &serve, &mut scratches[i]);
            next.push(argmax(&scratches[i].logits) as u32);
        }
        let mut graph_cache = DecodeGraphCache::new();
        let mut steps_done = vec![0usize; prompts.len()];
        let phases: [(usize, usize); 3] = [(3, 2), (2, 2), (3, 2)];
        let mut total_builds = 0u64;
        for (nseq, steps) in phases {
            for _ in 0..steps {
                let mut items: Vec<DecodeItem> = Vec::new();
                for (i, ((cache, state), scratch)) in caches
                    .iter_mut()
                    .zip(states.iter_mut())
                    .zip(scratches.iter_mut())
                    .enumerate()
                    .take(nseq)
                {
                    items.push(DecodeItem {
                        token: next[i],
                        pos: prompts[i].len() + steps_done[i],
                        cache,
                        state,
                        scratch,
                    });
                }
                let stats = model.decode_batch(
                    &mut items,
                    &serve,
                    sel_ref(&sel),
                    &pool,
                    &mut workers,
                    &mut graph_cache,
                );
                total_builds += stats.graph_builds;
                drop(items);
                for i in 0..nseq {
                    let step = steps_done[i];
                    assert_eq!(
                        scratches[i].logits, want_logits[i][step],
                        "seq {i} step {step} logits"
                    );
                    next[i] = argmax(&scratches[i].logits) as u32;
                    steps_done[i] += 1;
                }
            }
        }
        // exactly one build per batch-shape change (3 phases), the rest hits
        assert_eq!(total_builds, 3);
    }
}
