//! Cost models: memory-traffic accounting (the quantity the paper's
//! speedups are made of), a PCIe transfer model for the offloading
//! experiments (Table 3), and TPU roofline estimates for the L1 kernels.

pub mod hbm;
pub mod pcie;
pub mod roofline;
