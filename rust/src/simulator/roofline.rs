//! TPU roofline estimates for the L1 Pallas kernels.
//!
//! `interpret=True` gives CPU-numpy timings only, so real-TPU performance
//! is *estimated* from the BlockSpec schedule: VMEM residency, bytes
//! streamed from HBM, and MXU/VPU work (DESIGN.md §3). Numbers below use
//! TPU v4-class constants; swap `Device` to retarget.

/// Device constants for roofline math.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// HBM bandwidth, bytes/s
    pub hbm_bw: f64,
    /// MXU peak, FLOP/s (bf16)
    pub mxu_flops: f64,
    /// VPU peak, simple-op/s
    pub vpu_ops: f64,
    /// VMEM capacity, bytes
    pub vmem: usize,
}

impl Device {
    /// TPU v4-class constants.
    pub fn tpu_v4() -> Self {
        Device { hbm_bw: 1.2e12, mxu_flops: 275e12, vpu_ops: 4e12, vmem: 16 << 20 }
    }

    /// Nominal single-core CPU constants for the native engine's
    /// roofline columns (microbench / fig9): ~20 GB/s sustained
    /// per-core DRAM bandwidth, and 8-lane AVX2 FMA peak at ~3 GHz
    /// (2 FMA ports x 8 lanes x 2 flops x 3e9 = 96 GFLOP/s). `vpu_ops`
    /// is the same pipe without FMA fusion (one rounded op per cycle
    /// per lane pair) and `vmem` stands in for L2. These are *nominal*
    /// bounds — the benches print measured GB/s and FLOP/s next to
    /// them, so absolute calibration only shifts the `%roof` column,
    /// never the mode-vs-mode speedups.
    pub fn cpu() -> Self {
        Device { hbm_bw: 2.0e10, mxu_flops: 96e9, vpu_ops: 48e9, vmem: 1 << 20 }
    }
}

/// Roofline estimate for one kernel invocation.
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    /// Bytes streamed from/to HBM.
    pub hbm_bytes: f64,
    /// MXU floating-point operations.
    pub flops: f64,
    /// VPU element operations.
    pub vpu_ops: f64,
    /// Peak VMEM residency.
    pub vmem_bytes: usize,
    /// max(memory time, compute time)
    pub seconds: f64,
    /// fraction of peak the bound resource achieves (1.0 = roofline)
    pub efficiency: f64,
}

fn finish(dev: &Device, hbm_bytes: f64, flops: f64, vpu: f64, vmem: usize) -> KernelEstimate {
    let t_mem = hbm_bytes / dev.hbm_bw;
    let t_mxu = flops / dev.mxu_flops;
    let t_vpu = vpu / dev.vpu_ops;
    let seconds = t_mem.max(t_mxu).max(t_vpu);
    let efficiency = if seconds == 0.0 { 1.0 } else { t_mem.max(t_mxu).max(t_vpu) / seconds };
    KernelEstimate { hbm_bytes, flops, vpu_ops: vpu, vmem_bytes: vmem, seconds, efficiency }
}

/// hash_encode kernel: [s, d] x [d, rbit] matmul + sign + pack.
/// VMEM: x tile + whole W_H + out tile (see hash_encode.py docstring).
pub fn hash_encode(dev: &Device, s: usize, d: usize, rbit: usize, tile_s: usize) -> KernelEstimate {
    let hbm = (s * d * 4 + d * rbit * 4 + s * rbit / 8) as f64;
    let flops = 2.0 * s as f64 * d as f64 * rbit as f64;
    let vpu = (s * rbit) as f64; // sign+pack
    let vmem = tile_s * d * 4 + d * rbit * 4 + tile_s * rbit / 8;
    finish(dev, hbm, flops, vpu, vmem)
}

/// hamming kernel: stream s codes, XOR+popcount+reduce on the VPU.
/// Output is the GROUP-AGGREGATED per-token score (s * i32); per-head
/// scores stay in VMEM tiles and never round-trip through HBM.
pub fn hamming(dev: &Device, h: usize, s: usize, rbit: usize, tile_k: usize) -> KernelEstimate {
    let words = rbit / 32;
    let hbm = (h * words * 4 + s * words * 4 + s * 4) as f64;
    let vpu = (h * s * words * 3) as f64; // xor, popcount, add
    let vmem = (h + tile_k) * words * 4 + h * tile_k * 4;
    finish(dev, hbm, 0.0, vpu, vmem)
}

/// fused sparse attention: k selected rows of K and V streamed once.
pub fn sparse_attention(dev: &Device, h: usize, dh: usize, k: usize, tile_n: usize) -> KernelEstimate {
    let hbm = (2 * k * dh * 4 + h * dh * 4 * 2) as f64;
    let flops = 2.0 * 2.0 * h as f64 * k as f64 * dh as f64; // qk and pv
    let vpu = (h * k * 4) as f64; // online softmax bookkeeping
    let vmem = 2 * tile_n * dh * 4 + h * dh * 4 * 3;
    finish(dev, hbm, flops, vpu, vmem)
}

/// Dense attention at the same shape, for the speedup ratio.
pub fn dense_attention(dev: &Device, h: usize, dh: usize, s: usize) -> KernelEstimate {
    sparse_attention(dev, h, dh, s, 512)
}

/// First-principles estimate for an arbitrary float kernel: bytes moved
/// and flops executed, no VPU/VMEM modeling (the CPU benches make the
/// working set explicit in the shape instead). The shared helper behind
/// every roofline column the float microbenches print.
pub fn float_kernel(dev: &Device, hbm_bytes: f64, flops: f64) -> KernelEstimate {
    finish(dev, hbm_bytes, flops, 0.0, 0)
}

/// Per-dtype variant of [`float_kernel`]: `elems` values streamed at the
/// KV storage dtype's element width. Half-precision rows move half the
/// bytes, so the roofline time halves relative to f32 at the same
/// bandwidth — the bound the `--kv-dtype` microbench rows print their
/// GB/s against.
pub fn float_kernel_dtype(
    dev: &Device,
    dtype: crate::tensor::simd::KvDtype,
    elems: f64,
    flops: f64,
) -> KernelEstimate {
    float_kernel(dev, elems * dtype.bytes() as f64, flops)
}

/// Integer/bit-op kernel estimate (the vectorized Hamming scorer):
/// bytes moved plus simple ALU ops (XOR + popcount + add) in the VPU
/// slot, no floating-point work. Gives the scorer its own GOP/s
/// roofline row per `KernelMode` instead of a meaningless GFLOP/s one.
pub fn int_kernel(dev: &Device, hbm_bytes: f64, ops: f64) -> KernelEstimate {
    finish(dev, hbm_bytes, 0.0, ops, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernels_fit_vmem() {
        let dev = Device::tpu_v4();
        assert!(hash_encode(&dev, 32_768, 128, 128, 256).vmem_bytes < dev.vmem);
        assert!(hamming(&dev, 8, 131_072, 128, 2048).vmem_bytes < dev.vmem);
        assert!(sparse_attention(&dev, 8, 128, 2048, 128).vmem_bytes < dev.vmem);
    }

    #[test]
    fn hamming_is_bandwidth_bound() {
        let dev = Device::tpu_v4();
        let e = hamming(&dev, 8, 1 << 20, 128, 2048);
        let t_mem = e.hbm_bytes / dev.hbm_bw;
        assert!((e.seconds - t_mem).abs() / e.seconds < 0.5);
    }

    #[test]
    fn hata_vs_dense_tpu_speedup_exceeds_paper_ratio() {
        // paper: up to 7.2x e2e on A100-class; the attention-only TPU
        // estimate at 32K ctx / 1.56% budget must exceed that. Per-KV-head
        // basis (each head owns its K/V and code stream).
        let dev = Device::tpu_v4();
        let (h, dh, s) = (1, 128, 32_768);
        let k = (s as f64 * 0.0156) as usize;
        let dense = dense_attention(&dev, h, dh, s).seconds;
        let hata = hamming(&dev, h, s, 128, 2048).seconds
            + sparse_attention(&dev, h, dh, k, 128).seconds
            + hash_encode(&dev, 1, dh, 128, 256).seconds;
        let speedup = dense / hata;
        assert!(speedup > 7.2, "tpu-modeled speedup {speedup}");
    }

    #[test]
    fn float_kernel_takes_binding_resource() {
        let dev = Device::cpu();
        // memory-bound: 1 GB moved, almost no flops
        let mem = float_kernel(&dev, 1e9, 1.0);
        assert!((mem.seconds - 1e9 / dev.hbm_bw).abs() / mem.seconds < 1e-9);
        // compute-bound: no traffic, 96 GFLOP = 1 s at nominal peak
        let cmp = float_kernel(&dev, 8.0, 96e9);
        assert!((cmp.seconds - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dtype_kernel_halves_bandwidth_bound_for_half_rows() {
        use crate::tensor::simd::KvDtype;
        let dev = Device::cpu();
        let elems = 1e8;
        let f32_est = float_kernel_dtype(&dev, KvDtype::F32, elems, 0.0);
        let bf16_est = float_kernel_dtype(&dev, KvDtype::Bf16, elems, 0.0);
        assert!((f32_est.hbm_bytes - 2.0 * bf16_est.hbm_bytes).abs() < 1.0);
        assert!((f32_est.seconds - 2.0 * bf16_est.seconds).abs() / f32_est.seconds < 1e-9);
    }

    #[test]
    fn int_kernel_takes_binding_resource() {
        let dev = Device::cpu();
        // memory-bound: 1 GB of codes streamed, trivial ALU work
        let mem = int_kernel(&dev, 1e9, 1.0);
        assert!((mem.seconds - 1e9 / dev.hbm_bw).abs() / mem.seconds < 1e-9);
        // ALU-bound: no traffic, 48 Gop = 1 s at the nominal VPU peak
        let alu = int_kernel(&dev, 8.0, 48e9);
        assert!((alu.seconds - 1.0).abs() < 1e-6);
        assert_eq!(alu.flops, 0.0);
    }

    #[test]
    fn estimates_scale_with_context() {
        let dev = Device::tpu_v4();
        let a = hamming(&dev, 8, 10_000, 128, 2048).seconds;
        let b = hamming(&dev, 8, 20_000, 128, 2048).seconds;
        assert!(b > 1.8 * a);
    }
}
