//! Memory-traffic accounting per attention method per decode step.
//!
//! The paper's speedups are bandwidth ratios: dense attention reads the
//! whole K and V cache every step; a top-k method reads its score
//! structure (codes / channels / block summaries) plus only k full K/V
//! rows. This model counts those bytes exactly, so benches can report the
//! *modeled* GPU-side speedup next to measured CPU wall time, and the
//! roofline module can translate to any device bandwidth.

use crate::attention::methods::LokiSelector;
use crate::attention::Selector;
use crate::config::{Method, ModelConfig, ServeConfig};

/// Bytes touched by one decode step of one sequence at context length `s`
/// with token budget `k`, across all layers/heads of `cfg`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepTraffic {
    /// bytes read to produce selection scores
    pub score_bytes: u64,
    /// bytes of K/V actually attended (gathered rows or full cache)
    pub attend_bytes: u64,
    /// bytes written (cache appends, code appends)
    pub write_bytes: u64,
}

impl StepTraffic {
    /// All bytes moved by the step.
    pub fn total(&self) -> u64 {
        self.score_bytes + self.attend_bytes + self.write_bytes
    }
}

/// Per-head-token sizes in bytes.
fn kv_row(cfg: &ModelConfig) -> u64 {
    (cfg.head_dim * 4) as u64
}

/// Compute the traffic of one decode step.
pub fn decode_traffic(cfg: &ModelConfig, serve: &ServeConfig, s: usize, budget: usize) -> StepTraffic {
    let heads = (cfg.n_layers * cfg.n_kv_heads) as u64;
    let row = kv_row(cfg);
    let s64 = s as u64;
    let k64 = budget.min(s) as u64;
    let writes = heads * (2 * row + (cfg.rbit as u64) / 8);
    let sparse_layers = cfg.n_layers.saturating_sub(cfg.dense_layers) as u64;
    let dense_layers = (cfg.n_layers as u64) - sparse_layers;
    let per_layer_heads = cfg.n_kv_heads as u64;
    let dense_attend = dense_layers * per_layer_heads * s64 * 2 * row;
    let mk = |score_per_tok: u64, gathered: bool| StepTraffic {
        score_bytes: sparse_layers * per_layer_heads * s64 * score_per_tok,
        attend_bytes: dense_attend
            + sparse_layers
                * per_layer_heads
                * (if gathered { k64 } else { s64 }) * 2 * row,
        write_bytes: writes,
    };
    match serve.method {
        Method::Dense => StepTraffic {
            score_bytes: 0,
            attend_bytes: (cfg.n_layers as u64) * per_layer_heads * s64 * 2 * row,
            write_bytes: heads * 2 * row,
        },
        // exact top-k reads all keys to score, then gathers k rows of K+V
        Method::ExactTopK => mk(row, true),
        Method::Hata => mk((cfg.rbit / 8) as u64, true),
        // the selector itself reports its score traffic (channels * 4 B
        // per token) — no special-casing here
        Method::Loki => {
            let sel = LokiSelector { channels: serve.loki_channels };
            mk(sel.score_bytes_per_token(cfg.head_dim, cfg.rbit) as u64, true)
        }
        Method::Quest => {
            // block summaries: 2*dh f32 per block => amortized per token
            let per_tok = (2 * cfg.head_dim * 4) as u64 / serve.quest_block as u64;
            mk(per_tok, true)
        }
        Method::MagicPig => mk((serve.magicpig_l * 2) as u64, true),
        // compression methods never score the whole cache
        Method::StreamingLlm | Method::SnapKv => mk(0, true),
        Method::H2o => mk(4, true),
    }
}

/// Modeled step seconds on a device with `bandwidth` bytes/s (bandwidth-
/// bound regime, which long-context decode is on both GPU and CPU).
pub fn modeled_step_seconds(traffic: &StepTraffic, bandwidth: f64) -> f64 {
    traffic.total() as f64 / bandwidth
}

/// Modeled speedup of `method` over dense at the same shape.
pub fn modeled_speedup(cfg: &ModelConfig, serve: &ServeConfig, s: usize, budget: usize) -> f64 {
    let dense = decode_traffic(cfg, &ServeConfig { method: Method::Dense, ..serve.clone() }, s, budget);
    let m = decode_traffic(cfg, serve, s, budget);
    dense.total() as f64 / m.total() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn serve(method: Method) -> ServeConfig {
        ServeConfig { method, ..Default::default() }
    }

    #[test]
    fn dense_scales_linearly_with_context() {
        let cfg = preset("mirror-llama2-7b").unwrap();
        let t1 = decode_traffic(&cfg, &serve(Method::Dense), 1000, 0);
        let t2 = decode_traffic(&cfg, &serve(Method::Dense), 2000, 0);
        assert!(t2.attend_bytes > 19 * t1.attend_bytes / 10);
    }

    #[test]
    fn hata_beats_dense_and_loki_at_long_context() {
        let cfg = preset("mirror-llama2-7b").unwrap();
        let s = 32_768;
        let k = (s as f64 * 0.0156) as usize;
        let hata = decode_traffic(&cfg, &serve(Method::Hata), s, k).total();
        let loki = decode_traffic(
            &cfg,
            &ServeConfig { method: Method::Loki, loki_channels: 32, ..Default::default() },
            s,
            k,
        )
        .total();
        let dense = decode_traffic(&cfg, &serve(Method::Dense), s, k).total();
        assert!(hata < loki, "hata {hata} < loki {loki}");
        assert!(loki < dense);
        let speedup = dense as f64 / hata as f64;
        // paper reports up to 7.2x e2e; raw attention traffic ratio must
        // comfortably exceed that at 32K (rest of model dilutes it)
        assert!(speedup > 4.0, "speedup {speedup}");
    }

    #[test]
    fn magicpig_scores_cost_more_than_hata() {
        // 150 tables * 16 bits vs 128-bit HATA codes — the paper's point
        let cfg = preset("mirror-llama31-8b").unwrap();
        let s = 65_536;
        let mp = decode_traffic(&cfg, &serve(Method::MagicPig), s, 1024);
        let hata = decode_traffic(&cfg, &serve(Method::Hata), s, 1024);
        assert!(mp.score_bytes > 10 * hata.score_bytes);
    }

    #[test]
    fn dense_first_layers_accounted() {
        let cfg = preset("hata-mha").unwrap(); // dense_layers = 1 of 3
        let t = decode_traffic(&cfg, &serve(Method::Hata), 1024, 32);
        // attend bytes must include a full-cache dense component
        let dense_one_layer =
            (cfg.n_kv_heads * 1024 * 2 * cfg.head_dim * 4) as u64;
        assert!(t.attend_bytes >= dense_one_layer);
    }

    #[test]
    fn modeled_speedup_monotone_in_context() {
        let cfg = preset("mirror-llama2-7b").unwrap();
        let s1 = modeled_speedup(&cfg, &serve(Method::Hata), 8_192, 128);
        let s2 = modeled_speedup(&cfg, &serve(Method::Hata), 131_072, 2048);
        assert!(s2 > s1);
    }
}
