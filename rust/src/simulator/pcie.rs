//! PCIe transfer cost model (Table 3 substrate).
//!
//! The paper's offloading testbed: PCIe 4.0 x16 (~32 GB/s peak, ~25 GB/s
//! effective) with 48 CPU threads. We model transfer time as
//! `latency + bytes / bandwidth` with a configurable effective bandwidth,
//! and expose an accumulating ledger so benches can report modeled
//! transfer seconds alongside measured compute seconds (DESIGN.md §4).

/// One direction of a PCIe link.
#[derive(Clone, Copy, Debug)]
pub struct PcieModel {
    /// effective bandwidth, bytes/second
    pub bandwidth: f64,
    /// per-transfer latency, seconds (DMA setup + doorbell)
    pub latency: f64,
}

impl PcieModel {
    /// PCIe 4.0 x16 effective numbers (25 GB/s, 10 us setup).
    pub fn gen4_x16() -> Self {
        PcieModel { bandwidth: 25.0e9, latency: 10e-6 }
    }

    /// Seconds to move `bytes` in one DMA.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Seconds to move `bytes` split into `n` scattered row reads
    /// (gathers of non-contiguous KV rows pay per-row overhead, amortized
    /// 8x by batching descriptors).
    pub fn gather_time(&self, bytes: usize, rows: usize) -> f64 {
        let batches = rows.div_ceil(8);
        self.latency * batches as f64 + bytes as f64 / self.bandwidth
    }
}

/// Accumulates modeled transfer time + bytes for a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransferLedger {
    /// Bytes that crossed the link.
    pub bytes: u64,
    /// Modeled link seconds.
    pub seconds: f64,
    /// DMA count.
    pub transfers: u64,
}

impl TransferLedger {
    /// Account one contiguous DMA.
    pub fn add(&mut self, model: &PcieModel, bytes: usize) {
        self.bytes += bytes as u64;
        self.seconds += model.transfer_time(bytes);
        self.transfers += 1;
    }

    /// Account one scattered row gather.
    pub fn add_gather(&mut self, model: &PcieModel, bytes: usize, rows: usize) {
        self.bytes += bytes as u64;
        self.seconds += model.gather_time(bytes, rows);
        self.transfers += 1;
    }

    /// Fold another ledger into this one (used to roll per-step or
    /// per-worker ledgers up into a run total).
    pub fn merge(&mut self, other: &TransferLedger) {
        self.bytes += other.bytes;
        self.seconds += other.seconds;
        self.transfers += other.transfers;
    }

    /// Overlap compute and transfer: wall time of a step that computes
    /// for `compute_s` while this ledger's last transfer streams.
    pub fn overlapped(compute_s: f64, transfer_s: f64) -> f64 {
        compute_s.max(transfer_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let m = PcieModel::gen4_x16();
        let t = m.transfer_time(25_000_000_000usize);
        assert!((t - 1.0).abs() < 0.01, "{t}");
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let m = PcieModel::gen4_x16();
        assert!(m.transfer_time(64) < 11e-6);
        assert!(m.transfer_time(64) >= 10e-6);
    }

    #[test]
    fn gather_pays_per_batch_latency() {
        let m = PcieModel::gen4_x16();
        let contiguous = m.transfer_time(1 << 20);
        let scattered = m.gather_time(1 << 20, 1024);
        assert!(scattered > contiguous);
        // 1024 rows -> 128 descriptor batches
        assert!((scattered - contiguous - 127.0 * m.latency).abs() < 1e-6);
    }

    #[test]
    fn gather_of_zero_rows_pays_no_latency() {
        // rows=0 -> zero descriptor batches: an empty gather models a
        // fetch pass that found every block resident (runtime hit path).
        let m = PcieModel::gen4_x16();
        assert_eq!(m.gather_time(0, 0), 0.0);
        // bytes with rows=0 would be a caller bug, but the model stays
        // well-defined: pure bandwidth term, no setup cost.
        assert!((m.gather_time(1 << 20, 0) - (1 << 20) as f64 / m.bandwidth).abs() < 1e-12);
    }

    #[test]
    fn gather_under_eight_rows_is_one_batch() {
        // rows < 8 still needs one descriptor batch: same setup cost as
        // a contiguous DMA of the same size.
        let m = PcieModel::gen4_x16();
        for rows in 1..8 {
            assert!((m.gather_time(4096, rows) - m.transfer_time(4096)).abs() < 1e-12, "rows={rows}");
        }
        // the ninth row starts a second batch
        assert!(m.gather_time(4096, 9) > m.gather_time(4096, 8));
    }

    #[test]
    fn ledger_gather_accounting_matches_runtime_fetch_path() {
        // The runtime fetch path accounts each demand-fetch pass as one
        // gather of `missing_rows` K/V rows; the ledger must agree with
        // PcieModel::gather_time exactly and merge() must be lossless.
        let m = PcieModel::gen4_x16();
        let mut per_pass = TransferLedger::default();
        per_pass.add_gather(&m, 3 * 4096, 3 * 2 * 4); // 3 blocks, 2*bt rows each
        assert_eq!(per_pass.transfers, 1);
        assert!((per_pass.seconds - m.gather_time(3 * 4096, 24)).abs() < 1e-15);
        let mut total = TransferLedger::default();
        total.add(&m, 1000);
        total.merge(&per_pass);
        assert_eq!(total.bytes, 1000 + 3 * 4096);
        assert_eq!(total.transfers, 2);
        assert!((total.seconds - (m.transfer_time(1000) + per_pass.seconds)).abs() < 1e-15);
    }

    #[test]
    fn ledger_accumulates() {
        let m = PcieModel::gen4_x16();
        let mut l = TransferLedger::default();
        l.add(&m, 1000);
        l.add_gather(&m, 2000, 16);
        assert_eq!(l.bytes, 3000);
        assert_eq!(l.transfers, 2);
        assert!(l.seconds > 0.0);
        assert_eq!(TransferLedger::overlapped(2.0, 1.0), 2.0);
    }
}
