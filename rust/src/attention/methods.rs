//! One [`Selector`] per attention method in the paper's Table 5.
//!
//! Top-k family (re-select per decode step):
//! * [`HataSelector`]      — the paper: Hamming scores on trained codes.
//! * [`ExactTopK`]         — oracle upper bound (exact qk scores).
//! * [`LokiSelector`]      — low-rank PCA channel scores.
//! * [`QuestSelector`]     — block min/max upper-bound scores.
//! * [`MagicPigSelector`]  — LSH collision sampling.
//!
//! KV-compression family (static or slowly-evolving keep sets):
//! * [`StreamingLlm`]      — attention sinks + recent window.
//! * [`H2oSelector`]       — cumulative-attention heavy hitters + recents.
//! * [`SnapKvSelector`]    — prefill observation-window keeps + recents.

use super::compute::exact_group_scores;
use super::hamming::{scores_group, scores_group_into};
use super::hashenc::encode_fused_blocked;
use super::topk::{topk_counting, topk_quickselect};
use super::{AttnInputs, MethodState, Scratch, Selector};
use crate::tensor::ops::dot;
use crate::tensor::simd::{self, KernelMode};

// --------------------------------------------------------------------- HATA

/// The paper's method (Alg. 3): encode the group's queries with the
/// trained hash weights, score every cached key code with XOR+POPCNT,
/// aggregate over the GQA group, counting-select the top-k.
#[derive(Clone, Copy, Debug, Default)]
pub struct HataSelector;

impl Selector for HataSelector {
    fn select(&self, inp: &AttnInputs, _st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        debug_assert!(!inp.side.hash_w.is_empty(), "HATA needs hash weights");
        sc.qcodes.clear();
        for g in 0..inp.group {
            encode_fused_blocked(inp.q_row(g), inp.side.hash_w, inp.rbit, &mut sc.qcodes);
        }
        if inp.bt.is_empty() {
            let rows = &inp.codes[..inp.s * inp.words];
            scores_group(inp.kernels, &sc.qcodes, inp.group, rows, inp.rbit, &mut sc.iscores);
        } else {
            // paged cache: the code rows of one logical block are
            // contiguous inside their physical block, so score block by
            // block, appending into one logical score vector — per-row
            // arithmetic identical to the contiguous one-shot call
            sc.iscores.clear();
            sc.iscores.reserve(inp.s);
            let bt = inp.block_tokens;
            let mut t = 0;
            while t < inp.s {
                let n = bt.min(inp.s - t);
                let r = inp.phys_row(t);
                let rows = &inp.codes[r * inp.words..(r + n) * inp.words];
                let sg = &mut sc.iscores;
                scores_group_into(inp.kernels, &sc.qcodes, inp.group, rows, inp.rbit, sg);
                t += n;
            }
        }
        let max_score = (inp.group * inp.rbit) as i32;
        topk_counting(&sc.iscores, max_score, budget, &mut sc.hist, &mut sc.indices);
    }

    fn name(&self) -> &'static str {
        "hata"
    }

    fn score_bytes_per_token(&self, _dh: usize, rbit: usize) -> usize {
        rbit / 8
    }
}

// -------------------------------------------------------------- exact top-k

/// Oracle: exact group-aggregated qk scores, then top-k. Reads full keys.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExactTopK;

impl Selector for ExactTopK {
    fn select(&self, inp: &AttnInputs, _st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        exact_group_scores(inp, &mut sc.scores);
        topk_quickselect(&sc.scores, budget, &mut sc.perm, &mut sc.indices);
    }

    fn name(&self) -> &'static str {
        "topk"
    }

    fn score_bytes_per_token(&self, dh: usize, _rbit: usize) -> usize {
        dh * 4
    }
}

// --------------------------------------------------------------------- Loki

/// Loki (Singhania et al. 2024): score with the first `channels` PCA
/// dimensions of queries and keys; top-k on the approximate scores.
#[derive(Clone, Copy, Debug)]
pub struct LokiSelector {
    /// Retained low-rank channels (`serve.loki_channels`); drives the
    /// per-token score traffic this method reports. Selection itself
    /// reads the per-head channel count from `AttnInputs::side`.
    pub channels: usize,
}

impl Selector for LokiSelector {
    fn select(&self, inp: &AttnInputs, _st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        let r = inp.side.loki_channels;
        debug_assert!(r > 0 && !inp.side.loki_kproj.is_empty(), "Loki needs PCA data");
        // project the group's queries onto the first r PCA channels
        sc.fbuf.clear();
        sc.fbuf.resize(inp.group * r, 0.0);
        for g in 0..inp.group {
            let q = inp.q_row(g);
            for c in 0..r {
                // pca is [dh, channels] row-major
                let mut acc = 0.0;
                for i in 0..inp.dh {
                    acc += q[i] * inp.side.loki_pca[i * r + c];
                }
                sc.fbuf[g * r + c] = acc;
            }
        }
        sc.scores.clear();
        sc.scores.resize(inp.s, 0.0);
        for t in 0..inp.s {
            let kp = &inp.side.loki_kproj[t * r..(t + 1) * r];
            let mut acc = 0.0;
            for g in 0..inp.group {
                acc += dot(&sc.fbuf[g * r..(g + 1) * r], kp);
            }
            sc.scores[t] = acc;
        }
        topk_quickselect(&sc.scores, budget, &mut sc.perm, &mut sc.indices);
    }

    fn name(&self) -> &'static str {
        "loki"
    }

    fn score_bytes_per_token(&self, _dh: usize, _rbit: usize) -> usize {
        // `channels` projected f32 per cached token — the value the
        // traffic model (simulator/hbm.rs) consumes directly.
        self.channels * 4
    }
}

// -------------------------------------------------------------------- Quest

/// Quest (Tang et al. 2024): per-block upper bound
/// `sum_i max(q_i * min_i, q_i * max_i)`, select whole blocks until the
/// token budget is filled (block granularity is the accuracy cost the
/// paper highlights).
#[derive(Clone, Copy, Debug)]
pub struct QuestSelector;

impl Selector for QuestSelector {
    fn select(&self, inp: &AttnInputs, _st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        let b = inp.side.quest_block;
        debug_assert!(b > 0, "Quest needs block metadata");
        let nblocks = (inp.s + b - 1) / b;
        sc.scores.clear();
        sc.scores.resize(nblocks, 0.0);
        for blk in 0..nblocks {
            let bmin = &inp.side.quest_min[blk * inp.dh..(blk + 1) * inp.dh];
            let bmax = &inp.side.quest_max[blk * inp.dh..(blk + 1) * inp.dh];
            let mut acc = 0.0f32;
            for g in 0..inp.group {
                let q = inp.q_row(g);
                for i in 0..inp.dh {
                    acc += (q[i] * bmin[i]).max(q[i] * bmax[i]);
                }
            }
            sc.scores[blk] = acc;
        }
        let want_blocks = (budget + b - 1) / b;
        topk_quickselect(&sc.scores, want_blocks, &mut sc.perm, &mut sc.idxbuf);
        sc.indices.clear();
        for &blk in &sc.idxbuf {
            let start = blk as usize * b;
            let end = (start + b).min(inp.s);
            sc.indices.extend(start as u32..end as u32);
        }
        sc.indices.sort_unstable();
    }

    fn name(&self) -> &'static str {
        "quest"
    }

    fn score_bytes_per_token(&self, dh: usize, _rbit: usize) -> usize {
        // 2 * dh f32 per BLOCK; amortized per token below for block 16
        2 * dh * 4 / 16
    }
}

// ----------------------------------------------------------------- MagicPIG

/// MagicPIG (Chen et al. 2024) proxy: K-bit LSH signatures in L tables;
/// score = number of colliding tables (importance sampling is replaced by
/// top-k on collision count — see DESIGN.md §4 substitutions).
#[derive(Clone, Copy, Debug)]
pub struct MagicPigSelector;

impl Selector for MagicPigSelector {
    fn select(&self, inp: &AttnInputs, _st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        let (k, l) = (inp.side.mp_k, inp.side.mp_l);
        debug_assert!(k > 0 && l > 0 && !inp.side.mp_sigs.is_empty());
        // mean query of the group (MagicPIG hashes the query once per KV
        // head group in GQA mode)
        sc.fbuf.clear();
        sc.fbuf.resize(inp.dh, 0.0);
        for g in 0..inp.group {
            for (a, &b) in sc.fbuf.iter_mut().zip(inp.q_row(g)) {
                *a += b;
            }
        }
        // query signatures per table (scratch-resident: the decode hot
        // path must not allocate)
        sc.sigbuf.clear();
        sc.sigbuf.resize(l, 0);
        for t in 0..l {
            let mut sig = 0u16;
            for bit in 0..k {
                let plane = &inp.side.mp_planes[(t * k + bit) * inp.dh..(t * k + bit + 1) * inp.dh];
                sig |= ((dot(&sc.fbuf, plane) >= 0.0) as u16) << bit;
            }
            sc.sigbuf[t] = sig;
        }
        sc.iscores.clear();
        sc.iscores.resize(inp.s, 0);
        for tok in 0..inp.s {
            let sigs = &inp.side.mp_sigs[tok * l..(tok + 1) * l];
            let mut c = 0i32;
            for t in 0..l {
                c += (sigs[t] == sc.sigbuf[t]) as i32;
            }
            sc.iscores[tok] = c;
        }
        topk_counting(&sc.iscores, l as i32, budget, &mut sc.hist, &mut sc.indices);
    }

    fn name(&self) -> &'static str {
        "magicpig"
    }

    fn score_bytes_per_token(&self, _dh: usize, _rbit: usize) -> usize {
        // L u16 signatures per token (paper: ~1500 bits = 187 B)
        150 * 2
    }
}

// ------------------------------------------------------------- StreamingLLM

/// StreamingLLM (Xiao et al. 2023): `sinks` initial tokens + recent window.
#[derive(Clone, Copy, Debug)]
pub struct StreamingLlm {
    /// Always-kept initial sink tokens.
    pub sinks: usize,
}

impl Selector for StreamingLlm {
    fn select(&self, inp: &AttnInputs, _st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        sc.indices.clear();
        let sinks = self.sinks.min(inp.s).min(budget);
        let recent = budget - sinks;
        let start = inp.s.saturating_sub(recent);
        sc.indices.extend(0..sinks as u32);
        for t in start.max(sinks)..inp.s {
            sc.indices.push(t as u32);
        }
    }

    fn name(&self) -> &'static str {
        "streamingllm"
    }

    fn score_bytes_per_token(&self, _dh: usize, _rbit: usize) -> usize {
        0 // no scoring pass at all
    }
}

// ---------------------------------------------------------------------- H2O

/// H2O (Zhang et al. 2024): half the budget goes to the tokens with the
/// highest cumulative attention mass (heavy hitters), half to recents.
/// `MethodState::h2o_cum` is updated by the engine after every step.
#[derive(Clone, Copy, Debug)]
pub struct H2oSelector;

impl Selector for H2oSelector {
    fn select(&self, inp: &AttnInputs, st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        st.h2o_cum.resize(inp.s, 0.0);
        let heavy = budget / 2;
        let recent = budget - heavy;
        let recent_start = inp.s.saturating_sub(recent);
        // heavy hitters among the non-recent region
        sc.scores.clear();
        sc.scores.extend_from_slice(&st.h2o_cum[..recent_start]);
        topk_quickselect(&sc.scores, heavy.min(recent_start), &mut sc.perm, &mut sc.idxbuf);
        sc.indices.clear();
        sc.indices.extend_from_slice(&sc.idxbuf);
        sc.indices.extend(recent_start as u32..inp.s as u32);
        sc.indices.sort_unstable();
        sc.indices.dedup();
    }

    fn name(&self) -> &'static str {
        "h2o"
    }

    fn score_bytes_per_token(&self, _dh: usize, _rbit: usize) -> usize {
        4 // one cumulative f32 per token
    }
}

/// Engine hook: fold this step's attention probabilities into the H2O
/// cumulative mass (only selected tokens received probability).
pub fn h2o_accumulate(st: &mut MethodState, indices: &[u32], probs: &[f32], s: usize) {
    st.h2o_cum.resize(s, 0.0);
    for (&t, &p) in indices.iter().zip(probs) {
        st.h2o_cum[t as usize] += p;
    }
}

// ------------------------------------------------------------------- SnapKV

/// SnapKV (Li et al. 2024): the keep-set is chosen ONCE from the last
/// `window` prefill queries' mean attention; decode adds a recent window.
#[derive(Clone, Copy, Debug)]
pub struct SnapKvSelector {
    /// Observation-window length used at prefill and for recents.
    pub window: usize,
}

impl Selector for SnapKvSelector {
    fn select(&self, inp: &AttnInputs, st: &mut MethodState, budget: usize, sc: &mut Scratch) {
        sc.indices.clear();
        let recent = self.window.min(budget);
        let recent_start = inp.s.saturating_sub(recent);
        let kept = budget - recent;
        for &t in st.snapkv_keep.iter().take(kept) {
            if (t as usize) < recent_start {
                sc.indices.push(t);
            }
        }
        sc.indices.extend(recent_start as u32..inp.s as u32);
        sc.indices.sort_unstable();
        sc.indices.dedup();
    }

    fn name(&self) -> &'static str {
        "snapkv"
    }

    fn score_bytes_per_token(&self, _dh: usize, _rbit: usize) -> usize {
        0 // selection was precomputed at prefill
    }
}

/// Engine hook at prefill end: rank prefix tokens by the mean attention
/// they received from the last `window` queries; store the full ranking
/// (the selector trims to budget). Temporaries live in `scratch`
/// (`fbuf` for per-query logits, `perm`/`idxbuf` for the ranking) so
/// the pass reuses warmed buffers like every other selector routine.
pub fn snapkv_prefill(
    st: &mut MethodState,
    inp: &AttnInputs,
    window: usize,
    scratch: &mut Scratch,
) {
    let s = inp.s;
    let w = window.min(s);
    let scale = 1.0 / (inp.dh as f32).sqrt();
    let Scratch { scores, fbuf, perm, idxbuf, .. } = scratch;
    scores.clear();
    scores.resize(s, 0.0);
    // mean softmax attention from each of the last w positions
    let logits = fbuf;
    logits.clear();
    logits.resize(s, 0.0);
    for qi in s - w..s {
        for g in 0..inp.group {
            // the observation query at position qi for head-group g: we
            // approximate with the cached KEY row as a stand-in query is
            // wrong; the engine passes actual queries via inp.q laid out
            // as [w * group, dh].
            let q = &inp.q[((qi - (s - w)) * inp.group + g) * inp.dh..][..inp.dh];
            let causal_end = qi + 1;
            let mut max = f32::NEG_INFINITY;
            for (t, l) in logits.iter_mut().enumerate().take(causal_end) {
                // Reference tier: the prefill observation pass must rank
                // identically on every backend (the keep-set is sticky
                // state, so any divergence here outlives the step).
                *l = simd::dot_wide(KernelMode::Reference, inp.kv_dtype, q, inp.k_row(t)) * scale;
                if *l > max {
                    max = *l;
                }
            }
            let mut denom = 0.0;
            for l in logits.iter_mut().take(causal_end) {
                *l = (*l - max).exp();
                denom += *l;
            }
            for (t, l) in logits.iter().enumerate().take(causal_end) {
                scores[t] += l / denom;
            }
        }
    }
    topk_quickselect(scores, s, perm, idxbuf);
    // idxbuf is index-sorted; we want score-sorted order for trimming.
    // The (score desc, index asc) key reproduces exactly what the old
    // stable sort over index-sorted input produced.
    idxbuf.sort_unstable_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    st.snapkv_keep.clear();
    st.snapkv_keep.extend_from_slice(idxbuf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::hashenc::encode_rows;
    use crate::attention::Side;
    use crate::tensor::simd::KvDtype;
    use crate::util::rng::Rng;

    fn base_inputs<'a>(
        q: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
        group: usize,
        dh: usize,
        s: usize,
    ) -> AttnInputs<'a> {
        AttnInputs {
            q,
            group,
            dh,
            k,
            v,
            codes: &[],
            words: 0,
            rbit: 0,
            s,
            pos: s - 1,
            bt: &[],
            block_tokens: 0,
            kv_dtype: KvDtype::F32,
            kernels: KernelMode::default(),
            side: Side::default(),
        }
    }

    #[test]
    fn hata_paged_scores_match_contiguous() {
        // the paged per-block scoring loop must reproduce the one-shot
        // contiguous selection exactly (same scores, same top-k)
        let dh = 16;
        let rbit = 128;
        let s = 57; // ends mid-block for bt in {4, 8, 16}
        let mut rng = Rng::new(6);
        let k = rng.normal_vec(s * dh);
        let hash_w = rng.normal_vec(dh * rbit);
        let codes = encode_rows(&k, dh, &hash_w, rbit);
        let q = rng.normal_vec(dh);
        let v = vec![0.0; s * dh];
        let mut flat = base_inputs(&q, &k, &v, 1, dh, s);
        flat.codes = &codes;
        flat.words = rbit / 64;
        flat.rbit = rbit;
        flat.side.hash_w = &hash_w;
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        HataSelector.select(&flat, &mut st, 10, &mut sc);
        let want = sc.indices.clone();
        for bt in [4usize, 8, 16] {
            // scatter code rows into shuffled physical blocks
            let words = rbit / 64;
            let nblocks = s.div_ceil(bt);
            let mut table: Vec<u32> = (0..nblocks as u32).collect();
            table.reverse();
            let mut pcodes = vec![0u64; nblocks * bt * words];
            let mut pk = vec![0.0f32; nblocks * bt * dh];
            for t in 0..s {
                let r = table[t / bt] as usize * bt + t % bt;
                let (cs, cd) = (&codes[t * words..(t + 1) * words], r * words);
                pcodes[cd..cd + words].copy_from_slice(cs);
                pk[r * dh..(r + 1) * dh].copy_from_slice(&k[t * dh..(t + 1) * dh]);
            }
            let mut paged = base_inputs(&q, &pk, &v, 1, dh, s);
            paged.codes = &pcodes;
            paged.words = words;
            paged.rbit = rbit;
            paged.side.hash_w = &hash_w;
            paged.bt = &table;
            paged.block_tokens = bt;
            HataSelector.select(&paged, &mut st, 10, &mut sc);
            assert_eq!(want, sc.indices, "bt={bt}");
        }
    }

    #[test]
    fn exact_topk_selects_true_best() {
        let dh = 8;
        let s = 50;
        let mut rng = Rng::new(1);
        let k = rng.normal_vec(s * dh);
        // query equal to key 17 -> its score dominates
        let q = k[17 * dh..18 * dh].iter().map(|x| x * 10.0).collect::<Vec<_>>();
        let v = vec![0.0; s * dh];
        let inp = base_inputs(&q, &k, &v, 1, dh, s);
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        ExactTopK.select(&inp, &mut st, 5, &mut sc);
        assert!(sc.indices.contains(&17));
        assert_eq!(sc.indices.len(), 5);
    }

    #[test]
    fn hata_recovers_identical_key() {
        let dh = 16;
        let rbit = 128;
        let s = 200;
        let mut rng = Rng::new(2);
        let k = rng.normal_vec(s * dh);
        let hash_w = rng.normal_vec(dh * rbit);
        let codes = encode_rows(&k, dh, &hash_w, rbit);
        let q = k[99 * dh..100 * dh].to_vec();
        let v = vec![0.0; s * dh];
        let mut inp = base_inputs(&q, &k, &v, 1, dh, s);
        inp.codes = &codes;
        inp.words = rbit / 64;
        inp.rbit = rbit;
        inp.side.hash_w = &hash_w;
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        HataSelector.select(&inp, &mut st, 10, &mut sc);
        assert!(sc.indices.contains(&99), "identical key must be top-scored");
    }

    #[test]
    fn quest_selects_block_containing_spike() {
        let dh = 4;
        let s = 64;
        let block = 8;
        let mut k = vec![0.01f32; s * dh];
        // token 37: large positive key
        for i in 0..dh {
            k[37 * dh + i] = 5.0;
        }
        let q = vec![1.0; dh];
        let v = vec![0.0; s * dh];
        // build block min/max
        let nb = s / block;
        let mut bmin = vec![f32::INFINITY; nb * dh];
        let mut bmax = vec![f32::NEG_INFINITY; nb * dh];
        for t in 0..s {
            let b = t / block;
            for i in 0..dh {
                bmin[b * dh + i] = bmin[b * dh + i].min(k[t * dh + i]);
                bmax[b * dh + i] = bmax[b * dh + i].max(k[t * dh + i]);
            }
        }
        let mut inp = base_inputs(&q, &k, &v, 1, dh, s);
        inp.side.quest_min = &bmin;
        inp.side.quest_max = &bmax;
        inp.side.quest_block = block;
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        QuestSelector.select(&inp, &mut st, 8, &mut sc);
        assert!(sc.indices.contains(&37));
        assert_eq!(sc.indices.len(), 8); // whole block
    }

    #[test]
    fn streaming_llm_shape() {
        let dh = 4;
        let s = 100;
        let q = vec![0.0; dh];
        let k = vec![0.0; s * dh];
        let v = vec![0.0; s * dh];
        let inp = base_inputs(&q, &k, &v, 1, dh, s);
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        StreamingLlm { sinks: 4 }.select(&inp, &mut st, 20, &mut sc);
        assert_eq!(sc.indices.len(), 20);
        assert_eq!(&sc.indices[..4], &[0, 1, 2, 3]);
        assert_eq!(*sc.indices.last().unwrap(), 99);
    }

    #[test]
    fn h2o_mixes_heavy_and_recent() {
        let dh = 4;
        let s = 100;
        let q = vec![0.0; dh];
        let k = vec![0.0; s * dh];
        let v = vec![0.0; s * dh];
        let inp = base_inputs(&q, &k, &v, 1, dh, s);
        let mut st = MethodState::default();
        st.h2o_cum = vec![0.0; s];
        st.h2o_cum[7] = 5.0; // heavy hitter
        let mut sc = Scratch::default();
        H2oSelector.select(&inp, &mut st, 10, &mut sc);
        assert!(sc.indices.contains(&7));
        assert!(sc.indices.contains(&99));
        assert!(sc.indices.len() <= 10);
    }

    #[test]
    fn h2o_accumulate_adds_mass() {
        let mut st = MethodState::default();
        h2o_accumulate(&mut st, &[3, 5], &[0.7, 0.3], 10);
        h2o_accumulate(&mut st, &[3], &[1.0], 10);
        assert!((st.h2o_cum[3] - 1.7).abs() < 1e-6);
        assert!((st.h2o_cum[5] - 0.3).abs() < 1e-6);
    }

    #[test]
    fn snapkv_keeps_attended_token_and_recents() {
        let dh = 8;
        let s = 60;
        let w = 4;
        let mut rng = Rng::new(9);
        let mut k = rng.normal_vec(s * dh);
        for i in 0..dh {
            k[11 * dh + i] = 0.0;
        }
        // observation queries strongly aligned with key 11's direction
        let target: Vec<f32> = (0..dh).map(|i| if i == 0 { 8.0 } else { 0.0 }).collect();
        for i in 0..dh {
            k[11 * dh + i] = target[i];
        }
        let mut qwin = Vec::new();
        for _ in 0..w {
            qwin.extend_from_slice(&target);
        }
        let v = vec![0.0; s * dh];
        let mut inp = base_inputs(&qwin, &k, &v, 1, dh, s);
        inp.s = s;
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        snapkv_prefill(&mut st, &inp, w, &mut sc);
        assert_eq!(st.snapkv_keep.len(), s);
        // token 11 should rank near the top
        let rank = st.snapkv_keep.iter().position(|&t| t == 11).unwrap();
        assert!(rank < 8, "rank {rank}");
        // decode-time selection includes it
        let q = vec![0.0; dh];
        let mut inp2 = base_inputs(&q, &k, &v, 1, dh, s);
        inp2.s = s;
        SnapKvSelector { window: 4 }.select(&inp2, &mut st, 12, &mut sc);
        assert!(sc.indices.contains(&11));
        assert!(sc.indices.contains(&(s as u32 - 1)));
    }

    #[test]
    fn magicpig_finds_aligned_key() {
        let dh = 16;
        let (kbits, l) = (6, 40);
        let s = 150;
        let mut rng = Rng::new(21);
        let keys = rng.normal_vec(s * dh);
        let planes = rng.normal_vec(l * kbits * dh);
        // per-token signatures
        let mut sigs = vec![0u16; s * l];
        for t in 0..s {
            for table in 0..l {
                let mut sig = 0u16;
                for bit in 0..kbits {
                    let plane = &planes[(table * kbits + bit) * dh..(table * kbits + bit + 1) * dh];
                    sig |= ((dot(&keys[t * dh..(t + 1) * dh], plane) >= 0.0) as u16) << bit;
                }
                sigs[t * l + table] = sig;
            }
        }
        let q = keys[42 * dh..43 * dh].to_vec();
        let v = vec![0.0; s * dh];
        let mut inp = base_inputs(&q, &keys, &v, 1, dh, s);
        inp.side.mp_sigs = &sigs;
        inp.side.mp_planes = &planes;
        inp.side.mp_k = kbits;
        inp.side.mp_l = l;
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        MagicPigSelector.select(&inp, &mut st, 10, &mut sc);
        assert!(sc.indices.contains(&42), "identical key collides in every table");
    }

    #[test]
    fn loki_with_identity_pca_matches_exact() {
        let dh = 8;
        let s = 80;
        let mut rng = Rng::new(33);
        let k = rng.normal_vec(s * dh);
        let q = rng.normal_vec(dh);
        let v = vec![0.0; s * dh];
        // identity PCA, all channels -> loki == exact
        let mut pca = vec![0.0f32; dh * dh];
        for i in 0..dh {
            pca[i * dh + i] = 1.0;
        }
        let kproj = k.clone();
        let mut inp = base_inputs(&q, &k, &v, 1, dh, s);
        inp.side.loki_pca = &pca;
        inp.side.loki_kproj = &kproj;
        inp.side.loki_channels = dh;
        let mut st = MethodState::default();
        let mut sc = Scratch::default();
        LokiSelector { channels: dh }.select(&inp, &mut st, 12, &mut sc);
        let loki_sel = sc.indices.clone();
        ExactTopK.select(&inp, &mut st, 12, &mut sc);
        assert_eq!(loki_sel, sc.indices);
    }

    #[test]
    fn loki_reports_channel_score_bytes() {
        // used by the HBM traffic model: channels f32 per cached token
        assert_eq!(LokiSelector { channels: 4 }.score_bytes_per_token(16, 128), 16);
        assert_eq!(LokiSelector { channels: 32 }.score_bytes_per_token(128, 128), 128);
    }

    #[test]
    fn scratch_reuse_across_selectors_leaves_no_stale_state() {
        // One Scratch arena cycled through every selector family (the
        // worker-arena situation when an engine switches methods, and
        // the per-worker situation inside one mixed bench process): each
        // selector's output must equal what a fresh scratch produces.
        let dh = 16;
        let rbit = 128;
        let s = 120;
        let budget = 12;
        let mut rng = Rng::new(77);
        let k = rng.normal_vec(s * dh);
        let q = rng.normal_vec(dh);
        let v = vec![0.0; s * dh];
        let hash_w = rng.normal_vec(dh * rbit);
        let codes = encode_rows(&k, dh, &hash_w, rbit);
        // MagicPIG side data
        let (kbits, l) = (6usize, 30usize);
        let planes = rng.normal_vec(l * kbits * dh);
        let mut sigs = vec![0u16; s * l];
        for t in 0..s {
            for table in 0..l {
                let mut sig = 0u16;
                for bit in 0..kbits {
                    let p = &planes[(table * kbits + bit) * dh..(table * kbits + bit + 1) * dh];
                    sig |= ((dot(&k[t * dh..(t + 1) * dh], p) >= 0.0) as u16) << bit;
                }
                sigs[t * l + table] = sig;
            }
        }
        let mut inp = base_inputs(&q, &k, &v, 1, dh, s);
        inp.codes = &codes;
        inp.words = rbit / 64;
        inp.rbit = rbit;
        inp.side.hash_w = &hash_w;
        inp.side.mp_sigs = &sigs;
        inp.side.mp_planes = &planes;
        inp.side.mp_k = kbits;
        inp.side.mp_l = l;
        let selectors: Vec<Box<dyn Selector>> = vec![
            Box::new(HataSelector),
            Box::new(ExactTopK),
            Box::new(MagicPigSelector),
            Box::new(StreamingLlm { sinks: 4 }),
            Box::new(H2oSelector),
        ];
        let mut shared = Scratch::default();
        // two full rounds: round 2 runs every selector on a scratch that
        // every OTHER selector has already dirtied
        let h2o_state = || MethodState {
            h2o_cum: (0..s).map(|t| (t % 7) as f32).collect(),
            ..Default::default()
        };
        for round in 0..2 {
            for sel in &selectors {
                let mut st = h2o_state();
                sel.select(&inp, &mut st, budget, &mut shared);
                let got = shared.indices.clone();
                let mut fresh = Scratch::default();
                let mut st2 = h2o_state();
                sel.select(&inp, &mut st2, budget, &mut fresh);
                assert_eq!(got, fresh.indices, "{} round {round}", sel.name());
            }
        }
    }
}
