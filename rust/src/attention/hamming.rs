//! The high-performance Hamming score operator (paper Sec. 4), CPU analog.
//!
//! The paper's CUDA kernel: load packed codes as integers, XOR, `popc`,
//! tree-reduce, with coalesced HBM reads.  Here the same structure maps to
//! `u64::count_ones` (hardware POPCNT) over contiguous code rows, with a
//! blocked variant that walks the code cache in L1-sized chunks and a
//! per-byte scalar variant kept as the Fig. 9 'Simple' baseline.
//!
//! Score = matching bits = rbit - hamming distance (higher = more similar),
//! identical to python/compile/kernels/ref.py.

/// 'Simple' baseline: per-byte table-free popcount, one token at a time.
/// Deliberately naive (the unoptimized PyTorch analog in Fig. 9).
pub fn scores_scalar(qcode: &[u64], codes: &[u64], rbit: usize, out: &mut Vec<i32>) {
    let words = qcode.len();
    out.clear();
    for row in codes.chunks_exact(words) {
        let mut mismatch = 0u32;
        for (a, b) in qcode.iter().zip(row) {
            let mut x = a ^ b;
            // bit-at-a-time popcount (intentionally slow baseline)
            while x != 0 {
                mismatch += (x & 1) as u32;
                x >>= 1;
            }
        }
        out.push(rbit as i32 - mismatch as i32);
    }
}

/// Word-parallel popcount (maps to POPCNT): the paper's 'Score' operator.
pub fn scores_word(qcode: &[u64], codes: &[u64], rbit: usize, out: &mut Vec<i32>) {
    let words = qcode.len();
    out.clear();
    out.reserve(codes.len() / words);
    match words {
        2 => {
            let (q0, q1) = (qcode[0], qcode[1]);
            for row in codes.chunks_exact(2) {
                let m = (q0 ^ row[0]).count_ones() + (q1 ^ row[1]).count_ones();
                out.push(rbit as i32 - m as i32);
            }
        }
        4 => {
            let (q0, q1, q2, q3) = (qcode[0], qcode[1], qcode[2], qcode[3]);
            for row in codes.chunks_exact(4) {
                let m = (q0 ^ row[0]).count_ones()
                    + (q1 ^ row[1]).count_ones()
                    + (q2 ^ row[2]).count_ones()
                    + (q3 ^ row[3]).count_ones();
                out.push(rbit as i32 - m as i32);
            }
        }
        _ => {
            for row in codes.chunks_exact(words) {
                let m: u32 = qcode.iter().zip(row).map(|(a, b)| (a ^ b).count_ones()).sum();
                out.push(rbit as i32 - m as i32);
            }
        }
    }
}

/// GQA aggregation: sum the match counts of all query heads in the group
/// in one pass over the code cache (one cache read serves the group, the
/// CPU analog of the paper's coalesced shared read).
pub fn scores_group(qcodes: &[u64], group: usize, codes: &[u64], rbit: usize, out: &mut Vec<i32>) {
    let words = qcodes.len() / group;
    out.clear();
    out.reserve(codes.len() / words);
    scores_group_into(qcodes, group, codes, rbit, out);
}

/// Appending variant of [`scores_group`]: scores `codes` and pushes onto
/// `out` without clearing it first. The paged selector path walks a
/// sequence's code cache one physical block at a time (blocks are not
/// adjacent in the shared plane), accumulating per-block scores into one
/// logical score vector — same arithmetic per row, so paged scoring is
/// bit-identical to scoring the contiguous cache in one call.
pub fn scores_group_into(
    qcodes: &[u64],
    group: usize,
    codes: &[u64],
    rbit: usize,
    out: &mut Vec<i32>,
) {
    let words = qcodes.len() / group;
    for row in codes.chunks_exact(words) {
        let mut match_bits = (group * rbit) as i32;
        for g in 0..group {
            let q = &qcodes[g * words..(g + 1) * words];
            let mismatch: u32 = q.iter().zip(row).map(|(a, b)| (a ^ b).count_ones()).sum();
            match_bits -= mismatch as i32;
        }
        out.push(match_bits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    fn rand_codes(rng: &mut Rng, n: usize, words: usize) -> Vec<u64> {
        (0..n * words).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn word_matches_scalar() {
        check(80, |rng: &mut Rng| {
            let words = [1, 2, 3, 4][rng.below(4)];
            let rbit = words * 64;
            let n = 1 + rng.below(100);
            let q = rand_codes(rng, 1, words);
            let codes = rand_codes(rng, n, words);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            scores_scalar(&q, &codes, rbit, &mut a);
            scores_word(&q, &codes, rbit, &mut b);
            prop_assert(a == b, "scalar != word")
        });
    }

    #[test]
    fn identical_code_scores_rbit() {
        let q = vec![0xDEADBEEFCAFEBABEu64, 0x0123456789ABCDEF];
        let mut out = Vec::new();
        scores_word(&q, &q, 128, &mut out);
        assert_eq!(out, vec![128]);
    }

    #[test]
    fn complement_scores_zero() {
        let q = vec![0xAAAAAAAAAAAAAAAAu64];
        let c = vec![!q[0]];
        let mut out = Vec::new();
        scores_word(&q, &c, 64, &mut out);
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn group_aggregation_equals_sum_of_singles() {
        check(60, |rng: &mut Rng| {
            let words = 2;
            let rbit = 128;
            let group = 1 + rng.below(4);
            let n = 1 + rng.below(60);
            let qs = rand_codes(rng, group, words);
            let codes = rand_codes(rng, n, words);
            let mut agg = Vec::new();
            scores_group(&qs, group, &codes, rbit, &mut agg);
            let mut want = vec![0i32; n];
            let mut single = Vec::new();
            for g in 0..group {
                scores_word(&qs[g * words..(g + 1) * words], &codes, rbit, &mut single);
                for (w, s) in want.iter_mut().zip(&single) {
                    *w += s;
                }
            }
            prop_assert(agg == want, "group aggregation mismatch")
        });
    }

    #[test]
    fn blockwise_group_scoring_matches_one_shot() {
        // the paged selector scores one physical block at a time; the
        // concatenation must equal one pass over a contiguous cache
        check(40, |rng: &mut Rng| {
            let words = 2;
            let rbit = 128;
            let group = 1 + rng.below(3);
            let n = 1 + rng.below(60);
            let qs = rand_codes(rng, group, words);
            let codes = rand_codes(rng, n, words);
            let mut whole = Vec::new();
            scores_group(&qs, group, &codes, rbit, &mut whole);
            let bt = 1 + rng.below(7);
            let mut blocked = Vec::new();
            for chunk in codes.chunks(bt * words) {
                scores_group_into(&qs, group, chunk, rbit, &mut blocked);
            }
            prop_assert(whole == blocked, "blockwise != one-shot")
        });
    }

    #[test]
    fn score_bounds() {
        let mut rng = Rng::new(4);
        let q = rand_codes(&mut rng, 1, 2);
        let codes = rand_codes(&mut rng, 500, 2);
        let mut out = Vec::new();
        scores_word(&q, &codes, 128, &mut out);
        assert!(out.iter().all(|&s| (0..=128).contains(&s)));
    }
}
