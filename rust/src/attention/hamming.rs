//! The high-performance Hamming score operator (paper Sec. 4), CPU analog.
//!
//! The paper's CUDA kernel: load packed codes as integers, XOR, `popc`,
//! tree-reduce, with coalesced HBM reads.  Here the same structure maps to
//! `u64::count_ones` (hardware POPCNT) over contiguous code rows, with a
//! blocked variant that walks the code cache in L1-sized chunks and a
//! per-byte scalar variant kept as the Fig. 9 'Simple' baseline.
//!
//! The GQA group scorer additionally has explicit-lane vector kernels
//! (AVX2 `vpshufb` nibble-popcount + `vpsadbw`, NEON `vcnt`) behind the
//! same [`KernelMode`] dispatch as the float kernels: integer XOR and
//! byte-count arithmetic has a single possible result, so the vector
//! paths are exactly equal to [`scores_word`] / the scalar group loop on
//! every input, and `Reference` (or `HATA_SIMD=scalar`) always falls back
//! to the scalar loop.
//!
//! Score = matching bits = rbit - hamming distance (higher = more similar),
//! identical to python/compile/kernels/ref.py.

use crate::tensor::simd::{self, KernelMode};

/// 'Simple' baseline: per-byte table-free popcount, one token at a time.
/// Deliberately naive (the unoptimized PyTorch analog in Fig. 9).
pub fn scores_scalar(qcode: &[u64], codes: &[u64], rbit: usize, out: &mut Vec<i32>) {
    let words = qcode.len();
    debug_assert_eq!(codes.len() % words, 0, "ragged codes slice");
    out.clear();
    out.reserve(codes.len() / words);
    for row in codes.chunks_exact(words) {
        let mut mismatch = 0u32;
        for (a, b) in qcode.iter().zip(row) {
            let mut x = a ^ b;
            // bit-at-a-time popcount (intentionally slow baseline)
            while x != 0 {
                mismatch += (x & 1) as u32;
                x >>= 1;
            }
        }
        out.push(rbit as i32 - mismatch as i32);
    }
}

/// Word-parallel popcount (maps to POPCNT): the paper's 'Score' operator.
pub fn scores_word(qcode: &[u64], codes: &[u64], rbit: usize, out: &mut Vec<i32>) {
    let words = qcode.len();
    out.clear();
    out.reserve(codes.len() / words);
    match words {
        2 => {
            let (q0, q1) = (qcode[0], qcode[1]);
            for row in codes.chunks_exact(2) {
                let m = (q0 ^ row[0]).count_ones() + (q1 ^ row[1]).count_ones();
                out.push(rbit as i32 - m as i32);
            }
        }
        4 => {
            let (q0, q1, q2, q3) = (qcode[0], qcode[1], qcode[2], qcode[3]);
            for row in codes.chunks_exact(4) {
                let m = (q0 ^ row[0]).count_ones()
                    + (q1 ^ row[1]).count_ones()
                    + (q2 ^ row[2]).count_ones()
                    + (q3 ^ row[3]).count_ones();
                out.push(rbit as i32 - m as i32);
            }
        }
        _ => {
            for row in codes.chunks_exact(words) {
                let m: u32 = qcode.iter().zip(row).map(|(a, b)| (a ^ b).count_ones()).sum();
                out.push(rbit as i32 - m as i32);
            }
        }
    }
}

/// GQA aggregation: sum the match counts of all query heads in the group
/// in one pass over the code cache (one cache read serves the group, the
/// CPU analog of the paper's coalesced shared read). `mode` selects the
/// scalar reference or the vectorized popcount kernels (exactly equal).
pub fn scores_group(
    mode: KernelMode,
    qcodes: &[u64],
    group: usize,
    codes: &[u64],
    rbit: usize,
    out: &mut Vec<i32>,
) {
    out.clear();
    scores_group_into(mode, qcodes, group, codes, rbit, out);
}

/// Appending variant of [`scores_group`]: scores `codes` and pushes onto
/// `out` without clearing it first. The paged selector path walks a
/// sequence's code cache one physical block at a time (blocks are not
/// adjacent in the shared plane), accumulating per-block scores into one
/// logical score vector — same arithmetic per row, so paged scoring is
/// bit-identical to scoring the contiguous cache in one call.
pub fn scores_group_into(
    mode: KernelMode,
    qcodes: &[u64],
    group: usize,
    codes: &[u64],
    rbit: usize,
    out: &mut Vec<i32>,
) {
    let words = qcodes.len() / group;
    debug_assert_eq!(codes.len() % words, 0, "ragged codes slice");
    out.reserve(codes.len() / words);
    if mode != KernelMode::Reference
        && simd::lanes_active()
        && vector_scores_into(qcodes, group, words, codes, rbit, out)
    {
        return;
    }
    scores_group_ref(qcodes, group, words, codes, rbit, out);
}

/// The scalar group loop: the bit-identical reference the vector paths
/// are checked against (integer arithmetic, so "identical" is exact
/// equality, not a tolerance).
fn scores_group_ref(
    qcodes: &[u64],
    group: usize,
    words: usize,
    codes: &[u64],
    rbit: usize,
    out: &mut Vec<i32>,
) {
    for row in codes.chunks_exact(words) {
        let mut match_bits = (group * rbit) as i32;
        for g in 0..group {
            let q = &qcodes[g * words..(g + 1) * words];
            let mismatch: u32 = q.iter().zip(row).map(|(a, b)| (a ^ b).count_ones()).sum();
            match_bits -= mismatch as i32;
        }
        out.push(match_bits);
    }
}

/// Arch-specific vector group scorer. Returns `false` when no kernel
/// covers this shape (scalar backend handled by the caller; oversized
/// groups or unusual word counts on x86) so the caller falls back to
/// [`scores_group_ref`].
#[allow(unused_variables, unreachable_code)]
fn vector_scores_into(
    qcodes: &[u64],
    group: usize,
    words: usize,
    codes: &[u64],
    rbit: usize,
    out: &mut Vec<i32>,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if group > x86::MAX_GROUP {
            return false;
        }
        match words {
            2 => unsafe { x86::scores_group_w2_avx2(qcodes, group, codes, rbit, out) },
            4 => unsafe { x86::scores_group_w4_avx2(qcodes, group, codes, rbit, out) },
            _ => return false,
        }
        return true;
    }
    #[cfg(target_arch = "aarch64")]
    {
        if words >= 2 {
            unsafe { neon::scores_group_neon(qcodes, group, words, codes, rbit, out) };
            return true;
        }
        return false;
    }
    false
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 group scorer: XOR the 128/256-bit code row against each
    //! query head's code, popcount bytes with the `vpshufb` nibble
    //! lookup, and horizontally sum bytes with `vpsadbw`. All integer
    //! ops, so the result is exactly the scalar loop's.

    use core::arch::x86_64::*;

    /// Per-head query codes are staged in a fixed register array;
    /// larger groups (not produced by any supported model config) fall
    /// back to the scalar loop.
    pub(super) const MAX_GROUP: usize = 8;

    /// Byte popcount: nibble LUT shuffle, low + high halves.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcount_bytes(v: __m256i, lut: __m256i, low: __m256i) -> __m256i {
        let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(v, low));
        let hi = _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi16::<4>(v), low));
        _mm256_add_epi8(lo, hi)
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn nibble_lut() -> __m256i {
        _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3,
            2, 3, 3, 4,
        )
    }

    /// words == 2 (rbit <= 128): two 16-byte code rows per 256-bit
    /// chunk, query codes broadcast to both lanes; `vpsadbw` leaves the
    /// per-row mismatch in u64 lanes (0+1 = row r, 2+3 = row r+1).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scores_group_w2_avx2(
        qcodes: &[u64],
        group: usize,
        codes: &[u64],
        rbit: usize,
        out: &mut Vec<i32>,
    ) {
        let base = (group * rbit) as i32;
        let zero = _mm256_setzero_si256();
        let (lut, low) = (nibble_lut(), _mm256_set1_epi8(0x0F));
        let mut qv = [_mm_setzero_si128(); MAX_GROUP];
        for (g, q) in qv.iter_mut().enumerate().take(group) {
            *q = _mm_loadu_si128(qcodes.as_ptr().add(g * 2) as *const __m128i);
        }
        let n = codes.len() / 2;
        let pc = codes.as_ptr();
        let mut r = 0;
        while r + 2 <= n {
            let rows = _mm256_loadu_si256(pc.add(r * 2) as *const __m256i);
            let mut acc = zero;
            for q in qv.iter().take(group) {
                let x = _mm256_xor_si256(rows, _mm256_broadcastsi128_si256(*q));
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(popcount_bytes(x, lut, low), zero));
            }
            let mut lanes = [0u64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            out.push(base - (lanes[0] + lanes[1]) as i32);
            out.push(base - (lanes[2] + lanes[3]) as i32);
            r += 2;
        }
        if r < n {
            let row = &codes[r * 2..r * 2 + 2];
            let mut mismatch = 0u32;
            for g in 0..group {
                let q = &qcodes[g * 2..g * 2 + 2];
                mismatch += (q[0] ^ row[0]).count_ones() + (q[1] ^ row[1]).count_ones();
            }
            out.push(base - mismatch as i32);
        }
    }

    /// words == 4 (rbit <= 256): one 32-byte code row per 256-bit load.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scores_group_w4_avx2(
        qcodes: &[u64],
        group: usize,
        codes: &[u64],
        rbit: usize,
        out: &mut Vec<i32>,
    ) {
        let base = (group * rbit) as i32;
        let zero = _mm256_setzero_si256();
        let (lut, low) = (nibble_lut(), _mm256_set1_epi8(0x0F));
        let mut qv = [_mm256_setzero_si256(); MAX_GROUP];
        for (g, q) in qv.iter_mut().enumerate().take(group) {
            *q = _mm256_loadu_si256(qcodes.as_ptr().add(g * 4) as *const __m256i);
        }
        let n = codes.len() / 4;
        let pc = codes.as_ptr();
        for r in 0..n {
            let row = _mm256_loadu_si256(pc.add(r * 4) as *const __m256i);
            let mut acc = zero;
            for q in qv.iter().take(group) {
                let x = _mm256_xor_si256(row, *q);
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(popcount_bytes(x, lut, low), zero));
            }
            let mut lanes = [0u64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            out.push(base - (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as i32);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON group scorer: `veor` + `vcnt` byte popcount + `vaddv`
    //! horizontal sum per 16-byte chunk (sum <= 128, fits the u8 lane
    //! reduction). Exactly equal to the scalar loop.

    use core::arch::aarch64::*;

    pub(super) unsafe fn scores_group_neon(
        qcodes: &[u64],
        group: usize,
        words: usize,
        codes: &[u64],
        rbit: usize,
        out: &mut Vec<i32>,
    ) {
        let base = (group * rbit) as i32;
        for row in codes.chunks_exact(words) {
            let mut mismatch = 0u32;
            for g in 0..group {
                let q = &qcodes[g * words..(g + 1) * words];
                let mut c = 0;
                while c + 2 <= words {
                    let x = veorq_u8(
                        vreinterpretq_u8_u64(vld1q_u64(q.as_ptr().add(c))),
                        vreinterpretq_u8_u64(vld1q_u64(row.as_ptr().add(c))),
                    );
                    mismatch += vaddvq_u8(vcntq_u8(x)) as u32;
                    c += 2;
                }
                if c < words {
                    mismatch += (q[c] ^ row[c]).count_ones();
                }
            }
            out.push(base - mismatch as i32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    fn rand_codes(rng: &mut Rng, n: usize, words: usize) -> Vec<u64> {
        (0..n * words).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn word_matches_scalar() {
        check(80, |rng: &mut Rng| {
            let words = [1, 2, 3, 4][rng.below(4)];
            let rbit = words * 64;
            let n = 1 + rng.below(100);
            let q = rand_codes(rng, 1, words);
            let codes = rand_codes(rng, n, words);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            scores_scalar(&q, &codes, rbit, &mut a);
            scores_word(&q, &codes, rbit, &mut b);
            prop_assert(a == b, "scalar != word")
        });
    }

    #[test]
    fn identical_code_scores_rbit() {
        let q = vec![0xDEADBEEFCAFEBABEu64, 0x0123456789ABCDEF];
        let mut out = Vec::new();
        scores_word(&q, &q, 128, &mut out);
        assert_eq!(out, vec![128]);
    }

    #[test]
    fn complement_scores_zero() {
        let q = vec![0xAAAAAAAAAAAAAAAAu64];
        let c = vec![!q[0]];
        let mut out = Vec::new();
        scores_word(&q, &c, 64, &mut out);
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn group_aggregation_equals_sum_of_singles() {
        check(60, |rng: &mut Rng| {
            let words = 2;
            let rbit = 128;
            let group = 1 + rng.below(4);
            let n = 1 + rng.below(60);
            let qs = rand_codes(rng, group, words);
            let codes = rand_codes(rng, n, words);
            let mut agg = Vec::new();
            scores_group(KernelMode::Simd, &qs, group, &codes, rbit, &mut agg);
            let mut want = vec![0i32; n];
            let mut single = Vec::new();
            for g in 0..group {
                scores_word(&qs[g * words..(g + 1) * words], &codes, rbit, &mut single);
                for (w, s) in want.iter_mut().zip(&single) {
                    *w += s;
                }
            }
            prop_assert(agg == want, "group aggregation mismatch")
        });
    }

    #[test]
    fn blockwise_group_scoring_matches_one_shot() {
        // the paged selector scores one physical block at a time; the
        // concatenation must equal one pass over a contiguous cache
        check(40, |rng: &mut Rng| {
            let words = 2;
            let rbit = 128;
            let group = 1 + rng.below(3);
            let n = 1 + rng.below(60);
            let qs = rand_codes(rng, group, words);
            let codes = rand_codes(rng, n, words);
            let mut whole = Vec::new();
            scores_group(KernelMode::Simd, &qs, group, &codes, rbit, &mut whole);
            let bt = 1 + rng.below(7);
            let mut blocked = Vec::new();
            for chunk in codes.chunks(bt * words) {
                scores_group_into(KernelMode::Simd, &qs, group, chunk, rbit, &mut blocked);
            }
            prop_assert(whole == blocked, "blockwise != one-shot")
        });
    }

    /// The vectorized group scorers must be *exactly* equal to the
    /// scalar reference — integer arithmetic leaves no tolerance — for
    /// every word count (vector and fallback shapes), group size
    /// (including past the x86 register-staging cap) and row-count
    /// parity (odd tails in the two-rows-per-chunk kernel).
    #[test]
    fn vectorized_group_scorer_equals_reference() {
        check(80, |rng: &mut Rng| {
            let words = [1, 2, 3, 4][rng.below(4)];
            let rbit = words * 64 - rng.below(5);
            let group = 1 + rng.below(10);
            let n = 1 + rng.below(40);
            let qs = rand_codes(rng, group, words);
            let codes = rand_codes(rng, n, words);
            let mut reference = Vec::new();
            scores_group(KernelMode::Reference, &qs, group, &codes, rbit, &mut reference);
            for mode in [KernelMode::Simd, KernelMode::SimdFma] {
                let mut got = Vec::new();
                scores_group(mode, &qs, group, &codes, rbit, &mut got);
                prop_assert(got == reference, "vectorized scorer != reference")?;
            }
            Ok(())
        });
    }

    #[test]
    fn score_bounds() {
        let mut rng = Rng::new(4);
        let q = rand_codes(&mut rng, 1, 2);
        let codes = rand_codes(&mut rng, 500, 2);
        let mut out = Vec::new();
        scores_word(&q, &codes, 128, &mut out);
        assert!(out.iter().all(|&s| (0..=128).contains(&s)));
    }
}
