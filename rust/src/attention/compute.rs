//! Attention computation: dense, and sparse in two flavors mirroring the
//! paper's Fig. 9 'FusedAttn' ablation:
//!
//! * [`sparse_attention_gather`] — 'Simple': materialize gathered K/V
//!   copies, then run dense attention over them (double memory traffic).
//! * [`sparse_attention_fused`] — gather folded into the score/accumulate
//!   loops; selected rows are read exactly once, straight from the cache.
//!
//! All functions compute one KV head for `group` query heads (GQA) and
//! write `group * dh` outputs. They run on threadpool workers in the
//! batched decode path: inputs are shared borrows, outputs and the
//! `probs` scratch are exclusive to the caller's work item, and every
//! scratch prefix that is read is overwritten first — so a reused
//! worker arena can never leak state between items.
//!
//! [`prefill_tile_attention`] extends the same contract to the tiled
//! prefill path: a [`PrefillTile`] covers a run of consecutive query
//! rows of one (sequence, kv-head), each row causally masked by bounding
//! `s` and reduced by the identical [`dense_attention`] kernel, which is
//! what makes tiled prefill bit-identical to token-serial prefill.

use super::AttnInputs;
use crate::tensor::simd::{self, KernelMode, KvDtype};

/// Dense attention over the full cache: out[g] = softmax(q_g K^T / sqrt(d)) V.
///
/// The kernel is staged onto the mode-dispatched primitives in
/// [`crate::tensor::simd`]: a [`simd::dot_wide`] score pass with a
/// scalar streaming max, then a fused exp/accumulate pass that
/// dispatches the dominant `o += p * v` row update through
/// [`simd::axpy_wide`] (the scalar `exp` is 1/dh of the MAC work and
/// keeps `probs` holding the raw scores, which the H2O accumulator
/// reads after the call), and a final [`simd::scale`]. The `*_wide`
/// kernels widen half-precision K/V rows in-register and are exactly
/// the f32 kernels for `KvDtype::F32`. For `Reference` and `Simd`
/// every per-element operation happens in the same order as the
/// historical fused scalar loop, so the result is bit-identical across
/// all three of {old scalar kernel, `Reference`, `Simd`} per dtype;
/// `SimdFma` is the documented fast-math tier (FMA contractions in
/// `dot`/`axpy`).
pub fn dense_attention(mode: KernelMode, inp: &AttnInputs, probs: &mut Vec<f32>, out: &mut [f32]) {
    let scale = 1.0 / (inp.dh as f32).sqrt();
    let dt = inp.kv_dtype;
    probs.clear();
    probs.resize(inp.s, 0.0);
    for g in 0..inp.group {
        let q = inp.q_row(g);
        // score pass (scalar streaming max: trivial cost, fixed order)
        let mut max = f32::NEG_INFINITY;
        for t in 0..inp.s {
            let s = simd::dot_wide(mode, dt, q, inp.k_row(t)) * scale;
            probs[t] = s;
            if s > max {
                max = s;
            }
        }
        // softmax + weighted sum fused: scalar exp per token, then one
        // lane-parallel row update (probs keeps the raw scores)
        let o = &mut out[g * inp.dh..(g + 1) * inp.dh];
        o.fill(0.0);
        let mut denom = 0.0f32;
        for t in 0..inp.s {
            let p = (probs[t] - max).exp();
            denom += p;
            simd::axpy_wide(mode, dt, p, inp.v_row(t), o);
        }
        simd::scale(mode, o, 1.0 / denom);
    }
}

/// 'Simple' sparse: explicit gather into scratch buffers, then attend.
pub fn sparse_attention_gather(
    mode: KernelMode,
    inp: &AttnInputs,
    indices: &[u32],
    kbuf: &mut Vec<f32>,
    vbuf: &mut Vec<f32>,
    probs: &mut Vec<f32>,
    out: &mut [f32],
) {
    let n = indices.len();
    let dh = inp.dh;
    kbuf.clear();
    vbuf.clear();
    kbuf.reserve(n * dh);
    vbuf.reserve(n * dh);
    for &t in indices {
        // half rows widen exactly during the gather, so the copies are
        // plain f32 regardless of the storage dtype
        simd::widen_extend(inp.kv_dtype, inp.k_row(t as usize), kbuf);
        simd::widen_extend(inp.kv_dtype, inp.v_row(t as usize), vbuf);
    }
    // the gathered copies are contiguous f32 regardless of source layout
    let gathered = AttnInputs {
        q: inp.q,
        group: inp.group,
        dh,
        k: kbuf,
        v: vbuf,
        codes: &[],
        words: 0,
        rbit: inp.rbit,
        s: n,
        pos: inp.pos,
        bt: &[],
        block_tokens: 0,
        kv_dtype: KvDtype::F32,
        kernels: mode,
        side: super::Side::default(),
    };
    dense_attention(mode, &gathered, probs, out);
}

/// Fused gather + attention: selected K/V rows stream through the score
/// and accumulate passes without an intermediate copy. Staged onto the
/// same mode-dispatched primitives as [`dense_attention`] (and with the
/// same bit-identity guarantee for `Reference`/`Simd`): the gather is a
/// per-row indirection, but each gathered row is contiguous, so the
/// lane kernels read contiguous memory.
pub fn sparse_attention_fused(
    mode: KernelMode,
    inp: &AttnInputs,
    indices: &[u32],
    probs: &mut Vec<f32>,
    out: &mut [f32],
) {
    let scale = 1.0 / (inp.dh as f32).sqrt();
    let n = indices.len();
    probs.clear();
    probs.resize(n, 0.0);
    let dt = inp.kv_dtype;
    for g in 0..inp.group {
        let q = inp.q_row(g);
        let mut max = f32::NEG_INFINITY;
        for (j, &t) in indices.iter().enumerate() {
            let s = simd::dot_wide(mode, dt, q, inp.k_row(t as usize)) * scale;
            probs[j] = s;
            if s > max {
                max = s;
            }
        }
        let o = &mut out[g * inp.dh..(g + 1) * inp.dh];
        o.fill(0.0);
        let mut denom = 0.0f32;
        for (j, &t) in indices.iter().enumerate() {
            let p = (probs[j] - max).exp();
            denom += p;
            simd::axpy_wide(mode, dt, p, inp.v_row(t as usize), o);
        }
        simd::scale(mode, o, 1.0 / denom);
    }
}

/// One (sequence, kv-head, query-tile) work item of the tiled prefill
/// pass: a run of consecutive query rows attending causally over one
/// head's cache (flash-style query tiling, Dao et al.).
pub struct PrefillTile<'a> {
    /// All rotated query rows of the block, [block_len, qstride].
    pub q: &'a [f32],
    /// This head's full key cache (prefix + the already-appended block).
    pub k: &'a [f32],
    /// This head's full value cache.
    pub v: &'a [f32],
    /// GQA query heads per KV head.
    pub group: usize,
    /// Head dimension.
    pub dh: usize,
    /// Stride between consecutive tokens' query rows (n_heads * dh).
    pub qstride: usize,
    /// Offset of this KV head's group inside a query row (kv * group * dh).
    pub qoff: usize,
    /// Block-local index of the tile's first query row.
    pub t0: usize,
    /// Absolute position of block row 0.
    pub start: usize,
    /// Paged layout: the sequence's block table (empty = contiguous).
    pub bt: &'a [u32],
    /// Paged layout: tokens per physical block (0 when contiguous).
    pub block_tokens: usize,
    /// Storage dtype of the `k`/`v` planes (packed rows for the half
    /// dtypes, as in [`AttnInputs::kv_dtype`]).
    pub kv_dtype: KvDtype,
    /// Kernel tier to run the per-row [`dense_attention`] in.
    pub kernels: KernelMode,
}

/// Causally-masked attention for one query tile: row `r` (block index
/// `t0 + r`, absolute position `start + t0 + r`) attends densely over
/// cache positions `0..=start + t0 + r`. Each row runs the exact
/// [`dense_attention`] kernel — same streaming max + fused
/// exp/accumulate reduction in the same key order — so a tiled prefill
/// is bit-identical to the token-serial decode path regardless of tile
/// size or which worker runs the tile. `out` is [rows, group * dh];
/// `probs` is caller scratch, fully overwritten per row.
pub fn prefill_tile_attention(tile: &PrefillTile, probs: &mut Vec<f32>, out: &mut [f32]) {
    let ghd = tile.group * tile.dh;
    let rows = out.len() / ghd;
    for r in 0..rows {
        let t = tile.t0 + r;
        let pos = tile.start + t;
        let s = pos + 1;
        let qat = t * tile.qstride + tile.qoff;
        let inp = AttnInputs {
            q: &tile.q[qat..qat + ghd],
            group: tile.group,
            dh: tile.dh,
            k: tile.k,
            v: tile.v,
            codes: &[],
            words: 0,
            rbit: 0,
            s,
            pos,
            bt: tile.bt,
            block_tokens: tile.block_tokens,
            kv_dtype: tile.kv_dtype,
            kernels: tile.kernels,
            side: super::Side::default(),
        };
        dense_attention(tile.kernels, &inp, probs, &mut out[r * ghd..(r + 1) * ghd]);
    }
}

/// Exact per-query-head qk scores aggregated over the GQA group with
/// softmax weighting — used by the ExactTopK oracle selector. Always
/// runs the canonical-order reference dot (widening for half storage),
/// so the oracle is kernel-mode-independent.
pub fn exact_group_scores(inp: &AttnInputs, out: &mut Vec<f32>) {
    let scale = 1.0 / (inp.dh as f32).sqrt();
    out.clear();
    out.resize(inp.s, 0.0);
    for g in 0..inp.group {
        let q = inp.q_row(g);
        for t in 0..inp.s {
            out[t] += simd::dot_wide(KernelMode::Reference, inp.kv_dtype, q, inp.k_row(t)) * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert, prop_close};
    use crate::util::rng::Rng;

    fn make_inputs<'a>(
        q: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
        group: usize,
        dh: usize,
        s: usize,
    ) -> AttnInputs<'a> {
        AttnInputs {
            q,
            group,
            dh,
            k,
            v,
            codes: &[],
            words: 0,
            rbit: 0,
            s,
            pos: s - 1,
            bt: &[],
            block_tokens: 0,
            kv_dtype: KvDtype::F32,
            kernels: KernelMode::default(),
            side: crate::attention::Side::default(),
        }
    }

    /// Reference dense attention (no fusion tricks).
    fn reference(q: &[f32], k: &[f32], v: &[f32], dh: usize, s: usize) -> Vec<f32> {
        let scale = 1.0 / (dh as f32).sqrt();
        let mut logits: Vec<f32> = (0..s)
            .map(|t| {
                (0..dh).map(|i| q[i] * k[t * dh + i]).sum::<f32>() * scale
            })
            .collect();
        let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0;
        for l in logits.iter_mut() {
            *l = (*l - max).exp();
            denom += *l;
        }
        let mut out = vec![0.0; dh];
        for t in 0..s {
            let p = logits[t] / denom;
            for i in 0..dh {
                out[i] += p * v[t * dh + i];
            }
        }
        out
    }

    #[test]
    fn dense_matches_reference() {
        check(60, |rng: &mut Rng| {
            let dh = 16;
            let s = 1 + rng.below(80);
            let group = 1 + rng.below(3);
            let q = rng.normal_vec(group * dh);
            let k = rng.normal_vec(s * dh);
            let v = rng.normal_vec(s * dh);
            let inp = make_inputs(&q, &k, &v, group, dh, s);
            let mut probs = Vec::new();
            for mode in KernelMode::all() {
                let mut out = vec![0.0; group * dh];
                dense_attention(mode, &inp, &mut probs, &mut out);
                for g in 0..group {
                    let want = reference(&q[g * dh..(g + 1) * dh], &k, &v, dh, s);
                    for (a, b) in out[g * dh..(g + 1) * dh].iter().zip(&want) {
                        prop_close(*a, *b, 1e-4, "dense out")?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fused_equals_gather_sparse() {
        check(60, |rng: &mut Rng| {
            let dh = 16;
            let s = 8 + rng.below(100);
            let group = 1 + rng.below(4);
            let n = 1 + rng.below(s);
            let q = rng.normal_vec(group * dh);
            let k = rng.normal_vec(s * dh);
            let v = rng.normal_vec(s * dh);
            let idx: Vec<u32> = rng.choose_distinct(s, n).iter().map(|&i| i as u32).collect();
            let inp = make_inputs(&q, &k, &v, group, dh, s);
            let (mut kb, mut vb, mut p1, mut p2) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            let mut out_g = vec![0.0; group * dh];
            let mut out_f = vec![0.0; group * dh];
            let mode = KernelMode::Simd;
            sparse_attention_gather(mode, &inp, &idx, &mut kb, &mut vb, &mut p1, &mut out_g);
            sparse_attention_fused(mode, &inp, &idx, &mut p2, &mut out_f);
            for (a, b) in out_g.iter().zip(&out_f) {
                prop_close(*a, *b, 1e-5, "gather vs fused")?;
            }
            Ok(())
        });
    }

    #[test]
    fn full_index_set_equals_dense() {
        let mut rng = Rng::new(12);
        let (dh, s, group) = (16, 40, 2);
        let q = rng.normal_vec(group * dh);
        let k = rng.normal_vec(s * dh);
        let v = rng.normal_vec(s * dh);
        let inp = make_inputs(&q, &k, &v, group, dh, s);
        let idx: Vec<u32> = (0..s as u32).collect();
        let mut probs = Vec::new();
        let mut a = vec![0.0; group * dh];
        let mut b = vec![0.0; group * dh];
        dense_attention(KernelMode::Simd, &inp, &mut probs, &mut a);
        sparse_attention_fused(KernelMode::Simd, &inp, &idx, &mut probs, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn single_token_returns_value_row() {
        let mut rng = Rng::new(3);
        let (dh, s) = (8, 20);
        let q = rng.normal_vec(dh);
        let k = rng.normal_vec(s * dh);
        let v = rng.normal_vec(s * dh);
        let inp = make_inputs(&q, &k, &v, 1, dh, s);
        let mut probs = Vec::new();
        let mut out = vec![0.0; dh];
        sparse_attention_fused(KernelMode::Simd, &inp, &[7], &mut probs, &mut out);
        for (a, b) in out.iter().zip(&v[7 * dh..8 * dh]) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn large_logits_stay_finite() {
        let dh = 8;
        let s = 16;
        let q = vec![40.0; dh];
        let k = vec![40.0; s * dh];
        let v = vec![1.0; s * dh];
        let inp = make_inputs(&q, &k, &v, 1, dh, s);
        let mut probs = Vec::new();
        for mode in KernelMode::all() {
            let mut out = vec![0.0; dh];
            dense_attention(mode, &inp, &mut probs, &mut out);
            assert!(out.iter().all(|x| x.is_finite()), "{}", mode.name());
            assert!((out[0] - 1.0).abs() < 1e-5, "{}", mode.name());
        }
    }

    /// The tentpole invariant at the attention level: `Simd` output is
    /// bitwise equal to `Reference` for the dense and fused-sparse
    /// kernels, at the real head dim (dh = 128) and with ragged index
    /// sets exercising every lane tail.
    #[test]
    fn simd_mode_bit_identical_dense_and_sparse() {
        check(20, |rng: &mut Rng| {
            let dh = 128;
            let s = 1 + rng.below(60);
            let group = 1 + rng.below(4);
            let q = rng.normal_vec(group * dh);
            let k = rng.normal_vec(s * dh);
            let v = rng.normal_vec(s * dh);
            let inp = make_inputs(&q, &k, &v, group, dh, s);
            let mut probs = Vec::new();
            let mut a = vec![0.0f32; group * dh];
            let mut b = vec![0.0f32; group * dh];
            dense_attention(KernelMode::Reference, &inp, &mut probs, &mut a);
            dense_attention(KernelMode::Simd, &inp, &mut probs, &mut b);
            prop_assert(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "dense simd bits",
            )?;
            let n = 1 + rng.below(s);
            let idx: Vec<u32> = rng.choose_distinct(s, n).iter().map(|&i| i as u32).collect();
            sparse_attention_fused(KernelMode::Reference, &inp, &idx, &mut probs, &mut a);
            sparse_attention_fused(KernelMode::Simd, &inp, &idx, &mut probs, &mut b);
            prop_assert(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "fused simd bits",
            )
        });
    }

    #[test]
    fn prefill_tile_rows_bit_equal_per_token_dense() {
        // a query tile must reproduce, bit for bit, what the serial path
        // computes per token: dense_attention with s = pos + 1
        check(40, |rng: &mut Rng| {
            let dh = 16;
            let group = 1 + rng.below(3);
            let n_kv = 2;
            let qstride = n_kv * group * dh;
            let start = rng.below(20);
            let block = 1 + rng.below(12);
            let s_total = start + block;
            let q = rng.normal_vec(block * qstride);
            let k = rng.normal_vec(s_total * dh);
            let v = rng.normal_vec(s_total * dh);
            let kv = rng.below(n_kv);
            let t0 = rng.below(block);
            let rows = 1 + rng.below(block - t0);
            let tile = PrefillTile {
                q: &q,
                k: &k,
                v: &v,
                group,
                dh,
                qstride,
                qoff: kv * group * dh,
                t0,
                start,
                bt: &[],
                block_tokens: 0,
                kv_dtype: KvDtype::F32,
                kernels: KernelMode::Simd,
            };
            let mut probs = Vec::new();
            let mut got = vec![0.0f32; rows * group * dh];
            prefill_tile_attention(&tile, &mut probs, &mut got);
            for r in 0..rows {
                let t = t0 + r;
                let s = start + t + 1;
                let inp = make_inputs(
                    &q[t * qstride + kv * group * dh..t * qstride + (kv + 1) * group * dh],
                    &k[..s * dh],
                    &v[..s * dh],
                    group,
                    dh,
                    s,
                );
                let mut want = vec![0.0f32; group * dh];
                dense_attention(KernelMode::Reference, &inp, &mut probs, &mut want);
                prop_assert(
                    got[r * group * dh..(r + 1) * group * dh] == want[..],
                    "tile row differs from per-token dense",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn paged_layout_bit_identical_to_contiguous() {
        // the same rows scattered into out-of-order physical blocks must
        // produce bit-identical dense and fused-sparse outputs — the
        // kernel-level half of the paged differential guarantee
        check(30, |rng: &mut Rng| {
            let dh = 16;
            let bt = 1 + rng.below(6);
            let s = 1 + rng.below(50);
            let group = 1 + rng.below(3);
            let q = rng.normal_vec(group * dh);
            let k = rng.normal_vec(s * dh);
            let v = rng.normal_vec(s * dh);
            let nblocks = s.div_ceil(bt);
            let mut table: Vec<u32> = (0..nblocks as u32).collect();
            for i in (1..table.len()).rev() {
                table.swap(i, rng.below(i + 1));
            }
            let mut pk = vec![0.0f32; nblocks * bt * dh];
            let mut pv = vec![0.0f32; nblocks * bt * dh];
            for t in 0..s {
                let r = table[t / bt] as usize * bt + t % bt;
                pk[r * dh..(r + 1) * dh].copy_from_slice(&k[t * dh..(t + 1) * dh]);
                pv[r * dh..(r + 1) * dh].copy_from_slice(&v[t * dh..(t + 1) * dh]);
            }
            let flat = make_inputs(&q, &k, &v, group, dh, s);
            let mut paged = make_inputs(&q, &pk, &pv, group, dh, s);
            paged.bt = &table;
            paged.block_tokens = bt;
            let mut probs = Vec::new();
            let mut a = vec![0.0f32; group * dh];
            let mut b = vec![0.0f32; group * dh];
            dense_attention(KernelMode::Simd, &flat, &mut probs, &mut a);
            dense_attention(KernelMode::Simd, &paged, &mut probs, &mut b);
            prop_assert(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "dense paged bits",
            )?;
            let n = 1 + rng.below(s);
            let idx: Vec<u32> = rng.choose_distinct(s, n).iter().map(|&i| i as u32).collect();
            sparse_attention_fused(KernelMode::Simd, &flat, &idx, &mut probs, &mut a);
            sparse_attention_fused(KernelMode::Simd, &paged, &idx, &mut probs, &mut b);
            prop_assert(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "fused paged bits",
            )
        });
    }

    /// Half-precision storage invariant: attention over a packed
    /// bf16/f16 cache is bitwise equal to attention over the *widened*
    /// f32 copy of that cache (widening is exact and the `*_wide`
    /// kernels keep the canonical order), and `Simd` stays bit-equal to
    /// `Reference` per dtype. The quantization itself is the only lossy
    /// step, bounded in halfkv.rs at the engine level.
    #[test]
    fn half_kv_bit_identical_to_widened_f32() {
        check(30, |rng: &mut Rng| {
            let dh = 32;
            let s = 1 + rng.below(60);
            let group = 1 + rng.below(3);
            let q = rng.normal_vec(group * dh);
            let k = rng.normal_vec(s * dh);
            let v = rng.normal_vec(s * dh);
            for dt in [KvDtype::Bf16, KvDtype::F16] {
                let mut pk = vec![0.0f32; dt.elems(s * dh)];
                let mut pv = vec![0.0f32; dt.elems(s * dh)];
                simd::pack_row(dt, &k, &mut pk);
                simd::pack_row(dt, &v, &mut pv);
                let mut wk = vec![0.0f32; s * dh];
                let mut wv = vec![0.0f32; s * dh];
                simd::widen_row(dt, &pk, &mut wk);
                simd::widen_row(dt, &pv, &mut wv);
                let f32_inp = make_inputs(&q, &wk, &wv, group, dh, s);
                let mut half_inp = make_inputs(&q, &pk, &pv, group, dh, s);
                half_inp.kv_dtype = dt;
                let mut probs = Vec::new();
                let mut a = vec![0.0f32; group * dh];
                let mut b = vec![0.0f32; group * dh];
                let mut c = vec![0.0f32; group * dh];
                dense_attention(KernelMode::Reference, &f32_inp, &mut probs, &mut a);
                dense_attention(KernelMode::Reference, &half_inp, &mut probs, &mut b);
                dense_attention(KernelMode::Simd, &half_inp, &mut probs, &mut c);
                prop_assert(
                    a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "half dense != widened f32 dense",
                )?;
                prop_assert(
                    b.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "half simd != half reference",
                )?;
                let n = 1 + rng.below(s);
                let idx: Vec<u32> =
                    rng.choose_distinct(s, n).iter().map(|&i| i as u32).collect();
                let (mut kb, mut vb) = (Vec::new(), Vec::new());
                sparse_attention_fused(KernelMode::Simd, &f32_inp, &idx, &mut probs, &mut a);
                sparse_attention_fused(KernelMode::Simd, &half_inp, &idx, &mut probs, &mut b);
                sparse_attention_gather(
                    KernelMode::Simd,
                    &half_inp,
                    &idx,
                    &mut kb,
                    &mut vb,
                    &mut probs,
                    &mut c,
                );
                prop_assert(
                    a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "half fused != widened f32 fused",
                )?;
                prop_assert(
                    b.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "half gather != half fused",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn exact_group_scores_sum_heads() {
        let mut rng = Rng::new(8);
        let (dh, s, group) = (8, 12, 3);
        let q = rng.normal_vec(group * dh);
        let k = rng.normal_vec(s * dh);
        let v = vec![0.0; s * dh];
        let inp = make_inputs(&q, &k, &v, group, dh, s);
        let mut got = Vec::new();
        exact_group_scores(&inp, &mut got);
        let scale = 1.0 / (dh as f32).sqrt();
        for t in 0..s {
            let want: f32 = (0..group)
                .map(|g| {
                    (0..dh).map(|i| q[g * dh + i] * k[t * dh + i]).sum::<f32>() * scale
                })
                .sum();
            assert!((got[t] - want).abs() < 1e-4);
        }
    }
}
