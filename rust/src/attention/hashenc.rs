//! Fused hash encoding on the request path (paper Alg. 2 + the Sec. 4
//! "kernel fusion" optimization, CPU analog).
//!
//! Projection (vec x W_H), sign and bitpack run in one pass per 64-bit
//! word: the projection accumulator for a bit is consumed immediately into
//! the packed word, so no intermediate f32 row or bool row is ever
//! materialized — the same traffic-saving the paper's fused CUDA kernel
//! gets. The unfused variant is kept for the Fig. 9 'Encode' ablation.
//!
//! Bit convention matches python/compile/kernels/ref.py: bit b of token t
//! is word ``b / 64``, position ``b % 64`` (little-endian u32 pairs from
//! the Python side reinterpret as these u64 words on x86).

use crate::tensor::ops::dot;

/// Packed code words per rbit. `rbit` need not be a multiple of 64: the
/// last word is then partial, and every encoder leaves its high padding
/// bits zero (so padded codes XOR/popcount cleanly in the hamming path).
pub fn words64(rbit: usize) -> usize {
    rbit.div_ceil(64)
}

/// Fused: project+sign+pack one vector `x` [dh] with `w` [dh, rbit]
/// (row-major), appending `rbit/64` words to `out`.
pub fn encode_fused(x: &[f32], w: &[f32], rbit: usize, out: &mut Vec<u64>) {
    let dh = x.len();
    debug_assert_eq!(w.len(), dh * rbit);
    for word in 0..words64(rbit) {
        let mut packed = 0u64;
        let base = word * 64;
        for bit in 0..(rbit - base).min(64) {
            let col = base + bit;
            // y = sum_i x[i] * w[i, col]; sign >= 0 -> bit set
            let mut y = 0.0f32;
            let mut i = 0;
            while i < dh {
                y += x[i] * w[i * rbit + col];
                i += 1;
            }
            packed |= ((y >= 0.0) as u64) << bit;
        }
        out.push(packed);
    }
}

/// Unfused reference ('Simple' in Fig. 9): materializes the f32 projection
/// row, then a sign pass, then a pack pass — three passes over rbit.
pub fn encode_unfused(x: &[f32], w: &[f32], rbit: usize, out: &mut Vec<u64>) {
    let dh = x.len();
    let mut proj = vec![0.0f32; rbit];
    for (col, p) in proj.iter_mut().enumerate() {
        let wcol: Vec<f32> = (0..dh).map(|i| w[i * rbit + col]).collect();
        *p = dot(x, &wcol);
    }
    let bits: Vec<bool> = proj.iter().map(|&y| y >= 0.0).collect();
    for word in 0..words64(rbit) {
        let mut packed = 0u64;
        for bit in 0..(rbit - word * 64).min(64) {
            packed |= (bits[word * 64 + bit] as u64) << bit;
        }
        out.push(packed);
    }
}

/// Column-major-friendly fused variant: iterates W by column blocks of 64
/// with the accumulators held in registers; the §Perf winner for dh <= 32.
pub fn encode_fused_blocked(x: &[f32], w: &[f32], rbit: usize, out: &mut Vec<u64>) {
    for word in 0..words64(rbit) {
        let base = word * 64;
        let width = (rbit - base).min(64);
        let mut acc = [0.0f32; 64];
        for (i, &xi) in x.iter().enumerate() {
            let row = &w[i * rbit + base..i * rbit + base + width];
            for (a, &r) in acc.iter_mut().zip(row) {
                *a += xi * r;
            }
        }
        let mut packed = 0u64;
        for (b, &a) in acc.iter().take(width).enumerate() {
            packed |= ((a >= 0.0) as u64) << b;
        }
        out.push(packed);
    }
}

/// In-place variant of [`encode_fused_blocked`] writing the packed words
/// into `out` (length [`words64`]`(rbit)`) instead of appending — the
/// paged cache encodes straight into a token's code row inside the
/// shared [`crate::kvcache::BlockStore`] plane. Identical arithmetic and
/// reduction order, so codes are bit-identical across layouts.
pub fn encode_fused_blocked_into(x: &[f32], w: &[f32], rbit: usize, out: &mut [u64]) {
    debug_assert_eq!(out.len(), words64(rbit));
    for (word, slot) in out.iter_mut().enumerate() {
        let base = word * 64;
        let width = (rbit - base).min(64);
        let mut acc = [0.0f32; 64];
        for (i, &xi) in x.iter().enumerate() {
            let row = &w[i * rbit + base..i * rbit + base + width];
            for (a, &r) in acc.iter_mut().zip(row) {
                *a += xi * r;
            }
        }
        let mut packed = 0u64;
        for (b, &a) in acc.iter().take(width).enumerate() {
            packed |= ((a >= 0.0) as u64) << b;
        }
        *slot = packed;
    }
}

/// Encode a batch of contiguous rows in row order. The tiled prefill
/// block-append path ([`crate::kvcache::HeadMut::append_block`]) runs
/// the same per-row [`encode_fused_blocked`] over strided rows, so both
/// produce codes bit-identical to encoding one row per decode step.
pub fn encode_rows(xs: &[f32], dh: usize, w: &[f32], rbit: usize) -> Vec<u64> {
    let rows = xs.len() / dh;
    let mut out = Vec::with_capacity(rows * words64(rbit));
    for r in 0..rows {
        encode_fused_blocked(&xs[r * dh..(r + 1) * dh], w, rbit, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    fn reference_bits(x: &[f32], w: &[f32], rbit: usize) -> Vec<bool> {
        let dh = x.len();
        (0..rbit)
            .map(|c| (0..dh).map(|i| x[i] * w[i * rbit + c]).sum::<f32>() >= 0.0)
            .collect()
    }

    fn unpack(words: &[u64], rbit: usize) -> Vec<bool> {
        (0..rbit).map(|b| (words[b / 64] >> (b % 64)) & 1 == 1).collect()
    }

    #[test]
    fn all_variants_agree_with_reference() {
        check(60, |rng: &mut Rng| {
            let dh = [8, 16, 24, 32][rng.below(4)];
            let rbit = [64, 128, 256][rng.below(3)];
            let x = rng.normal_vec(dh);
            let w = rng.normal_vec(dh * rbit);
            let want = reference_bits(&x, &w, rbit);
            let (mut a, mut b, mut c) = (Vec::new(), Vec::new(), Vec::new());
            encode_fused(&x, &w, rbit, &mut a);
            encode_unfused(&x, &w, rbit, &mut b);
            encode_fused_blocked(&x, &w, rbit, &mut c);
            let mut d = vec![u64::MAX; words64(rbit)];
            encode_fused_blocked_into(&x, &w, rbit, &mut d);
            prop_assert(unpack(&a, rbit) == want, "fused mismatch")?;
            prop_assert(a == b, "unfused differs from fused")?;
            prop_assert(a == c, "blocked differs from fused")?;
            prop_assert(a == d, "in-place blocked differs from fused")
        });
    }

    #[test]
    fn fused_equals_unfused_any_rbit_and_padding_is_zero() {
        // rbit sweep includes non-multiples of 64: the last word is then
        // partial and its high padding bits must stay zero everywhere.
        check(80, |rng: &mut Rng| {
            let dh = [8, 16, 24, 32][rng.below(4)];
            let rbit = [64, 128, 192, 256, 40, 100, 130, 200][rng.below(8)];
            let x = rng.normal_vec(dh);
            let w = rng.normal_vec(dh * rbit);
            let want = reference_bits(&x, &w, rbit);
            let (mut a, mut b, mut c) = (Vec::new(), Vec::new(), Vec::new());
            encode_fused(&x, &w, rbit, &mut a);
            encode_unfused(&x, &w, rbit, &mut b);
            encode_fused_blocked(&x, &w, rbit, &mut c);
            prop_assert(a.len() == rbit.div_ceil(64), "word count")?;
            prop_assert(unpack(&a, rbit) == want, "fused mismatch vs reference")?;
            prop_assert(a == b, "unfused differs from fused")?;
            prop_assert(a == c, "blocked differs from fused")?;
            if rbit % 64 != 0 {
                let pad = a[a.len() - 1] >> (rbit % 64);
                prop_assert(pad == 0, "padding bits of the partial last word set")?;
            }
            Ok(())
        });
    }

    #[test]
    fn zero_vector_encodes_all_ones() {
        // y == 0 -> bit set, matching the Python `>= 0` convention.
        let x = vec![0.0; 16];
        let w = vec![1.0; 16 * 64];
        let mut out = Vec::new();
        encode_fused(&x, &w, 64, &mut out);
        assert_eq!(out, vec![u64::MAX]);
    }

    #[test]
    fn encode_rows_layout() {
        let mut rng = Rng::new(3);
        let dh = 16;
        let rbit = 128;
        let xs = rng.normal_vec(5 * dh);
        let w = rng.normal_vec(dh * rbit);
        let all = encode_rows(&xs, dh, &w, rbit);
        assert_eq!(all.len(), 5 * 2);
        let mut row3 = Vec::new();
        encode_fused(&xs[3 * dh..4 * dh], &w, rbit, &mut row3);
        assert_eq!(&all[3 * 2..4 * 2], &row3[..]);
    }

    #[test]
    fn sign_flip_flips_bits() {
        let mut rng = Rng::new(5);
        let dh = 8;
        let rbit = 64;
        let x = rng.normal_vec(dh);
        let w = rng.normal_vec(dh * rbit);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        encode_fused(&x, &w, rbit, &mut a);
        encode_fused(&neg, &w, rbit, &mut b);
        // y -> -y flips strict signs; equality (y == 0) keeps bit 1 in
        // both, measure on random data where exact zeros don't occur.
        assert_eq!(a[0] ^ b[0], u64::MAX);
    }
}
