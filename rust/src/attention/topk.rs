//! Partial top-k selection over score vectors.
//!
//! Two algorithms, both returning indices of the k largest scores:
//! * [`topk_heap`] — O(s log k) min-heap; good for k << s.
//! * [`topk_quickselect`] — expected O(s) in-place partition; the hot-path
//!   default after the §Perf pass.
//!
//! Ties broken toward lower indices (stable across both algorithms so the
//! accuracy evals are implementation-independent).
//!
//! The hot-path variants ([`topk_quickselect`], [`topk_counting`]) take
//! their working buffer (index permutation / histogram) from the caller —
//! in the engine, fields of [`crate::attention::Scratch`] — so the
//! steady-state decode step never allocates here (rust/tests/alloc.rs).

/// Min-heap over (score, index) keyed by score then reverse index.
pub fn topk_heap(scores: &[f32], k: usize, out: &mut Vec<u32>) {
    out.clear();
    let k = k.min(scores.len());
    if k == 0 {
        return;
    }
    // (score, Reverse(index)) ordering via tuple compare on (f32 bits)
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    #[derive(PartialEq)]
    struct Entry(f32, u32);
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            // smaller score = "greater" for min-heap via Reverse below;
            // among equal scores prefer KEEPING lower index, so a higher
            // index compares as smaller.
            self.0
                .partial_cmp(&o.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(o.1.cmp(&self.1))
        }
    }
    let mut heap: BinaryHeap<Reverse<Entry>> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        if heap.len() < k {
            heap.push(Reverse(Entry(s, i as u32)));
        } else if let Some(Reverse(min)) = heap.peek() {
            if s > min.0 {
                heap.pop();
                heap.push(Reverse(Entry(s, i as u32)));
            }
        }
    }
    out.extend(heap.into_iter().map(|Reverse(e)| e.1));
    out.sort_unstable();
}

/// Expected-linear selection: partition a caller-provided (score, index)
/// permutation buffer (`perm`, cleared and refilled here — pass a
/// [`crate::attention::Scratch`] field on the hot path so no allocation
/// happens once warmed).
pub fn topk_quickselect(scores: &[f32], k: usize, perm: &mut Vec<u32>, out: &mut Vec<u32>) {
    out.clear();
    let n = scores.len();
    let k = k.min(n);
    if k == 0 {
        return;
    }
    if k == n {
        out.extend(0..n as u32);
        return;
    }
    // Work on an index permutation; compare by (score desc, index asc).
    perm.clear();
    perm.extend(0..n as u32);
    let idx = perm;
    let better = |a: u32, b: u32| -> bool {
        let (sa, sb) = (scores[a as usize], scores[b as usize]);
        sa > sb || (sa == sb && a < b)
    };
    let (mut lo, mut hi) = (0usize, n);
    let target = k;
    // invariant: the final top-k occupy idx[..k] when lo >= target
    let mut seed = 0x9E3779B97F4A7C15u64;
    while hi - lo > 1 {
        // median-of-3-ish pivot using a cheap LCG to dodge adversarial order
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        let p = lo + (seed >> 33) as usize % (hi - lo);
        idx.swap(lo, p);
        let pivot = idx[lo];
        let mut store = lo + 1;
        for i in lo + 1..hi {
            if better(idx[i], pivot) {
                idx.swap(i, store);
                store += 1;
            }
        }
        idx.swap(lo, store - 1);
        let pivot_rank = store - 1;
        if pivot_rank == target || pivot_rank + 1 == target {
            if pivot_rank + 1 <= target {
                break;
            }
            hi = pivot_rank;
        } else if pivot_rank > target {
            hi = pivot_rank;
        } else {
            lo = store;
        }
        if lo >= target {
            break;
        }
    }
    out.extend_from_slice(&idx[..k]);
    out.sort_unstable();
}

/// Integer-score variant used by the Hamming path (scores in [0, rbit]):
/// counting-select in O(s + rbit), no comparisons at all. `hist` is the
/// caller-provided histogram buffer (one slot per score value, cleared
/// and refilled here).
pub fn topk_counting(
    scores: &[i32],
    max_score: i32,
    k: usize,
    hist: &mut Vec<u32>,
    out: &mut Vec<u32>,
) {
    out.clear();
    let n = scores.len();
    let k = k.min(n);
    if k == 0 {
        return;
    }
    let m = (max_score + 1) as usize;
    hist.clear();
    hist.resize(m, 0);
    for &s in scores {
        hist[s.clamp(0, max_score) as usize] += 1;
    }
    // find threshold t: count of scores > t is < k, >= t is >= k
    let mut remaining = k;
    let mut thr = 0i32;
    let mut take_at_thr = 0u32;
    for s in (0..m).rev() {
        let c = hist[s];
        if (c as usize) >= remaining {
            thr = s as i32;
            take_at_thr = remaining as u32;
            break;
        }
        remaining -= c as usize;
    }
    let mut at_thr = 0u32;
    for (i, &s) in scores.iter().enumerate() {
        if s > thr {
            out.push(i as u32);
        } else if s == thr && at_thr < take_at_thr {
            out.push(i as u32);
            at_thr += 1;
        }
        if out.len() == k {
            // all remaining candidates score <= thr and the thr quota is
            // filled — nothing left to take, stop scanning.
            break;
        }
    }
    out.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    fn reference_topk(scores: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.truncate(k.min(scores.len()));
        idx.sort_unstable();
        idx
    }

    #[test]
    fn heap_matches_reference() {
        check(200, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let k = rng.below(n + 4);
            let scores: Vec<f32> = (0..n).map(|_| (rng.below(50) as f32) - 25.0).collect();
            let mut out = Vec::new();
            topk_heap(&scores, k, &mut out);
            prop_assert(out == reference_topk(&scores, k), "heap != reference")
        });
    }

    #[test]
    fn quickselect_selects_same_score_set() {
        check(200, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let k = rng.below(n + 1);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut perm = Vec::new();
            let mut out = Vec::new();
            topk_quickselect(&scores, k, &mut perm, &mut out);
            let want = reference_topk(&scores, k);
            prop_assert(out.len() == want.len(), "wrong k")?;
            // same multiset of scores (ties may pick different indices)
            let mut a: Vec<f32> = out.iter().map(|&i| scores[i as usize]).collect();
            let mut b: Vec<f32> = want.iter().map(|&i| scores[i as usize]).collect();
            a.sort_by(|x, y| x.partial_cmp(y).unwrap());
            b.sort_by(|x, y| x.partial_cmp(y).unwrap());
            prop_assert(a == b, "score multiset differs")
        });
    }

    #[test]
    fn counting_matches_reference_on_ints() {
        check(200, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let k = rng.below(n + 1);
            let scores: Vec<i32> = (0..n).map(|_| rng.below(129) as i32).collect();
            let fscores: Vec<f32> = scores.iter().map(|&s| s as f32).collect();
            let mut hist = Vec::new();
            let mut out = Vec::new();
            topk_counting(&scores, 128, k, &mut hist, &mut out);
            let want = reference_topk(&fscores, k);
            prop_assert(out == want, "counting != reference")
        });
    }

    #[test]
    fn counting_tie_quota_stops_at_k_with_equal_scores_remaining() {
        // k fills exactly at the threshold score while later candidates
        // share that same score: the quota must admit the LOWEST-index
        // ties only, and the early break must not truncate the result
        let scores = [5, 9, 5, 9, 5, 5, 9, 5];
        // threshold is 5 (three 9s, then 5s fill the rest); k = 5 takes
        // all 9s plus the first two 5s — indices 0 and 2 — leaving three
        // equal-score candidates (4, 5, 7) unselected past the break
        let mut hist = Vec::new();
        let mut out = Vec::new();
        topk_counting(&scores, 16, 5, &mut hist, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3, 6]);
        // exact-k boundary: k equals the count of strictly-above-threshold
        // scores, so the tie quota is zero and no 5 may slip in
        topk_counting(&scores, 16, 3, &mut hist, &mut out);
        assert_eq!(out, vec![1, 3, 6]);
        // reused histogram must not leak the previous call's counts
        let shifted = [2, 2, 2, 2];
        topk_counting(&shifted, 16, 2, &mut hist, &mut out);
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn k_zero_and_k_full() {
        let scores = [3.0, 1.0, 2.0];
        let mut perm = Vec::new();
        let mut out = Vec::new();
        topk_heap(&scores, 0, &mut out);
        assert!(out.is_empty());
        topk_quickselect(&scores, 3, &mut perm, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
        topk_heap(&scores, 10, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn all_equal_scores_prefer_low_indices() {
        let scores = [5.0; 10];
        let mut out = Vec::new();
        topk_heap(&scores, 3, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
        let mut hist = Vec::new();
        let mut out2 = Vec::new();
        topk_counting(&[7; 10], 128, 3, &mut hist, &mut out2);
        assert_eq!(out2, vec![0, 1, 2]);
    }
}
