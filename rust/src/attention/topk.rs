//! Partial top-k selection over score vectors.
//!
//! Two algorithms, both returning indices of the k largest scores:
//! * [`topk_heap`] — O(s log k) min-heap; good for k << s.
//! * [`topk_quickselect`] — expected O(s) in-place partition; the hot-path
//!   default after the §Perf pass.
//!
//! Ties broken toward lower indices (stable across both algorithms so the
//! accuracy evals are implementation-independent).

/// Min-heap over (score, index) keyed by score then reverse index.
pub fn topk_heap(scores: &[f32], k: usize, out: &mut Vec<u32>) {
    out.clear();
    let k = k.min(scores.len());
    if k == 0 {
        return;
    }
    // (score, Reverse(index)) ordering via tuple compare on (f32 bits)
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    #[derive(PartialEq)]
    struct Entry(f32, u32);
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            // smaller score = "greater" for min-heap via Reverse below;
            // among equal scores prefer KEEPING lower index, so a higher
            // index compares as smaller.
            self.0
                .partial_cmp(&o.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(o.1.cmp(&self.1))
        }
    }
    let mut heap: BinaryHeap<Reverse<Entry>> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        if heap.len() < k {
            heap.push(Reverse(Entry(s, i as u32)));
        } else if let Some(Reverse(min)) = heap.peek() {
            if s > min.0 {
                heap.pop();
                heap.push(Reverse(Entry(s, i as u32)));
            }
        }
    }
    out.extend(heap.into_iter().map(|Reverse(e)| e.1));
    out.sort_unstable();
}

/// Expected-linear selection: partition a (score, index) working buffer.
pub fn topk_quickselect(scores: &[f32], k: usize, out: &mut Vec<u32>) {
    out.clear();
    let n = scores.len();
    let k = k.min(n);
    if k == 0 {
        return;
    }
    if k == n {
        out.extend(0..n as u32);
        return;
    }
    // Work on index permutation; compare by (score desc, index asc).
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let better = |a: u32, b: u32| -> bool {
        let (sa, sb) = (scores[a as usize], scores[b as usize]);
        sa > sb || (sa == sb && a < b)
    };
    let (mut lo, mut hi) = (0usize, n);
    let mut target = k;
    // invariant: the final top-k occupy idx[..k] when lo >= target
    let mut seed = 0x9E3779B97F4A7C15u64;
    while hi - lo > 1 {
        // median-of-3-ish pivot using a cheap LCG to dodge adversarial order
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        let p = lo + (seed >> 33) as usize % (hi - lo);
        idx.swap(lo, p);
        let pivot = idx[lo];
        let mut store = lo + 1;
        for i in lo + 1..hi {
            if better(idx[i], pivot) {
                idx.swap(i, store);
                store += 1;
            }
        }
        idx.swap(lo, store - 1);
        let pivot_rank = store - 1;
        if pivot_rank == target || pivot_rank + 1 == target {
            if pivot_rank + 1 <= target {
                break;
            }
            hi = pivot_rank;
        } else if pivot_rank > target {
            hi = pivot_rank;
        } else {
            lo = store;
        }
        let _ = &mut target;
        if lo >= target {
            break;
        }
    }
    out.extend_from_slice(&idx[..k]);
    out.sort_unstable();
}

/// Integer-score variant used by the Hamming path (scores in [0, rbit]):
/// counting-select in O(s + rbit), no comparisons at all.
pub fn topk_counting(scores: &[i32], max_score: i32, k: usize, out: &mut Vec<u32>) {
    out.clear();
    let n = scores.len();
    let k = k.min(n);
    if k == 0 {
        return;
    }
    let m = (max_score + 1) as usize;
    let mut hist = vec![0u32; m];
    for &s in scores {
        hist[s.clamp(0, max_score) as usize] += 1;
    }
    // find threshold t: count of scores > t is < k, >= t is >= k
    let mut remaining = k;
    let mut thr = 0i32;
    let mut take_at_thr = 0u32;
    for s in (0..m).rev() {
        let c = hist[s];
        if (c as usize) >= remaining {
            thr = s as i32;
            take_at_thr = remaining as u32;
            break;
        }
        remaining -= c as usize;
    }
    let mut at_thr = 0u32;
    for (i, &s) in scores.iter().enumerate() {
        if s > thr {
            out.push(i as u32);
        } else if s == thr && at_thr < take_at_thr {
            out.push(i as u32);
            at_thr += 1;
        }
        if out.len() == k {
            // keep scanning only if we could still replace nothing — we
            // can stop: all remaining are <= thr and thr quota is filled.
            break;
        }
    }
    out.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    fn reference_topk(scores: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.truncate(k.min(scores.len()));
        idx.sort_unstable();
        idx
    }

    #[test]
    fn heap_matches_reference() {
        check(200, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let k = rng.below(n + 4);
            let scores: Vec<f32> = (0..n).map(|_| (rng.below(50) as f32) - 25.0).collect();
            let mut out = Vec::new();
            topk_heap(&scores, k, &mut out);
            prop_assert(out == reference_topk(&scores, k), "heap != reference")
        });
    }

    #[test]
    fn quickselect_selects_same_score_set() {
        check(200, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let k = rng.below(n + 1);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut out = Vec::new();
            topk_quickselect(&scores, k, &mut out);
            let want = reference_topk(&scores, k);
            prop_assert(out.len() == want.len(), "wrong k")?;
            // same multiset of scores (ties may pick different indices)
            let mut a: Vec<f32> = out.iter().map(|&i| scores[i as usize]).collect();
            let mut b: Vec<f32> = want.iter().map(|&i| scores[i as usize]).collect();
            a.sort_by(|x, y| x.partial_cmp(y).unwrap());
            b.sort_by(|x, y| x.partial_cmp(y).unwrap());
            prop_assert(a == b, "score multiset differs")
        });
    }

    #[test]
    fn counting_matches_reference_on_ints() {
        check(200, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let k = rng.below(n + 1);
            let scores: Vec<i32> = (0..n).map(|_| rng.below(129) as i32).collect();
            let fscores: Vec<f32> = scores.iter().map(|&s| s as f32).collect();
            let mut out = Vec::new();
            topk_counting(&scores, 128, k, &mut out);
            let want = reference_topk(&fscores, k);
            prop_assert(out == want, "counting != reference")
        });
    }

    #[test]
    fn k_zero_and_k_full() {
        let scores = [3.0, 1.0, 2.0];
        let mut out = Vec::new();
        topk_heap(&scores, 0, &mut out);
        assert!(out.is_empty());
        topk_quickselect(&scores, 3, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
        topk_heap(&scores, 10, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn all_equal_scores_prefer_low_indices() {
        let scores = [5.0; 10];
        let mut out = Vec::new();
        topk_heap(&scores, 3, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
        let mut out2 = Vec::new();
        topk_counting(&[7; 10], 128, 3, &mut out2);
        assert_eq!(out2, vec![0, 1, 2]);
    }
}
