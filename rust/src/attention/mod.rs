//! Top-k attention methods: the paper's HATA plus every baseline it
//! compares against (Table 5), behind one [`Selector`] interface so the
//! engine, accuracy evals and benches swap methods freely — the paper's
//! "users need only replace standard attention with HATA's attention".
//!
//! Submodules:
//! * [`hashenc`]  — fused hash encoding (projection → sign → bitpack)
//! * [`hamming`]  — Hamming score operator, scalar/word/blocked variants
//!   (the Fig. 9 'Score' ablation axis)
//! * [`topk`]     — partial selection (heap and quickselect)
//! * [`compute`]  — dense + sparse attention, separate-gather vs fused
//!   (the Fig. 9 'FusedAttn' ablation axis)
//! * [`methods`]  — one [`Selector`] per paper baseline
//!
//! ## Scratch ownership in the batched decode path
//!
//! Selection buffers ([`Scratch`]) are *worker-thread arenas*: the engine
//! keeps one per threadpool worker and lends it to whichever
//! (sequence, kv-head) work item that worker picks up. Every routine that
//! reads a scratch buffer fully overwrites the prefix it reads first
//! (`clear()`/`resize()` + full write), so results never depend on which
//! worker — or which previous item — last touched the arena; this is the
//! invariant that makes `threads = N` byte-identical to `threads = 1`.
//! Per-sequence state that must survive a step ([`MethodState`]) is owned
//! by the sequence and handed to items as disjoint `&mut`, never shared.
//! [`Selector`] implementations are required to be `Send + Sync`
//! (stateless policy objects) so one instance can serve all workers.

pub mod compute;
pub mod hamming;
pub mod hashenc;
pub mod methods;
pub mod topk;

use crate::tensor::simd::{KernelMode, KvDtype};

/// Everything a selector may look at for one (layer, kv-head) decode step.
///
/// `q` holds the `group` query-head rows sharing this KV head (GQA scores
/// are aggregated over them, paper Sec 3.2); `k`/`v` are the full per-head
/// caches; `codes` is the packed key-code cache (HATA) and `pos` the
/// current absolute position (== s - 1 at decode time).
///
/// With the paged KV layout (`bt` non-empty), `k`/`v`/`codes` are whole
/// [`crate::kvcache::BlockStore`] planes and logical token `t` resolves
/// through [`AttnInputs::phys_row`]; the row accessors do this
/// transparently, so selectors and attention kernels are layout-agnostic.
pub struct AttnInputs<'a> {
    /// The `group` query-head rows sharing this KV head, [group, dh].
    pub q: &'a [f32],
    /// GQA query heads per KV head.
    pub group: usize,
    /// Head dimension.
    pub dh: usize,
    /// This head's full key cache, [s, dh] row-major (the whole shared
    /// plane when paged).
    pub k: &'a [f32],
    /// This head's full value cache, [s, dh] row-major (the whole shared
    /// plane when paged).
    pub v: &'a [f32],
    /// Packed key-code cache (HATA), `words` u64 per token.
    pub codes: &'a [u64],
    /// Packed code words per token (rbit / 64).
    pub words: usize,
    /// Hash code bits per key.
    pub rbit: usize,
    /// Tokens visible to this query (causal bound; <= cache length).
    pub s: usize,
    /// Absolute position of the query token (== s - 1).
    pub pos: usize,
    /// Paged layout: block table mapping logical block -> physical block
    /// id. Empty = contiguous (physical row == token index).
    pub bt: &'a [u32],
    /// Paged layout: tokens per physical block (0 when contiguous).
    pub block_tokens: usize,
    /// Storage dtype of the `k`/`v` planes. Half dtypes store rows
    /// packed two elements per f32 slot ([`KvDtype::elems`]); the row
    /// accessors return *packed* rows, which the widening kernels in
    /// [`crate::tensor::simd`] read directly. `codes` and every [`Side`]
    /// structure stay f32/u64 and are built from pre-quantization keys,
    /// so selection is dtype-independent.
    pub kv_dtype: KvDtype,
    /// Kernel tier the attention kernels and the Hamming scorer run at.
    pub kernels: KernelMode,
    /// Method-specific side structures maintained by the KV cache.
    pub side: Side<'a>,
}

/// Borrowed views of the per-(layer, kv-head) side structures each
/// baseline needs; empty slices when the method is not in use.
#[derive(Clone, Copy, Default)]
pub struct Side<'a> {
    /// HATA: trained hash weights [dh, rbit] row-major for this head.
    pub hash_w: &'a [f32],
    /// Quest: per-block elementwise key minima, [nblocks, dh].
    pub quest_min: &'a [f32],
    /// Quest: per-block elementwise key maxima, [nblocks, dh].
    pub quest_max: &'a [f32],
    /// Quest: tokens per block.
    pub quest_block: usize,
    /// Loki: PCA-projected keys, [s, channels].
    pub loki_kproj: &'a [f32],
    /// Loki: projection matrix [dh, channels] applied to the query.
    pub loki_pca: &'a [f32],
    /// Loki: retained low-rank channels.
    pub loki_channels: usize,
    /// MagicPIG: per-token LSH table signatures, [s, L].
    pub mp_sigs: &'a [u16],
    /// MagicPIG: random hyperplanes [L * K, dh] shared by queries.
    pub mp_planes: &'a [f32],
    /// MagicPIG: bits per table signature.
    pub mp_k: usize,
    /// MagicPIG: table count.
    pub mp_l: usize,
}

impl<'a> AttnInputs<'a> {
    /// Query row of group head `g`.
    pub fn q_row(&self, g: usize) -> &'a [f32] {
        &self.q[g * self.dh..(g + 1) * self.dh]
    }

    /// Physical storage row of logical token `t` (identity when
    /// contiguous, block-table indirection when paged).
    #[inline]
    pub fn phys_row(&self, t: usize) -> usize {
        if self.bt.is_empty() {
            t
        } else {
            self.bt[t / self.block_tokens] as usize * self.block_tokens + t % self.block_tokens
        }
    }

    /// f32 storage slots per stored K/V row (`dh` for f32 storage,
    /// `dh / 2` packed for the half dtypes).
    #[inline]
    pub fn kv_elems(&self) -> usize {
        self.kv_dtype.elems(self.dh)
    }

    /// Cached key row of logical token `t` — *packed* storage form
    /// (`kv_elems()` long); read it through the `*_wide` kernels.
    pub fn k_row(&self, t: usize) -> &'a [f32] {
        let r = self.phys_row(t);
        let e = self.kv_elems();
        &self.k[r * e..(r + 1) * e]
    }

    /// Cached value row of logical token `t` — packed storage form, as
    /// [`AttnInputs::k_row`].
    pub fn v_row(&self, t: usize) -> &'a [f32] {
        let r = self.phys_row(t);
        let e = self.kv_elems();
        &self.v[r * e..(r + 1) * e]
    }

    /// Packed code row of logical token `t`.
    pub fn code_row(&self, t: usize) -> &'a [u64] {
        let r = self.phys_row(t);
        &self.codes[r * self.words..(r + 1) * self.words]
    }
}

/// Reusable per-thread scratch so the decode loop never allocates.
///
/// Every selector temporary lives here — including the top-k working
/// buffers (`hist`, `perm`) and the per-method staging buffers (`idxbuf`,
/// `sigbuf`) — so a warmed-up steady-state decode step performs zero
/// heap allocations (enforced by rust/tests/alloc.rs). Each buffer is
/// fully overwritten (clear/resize + write) before it is read, so
/// switching selectors on a shared scratch can never leak state between
/// methods.
#[derive(Default)]
pub struct Scratch {
    /// Float selection scores, one per candidate.
    pub scores: Vec<f32>,
    /// Integer (Hamming / collision-count) scores.
    pub iscores: Vec<i32>,
    /// Selected token indices (the selector's output).
    pub indices: Vec<u32>,
    /// Attention probabilities / score staging.
    pub probs: Vec<f32>,
    /// Packed query hash codes (HATA).
    pub qcodes: Vec<u64>,
    /// Generic float staging (Loki projections, MagicPIG mean query).
    pub fbuf: Vec<f32>,
    /// Counting-select histogram ([`topk::topk_counting`]).
    pub hist: Vec<u32>,
    /// Quickselect index permutation ([`topk::topk_quickselect`]).
    pub perm: Vec<u32>,
    /// Secondary index staging (Quest block picks, H2O heavy hitters,
    /// SnapKV prefill ranking).
    pub idxbuf: Vec<u32>,
    /// MagicPIG per-table query signatures.
    pub sigbuf: Vec<u16>,
}

/// Per-sequence, per-(layer, kv-head) method state that outlives a step
/// (H2O cumulative scores, SnapKV prefill selection; Quest block metadata
/// lives in the kv cache instead since it is append-maintained).
#[derive(Clone, Debug, Default)]
pub struct MethodState {
    /// H2O: cumulative attention mass per cached token.
    pub h2o_cum: Vec<f32>,
    /// SnapKV: token set chosen from the observation window at prefill.
    pub snapkv_keep: Vec<u32>,
    /// Offload: physical block ids this head's selection touched at the
    /// last decode step — the layer-ahead prefetch task's fetch list.
    /// Written only when a residency tier is attached (stays empty, and
    /// allocation-free, otherwise).
    pub sel_blocks: Vec<u32>,
}

/// A token-selection policy for sparse attention.
///
/// `Send + Sync` is a supertrait: one selector instance is shared by all
/// threadpool workers during a batched step, so implementations must be
/// stateless policy objects (all per-sequence state lives in
/// [`MethodState`], all transient buffers in the per-worker [`Scratch`]).
pub trait Selector: Send + Sync {
    /// Write the selected token indices for this step into
    /// `scratch.indices` (any order, no duplicates, all `< inputs.s`).
    fn select(
        &self,
        inputs: &AttnInputs,
        state: &mut MethodState,
        budget: usize,
        scratch: &mut Scratch,
    );

    /// Stable lowercase method name (table rows, CLI).
    fn name(&self) -> &'static str;

    /// Bytes this selector reads per cached token at score time — drives
    /// the memory-traffic model (simulator/hbm.rs).
    fn score_bytes_per_token(&self, dh: usize, rbit: usize) -> usize;
}
