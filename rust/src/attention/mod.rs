//! Top-k attention methods: the paper's HATA plus every baseline it
//! compares against (Table 5), behind one [`Selector`] interface so the
//! engine, accuracy evals and benches swap methods freely — the paper's
//! "users need only replace standard attention with HATA's attention".
//!
//! Submodules:
//! * [`hashenc`]  — fused hash encoding (projection → sign → bitpack)
//! * [`hamming`]  — Hamming score operator, scalar/word/blocked variants
//!   (the Fig. 9 'Score' ablation axis)
//! * [`topk`]     — partial selection (heap and quickselect)
//! * [`compute`]  — dense + sparse attention, separate-gather vs fused
//!   (the Fig. 9 'FusedAttn' ablation axis)
//! * [`methods`]  — one [`Selector`] per paper baseline
//!
//! ## Scratch ownership in the batched decode path
//!
//! Selection buffers ([`Scratch`]) are *worker-thread arenas*: the engine
//! keeps one per threadpool worker and lends it to whichever
//! (sequence, kv-head) work item that worker picks up. Every routine that
//! reads a scratch buffer fully overwrites the prefix it reads first
//! (`clear()`/`resize()` + full write), so results never depend on which
//! worker — or which previous item — last touched the arena; this is the
//! invariant that makes `threads = N` byte-identical to `threads = 1`.
//! Per-sequence state that must survive a step ([`MethodState`]) is owned
//! by the sequence and handed to items as disjoint `&mut`, never shared.
//! [`Selector`] implementations are required to be `Send + Sync`
//! (stateless policy objects) so one instance can serve all workers.

pub mod compute;
pub mod hamming;
pub mod hashenc;
pub mod methods;
pub mod topk;

/// Everything a selector may look at for one (layer, kv-head) decode step.
///
/// `q` holds the `group` query-head rows sharing this KV head (GQA scores
/// are aggregated over them, paper Sec 3.2); `k`/`v` are the full per-head
/// caches; `codes` is the packed key-code cache (HATA) and `pos` the
/// current absolute position (== s - 1 at decode time).
pub struct AttnInputs<'a> {
    pub q: &'a [f32],
    pub group: usize,
    pub dh: usize,
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub codes: &'a [u64],
    pub words: usize,
    pub rbit: usize,
    pub s: usize,
    pub pos: usize,
    /// Method-specific side structures maintained by the KV cache.
    pub side: Side<'a>,
}

/// Borrowed views of the per-(layer, kv-head) side structures each
/// baseline needs; empty slices when the method is not in use.
#[derive(Clone, Copy, Default)]
pub struct Side<'a> {
    /// HATA: trained hash weights [dh, rbit] row-major for this head.
    pub hash_w: &'a [f32],
    /// Quest: per-block elementwise min/max of keys, [nblocks, dh] each.
    pub quest_min: &'a [f32],
    pub quest_max: &'a [f32],
    pub quest_block: usize,
    /// Loki: PCA-projected keys [s, channels] and the projection matrix
    /// [dh, channels] used to project the query at step time.
    pub loki_kproj: &'a [f32],
    pub loki_pca: &'a [f32],
    pub loki_channels: usize,
    /// MagicPIG: per-token LSH table signatures [s, L] and the random
    /// hyperplanes [L * K, dh] shared by queries.
    pub mp_sigs: &'a [u16],
    pub mp_planes: &'a [f32],
    pub mp_k: usize,
    pub mp_l: usize,
}

impl<'a> AttnInputs<'a> {
    pub fn q_row(&self, g: usize) -> &'a [f32] {
        &self.q[g * self.dh..(g + 1) * self.dh]
    }

    pub fn k_row(&self, t: usize) -> &'a [f32] {
        &self.k[t * self.dh..(t + 1) * self.dh]
    }

    pub fn code_row(&self, t: usize) -> &'a [u64] {
        &self.codes[t * self.words..(t + 1) * self.words]
    }
}

/// Reusable per-thread scratch so the decode loop never allocates.
#[derive(Default)]
pub struct Scratch {
    pub scores: Vec<f32>,
    pub iscores: Vec<i32>,
    pub indices: Vec<u32>,
    pub probs: Vec<f32>,
    pub qcodes: Vec<u64>,
    pub fbuf: Vec<f32>,
}

/// Per-sequence, per-(layer, kv-head) method state that outlives a step
/// (H2O cumulative scores, SnapKV prefill selection; Quest block metadata
/// lives in the kv cache instead since it is append-maintained).
#[derive(Clone, Debug, Default)]
pub struct MethodState {
    /// H2O: cumulative attention mass per cached token.
    pub h2o_cum: Vec<f32>,
    /// SnapKV: token set chosen from the observation window at prefill.
    pub snapkv_keep: Vec<u32>,
}

/// A token-selection policy for sparse attention.
///
/// `Send + Sync` is a supertrait: one selector instance is shared by all
/// threadpool workers during a batched step, so implementations must be
/// stateless policy objects (all per-sequence state lives in
/// [`MethodState`], all transient buffers in the per-worker [`Scratch`]).
pub trait Selector: Send + Sync {
    /// Write the selected token indices for this step into
    /// `scratch.indices` (any order, no duplicates, all `< inputs.s`).
    fn select(
        &self,
        inputs: &AttnInputs,
        state: &mut MethodState,
        budget: usize,
        scratch: &mut Scratch,
    );

    fn name(&self) -> &'static str;

    /// Bytes this selector reads per cached token at score time — drives
    /// the memory-traffic model (simulator/hbm.rs).
    fn score_bytes_per_token(&self, dh: usize, rbit: usize) -> usize;
}
