//! L3 serving coordinator: the vLLM-router-shaped layer that owns request
//! lifecycle, continuous batching, the prefill/decode scheduler, KV
//! admission, and metrics. Python never appears on this path.
//!
//! * [`request`]  — request/response types and lifecycle states
//! * [`scheduler`]— admission + prefill-chunk/decode interleaving policy
//! * [`engine`]   — the step loop driving the native model
//! * [`router`]   — multi-worker front door (round-robin / least-loaded)
//!   with a `--max-concurrent` admission semaphore
//! * [`stream`]   — channel-backed per-token [`stream::ResponseStream`]
//!   handles for streaming callers
//! * [`metrics`]  — latency histograms (TTFT/TPOT/queue depth),
//!   throughput counters
//!
//! ## Batched-step data flow (`serve.threads`)
//!
//! Each engine step turns the scheduler's [`scheduler::StepPlan`] — a
//! batch structure of [`scheduler::DecodeWork`] (id + token position) and
//! [`scheduler::PrefillWork`] (id + chunk range + finality + attention
//! tile geometry) — into disjoint-`&mut` work items and hands them to
//! the model's batched entry points, which fan them across the engine's
//! threadpool:
//!
//! * prefill chunks advance as **token blocks**: per layer, (sequence,
//!   tile) projection/MLP items and (sequence, kv-head, query-tile)
//!   causally-masked attention items run on pool workers — the chunk is
//!   no longer serial inside;
//! * decode parallelizes at **(sequence, kv-head)** granularity within
//!   each layer — hash encode/append, Hamming scoring, top-k select and
//!   sparse attend all run on pool workers.
//!
//! Ownership: the engine keeps one `DecodeScratch` per batch slot
//! (sequence activations + prefill block arenas + logits, read back for
//! sampling) and one `WorkerScratch` per pool worker (selection buffers
//! + tile temporaries). KV writes are disjoint per (layer, head) region
//! (`SeqKvCache::layer_heads_mut`), so no lock sits on the decode hot
//! path, and `threads = N` produces byte-identical token streams to
//! `threads = 1` — prefill tiling included.

pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod stream;
