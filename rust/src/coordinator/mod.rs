//! L3 serving coordinator: the vLLM-router-shaped layer that owns request
//! lifecycle, continuous batching, the prefill/decode scheduler, KV
//! admission, and metrics. Python never appears on this path.
//!
//! * [`request`]  — request/response types and lifecycle states
//! * [`scheduler`]— admission + prefill-chunk/decode interleaving policy
//! * [`engine`]   — the step loop driving the native model
//! * [`router`]   — multi-worker front door (round-robin / least-loaded)
//! * [`metrics`]  — latency histograms, throughput counters

pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
