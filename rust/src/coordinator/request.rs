//! Request lifecycle types.

/// A generation request as submitted by a client.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen unique id (echoed in the response).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<u32>,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// stop generation at this token (e.g. b'\n') if Some
    pub stop_token: Option<u32>,
    /// submission timestamp (engine clock, seconds)
    pub arrival: f64,
}

/// Where a request is in its life.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// waiting for admission (KV pool or batch slots full)
    Queued,
    /// prompt tokens being prefilled (chunked)
    Prefilling,
    /// decoding one token per engine step
    Decoding,
    /// done (completed, stopped, or cancelled)
    Finished(FinishReason),
}

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new_tokens` generated.
    MaxTokens,
    /// The configured stop token was produced.
    StopToken,
    /// Cancelled by the client.
    Cancelled,
    /// evicted under memory pressure and not retried
    Preempted,
}

/// Completed response with timing milestones.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// Generated token ids.
    pub tokens: Vec<u32>,
    /// Why generation stopped.
    pub reason: FinishReason,
    /// seconds from arrival to first generated token
    pub ttft: f64,
    /// seconds from arrival to completion
    pub total_time: f64,
    /// Prompt length (throughput accounting).
    pub prompt_len: usize,
}

impl Response {
    /// Decode throughput in tokens/second (excludes prefill time).
    pub fn decode_tps(&self) -> f64 {
        let decode_time = self.total_time - self.ttft;
        if decode_time <= 0.0 || self.tokens.len() <= 1 {
            return f64::NAN;
        }
        (self.tokens.len() - 1) as f64 / decode_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_tps_math() {
        let r = Response {
            id: 1,
            tokens: vec![1; 11],
            reason: FinishReason::MaxTokens,
            ttft: 1.0,
            total_time: 2.0,
            prompt_len: 4,
        };
        assert!((r.decode_tps() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_tps_is_nan() {
        let r = Response {
            id: 1,
            tokens: vec![1],
            reason: FinishReason::StopToken,
            ttft: 1.0,
            total_time: 1.0,
            prompt_len: 4,
        };
        assert!(r.decode_tps().is_nan());
    }
}
