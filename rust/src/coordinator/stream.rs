//! Per-token response streaming: the channel-backed handle a caller
//! holds while the engine generates (text-generation-inference's
//! `infer` shape, scaled to one process).
//!
//! The engine owns a [`StreamSender`] inside the live sequence and
//! pushes a [`StreamEvent::Token`] at every token-commit point of the
//! step loop, then [`StreamEvent::Done`] with the full
//! [`Response`] when the request finishes (including stall-recovery
//! preemptions — a stream always terminates). The caller side is a
//! plain mpsc receiver: poll it with [`ResponseStream::try_recv`] from
//! an open-loop client, block on [`ResponseStream::recv`], or collect
//! everything with [`ResponseStream::wait`]. A hung-up caller never
//! stalls the engine: sends to a dropped receiver are ignored.

use std::sync::mpsc::{channel, Receiver, Sender};

use super::request::Response;

/// One event on a request's token stream.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// A newly generated token, streamed at its commit point.
    Token {
        /// the generated token id
        token: u32,
        /// 0-based index within the request's generated output
        index: usize,
    },
    /// Generation finished; carries the full response (its `tokens`
    /// repeat everything streamed, so late consumers need no replay).
    Done(Response),
}

/// Engine-side sending half of a request's stream.
#[derive(Clone)]
pub struct StreamSender {
    tx: Sender<StreamEvent>,
}

impl StreamSender {
    /// Emit one generated token (a hung-up caller is ignored).
    pub fn send_token(&self, token: u32, index: usize) {
        let _ = self.tx.send(StreamEvent::Token { token, index });
    }

    /// Emit the terminal event (a hung-up caller is ignored).
    pub fn finish(&self, resp: Response) {
        let _ = self.tx.send(StreamEvent::Done(resp));
    }
}

/// Caller-side handle: the live token stream of one request.
pub struct ResponseStream {
    id: u64,
    rx: Receiver<StreamEvent>,
}

/// Everything a fully drained [`ResponseStream`] produced.
pub struct StreamOutcome {
    /// Tokens in streamed order.
    pub tokens: Vec<u32>,
    /// The terminal response; `None` only if the engine was torn down
    /// mid-request (sender dropped without a [`StreamEvent::Done`]).
    pub response: Option<Response>,
}

impl ResponseStream {
    /// A connected (stream, sender) pair for request `id`.
    pub fn channel(id: u64) -> (ResponseStream, StreamSender) {
        let (tx, rx) = channel();
        (ResponseStream { id, rx }, StreamSender { tx })
    }

    /// The request id this stream belongs to.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` once the stream is exhausted
    /// and the engine has dropped its sender.
    pub fn recv(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll: `None` when no event is ready right now (or
    /// the stream is exhausted) — the open-loop client's primitive.
    pub fn try_recv(&self) -> Option<StreamEvent> {
        self.rx.try_recv().ok()
    }

    /// Drain the stream to completion: collect every streamed token and
    /// the terminal response. Returns as soon as `Done` arrives (or the
    /// sender is dropped), so it never outwaits a finished request.
    pub fn wait(self) -> StreamOutcome {
        let mut tokens = Vec::new();
        let mut response = None;
        while let Ok(ev) = self.rx.recv() {
            match ev {
                StreamEvent::Token { token, .. } => tokens.push(token),
                StreamEvent::Done(r) => {
                    response = Some(r);
                    break;
                }
            }
        }
        StreamOutcome { tokens, response }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    fn resp(id: u64, tokens: Vec<u32>) -> Response {
        Response {
            id,
            prompt_len: 4,
            tokens,
            reason: FinishReason::MaxTokens,
            ttft: 0.0,
            total_time: 0.0,
        }
    }

    #[test]
    fn wait_collects_tokens_and_terminal_response() {
        let (stream, tx) = ResponseStream::channel(7);
        tx.send_token(10, 0);
        tx.send_token(11, 1);
        tx.finish(resp(7, vec![10, 11]));
        let out = stream.wait();
        assert_eq!(out.tokens, vec![10, 11]);
        let r = out.response.expect("terminal event");
        assert_eq!(r.id, 7);
        assert_eq!(r.tokens, out.tokens, "Done must repeat the streamed tokens");
    }

    #[test]
    fn dropped_sender_terminates_wait_without_response() {
        let (stream, tx) = ResponseStream::channel(1);
        tx.send_token(5, 0);
        drop(tx);
        let out = stream.wait();
        assert_eq!(out.tokens, vec![5]);
        assert!(out.response.is_none());
    }

    #[test]
    fn dropped_receiver_never_errors_the_sender() {
        let (stream, tx) = ResponseStream::channel(2);
        assert_eq!(stream.id(), 2);
        drop(stream);
        tx.send_token(1, 0); // must not panic
        tx.finish(resp(2, vec![1]));
    }

    #[test]
    fn try_recv_is_nonblocking() {
        let (stream, tx) = ResponseStream::channel(3);
        assert!(stream.try_recv().is_none());
        tx.send_token(9, 0);
        assert!(matches!(stream.try_recv(), Some(StreamEvent::Token { token: 9, index: 0 })));
        assert!(stream.try_recv().is_none());
    }
}
