//! Prefill/decode scheduler with continuous batching.
//!
//! Policy (decode-first, chunked prefill — the shape Orca/vLLM converged
//! on and the one the paper's serving experiments assume):
//!   1. all Decoding sequences advance one token per engine step;
//!   2. leftover step budget (`prefill_chunk` tokens) goes to the oldest
//!      Prefilling sequence, admitted only while the KV pool has room;
//!   3. Queued requests are admitted FCFS when a batch slot + KV pages
//!      are available.
//!
//! The plan is a *batch structure*, not id lists: each [`DecodeWork`]
//! carries the absolute token position and each [`PrefillWork`] its chunk
//! range, finality and attention tile geometry, so the engine can build
//! the whole step's work up front — under `--exec queue`, one dependency
//! task graph per batch (`crate::util::workqueue`); under `--exec
//! barrier`, per-stage scatter vectors — without re-deriving per-sequence
//! state mid-step.

use std::collections::VecDeque;

use crate::config::ServeConfig;
use crate::kvcache::pool::KvPool;

/// Scheduler's view of one live sequence.
#[derive(Clone, Debug)]
pub struct SeqTicket {
    /// Sequence id (request id).
    pub id: u64,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Prompt tokens already prefilled.
    pub prefilled: usize,
    /// Tokens generated so far.
    pub generated: usize,
    /// Generation budget (`max_new_tokens`).
    pub max_new: usize,
}

impl SeqTicket {
    /// Whole prompt is in the KV cache; the sequence can decode.
    pub fn is_prefill_done(&self) -> bool {
        self.prefilled >= self.prompt_len
    }
}

/// One decode slot of a step batch: feed the sampled token at `pos`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecodeWork {
    /// Sequence id.
    pub id: u64,
    /// Absolute position of the token being fed (prompt_len + generated).
    pub pos: usize,
}

/// One prefill chunk of a step batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrefillWork {
    /// Sequence id.
    pub id: u64,
    /// Prompt token range this chunk covers.
    pub range: std::ops::Range<usize>,
    /// This chunk completes the prompt (the sequence becomes decodable).
    pub is_final: bool,
    /// Query rows per attention tile when the engine fans this chunk's
    /// block pass across the threadpool (`serve.prefill_tile`). Tile
    /// geometry travels with the work order so the engine never
    /// re-derives per-chunk state mid-step.
    pub tile: usize,
}

/// One engine step's work order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepPlan {
    /// sequences that decode one token this step
    pub decode: Vec<DecodeWork>,
    /// prefill chunks this step
    pub prefill: Vec<PrefillWork>,
    /// requests admitted from the queue this step
    pub admitted: Vec<u64>,
}

/// FCFS admission + decode-first step planning.
pub struct Scheduler {
    queue: VecDeque<SeqTicket>,
    live: Vec<SeqTicket>,
    max_batch: usize,
    prefill_chunk: usize,
    prefill_tile: usize,
    waiting_served_ratio: f64,
}

impl Scheduler {
    /// Scheduler for `serve`'s batch/chunk/tile policy knobs.
    pub fn new(serve: &ServeConfig) -> Self {
        Scheduler {
            queue: VecDeque::new(),
            live: Vec::new(),
            max_batch: serve.max_batch,
            prefill_chunk: serve.prefill_chunk.max(1),
            prefill_tile: serve.prefill_tile,
            waiting_served_ratio: serve.waiting_served_ratio,
        }
    }

    /// Enqueue a new sequence for FCFS admission.
    pub fn submit(&mut self, ticket: SeqTicket) {
        self.queue.push_back(ticket);
    }

    /// Sequences waiting for admission.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Admitted (prefilling or decoding) sequences.
    pub fn live_len(&self) -> usize {
        self.live.len()
    }

    /// Look up a live sequence's ticket.
    pub fn ticket(&self, id: u64) -> Option<&SeqTicket> {
        self.live.iter().find(|t| t.id == id)
    }

    /// Record one generated token for `id` (engine callback).
    pub fn on_decoded(&mut self, id: u64) {
        if let Some(t) = self.live.iter_mut().find(|t| t.id == id) {
            t.generated += 1;
        }
    }

    /// Record `n` prefilled prompt tokens for `id` (engine callback).
    pub fn on_prefilled(&mut self, id: u64, n: usize) {
        if let Some(t) = self.live.iter_mut().find(|t| t.id == id) {
            t.prefilled += n;
        }
    }

    /// Remove a finished sequence and free its pool pages.
    pub fn finish(&mut self, id: u64, pool: &mut KvPool) {
        self.live.retain(|t| t.id != id);
        let _ = pool.release(id);
    }

    /// Preempt a live sequence: move its ticket to the queue front,
    /// keeping `prefilled`/`generated` progress intact. Pool pages are
    /// deliberately *not* released — block tables make them cheap to
    /// hold, so on re-admission the sequence resumes exactly where it
    /// left off with zero recompute. Returns whether `id` was live.
    pub fn preempt(&mut self, id: u64) -> bool {
        let Some(i) = self.live.iter().position(|t| t.id == id) else {
            return false;
        };
        let t = self.live.remove(i);
        self.queue.push_front(t);
        true
    }

    /// Drop every queued and live ticket (stall recovery); returns the
    /// evicted ids so the engine can release pages and respond.
    pub fn evict_all(&mut self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.queue.drain(..).map(|t| t.id).collect();
        ids.extend(self.live.drain(..).map(|t| t.id));
        ids
    }

    /// Plan the next engine step.
    pub fn plan(&mut self, pool: &mut KvPool) -> StepPlan {
        let mut plan = StepPlan::default();
        self.plan_into(pool, &mut plan);
        plan
    }

    /// Plan the next engine step into a caller-owned [`StepPlan`],
    /// clearing and refilling its work vectors in place. The engine
    /// keeps one plan across steps so steady-state planning reuses the
    /// `DecodeWork`/`PrefillWork` allocations instead of rebuilding
    /// them every token.
    pub fn plan_into(&mut self, pool: &mut KvPool, plan: &mut StepPlan) {
        plan.decode.clear();
        plan.prefill.clear();
        plan.admitted.clear();
        // 1. admit while there is room. Admission *reserves* pages (not
        // just checks), so two candidates can never both pass against
        // the same free pages within one plan — and it charges only the
        // request's next prefill chunk, not the whole prompt: a long
        // prompt streams into the pool chunk by chunk exactly as it
        // prefills. The final chunk's reservation includes the first
        // decode slot so a prompt that fits never finishes prefill
        // unable to emit a token. A preempted sequence keeps its pool
        // pages, so on re-admission the delta beyond what it already
        // holds is usually zero.
        //
        // The waiting/served gate (TGI-style batching policy): while a
        // batch is running, hold admissions until the waiting pool is
        // worth a prefill pass relative to it. ratio 0.0 always admits.
        let gate_open = self.live.is_empty()
            || self.queue.len() as f64 >= self.waiting_served_ratio * self.live.len() as f64;
        while gate_open && self.live.len() < self.max_batch {
            let Some(front) = self.queue.front() else { break };
            let remaining = front.prompt_len - front.prefilled;
            let next_target = if remaining <= self.prefill_chunk {
                front.prompt_len + 1
            } else {
                front.prefilled + self.prefill_chunk
            };
            let delta = next_target.saturating_sub(pool.seq_tokens(front.id));
            if pool.grow(front.id, delta).is_err() {
                break;
            }
            let t = self.queue.pop_front().unwrap();
            plan.admitted.push(t.id);
            self.live.push(t);
        }
        // 2. all fully-prefilled, unfinished sequences decode
        for t in &self.live {
            if t.is_prefill_done() && t.generated < t.max_new {
                plan.decode.push(DecodeWork { id: t.id, pos: t.prompt_len + t.generated });
            }
        }
        // reserve through the token being written (pos + 1 rows); the
        // slot admission pre-reserved makes the first delta zero. A
        // sequence whose reservation fails under pool pressure sits out
        // this step (it stays live and retries next plan)
        plan.decode.retain(|w| {
            let delta = (w.pos + 1).saturating_sub(pool.seq_tokens(w.id));
            pool.grow(w.id, delta).is_ok()
        });
        // 3. chunked prefill for the oldest incomplete prefill; grow
        // only past the tokens admission (or a held preemption) already
        // reserved for this id
        let mut chunk_left = self.prefill_chunk;
        for t in self.live.iter() {
            if chunk_left == 0 {
                break;
            }
            if !t.is_prefill_done() {
                let take = chunk_left.min(t.prompt_len - t.prefilled);
                let delta = (t.prefilled + take).saturating_sub(pool.seq_tokens(t.id));
                if pool.grow(t.id, delta).is_ok() {
                    plan.prefill.push(PrefillWork {
                        id: t.id,
                        range: t.prefilled..t.prefilled + take,
                        is_final: t.prefilled + take >= t.prompt_len,
                        tile: self.prefill_tile,
                    });
                    chunk_left -= take;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::kvcache::pool::PAGE_TOKENS;

    fn mk(id: u64, prompt: usize, max_new: usize) -> SeqTicket {
        SeqTicket { id, prompt_len: prompt, prefilled: 0, generated: 0, max_new }
    }

    fn scheduler(max_batch: usize, chunk: usize) -> Scheduler {
        Scheduler::new(&ServeConfig {
            max_batch,
            prefill_chunk: chunk,
            ..Default::default()
        })
    }

    fn pf(id: u64, range: std::ops::Range<usize>, is_final: bool) -> PrefillWork {
        PrefillWork { id, range, is_final, tile: ServeConfig::default().prefill_tile }
    }

    #[test]
    fn admits_fcfs_until_batch_full() {
        let mut s = scheduler(2, 128);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        for i in 0..4 {
            s.submit(mk(i, 10, 5));
        }
        let plan = s.plan(&mut pool);
        assert_eq!(plan.admitted, vec![0, 1]);
        assert_eq!(s.queue_len(), 2);
        assert_eq!(s.live_len(), 2);
    }

    #[test]
    fn chunked_prefill_progresses_then_decodes() {
        let mut s = scheduler(4, 64);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        s.submit(mk(1, 150, 3));
        let p1 = s.plan(&mut pool);
        assert_eq!(p1.prefill, vec![pf(1, 0..64, false)]);
        s.on_prefilled(1, 64);
        let p2 = s.plan(&mut pool);
        assert_eq!(p2.prefill, vec![pf(1, 64..128, false)]);
        s.on_prefilled(1, 64);
        let p3 = s.plan(&mut pool);
        assert_eq!(p3.prefill, vec![pf(1, 128..150, true)]);
        s.on_prefilled(1, 22);
        let p4 = s.plan(&mut pool);
        assert!(p4.prefill.is_empty());
        assert_eq!(p4.decode, vec![DecodeWork { id: 1, pos: 150 }]);
    }

    #[test]
    fn decode_positions_advance_with_generation() {
        let mut s = scheduler(4, 64);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        s.submit(mk(1, 10, 5));
        let _ = s.plan(&mut pool);
        s.on_prefilled(1, 10);
        let p = s.plan(&mut pool);
        assert_eq!(p.decode, vec![DecodeWork { id: 1, pos: 10 }]);
        s.on_decoded(1);
        let p = s.plan(&mut pool);
        assert_eq!(p.decode, vec![DecodeWork { id: 1, pos: 11 }]);
    }

    #[test]
    fn decode_first_over_new_prefills() {
        let mut s = scheduler(4, 32);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        s.submit(mk(1, 10, 5));
        let _ = s.plan(&mut pool); // admit + prefill chunk
        s.on_prefilled(1, 10);
        s.submit(mk(2, 40, 5));
        let plan = s.plan(&mut pool);
        assert_eq!(plan.decode, vec![DecodeWork { id: 1, pos: 10 }]);
        assert_eq!(plan.prefill.len(), 1);
        assert_eq!(plan.prefill[0].id, 2);
        assert!(!plan.prefill[0].is_final);
    }

    #[test]
    fn admission_blocked_by_pool_pressure() {
        let mut s = scheduler(8, 128);
        // tiny pool: 2 pages
        let mut pool = KvPool::new(2 * PAGE_TOKENS);
        s.submit(mk(1, PAGE_TOKENS, 4));
        s.submit(mk(2, 4 * PAGE_TOKENS, 4)); // cannot ever fit
        let plan = s.plan(&mut pool);
        assert_eq!(plan.admitted, vec![1]);
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn finish_releases_and_stops_decoding() {
        let mut s = scheduler(2, 64);
        let mut pool = KvPool::new(10 * PAGE_TOKENS);
        s.submit(mk(1, 8, 2));
        let _ = s.plan(&mut pool);
        s.on_prefilled(1, 8);
        let p = s.plan(&mut pool);
        assert_eq!(p.decode, vec![DecodeWork { id: 1, pos: 8 }]);
        s.on_decoded(1);
        s.on_decoded(1);
        // generated == max_new -> no more decode
        let p = s.plan(&mut pool);
        assert!(p.decode.is_empty());
        s.finish(1, &mut pool);
        assert_eq!(s.live_len(), 0);
        assert_eq!(pool.active_seqs(), 0);
    }

    #[test]
    fn plan_into_reuse_matches_fresh_plans() {
        // the engine's recycled StepPlan must see exactly what a fresh
        // plan() would produce, step after step
        let mut fresh = scheduler(4, 64);
        let mut reusing = scheduler(4, 64);
        let mut pool_a = KvPool::new(100 * PAGE_TOKENS);
        let mut pool_b = KvPool::new(100 * PAGE_TOKENS);
        for s in [&mut fresh, &mut reusing] {
            s.submit(mk(1, 150, 2));
            s.submit(mk(2, 40, 2));
        }
        let mut plan = StepPlan::default();
        for _ in 0..6 {
            let want = fresh.plan(&mut pool_a);
            reusing.plan_into(&mut pool_b, &mut plan);
            assert_eq!(want, plan);
            for w in &want.prefill {
                fresh.on_prefilled(w.id, w.range.len());
                reusing.on_prefilled(w.id, w.range.len());
            }
            for w in &want.decode {
                fresh.on_decoded(w.id);
                reusing.on_decoded(w.id);
            }
        }
    }

    #[test]
    fn preempt_keeps_pages_and_resumes_without_recompute() {
        let mut s = scheduler(4, 64);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        s.submit(mk(1, 150, 3));
        let _ = s.plan(&mut pool); // admit + first chunk (0..64)
        s.on_prefilled(1, 64);
        let held = pool.seq_tokens(1);
        assert!(held >= 64);
        assert!(s.preempt(1));
        assert_eq!(s.live_len(), 0);
        assert_eq!(s.queue_len(), 1);
        // pages retained across preemption
        assert_eq!(pool.seq_tokens(1), held);
        assert!(!s.preempt(1)); // not live anymore
        // re-admission resumes from the retained prefill offset
        let plan = s.plan(&mut pool);
        assert_eq!(plan.admitted, vec![1]);
        assert_eq!(plan.prefill, vec![pf(1, 64..128, false)]);
    }

    #[test]
    fn preempted_seq_readmits_with_remaining_need_only() {
        // pool with 3 pages; prompt needs 2 pages (+1 token for decode)
        let mut s = scheduler(4, 4 * PAGE_TOKENS);
        let mut pool = KvPool::new(3 * PAGE_TOKENS);
        s.submit(mk(1, 2 * PAGE_TOKENS, 2));
        let _ = s.plan(&mut pool); // admits + prefills whole prompt
        s.on_prefilled(1, 2 * PAGE_TOKENS);
        assert!(s.preempt(1));
        // a fresh sequence asking for 2*PAGE_TOKENS+1 could not fit in
        // the 1 remaining free page, but seq 1 already holds its pages:
        // admission only needs the +1 decode token
        let plan = s.plan(&mut pool);
        assert_eq!(plan.admitted, vec![1]);
        assert_eq!(plan.decode, vec![DecodeWork { id: 1, pos: 2 * PAGE_TOKENS }]);
    }

    #[test]
    fn decode_sits_out_when_pool_exhausted() {
        let mut s = scheduler(4, 64);
        let mut pool = KvPool::new(PAGE_TOKENS);
        s.submit(mk(1, PAGE_TOKENS - 1, 4));
        let _ = s.plan(&mut pool);
        s.on_prefilled(1, PAGE_TOKENS - 1);
        // first decode token still fits in the last slot of the page
        let p = s.plan(&mut pool);
        assert_eq!(p.decode.len(), 1);
        s.on_decoded(1);
        // next token would need a second page; the pool has none, so the
        // sequence sits out instead of decoding without a reservation
        let p = s.plan(&mut pool);
        assert!(p.decode.is_empty());
        assert_eq!(s.live_len(), 1);
    }

    #[test]
    fn chunked_admission_charges_only_next_chunk() {
        // a long prompt must not reserve its whole length at admission:
        // only the first prefill chunk's pages are charged, and each
        // later chunk pays as it runs (the --paged composition rule:
        // chunked admission charges only the next chunk's pages)
        let mut s = scheduler(4, 64);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        s.submit(mk(1, 300, 2));
        let plan = s.plan(&mut pool);
        assert_eq!(plan.admitted, vec![1]);
        assert_eq!(pool.seq_tokens(1), 64, "admission charged beyond the first chunk");
        s.on_prefilled(1, 64);
        let _ = s.plan(&mut pool);
        assert_eq!(pool.seq_tokens(1), 128, "second chunk pays for itself only");
    }

    #[test]
    fn final_chunk_admission_reserves_decode_slot() {
        // a prompt that fits in one chunk reserves prompt+1 tokens, so
        // finishing prefill can always emit the first token without a
        // fresh reservation racing other admissions
        let mut s = scheduler(4, 512);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        s.submit(mk(1, 10, 4));
        let _ = s.plan(&mut pool);
        assert_eq!(pool.seq_tokens(1), 11);
        s.on_prefilled(1, 10);
        // the pre-reserved slot makes the first decode's delta zero
        let free_before = pool.free_pages();
        let p = s.plan(&mut pool);
        assert_eq!(p.decode.len(), 1);
        assert_eq!(pool.free_pages(), free_before, "first decode re-charged its slot");
    }

    #[test]
    fn admission_cannot_overcommit_within_one_plan() {
        // two queued prompts that each fit alone but not together: the
        // reserve-at-admit rule must admit exactly one, never both
        let mut s = scheduler(8, 2 * PAGE_TOKENS);
        let mut pool = KvPool::new(2 * PAGE_TOKENS);
        s.submit(mk(1, 2 * PAGE_TOKENS - 1, 2));
        s.submit(mk(2, 2 * PAGE_TOKENS - 1, 2));
        let plan = s.plan(&mut pool);
        assert_eq!(plan.admitted, vec![1]);
        assert_eq!(s.queue_len(), 1);
        assert_eq!(pool.free_pages(), 0);
    }

    #[test]
    fn waiting_served_ratio_defers_admission_until_worth_it() {
        let mut s = Scheduler::new(&ServeConfig {
            max_batch: 8,
            prefill_chunk: 64,
            waiting_served_ratio: 2.0,
            ..Default::default()
        });
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        // an empty engine always admits (nothing to amortize against)
        s.submit(mk(1, 8, 4));
        let p = s.plan(&mut pool);
        assert_eq!(p.admitted, vec![1]);
        s.on_prefilled(1, 8);
        // one waiter against one running seq: 1 < 2.0 * 1, deferred
        s.submit(mk(2, 8, 4));
        let p = s.plan(&mut pool);
        assert!(p.admitted.is_empty(), "gate must defer a lone waiter");
        assert_eq!(p.decode.len(), 1, "running decode is never held up");
        // a second waiter tips the ratio: 2 >= 2.0 * 1, both admitted
        s.submit(mk(3, 8, 4));
        let p = s.plan(&mut pool);
        assert_eq!(p.admitted, vec![2, 3]);
    }

    #[test]
    fn evict_all_drains_queue_and_live() {
        let mut s = scheduler(1, 64);
        let mut pool = KvPool::new(100 * PAGE_TOKENS);
        s.submit(mk(1, 8, 2));
        s.submit(mk(2, 8, 2)); // stays queued (max_batch = 1)
        let _ = s.plan(&mut pool);
        let mut ids = s.evict_all();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(s.queue_len(), 0);
        assert_eq!(s.live_len(), 0);
    }
}
