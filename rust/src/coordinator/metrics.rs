//! Serving metrics: TTFT / per-token latency histograms, throughput,
//! and the work-queue executor's per-stage (decode vs prefill)
//! busy/idle counters.

use crate::kvcache::tier::OffloadStats;
use crate::util::stats::{LatencyHistogram, Summary};
use crate::util::workqueue::QueueStats;

/// Engine counters and latency histograms, updated every step.
#[derive(Default)]
pub struct Metrics {
    /// Time-to-first-token distribution.
    pub ttft: LatencyHistogram,
    /// Inter-token (TPOT) latency distribution: the gap between
    /// consecutive token commits of the same request, from the second
    /// generated token on.
    pub tpot: LatencyHistogram,
    /// Scheduler queue depth sampled at every step (waiting requests,
    /// live batch excluded) — the admission backlog the serve SLO sees.
    pub queue_depth: Summary,
    /// Engine step latency distribution.
    pub step_latency: LatencyHistogram,
    /// Per-request completion times.
    pub per_request: Summary,
    /// Prompt tokens of completed requests.
    pub prompt_tokens: u64,
    /// Tokens decoded across all steps.
    pub generated_tokens: u64,
    /// Requests finished (any reason but preemption).
    pub completed: u64,
    /// Requests evicted by stall recovery.
    pub preempted: u64,
    /// stall events: the engine detected zero progress for consecutive
    /// steps and preempted the stuck work (see `Engine::run_to_completion`)
    pub stalls: u64,
    /// Prompt tokens actually run through prefill. Under preempt/resume
    /// this stays equal to the sum of prompt lengths — held pages mean
    /// resumed sequences never recompute a chunk.
    pub prefill_tokens: u64,
    /// Prompt blocks deduplicated against another sequence's identical
    /// prefix (`--paged` copy-on-write sharing): each hit is one
    /// physical block stored once instead of twice.
    pub prefix_hits: u64,
    /// Work-queue executor counters for the decode stage (`--exec
    /// queue`; stays zero under `--exec barrier`). `idle_waits` high
    /// relative to `tasks` means workers starve — batch too small for
    /// the thread count.
    pub decode_exec: QueueStats,
    /// Work-queue executor counters for the prefill stage.
    pub prefill_exec: QueueStats,
    /// Whether the engine runs the paged KV layout: gates the `paged[..]`
    /// report section so `prefill_tokens` shows for every paged run, not
    /// only the ones that happened to share a prefix.
    pub paged_active: bool,
    /// Residency-tier counters, present when `--offload` is active; the
    /// engine refreshes this snapshot from the tier controller each step.
    pub offload: Option<OffloadStats>,
    started_at: Option<std::time::Instant>,
}

impl Metrics {
    /// Fresh metrics with the wall clock started now.
    pub fn new() -> Self {
        Metrics { started_at: Some(std::time::Instant::now()), ..Default::default() }
    }

    /// Record one engine step.
    pub fn on_step(&mut self, seconds: f64, decoded: usize) {
        self.step_latency.record(seconds);
        self.generated_tokens += decoded as u64;
    }

    /// Record a request's first generated token.
    pub fn on_first_token(&mut self, ttft: f64) {
        self.ttft.record(ttft);
    }

    /// Record one inter-token gap (TPOT sample) of a running request.
    pub fn on_inter_token(&mut self, gap: f64) {
        self.tpot.record(gap);
    }

    /// Record the scheduler's waiting-queue depth at a step boundary.
    pub fn on_queue_depth(&mut self, depth: usize) {
        self.queue_depth.add(depth as f64);
    }

    /// Record a request completion.
    pub fn on_complete(&mut self, total_time: f64, prompt_len: usize) {
        self.completed += 1;
        self.prompt_tokens += prompt_len as u64;
        self.per_request.add(total_time);
    }

    /// Record an engine stall that preempted `preempted` requests.
    pub fn on_stall(&mut self, preempted: usize) {
        self.stalls += 1;
        self.preempted += preempted as u64;
    }

    /// Accumulate one decode batch's work-queue executor counters.
    pub fn on_decode_exec(&mut self, s: QueueStats) {
        self.decode_exec.merge(s);
    }

    /// Accumulate one prefill batch's work-queue executor counters.
    pub fn on_prefill_exec(&mut self, s: QueueStats) {
        self.prefill_exec.merge(s);
    }

    /// Seconds since [`Metrics::new`].
    pub fn elapsed(&self) -> f64 {
        self.started_at.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    /// Generated tokens per wall-clock second.
    pub fn decode_throughput(&self) -> f64 {
        let e = self.elapsed();
        if e > 0.0 {
            self.generated_tokens as f64 / e
        } else {
            f64::NAN
        }
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        let mut line = format!(
            "completed={} gen_tokens={} prompt_tokens={} tput={:.1} tok/s \
             step p50={:.3}ms p99={:.3}ms ttft p50={:.1}ms p99={:.1}ms stalls={} preempted={}",
            self.completed,
            self.generated_tokens,
            self.prompt_tokens,
            self.decode_throughput(),
            self.step_latency.quantile(0.5) * 1e3,
            self.step_latency.quantile(0.99) * 1e3,
            self.ttft.quantile(0.5) * 1e3,
            self.ttft.quantile(0.99) * 1e3,
            self.stalls,
            self.preempted,
        );
        if self.tpot.count() > 0 {
            line.push_str(&format!(
                " tpot[p50={:.3}ms p99={:.3}ms]",
                self.tpot.quantile(0.5) * 1e3,
                self.tpot.quantile(0.99) * 1e3
            ));
        }
        if self.queue_depth.count() > 0 {
            line.push_str(&format!(
                " queue[mean={:.1} max={:.0}]",
                self.queue_depth.mean(),
                self.queue_depth.max()
            ));
        }
        for (stage, s) in [("decode", &self.decode_exec), ("prefill", &self.prefill_exec)] {
            if s.runs > 0 {
                line.push_str(&format!(
                    " q_{stage}[runs={} tasks={} idle_waits={}]",
                    s.runs, s.tasks, s.idle_waits
                ));
            }
        }
        // decode graph cache effectiveness: builds should plateau while
        // hits keep growing once the batch composition settles
        let d = &self.decode_exec;
        if d.graph_builds + d.graph_hits > 0 {
            line.push_str(&format!(
                " graph_cache[builds={} hits={}]",
                d.graph_builds, d.graph_hits
            ));
        }
        // paged-cache section whenever the paged layout is active, even
        // with zero sharing — prefill_tokens is meaningful either way
        if self.paged_active {
            line.push_str(&format!(
                " paged[prefix_hits={} prefill_tokens={}]",
                self.prefix_hits, self.prefill_tokens
            ));
        }
        if let Some(o) = &self.offload {
            line.push_str(&format!(
                " offload[fetch={} prefetch={} hit={} evict={} fetch_MB={:.2} \
                 model_s={:.4} wall_s={:.4}]",
                o.demand_fetches,
                o.prefetch_fetches,
                o.hits,
                o.evictions,
                o.fetch.bytes as f64 / 1e6,
                o.fetch.seconds,
                o.measured_fetch_s,
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.on_step(0.001, 4);
        m.on_step(0.002, 4);
        m.on_first_token(0.5);
        m.on_complete(1.0, 32);
        assert_eq!(m.generated_tokens, 8);
        assert_eq!(m.completed, 1);
        assert_eq!(m.prompt_tokens, 32);
        assert!(m.report().contains("completed=1"));
    }

    #[test]
    fn tpot_and_queue_sections_gated_on_samples() {
        let mut m = Metrics::new();
        let r = m.report();
        assert!(!r.contains("tpot["), "no inter-token samples yet: {r}");
        assert!(!r.contains("queue["), "no queue samples yet: {r}");
        m.on_inter_token(0.004);
        m.on_inter_token(0.004);
        m.on_queue_depth(3);
        m.on_queue_depth(7);
        assert_eq!(m.tpot.count(), 2);
        assert_eq!(m.queue_depth.count(), 2);
        let r = m.report();
        assert!(r.contains("tpot[p50="), "{r}");
        assert!(r.contains("queue[mean=5.0 max=7]"), "{r}");
    }

    #[test]
    fn paged_section_gated_on_mode_not_hits() {
        let mut m = Metrics::new();
        m.prefill_tokens = 256;
        m.prefix_hits = 3;
        assert!(!m.report().contains("paged["), "contiguous run never shows paged[]");
        m.paged_active = true;
        let r = m.report();
        assert!(r.contains("paged[prefix_hits=3 prefill_tokens=256]"), "{r}");
        // a paged run with zero sharing still reports its prefill tokens
        m.prefix_hits = 0;
        let r = m.report();
        assert!(r.contains("paged[prefix_hits=0 prefill_tokens=256]"), "{r}");
    }

    #[test]
    fn offload_section_reports_tier_counters() {
        let mut m = Metrics::new();
        assert!(!m.report().contains("offload["), "no tier yet");
        m.offload = Some(OffloadStats {
            demand_fetches: 5,
            prefetch_fetches: 2,
            hits: 40,
            evictions: 3,
            ..Default::default()
        });
        let r = m.report();
        assert!(r.contains("offload[fetch=5 prefetch=2 hit=40 evict=3"), "{r}");
    }

    #[test]
    fn queue_counters_accumulate_and_report() {
        let mut m = Metrics::new();
        assert!(!m.report().contains("q_decode"), "no queue runs yet");
        assert!(!m.report().contains("graph_cache"), "no graph runs yet");
        m.on_decode_exec(QueueStats {
            runs: 1,
            inline_runs: 0,
            tasks: 13,
            idle_waits: 2,
            graph_builds: 1,
            graph_hits: 0,
        });
        m.on_decode_exec(QueueStats {
            runs: 1,
            inline_runs: 1,
            tasks: 7,
            idle_waits: 0,
            graph_builds: 0,
            graph_hits: 1,
        });
        m.on_prefill_exec(QueueStats {
            runs: 1,
            inline_runs: 0,
            tasks: 40,
            idle_waits: 5,
            ..Default::default()
        });
        assert_eq!(m.decode_exec.tasks, 20);
        assert_eq!(m.decode_exec.runs, 2);
        assert_eq!(m.prefill_exec.idle_waits, 5);
        let r = m.report();
        assert!(r.contains("q_decode[runs=2 tasks=20 idle_waits=2]"), "{r}");
        assert!(r.contains("q_prefill[runs=1 tasks=40 idle_waits=5]"), "{r}");
        assert!(r.contains("graph_cache[builds=1 hits=1]"), "{r}");
    }
}
