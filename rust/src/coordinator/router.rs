//! Multi-worker router: the front door that shards requests across
//! engine worker threads (vllm-project/router shape, scaled to one node).
//!
//! Each worker thread owns an [`super::engine::Engine`]; the router picks
//! a worker per request (round-robin or least-loaded by outstanding
//! count), forwards over an mpsc channel, and funnels responses back.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::config::ServeConfig;
use crate::model::Model;

use super::engine::Engine;
use super::request::{Request, Response};

/// Worker-selection policy for incoming requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Cycle through workers in order.
    RoundRobin,
    /// Pick the worker with the fewest outstanding requests.
    LeastLoaded,
}

enum Msg {
    Req(Request),
    Drain,
}

/// Router owning N worker threads.
pub struct Router {
    txs: Vec<Sender<Msg>>,
    resp_rx: Receiver<Response>,
    outstanding: Vec<Arc<AtomicUsize>>,
    next: usize,
    policy: Policy,
    workers: Vec<std::thread::JoinHandle<()>>,
    in_flight: usize,
}

impl Router {
    /// Spawn `n_workers` engine threads sharing one model.
    pub fn new(model: Arc<Model>, serve: ServeConfig, n_workers: usize, policy: Policy) -> Self {
        let (resp_tx, resp_rx) = channel::<Response>();
        let mut txs = Vec::new();
        let mut outstanding = Vec::new();
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let (tx, rx) = channel::<Msg>();
            let load = Arc::new(AtomicUsize::new(0));
            let resp_tx = resp_tx.clone();
            let model = Arc::clone(&model);
            let serve = serve.clone();
            let load2 = Arc::clone(&load);
            workers.push(std::thread::spawn(move || {
                let mut engine = Engine::new(model, serve);
                loop {
                    // ingest every pending message without blocking while
                    // the engine has work; block when idle
                    let msg = if engine.has_work() {
                        match rx.try_recv() {
                            Ok(m) => Some(m),
                            Err(std::sync::mpsc::TryRecvError::Empty) => None,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
                        }
                    } else {
                        match rx.recv() {
                            Ok(m) => Some(m),
                            Err(_) => break,
                        }
                    };
                    match msg {
                        Some(Msg::Req(r)) => engine.submit(r),
                        Some(Msg::Drain) | None => {}
                    }
                    if engine.has_work() {
                        engine.step();
                        for r in engine.take_responses() {
                            load2.fetch_sub(1, Ordering::SeqCst);
                            let _ = resp_tx.send(r);
                        }
                    }
                }
            }));
            txs.push(tx);
            outstanding.push(load);
        }
        Router { txs, resp_rx, outstanding, next: 0, policy, workers, in_flight: 0 }
    }

    fn pick(&mut self) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                let i = self.next % self.txs.len();
                self.next += 1;
                i
            }
            Policy::LeastLoaded => self
                .outstanding
                .iter()
                .enumerate()
                .min_by_key(|(_, l)| l.load(Ordering::SeqCst))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Route one request to a worker according to the policy.
    pub fn submit(&mut self, req: Request) {
        let i = self.pick();
        self.outstanding[i].fetch_add(1, Ordering::SeqCst);
        self.in_flight += 1;
        self.txs[i].send(Msg::Req(req)).expect("worker alive");
    }

    /// Block until all in-flight requests respond; returns them.
    pub fn drain(&mut self) -> Vec<Response> {
        for tx in &self.txs {
            let _ = tx.send(Msg::Drain);
        }
        let mut out = Vec::with_capacity(self.in_flight);
        while out.len() < self.in_flight {
            out.push(self.resp_rx.recv().expect("worker alive"));
        }
        self.in_flight = 0;
        out
    }

    /// Engine worker threads owned by this router.
    pub fn worker_count(&self) -> usize {
        self.txs.len()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.txs.clear(); // closes channels; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, Method};
    use crate::kvcache::MethodAux;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn model() -> Arc<Model> {
        let cfg = preset("hata-gqa").unwrap();
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        Arc::new(Model::new(cfg, weights, MethodAux::default()))
    }

    fn serve() -> ServeConfig {
        ServeConfig { method: Method::Hata, budget: 16, max_batch: 2, ..Default::default() }
    }

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: (0..30).map(|i| 32 + (i % 64)).collect(),
            max_new_tokens: 3,
            stop_token: None,
            arrival: 0.0,
        }
    }

    #[test]
    fn routes_and_drains_all_requests() {
        let mut router = Router::new(model(), serve(), 2, Policy::RoundRobin);
        for i in 0..8 {
            router.submit(req(i));
        }
        let rs = router.drain();
        assert_eq!(rs.len(), 8);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn least_loaded_policy_works() {
        let mut router = Router::new(model(), serve(), 3, Policy::LeastLoaded);
        for i in 0..9 {
            router.submit(req(i));
        }
        let rs = router.drain();
        assert_eq!(rs.len(), 9);
    }

    #[test]
    fn single_worker_router() {
        let mut router = Router::new(model(), serve(), 1, Policy::RoundRobin);
        router.submit(req(1));
        let rs = router.drain();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens.len(), 3);
    }

    #[test]
    fn drop_joins_workers() {
        let router = Router::new(model(), serve(), 2, Policy::RoundRobin);
        drop(router); // must not hang
    }
}
