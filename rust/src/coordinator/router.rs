//! Multi-worker router: the front door that shards requests across
//! engine worker threads (vllm-project/router shape, scaled to one node).
//!
//! Each worker thread owns an [`super::engine::Engine`]; the router picks
//! a worker per request (round-robin or least-loaded by outstanding
//! count), forwards over an mpsc channel, and funnels responses back.
//! Three serving-path concerns live here:
//!
//! * **Bounded admission** (`--max-concurrent`): an [`Admission`]
//!   semaphore caps requests in flight across all workers. Closed-loop
//!   [`Router::submit`] and streaming [`Router::submit_stream`] block
//!   at the front door when full; open-loop clients use
//!   [`Router::try_submit_stream`] and shed load themselves.
//! * **Per-token streaming**: [`Router::submit_stream`] returns a
//!   [`ResponseStream`] fed by the owning worker's engine at every
//!   token-commit point. Streamed responses bypass the closed-loop
//!   drain channel (their terminal event carries the full response).
//! * **Stall recovery, not busy-spin**: a worker whose engine reports
//!   [`STALL_LIMIT`] consecutive zero-progress steps aborts the stuck
//!   requests ([`Engine::abort_stalled`]) instead of spinning at 100%
//!   CPU forever — which also means [`Router::drain`] always returns.
//!   An idle worker parks on its channel; [`Router::worker_stats`]
//!   exposes step/park counters so tests can prove both properties.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::ServeConfig;
use crate::model::Model;

use super::engine::{Engine, STALL_LIMIT};
use super::request::{Request, Response};
use super::stream::{ResponseStream, StreamSender};

/// Worker-selection policy for incoming requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Cycle through workers in order.
    RoundRobin,
    /// Pick the worker with the fewest outstanding requests.
    LeastLoaded,
}

enum Msg {
    Req(Request, Option<StreamSender>),
    Drain,
}

#[derive(Default)]
struct AdmissionState {
    in_flight: usize,
    peak: usize,
}

/// Counting semaphore over requests in flight across the whole router
/// (`--max-concurrent`). `limit == 0` means unbounded — the semaphore
/// still counts, so [`Admission::in_flight`] / [`Admission::peak`] stay
/// meaningful, but nothing ever blocks.
pub struct Admission {
    limit: usize,
    state: Mutex<AdmissionState>,
    freed: Condvar,
}

impl Admission {
    fn new(limit: usize) -> Self {
        Admission { limit, state: Mutex::new(AdmissionState::default()), freed: Condvar::new() }
    }

    /// Block until a slot frees, then take it.
    fn acquire(&self) {
        let mut st = self.state.lock().unwrap();
        while self.limit != 0 && st.in_flight >= self.limit {
            st = self.freed.wait(st).unwrap();
        }
        st.in_flight += 1;
        st.peak = st.peak.max(st.in_flight);
    }

    /// Take a slot only if one is free right now.
    fn try_acquire(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if self.limit != 0 && st.in_flight >= self.limit {
            return false;
        }
        st.in_flight += 1;
        st.peak = st.peak.max(st.in_flight);
        true
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.in_flight = st.in_flight.saturating_sub(1);
        drop(st);
        self.freed.notify_one();
    }

    /// The configured cap (0 = unbounded).
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Requests currently holding a slot.
    pub fn in_flight(&self) -> usize {
        self.state.lock().unwrap().in_flight
    }

    /// High-water mark of concurrent in-flight requests.
    pub fn peak(&self) -> usize {
        self.state.lock().unwrap().peak
    }
}

/// Live per-worker counters, shared with the worker thread.
#[derive(Default)]
struct SharedStats {
    /// engine steps executed
    steps: AtomicU64,
    /// times the worker parked on its request channel (idle, no work)
    idle_waits: AtomicU64,
}

/// Snapshot of one worker's loop counters ([`Router::worker_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerStats {
    /// Engine steps this worker has executed.
    pub steps: u64,
    /// Times the worker parked (blocking `recv`) with an idle engine —
    /// an idle worker accumulates *waits*, never *steps*.
    pub idle_waits: u64,
}

/// Router owning N worker threads.
pub struct Router {
    txs: Vec<Sender<Msg>>,
    resp_rx: Receiver<Response>,
    outstanding: Vec<Arc<AtomicUsize>>,
    stats: Vec<Arc<SharedStats>>,
    admission: Arc<Admission>,
    next: usize,
    policy: Policy,
    workers: Vec<std::thread::JoinHandle<()>>,
    in_flight: usize,
}

impl Router {
    /// Spawn `n_workers` engine threads sharing one model. The admission
    /// cap comes from `serve.max_concurrent` (0 = unbounded).
    pub fn new(model: Arc<Model>, serve: ServeConfig, n_workers: usize, policy: Policy) -> Self {
        let (resp_tx, resp_rx) = channel::<Response>();
        let admission = Arc::new(Admission::new(serve.max_concurrent));
        let mut txs = Vec::new();
        let mut outstanding = Vec::new();
        let mut stats = Vec::new();
        let mut workers = Vec::new();
        for _ in 0..n_workers.max(1) {
            let (tx, rx) = channel::<Msg>();
            let load = Arc::new(AtomicUsize::new(0));
            let shared = Arc::new(SharedStats::default());
            let resp_tx = resp_tx.clone();
            let model = Arc::clone(&model);
            let serve = serve.clone();
            let load2 = Arc::clone(&load);
            let shared2 = Arc::clone(&shared);
            let admission2 = Arc::clone(&admission);
            workers.push(std::thread::spawn(move || {
                let mut engine = Engine::new(model, serve);
                // ids submitted with a stream: their responses reach the
                // caller via the stream's terminal event, not resp_tx
                let mut streamed: HashSet<u64> = HashSet::new();
                let mut zero_steps = 0u64;
                loop {
                    // ingest every pending message without blocking while
                    // the engine has work; park on the channel when idle
                    let msg = if engine.has_work() {
                        match rx.try_recv() {
                            Ok(m) => Some(m),
                            Err(std::sync::mpsc::TryRecvError::Empty) => None,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
                        }
                    } else {
                        shared2.idle_waits.fetch_add(1, Ordering::Relaxed);
                        match rx.recv() {
                            Ok(m) => Some(m),
                            Err(_) => break,
                        }
                    };
                    match msg {
                        Some(Msg::Req(r, stream)) => {
                            if stream.is_some() {
                                streamed.insert(r.id);
                            }
                            engine.submit_with(r, stream);
                            zero_steps = 0;
                        }
                        Some(Msg::Drain) | None => {}
                    }
                    if engine.has_work() {
                        shared2.steps.fetch_add(1, Ordering::Relaxed);
                        let outcome = engine.step();
                        zero_steps = if outcome.progress() == 0 { zero_steps + 1 } else { 0 };
                        if zero_steps >= STALL_LIMIT {
                            // stuck admission (e.g. a prompt that can never
                            // fit the KV pool): preempt instead of spinning
                            // this thread at 100% CPU and hanging drain()
                            engine.abort_stalled();
                            zero_steps = 0;
                        }
                    }
                    for r in engine.take_responses() {
                        load2.fetch_sub(1, Ordering::SeqCst);
                        admission2.release();
                        if streamed.remove(&r.id) {
                            continue; // delivered via the stream's Done
                        }
                        let _ = resp_tx.send(r);
                    }
                }
            }));
            txs.push(tx);
            outstanding.push(load);
            stats.push(shared);
        }
        Router { txs, resp_rx, outstanding, stats, admission, next: 0, policy, workers, in_flight: 0 }
    }

    fn pick(&mut self) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                let i = self.next % self.txs.len();
                self.next += 1;
                i
            }
            Policy::LeastLoaded => self
                .outstanding
                .iter()
                .enumerate()
                .min_by_key(|(_, l)| l.load(Ordering::SeqCst))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Route one closed-loop request to a worker according to the
    /// policy; its response comes back through [`Router::drain`].
    /// Blocks at the admission gate when `--max-concurrent` is hit.
    pub fn submit(&mut self, req: Request) {
        self.admission.acquire();
        let i = self.pick();
        self.outstanding[i].fetch_add(1, Ordering::SeqCst);
        self.in_flight += 1;
        self.txs[i].send(Msg::Req(req, None)).expect("worker alive");
    }

    /// Route one request and return its live per-token stream. Blocks at
    /// the admission gate when `--max-concurrent` is hit. The terminal
    /// [`super::stream::StreamEvent::Done`] carries the full response;
    /// streamed requests do **not** appear in [`Router::drain`].
    pub fn submit_stream(&mut self, req: Request) -> ResponseStream {
        self.admission.acquire();
        self.stream_inner(req)
    }

    /// Non-blocking [`Router::submit_stream`]: sheds the request back to
    /// the caller instead of waiting when the admission gate is full —
    /// the open-loop load-generator primitive.
    pub fn try_submit_stream(&mut self, req: Request) -> Result<ResponseStream, Request> {
        if !self.admission.try_acquire() {
            return Err(req);
        }
        Ok(self.stream_inner(req))
    }

    fn stream_inner(&mut self, req: Request) -> ResponseStream {
        let (handle, sender) = ResponseStream::channel(req.id);
        let i = self.pick();
        self.outstanding[i].fetch_add(1, Ordering::SeqCst);
        self.txs[i].send(Msg::Req(req, Some(sender))).expect("worker alive");
        handle
    }

    /// Block until all closed-loop in-flight requests respond; returns
    /// them. Streamed requests are not waited on here — consume their
    /// [`ResponseStream`]s instead.
    pub fn drain(&mut self) -> Vec<Response> {
        for tx in &self.txs {
            let _ = tx.send(Msg::Drain);
        }
        let mut out = Vec::with_capacity(self.in_flight);
        while out.len() < self.in_flight {
            out.push(self.resp_rx.recv().expect("worker alive"));
        }
        self.in_flight = 0;
        out
    }

    /// Engine worker threads owned by this router.
    pub fn worker_count(&self) -> usize {
        self.txs.len()
    }

    /// The shared admission gate (inspect `in_flight`/`peak` in tests
    /// and load generators).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// Snapshot every worker's loop counters.
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        self.stats
            .iter()
            .map(|s| WorkerStats {
                steps: s.steps.load(Ordering::Relaxed),
                idle_waits: s.idle_waits.load(Ordering::Relaxed),
            })
            .collect()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.txs.clear(); // closes channels; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, Method};
    use crate::kvcache::MethodAux;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn model() -> Arc<Model> {
        let cfg = preset("hata-gqa").unwrap();
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        Arc::new(Model::new(cfg, weights, MethodAux::default()))
    }

    fn serve() -> ServeConfig {
        ServeConfig { method: Method::Hata, budget: 16, max_batch: 2, ..Default::default() }
    }

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: (0..30).map(|i| 32 + (i % 64)).collect(),
            max_new_tokens: 3,
            stop_token: None,
            arrival: 0.0,
        }
    }

    #[test]
    fn routes_and_drains_all_requests() {
        let mut router = Router::new(model(), serve(), 2, Policy::RoundRobin);
        for i in 0..8 {
            router.submit(req(i));
        }
        let rs = router.drain();
        assert_eq!(rs.len(), 8);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn least_loaded_policy_works() {
        let mut router = Router::new(model(), serve(), 3, Policy::LeastLoaded);
        for i in 0..9 {
            router.submit(req(i));
        }
        let rs = router.drain();
        assert_eq!(rs.len(), 9);
    }

    #[test]
    fn single_worker_router() {
        let mut router = Router::new(model(), serve(), 1, Policy::RoundRobin);
        router.submit(req(1));
        let rs = router.drain();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens.len(), 3);
    }

    #[test]
    fn drop_joins_workers() {
        let router = Router::new(model(), serve(), 2, Policy::RoundRobin);
        drop(router); // must not hang
    }

    #[test]
    fn streamed_requests_bypass_drain() {
        let mut router = Router::new(model(), serve(), 2, Policy::RoundRobin);
        let stream = router.submit_stream(req(11));
        router.submit(req(12)); // closed-loop alongside the stream
        let out = stream.wait();
        assert_eq!(out.tokens.len(), 3);
        let resp = out.response.expect("stream terminates with Done");
        assert_eq!(resp.id, 11);
        assert_eq!(resp.tokens, out.tokens);
        let rs = router.drain();
        assert_eq!(rs.len(), 1, "drain sees only the closed-loop request");
        assert_eq!(rs[0].id, 12);
    }

    #[test]
    fn admission_counts_and_releases() {
        let mut serve = serve();
        serve.max_concurrent = 2;
        let mut router = Router::new(model(), serve, 2, Policy::RoundRobin);
        let streams: Vec<_> = (0..2).map(|i| router.submit_stream(req(20 + i))).collect();
        assert!(router.admission().peak() <= 2);
        for s in streams {
            assert!(s.wait().response.is_some());
        }
        // release happens on the worker after the terminal event; give it
        // a bounded moment to settle
        for _ in 0..1000 {
            if router.admission().in_flight() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(router.admission().in_flight(), 0);
        assert_eq!(router.admission().peak(), 2);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let mut serve = serve();
        serve.max_concurrent = 1;
        let mut router = Router::new(model(), serve, 1, Policy::RoundRobin);
        // hold the only slot without handing the request to a worker:
        // the gate is router-wide state, so a manual acquire models a
        // long-running in-flight request deterministically
        router.admission().acquire();
        let shed = router.try_submit_stream(req(30));
        let req_back = match shed {
            Err(r) => r,
            Ok(_) => panic!("gate full: request must be shed"),
        };
        assert_eq!(req_back.id, 30);
        router.admission().release();
        let stream = router.try_submit_stream(req_back).expect("slot free");
        assert_eq!(stream.wait().tokens.len(), 3);
    }
}
