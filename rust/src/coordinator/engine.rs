//! The serving engine: continuous-batching step loop over the native
//! model. One engine = one worker process; the [`super::router`] shards
//! requests across engines, and within an engine the step fans
//! per-(sequence, kv-head) decode work and per-(sequence, kv-head,
//! query-tile) prefill work across `serve.threads` pool workers — as one
//! dependency-driven task graph per batch under the default `--exec
//! queue`, or as barrier-separated scatter stages under `--exec barrier`
//! (bit-identical outputs either way; the work-queue executor's busy/idle
//! counters land in [`Metrics::decode_exec`]/[`Metrics::prefill_exec`]).
//!
//! Scratch ownership per step: one [`DecodeScratch`] per batch slot
//! (sequence activations + tiled-prefill block arenas + logits), one
//! [`WorkerScratch`] per pool worker (selection buffers + tile
//! temporaries). The plan's decode/prefill batches are materialized into
//! disjoint-`&mut` work items and handed to [`Model::decode_batch`] /
//! [`Model::prefill_batch`].

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::config::ServeConfig;
use crate::kvcache::offload::OffloadRates;
use crate::kvcache::pool::KvPool;
use crate::kvcache::tier::TierController;
use crate::kvcache::{BlockStore, SeqKvCache};
use crate::model::sampler::Sampler;
use crate::model::{
    make_selector, sel_ref, DecodeGraphCache, DecodeItem, DecodeScratch, Model, PrefillItem,
    SeqState, WorkerScratch,
};
use crate::simulator::pcie::PcieModel;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::metrics::Metrics;
use super::request::{FinishReason, Request, Response};
use super::scheduler::{Scheduler, SeqTicket, StepPlan};
use super::stream::{ResponseStream, StreamSender};

/// Consecutive zero-progress steps before the engine declares a stall
/// (stuck scheduler or unsatisfiable admission), surfaces it through
/// metrics and preempts the stuck requests instead of spinning forever.
/// Shared with the router's worker loop, which applies the same limit
/// so a stuck engine never spins a worker thread at 100% CPU.
pub const STALL_LIMIT: u64 = 64;

/// The PCIe link model the residency tier charges its ledgers against:
/// the paper's Table 3 testbed link, with the bandwidth overridable via
/// `HATA_OFFLOAD_BW` (bytes/second) so benches can pin the model to a
/// machine's measured host<->device copy rate.
fn offload_pcie() -> PcieModel {
    let mut pcie = OffloadRates::paper_testbed().pcie;
    if let Ok(bw) = std::env::var("HATA_OFFLOAD_BW") {
        if let Ok(bw) = bw.parse::<f64>() {
            if bw > 0.0 {
                pcie.bandwidth = bw;
            }
        }
    }
    pcie
}

struct LiveSeq {
    req: Request,
    cache: SeqKvCache,
    state: SeqState,
    out: Vec<u32>,
    next_token: Option<u32>,
    first_token_at: Option<f64>,
    /// previous token's commit time, for inter-token (TPOT) latency
    last_token_at: Option<f64>,
    /// per-token stream to the caller, when submitted via
    /// [`Engine::submit_stream`]
    stream: Option<StreamSender>,
    rng: Rng,
}

/// What one engine step accomplished (progress accounting for the
/// stall detector and metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepOutcome {
    /// tokens decoded (one per running sequence)
    pub decoded: usize,
    /// prompt tokens prefilled
    pub prefilled: usize,
    /// requests admitted from the queue
    pub admitted: usize,
}

impl StepOutcome {
    /// Total units of work done (zero steps feed the stall detector).
    pub fn progress(&self) -> usize {
        self.decoded + self.prefilled + self.admitted
    }
}

/// Single-worker serving engine.
pub struct Engine {
    /// The model this engine serves (shared across engines).
    pub model: Arc<Model>,
    /// Serving parameters (method, budget, batch/chunk/tile knobs).
    pub serve: ServeConfig,
    selector: Option<Box<dyn crate::attention::Selector + Send + Sync>>,
    scheduler: Scheduler,
    pool: KvPool,
    /// shared physical block planes when `--paged`; `None` keeps every
    /// sequence on the contiguous per-head layout
    store: Option<Arc<BlockStore>>,
    /// residency-tier controller when `--offload`: tracks which physical
    /// blocks hold device-resident K/V, spills cold blocks to the slow
    /// tier under `--offload-budget` and services demand/prefetch fetches
    tier: Option<Arc<TierController>>,
    /// recycled per-step scratch for [`Self::enforce_offload_budget`]:
    /// every live sequence's physical blocks
    live_blocks: Vec<u32>,
    /// recycled per-step scratch: append-target (tail) blocks, exempt
    /// from eviction
    tail_blocks: Vec<u32>,
    seqs: HashMap<u64, LiveSeq>,
    workers: ThreadPool,
    worker_scratch: Vec<WorkerScratch>,
    /// per-batch-slot activation buffers, grown on demand
    seq_scratch: Vec<DecodeScratch>,
    /// cached decode task graph + payload arena (`--graph-cache`):
    /// rebuilt only when the batch shape changes, rebound per step
    graph_cache: DecodeGraphCache,
    /// recycled step plan: the scheduler refills its DecodeWork /
    /// PrefillWork vectors in place instead of reallocating per token
    plan: StepPlan,
    /// recycled (id, token, pos) decode feed for the current step
    decode_feed: Vec<(u64, u32, usize)>,
    /// recycled end-of-step completion list
    finished: Vec<(u64, FinishReason)>,
    sampler: Sampler,
    /// Latency/throughput counters, updated every step.
    pub metrics: Metrics,
    clock: Instant,
    responses: Vec<Response>,
}

impl Engine {
    /// Build an engine: scheduler, KV pool, threadpool and scratch sized
    /// from `serve`. `--offload` implies `--paged` (the residency tier
    /// tracks physical blocks, so it needs the shared block planes).
    pub fn new(model: Arc<Model>, mut serve: ServeConfig) -> Self {
        if serve.offload {
            serve.paged = true;
        }
        let selector = make_selector(&serve);
        let threads = serve.threads.max(1);
        let sampler = if serve.temperature > 0.0 {
            Sampler::Temperature(serve.temperature)
        } else {
            Sampler::Greedy
        };
        let store = serve.paged.then(|| {
            let cfg = &model.cfg;
            assert_eq!(cfg.rbit % 64, 0, "--paged requires rbit % 64 == 0");
            Arc::new(BlockStore::new(
                cfg.n_layers * cfg.n_kv_heads,
                cfg.head_dim,
                cfg.rbit / 64,
                serve.kv_block,
                serve.kv_dtype,
            ))
        });
        let tier = match (&store, serve.offload) {
            (Some(store), true) => {
                Some(Arc::new(TierController::new(store.clone(), offload_pcie())))
            }
            _ => None,
        };
        let mut metrics = Metrics::new();
        metrics.paged_active = serve.paged;
        Engine {
            scheduler: Scheduler::new(&serve),
            pool: KvPool::with_block(serve.kv_capacity, serve.kv_block),
            store,
            tier,
            live_blocks: Vec::new(),
            tail_blocks: Vec::new(),
            seqs: HashMap::new(),
            workers: ThreadPool::new(threads),
            worker_scratch: (0..threads).map(|_| WorkerScratch::default()).collect(),
            seq_scratch: Vec::new(),
            graph_cache: DecodeGraphCache::new(),
            plan: StepPlan::default(),
            decode_feed: Vec::new(),
            finished: Vec::new(),
            sampler,
            metrics,
            clock: Instant::now(),
            responses: Vec::new(),
            selector,
            model,
            serve,
        }
    }

    fn now(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }

    /// Accept a request: allocate its cache/state and queue it for
    /// admission. Responses come back through [`Engine::take_responses`]
    /// (the closed-loop path).
    pub fn submit(&mut self, req: Request) {
        self.submit_with(req, None);
    }

    /// Accept a request and return a live per-token stream for it. The
    /// caller sees every generated token at its commit point and a
    /// terminal [`super::stream::StreamEvent::Done`] when the request
    /// finishes — including stall-recovery preemptions, so a stream
    /// always terminates. The finished response is *also* pushed to
    /// [`Engine::take_responses`] (worker bookkeeping relies on that);
    /// callers consume one side or the other, not both.
    pub fn submit_stream(&mut self, req: Request) -> ResponseStream {
        let (handle, sender) = ResponseStream::channel(req.id);
        self.submit_with(req, Some(sender));
        handle
    }

    /// [`Engine::submit`] with an optional per-token stream attached.
    pub fn submit_with(&mut self, mut req: Request, stream: Option<StreamSender>) {
        req.arrival = self.now();
        self.scheduler.submit(SeqTicket {
            id: req.id,
            prompt_len: req.prompt.len(),
            prefilled: 0,
            generated: 0,
            max_new: req.max_new_tokens,
        });
        // per-request sampling stream: deterministic in (seed, id), so
        // results are independent of thread count and arrival order
        let rng = Rng::new(self.serve.seed ^ req.id.wrapping_mul(0x9E3779B97F4A7C15));
        // reserve the whole request's cache up front (prompt + budget),
        // so steady-state decode appends never reallocate — for paged
        // caches this sizes the block table; pages come from the pool
        let mut cache = match &self.store {
            Some(store) => SeqKvCache::new_paged(&self.model.cfg, &self.serve, store.clone()),
            None => SeqKvCache::new(&self.model.cfg, &self.serve),
        };
        if let Some(tier) = &self.tier {
            cache.attach_tier(tier.clone());
        }
        cache.reserve(req.prompt.len() + req.max_new_tokens + 1);
        self.seqs.insert(
            req.id,
            LiveSeq {
                cache,
                state: SeqState::new(&self.model.cfg),
                out: Vec::new(),
                next_token: None,
                first_token_at: None,
                last_token_at: None,
                stream,
                rng,
                req,
            },
        );
    }

    /// Anything queued or live?
    pub fn has_work(&self) -> bool {
        self.scheduler.queue_len() > 0 || self.scheduler.live_len() > 0
    }

    /// Drain completed responses accumulated since the last call.
    pub fn take_responses(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.responses)
    }

    /// Preempt a live sequence back to the queue front. Its cache, pool
    /// pages and generation state are all retained, so re-admission
    /// resumes with zero recompute (cheap under `--paged`, where held
    /// pages are exact block-table entries, not a contiguous region).
    /// Returns whether `id` was live.
    pub fn preempt(&mut self, id: u64) -> bool {
        self.scheduler.preempt(id)
    }

    /// The engine's KV pool (page accounting, refcounts, prefix registry).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// One engine step: decode every running sequence once (batched
    /// across the threadpool), advance prefill chunks, admit from the
    /// queue. Returns what got done.
    ///
    /// Steady-state bookkeeping is recycled across steps: the plan's
    /// work vectors, the decode feed, the completion list, the
    /// per-slot scratch and the decode graph cache are all engine
    /// fields refilled in place. (The per-step `by_id` borrow maps and
    /// item vectors are still rebuilt — they carry `&mut` borrows that
    /// cannot outlive the step; the zero-allocation guarantee applies
    /// to the model's decode step itself, see rust/tests/alloc.rs.)
    pub fn step(&mut self) -> StepOutcome {
        let t0 = Instant::now();
        let sampler = self.sampler;
        self.scheduler.plan_into(&mut self.pool, &mut self.plan);
        self.metrics.on_queue_depth(self.scheduler.queue_len());
        if let Some(store) = &self.store {
            // the plan's grows may have minted fresh physical pages:
            // extend the shared planes, then mirror the pool's block
            // lists into every planned sequence's table — both strictly
            // before any work item captures a PagedRef (engine thread,
            // between passes; see kvcache::paged's module contract)
            // SAFETY: no pass is running, so no worker holds a view
            unsafe { store.ensure_blocks(self.pool.minted_pages()) };
            if let Some(tier) = &self.tier {
                tier.ensure_capacity(self.pool.minted_pages());
                tier.begin_step();
            }
            let ids = self
                .plan
                .prefill
                .iter()
                .map(|w| w.id)
                .chain(self.plan.decode.iter().map(|w| w.id));
            for id in ids {
                if let Some(seq) = self.seqs.get_mut(&id) {
                    // blocks appended to the table this step are fresh
                    // device pages: mark them resident so the tier never
                    // "restores" stale slow-tier data from a previous
                    // owner of a recycled physical block. (Safe to diff
                    // by index: tables only grow while a sequence is
                    // live, and dedup swaps happen below the old length.)
                    let old_len = seq.cache.block_table().len();
                    seq.cache.sync_table(self.pool.seq_blocks(id));
                    if let Some(tier) = &self.tier {
                        for &b in seq.cache.block_table().get(old_len..).unwrap_or(&[]) {
                            tier.note_allocated(b);
                        }
                    }
                }
            }
        }
        let mut outcome =
            StepOutcome { admitted: self.plan.admitted.len(), ..Default::default() };
        let slots = self.plan.prefill.len().max(self.plan.decode.len());
        while self.seq_scratch.len() < slots {
            self.seq_scratch.push(DecodeScratch::new(&self.model.cfg));
        }
        // ---- batched prefill chunks
        if !self.plan.prefill.is_empty() {
            // prefill attends over the whole context so far, so the
            // sequence's every block must be device-resident before the
            // pass captures views (a preempted-then-resumed sequence may
            // have been spilled under the budget while it waited)
            if let Some(tier) = &self.tier {
                for w in &self.plan.prefill {
                    if let Some(seq) = self.seqs.get(&w.id) {
                        tier.fetch_table_all_planes(seq.cache.block_table());
                    }
                }
            }
            {
                let mut by_id: HashMap<u64, &mut LiveSeq> =
                    self.seqs.iter_mut().map(|(id, s)| (*id, s)).collect();
                let mut items: Vec<PrefillItem> = Vec::with_capacity(self.plan.prefill.len());
                for (w, scratch) in self.plan.prefill.iter().zip(self.seq_scratch.iter_mut()) {
                    let seq = by_id.remove(&w.id).expect("live seq");
                    let LiveSeq { req, cache, state, .. } = seq;
                    items.push(PrefillItem {
                        tokens: &req.prompt[w.range.clone()],
                        start: w.range.start,
                        prompt_len: req.prompt.len(),
                        is_final: w.is_final,
                        tile: w.tile,
                        cache,
                        state,
                        scratch,
                    });
                }
                let exec = self.model.prefill_batch(
                    &mut items,
                    &self.serve,
                    &self.workers,
                    &mut self.worker_scratch,
                );
                self.metrics.on_prefill_exec(exec);
            }
            for (slot, w) in self.plan.prefill.iter().enumerate() {
                self.scheduler.on_prefilled(w.id, w.range.len());
                outcome.prefilled += w.range.len();
                self.metrics.prefill_tokens += w.range.len() as u64;
                if w.is_final {
                    let logits = &self.seq_scratch[slot].logits;
                    let seq = self.seqs.get_mut(&w.id).expect("live seq");
                    seq.next_token = Some(sampler.sample(logits, &mut seq.rng));
                }
            }
            // copy-on-write prefix sharing: once a prompt is fully in
            // cache, alias any block another live sequence already
            // stores for the identical token chain (paged only; the
            // sequence decodes strictly past every shared block)
            if self.store.is_some() {
                for w in self.plan.prefill.iter().filter(|w| w.is_final) {
                    let seq = self.seqs.get_mut(&w.id).expect("live seq");
                    let hits = seq.cache.dedup_prefix(&mut self.pool, w.id, &seq.req.prompt);
                    self.metrics.prefix_hits += hits as u64;
                }
            }
            // degenerate max_new_tokens == 0: complete right after prefill
            let zero_new: Vec<u64> = self
                .plan
                .prefill
                .iter()
                .filter(|w| w.is_final && self.seqs[&w.id].req.max_new_tokens == 0)
                .map(|w| w.id)
                .collect();
            for id in zero_new {
                self.finish(id, FinishReason::MaxTokens);
            }
        }
        // ---- batched decode: one token per running sequence
        self.finished.clear();
        // commit the sampled token to each stream; stop-token sequences
        // drop out of the batch before the model runs
        self.decode_feed.clear();
        for w in &self.plan.decode {
            let seq = self.seqs.get_mut(&w.id).expect("live seq");
            let tok = seq.next_token.expect("prefill completed");
            seq.out.push(tok);
            let at = self.clock.elapsed().as_secs_f64();
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(at);
                self.metrics.on_first_token(at - seq.req.arrival);
            } else if let Some(prev) = seq.last_token_at {
                self.metrics.on_inter_token(at - prev);
            }
            seq.last_token_at = Some(at);
            if let Some(stream) = &seq.stream {
                stream.send_token(tok, seq.out.len() - 1);
            }
            if seq.req.stop_token == Some(tok) {
                self.finished.push((w.id, FinishReason::StopToken));
                continue;
            }
            self.decode_feed.push((w.id, tok, w.pos));
        }
        if !self.decode_feed.is_empty() {
            {
                let mut by_id: HashMap<u64, &mut LiveSeq> =
                    self.seqs.iter_mut().map(|(id, s)| (*id, s)).collect();
                let mut items: Vec<DecodeItem> = Vec::with_capacity(self.decode_feed.len());
                for ((id, tok, pos), scratch) in
                    self.decode_feed.iter().zip(self.seq_scratch.iter_mut())
                {
                    let seq = by_id.remove(id).expect("live seq");
                    let LiveSeq { cache, state, .. } = seq;
                    items.push(DecodeItem { token: *tok, pos: *pos, cache, state, scratch });
                }
                let exec = self.model.decode_batch(
                    &mut items,
                    &self.serve,
                    sel_ref(&self.selector),
                    &self.workers,
                    &mut self.worker_scratch,
                    &mut self.graph_cache,
                );
                self.metrics.on_decode_exec(exec);
            }
            for (slot, (id, _, _)) in self.decode_feed.iter().enumerate() {
                let logits = &self.seq_scratch[slot].logits;
                let seq = self.seqs.get_mut(id).expect("live seq");
                seq.next_token = Some(sampler.sample(logits, &mut seq.rng));
                let done = seq.out.len() >= seq.req.max_new_tokens;
                self.scheduler.on_decoded(*id);
                outcome.decoded += 1;
                if done {
                    self.finished.push((*id, FinishReason::MaxTokens));
                }
            }
        }
        let mut finished = std::mem::take(&mut self.finished);
        for (id, reason) in finished.drain(..) {
            self.finish(id, reason);
        }
        self.finished = finished;
        if self.tier.is_some() {
            self.enforce_offload_budget();
            if let Some(tier) = &self.tier {
                self.metrics.offload = Some(tier.stats());
            }
        }
        self.metrics.on_step(t0.elapsed().as_secs_f64(), outcome.decoded);
        outcome
    }

    /// Spill cold blocks to the slow tier until the device-resident count
    /// fits `--offload-budget` (in tokens; 0 keeps only append-target
    /// tails resident). Runs on the engine thread between passes: no
    /// worker holds a [`crate::kvcache::paged::PagedRef`] view, so moving
    /// block payloads is safe. Tail blocks of every tracked sequence —
    /// queued, live or preempted — are exempt so appends always land on
    /// device-resident rows.
    fn enforce_offload_budget(&mut self) {
        let Some(tier) = &self.tier else { return };
        self.live_blocks.clear();
        self.tail_blocks.clear();
        for &id in self.seqs.keys() {
            self.live_blocks.extend_from_slice(self.pool.seq_blocks(id));
            if let Some(tail) = self.pool.seq_tail(id) {
                self.tail_blocks.push(tail);
            }
        }
        let budget_blocks = self.serve.offload_budget / self.pool.block_tokens();
        tier.evict_to_budget(budget_blocks, &self.live_blocks, &self.tail_blocks);
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        self.scheduler.finish(id, &mut self.pool);
        if let Some(seq) = self.seqs.remove(&id) {
            let now = self.now();
            self.metrics.on_complete(now - seq.req.arrival, seq.req.prompt.len());
            let resp = Response {
                id,
                prompt_len: seq.req.prompt.len(),
                tokens: seq.out,
                reason,
                ttft: seq.first_token_at.unwrap_or(now) - seq.req.arrival,
                total_time: now - seq.req.arrival,
            };
            if let Some(stream) = &seq.stream {
                stream.finish(resp.clone());
            }
            self.responses.push(resp);
        }
    }

    /// Preempt everything still queued or live and record the stall in
    /// metrics — a stuck scheduler surfaces as a report, not a crash.
    /// Callers driving [`Engine::step`] directly (the router's worker
    /// loop) invoke this once [`STALL_LIMIT`] zero-progress steps
    /// accumulate; [`Engine::run_to_completion`] applies it internally.
    /// Streamed requests get their terminal `Done` event here too, so a
    /// stalled stream still terminates.
    pub fn abort_stalled(&mut self) {
        let stuck = self.scheduler.evict_all();
        self.metrics.on_stall(stuck.len());
        crate::util::logger::log(
            crate::util::logger::Level::Warn,
            "engine",
            format_args!(
                "stalled after {} zero-progress steps; preempting {} requests",
                STALL_LIMIT,
                stuck.len()
            ),
        );
        for id in stuck {
            let _ = self.pool.release(id);
            if let Some(seq) = self.seqs.remove(&id) {
                let now = self.now();
                let resp = Response {
                    id,
                    prompt_len: seq.req.prompt.len(),
                    tokens: seq.out,
                    reason: FinishReason::Preempted,
                    ttft: seq.first_token_at.unwrap_or(now) - seq.req.arrival,
                    total_time: now - seq.req.arrival,
                };
                if let Some(stream) = &seq.stream {
                    stream.finish(resp.clone());
                }
                self.responses.push(resp);
            }
        }
    }

    /// Drive until every submitted request completes; returns responses.
    ///
    /// If the engine stops making progress (e.g. a request that can never
    /// be admitted under the KV pool), the stall is recorded in metrics
    /// and the stuck requests come back as `FinishReason::Preempted`.
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut idle = 0u64;
        while self.has_work() {
            let outcome = self.step();
            idle = if outcome.progress() == 0 { idle + 1 } else { 0 };
            if idle >= STALL_LIMIT {
                self.abort_stalled();
                break;
            }
        }
        self.take_responses()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, Method};
    use crate::kvcache::pool::PAGE_TOKENS;
    use crate::kvcache::MethodAux;
    use crate::model::weights::Weights;

    fn engine_with(serve: ServeConfig) -> Engine {
        let cfg = preset("hata-gqa").unwrap();
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        Engine::new(Arc::new(Model::new(cfg, weights, aux)), serve)
    }

    fn engine(method: Method, max_batch: usize) -> Engine {
        engine_with(ServeConfig {
            method,
            budget: 16,
            max_batch,
            prefill_chunk: 64,
            ..Default::default()
        })
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt_len).map(|i| 32 + (i as u32 % 64)).collect(),
            max_new_tokens: max_new,
            stop_token: None,
            arrival: 0.0,
        }
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(Method::Hata, 4);
        e.submit(req(1, 40, 5));
        let rs = e.run_to_completion();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens.len(), 5);
        assert_eq!(rs[0].reason, FinishReason::MaxTokens);
        assert!(rs[0].ttft >= 0.0);
        assert!(rs[0].total_time >= rs[0].ttft);
    }

    #[test]
    fn batch_of_requests_all_complete() {
        let mut e = engine(Method::Hata, 3);
        for i in 0..6 {
            e.submit(req(i, 30 + (i as usize) * 7, 4));
        }
        let rs = e.run_to_completion();
        assert_eq!(rs.len(), 6);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert!(rs.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(e.metrics.completed, 6);
    }

    #[test]
    fn stop_token_halts_generation() {
        let mut e = engine(Method::Dense, 2);
        // find what the model generates first, then use it as stop token
        e.submit(req(7, 20, 3));
        let first = e.run_to_completion()[0].tokens[0];
        let mut e2 = engine(Method::Dense, 2);
        let mut r = req(8, 20, 10);
        r.stop_token = Some(first);
        e2.submit(r);
        let rs = e2.run_to_completion();
        assert_eq!(rs[0].reason, FinishReason::StopToken);
        assert_eq!(rs[0].tokens.len(), 1); // the stop token itself
    }

    #[test]
    fn chunked_prefill_same_output_as_whole() {
        // prompt longer than prefill_chunk exercises the chunked path;
        // outputs must match a single-chunk engine (dense method).
        let cfg = preset("hata-gqa").unwrap();
        let mk = |chunk: usize| {
            let serve = ServeConfig {
                method: Method::Dense,
                budget: 0,
                max_batch: 1,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let mut rng = Rng::new(3);
            let weights = Weights::random(&cfg, &mut rng);
            let aux = MethodAux::default();
            Engine::new(Arc::new(Model::new(cfg.clone(), weights, aux)), serve)
        };
        let mut small = mk(16);
        let mut big = mk(4096);
        small.submit(req(1, 100, 4));
        big.submit(req(1, 100, 4));
        assert_eq!(small.run_to_completion()[0].tokens, big.run_to_completion()[0].tokens);
    }

    #[test]
    fn submit_stream_sees_every_token_then_done() {
        let mut closed = engine(Method::Hata, 4);
        closed.submit(req(5, 40, 6));
        let reference = closed.run_to_completion().remove(0);

        let mut e = engine(Method::Hata, 4);
        let stream = e.submit_stream(req(5, 40, 6));
        e.run_to_completion();
        let out = stream.wait();
        let resp = out.response.expect("stream must terminate with Done");
        assert_eq!(out.tokens, reference.tokens, "streamed tokens match closed loop");
        assert_eq!(resp.tokens, reference.tokens);
        assert_eq!(resp.reason, FinishReason::MaxTokens);
    }

    #[test]
    fn metrics_populated() {
        let mut e = engine(Method::Hata, 2);
        e.submit(req(1, 25, 3));
        e.run_to_completion();
        assert!(e.metrics.generated_tokens >= 2);
        assert!(e.metrics.step_latency.count() > 0);
    }

    #[test]
    fn multithreaded_engine_matches_single_thread() {
        let run = |threads: usize| {
            let mut e = engine_with(ServeConfig {
                method: Method::Hata,
                budget: 16,
                max_batch: 4,
                prefill_chunk: 64,
                threads,
                ..Default::default()
            });
            for i in 0..5 {
                e.submit(req(i, 30 + (i as usize) * 11, 4));
            }
            let mut rs: Vec<(u64, Vec<u32>)> =
                e.run_to_completion().into_iter().map(|r| (r.id, r.tokens)).collect();
            rs.sort_by_key(|(id, _)| *id);
            rs
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn stalled_admission_preempts_instead_of_panicking() {
        // a prompt that can never fit in the KV pool used to livelock
        // run_to_completion (guarded only by a panic); it must now come
        // back as a Preempted response with the stall recorded
        let mut e = engine_with(ServeConfig {
            method: Method::Dense,
            budget: 0,
            max_batch: 2,
            kv_capacity: 2 * PAGE_TOKENS,
            ..Default::default()
        });
        e.submit(req(1, 10 * PAGE_TOKENS, 4));
        let rs = e.run_to_completion();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].reason, FinishReason::Preempted);
        assert!(rs[0].tokens.is_empty());
        assert_eq!(e.metrics.stalls, 1);
        assert_eq!(e.metrics.preempted, 1);
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let run = || {
            let mut e = engine_with(ServeConfig {
                method: Method::Dense,
                budget: 0,
                max_batch: 2,
                temperature: 0.8,
                seed: 7,
                ..Default::default()
            });
            e.submit(req(3, 24, 6));
            e.run_to_completion()[0].tokens.clone()
        };
        assert_eq!(run(), run());
    }
}
