//! The serving engine: continuous-batching step loop over the native
//! model. One engine = one worker; the [`super::router`] shards requests
//! across engines.

use std::collections::HashMap;
use std::time::Instant;

use crate::config::ServeConfig;
use crate::kvcache::pool::KvPool;
use crate::kvcache::SeqKvCache;
use crate::model::{make_selector, sel_ref, DecodeScratch, Model, SeqState};
use crate::tensor::ops::argmax;

use super::metrics::Metrics;
use super::request::{FinishReason, Request, Response};
use super::scheduler::{Scheduler, SeqTicket};

struct LiveSeq {
    req: Request,
    cache: SeqKvCache,
    state: SeqState,
    out: Vec<u32>,
    next_token: Option<u32>,
    first_token_at: Option<f64>,
}

/// Single-worker serving engine.
pub struct Engine {
    pub model: std::sync::Arc<Model>,
    pub serve: ServeConfig,
    selector: Option<Box<dyn crate::attention::Selector + Send + Sync>>,
    scheduler: Scheduler,
    pool: KvPool,
    seqs: HashMap<u64, LiveSeq>,
    scratch: DecodeScratch,
    pub metrics: Metrics,
    clock: Instant,
    responses: Vec<Response>,
}

impl Engine {
    pub fn new(model: std::sync::Arc<Model>, serve: ServeConfig) -> Self {
        let selector = make_selector(&serve);
        Engine {
            scheduler: Scheduler::new(&serve),
            pool: KvPool::new(serve.kv_capacity),
            seqs: HashMap::new(),
            scratch: DecodeScratch::new(&model.cfg),
            metrics: Metrics::new(),
            clock: Instant::now(),
            responses: Vec::new(),
            selector,
            model,
            serve,
        }
    }

    fn now(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }

    pub fn submit(&mut self, mut req: Request) {
        req.arrival = self.now();
        self.scheduler.submit(SeqTicket {
            id: req.id,
            prompt_len: req.prompt.len(),
            prefilled: 0,
            generated: 0,
            max_new: req.max_new_tokens,
        });
        self.seqs.insert(
            req.id,
            LiveSeq {
                cache: SeqKvCache::new(&self.model.cfg, &self.serve),
                state: SeqState::new(&self.model.cfg),
                out: Vec::new(),
                next_token: None,
                first_token_at: None,
                req,
            },
        );
    }

    pub fn has_work(&self) -> bool {
        self.scheduler.queue_len() > 0 || self.scheduler.live_len() > 0
    }

    pub fn take_responses(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.responses)
    }

    /// One engine step: decode every running sequence once, advance one
    /// prefill chunk, admit from the queue. Returns tokens decoded.
    pub fn step(&mut self) -> usize {
        let t0 = Instant::now();
        let plan = self.scheduler.plan(&mut self.pool);
        // ---- prefill chunks (token-by-token through the shared step path)
        for (id, range) in &plan.prefill {
            let seq = self.seqs.get_mut(id).expect("live seq");
            let tokens: Vec<u32> = seq.req.prompt[range.clone()].to_vec();
            let whole_prompt = range.end == seq.req.prompt.len();
            if range.start == 0 && whole_prompt {
                // single-chunk prompt: use prefill (captures SnapKV state)
                self.model.prefill(
                    &seq.req.prompt,
                    &mut seq.cache,
                    &mut seq.state,
                    &self.serve,
                    &mut self.scratch,
                );
            } else {
                let dense = ServeConfig { budget: 0, ..self.serve.clone() };
                for (i, &tok) in tokens.iter().enumerate() {
                    self.model.decode_step(
                        tok,
                        range.start + i,
                        &mut seq.cache,
                        &mut seq.state,
                        &dense,
                        None,
                        &mut self.scratch,
                    );
                }
            }
            self.scheduler.on_prefilled(*id, range.len());
            if whole_prompt {
                seq.next_token = Some(argmax(&self.scratch.logits) as u32);
            }
        }
        // degenerate max_new_tokens == 0: complete right after prefill
        let zero_new: Vec<u64> = plan
            .prefill
            .iter()
            .filter(|(id, r)| {
                r.end == self.seqs[id].req.prompt.len() && self.seqs[id].req.max_new_tokens == 0
            })
            .map(|(id, _)| *id)
            .collect();
        for id in zero_new {
            self.finish(id, FinishReason::MaxTokens);
        }
        // ---- decode one token per running sequence
        let mut decoded = 0;
        let mut finished: Vec<(u64, FinishReason)> = Vec::new();
        for id in &plan.decode {
            let seq = self.seqs.get_mut(id).expect("live seq");
            let tok = seq.next_token.expect("prefill completed");
            seq.out.push(tok);
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(self.clock.elapsed().as_secs_f64());
                self.metrics.on_first_token(seq.first_token_at.unwrap() - seq.req.arrival);
            }
            if seq.req.stop_token == Some(tok) {
                finished.push((*id, FinishReason::StopToken));
                continue;
            }
            let pos = seq.req.prompt.len() + seq.out.len() - 1;
            self.model.decode_step(
                tok,
                pos,
                &mut seq.cache,
                &mut seq.state,
                &self.serve,
                sel_ref(&self.selector),
                &mut self.scratch,
            );
            seq.next_token = Some(argmax(&self.scratch.logits) as u32);
            self.scheduler.on_decoded(*id);
            decoded += 1;
            if seq.out.len() >= seq.req.max_new_tokens {
                finished.push((*id, FinishReason::MaxTokens));
            }
        }
        for (id, reason) in finished {
            self.finish(id, reason);
        }
        self.metrics.on_step(t0.elapsed().as_secs_f64(), decoded);
        decoded
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        self.scheduler.finish(id, &mut self.pool);
        if let Some(seq) = self.seqs.remove(&id) {
            let now = self.now();
            self.metrics.on_complete(now - seq.req.arrival, seq.req.prompt.len());
            self.responses.push(Response {
                id,
                prompt_len: seq.req.prompt.len(),
                tokens: seq.out,
                reason,
                ttft: seq.first_token_at.unwrap_or(now) - seq.req.arrival,
                total_time: now - seq.req.arrival,
            });
        }
    }

    /// Drive until every submitted request completes; returns responses.
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut guard = 0u64;
        while self.has_work() {
            self.step();
            guard += 1;
            assert!(guard < 10_000_000, "engine livelock");
        }
        self.take_responses()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, Method};
    use crate::kvcache::MethodAux;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn engine(method: Method, max_batch: usize) -> Engine {
        let cfg = preset("hata-gqa").unwrap();
        let serve = ServeConfig { method, budget: 16, max_batch, prefill_chunk: 64, ..Default::default() };
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        let aux = MethodAux::build(&cfg, &serve, None, 1);
        Engine::new(std::sync::Arc::new(Model::new(cfg, weights, aux)), serve)
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt_len).map(|i| 32 + (i as u32 % 64)).collect(),
            max_new_tokens: max_new,
            stop_token: None,
            arrival: 0.0,
        }
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(Method::Hata, 4);
        e.submit(req(1, 40, 5));
        let rs = e.run_to_completion();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].tokens.len(), 5);
        assert_eq!(rs[0].reason, FinishReason::MaxTokens);
        assert!(rs[0].ttft >= 0.0);
        assert!(rs[0].total_time >= rs[0].ttft);
    }

    #[test]
    fn batch_of_requests_all_complete() {
        let mut e = engine(Method::Hata, 3);
        for i in 0..6 {
            e.submit(req(i, 30 + (i as usize) * 7, 4));
        }
        let rs = e.run_to_completion();
        assert_eq!(rs.len(), 6);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert!(rs.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(e.metrics.completed, 6);
    }

    #[test]
    fn stop_token_halts_generation() {
        let mut e = engine(Method::Dense, 2);
        // find what the model generates first, then use it as stop token
        e.submit(req(7, 20, 3));
        let first = e.run_to_completion()[0].tokens[0];
        let mut e2 = engine(Method::Dense, 2);
        let mut r = req(8, 20, 10);
        r.stop_token = Some(first);
        e2.submit(r);
        let rs = e2.run_to_completion();
        assert_eq!(rs[0].reason, FinishReason::StopToken);
        assert_eq!(rs[0].tokens.len(), 1); // the stop token itself
    }

    #[test]
    fn chunked_prefill_same_output_as_whole() {
        // prompt longer than prefill_chunk exercises the chunked path;
        // outputs must match a single-chunk engine (dense method).
        let cfg = preset("hata-gqa").unwrap();
        let mk = |chunk: usize| {
            let serve = ServeConfig {
                method: Method::Dense,
                budget: 0,
                max_batch: 1,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let mut rng = Rng::new(3);
            let weights = Weights::random(&cfg, &mut rng);
            let aux = MethodAux::default();
            Engine::new(std::sync::Arc::new(Model::new(cfg.clone(), weights, aux)), serve)
        };
        let mut small = mk(16);
        let mut big = mk(4096);
        small.submit(req(1, 100, 4));
        big.submit(req(1, 100, 4));
        assert_eq!(small.run_to_completion()[0].tokens, big.run_to_completion()[0].tokens);
    }

    #[test]
    fn metrics_populated() {
        let mut e = engine(Method::Hata, 2);
        e.submit(req(1, 25, 3));
        e.run_to_completion();
        assert!(e.metrics.generated_tokens >= 2);
        assert!(e.metrics.step_latency.count() > 0);
    }
}
