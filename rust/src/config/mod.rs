//! Typed configuration: model shapes, serving parameters, attention-method
//! selection, and the artifact manifest written by `python -m compile.aot`.

pub mod manifest;

use crate::util::json::Json;

/// Transformer shape parameters (mirror of python/compile/model.py).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Preset / manifest model name.
    pub name: String,
    /// Vocabulary size (byte tokenizer: 128).
    pub vocab: usize,
    /// Residual stream width.
    pub d_model: usize,
    /// Transformer block count.
    pub n_layers: usize,
    /// Query head count.
    pub n_heads: usize,
    /// KV head count (GQA when < `n_heads`).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// MLP hidden width.
    pub ffn_hidden: usize,
    /// RoPE base frequency.
    pub rope_theta: f32,
    /// Hash code bits per key (HATA).
    pub rbit: usize,
    /// First N layers always run dense attention (paper Sec 5.1).
    pub dense_layers: usize,
}

impl ModelConfig {
    /// Query heads per KV head.
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Packed u32 words per hash code.
    pub fn code_words(&self) -> usize {
        self.rbit / 32
    }

    /// Bytes of K+V cache per token at the nominal f32 storage width —
    /// the figure the *analytical* offload model (`kvcache/offload.rs`)
    /// prices traffic with. The live tier meters actual stored bytes,
    /// which scale with `ServeConfig::kv_dtype`
    /// ([`crate::tensor::simd::KvDtype::bytes`]).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim * 4
    }

    /// Bytes of packed key-code cache per token.
    pub fn code_bytes_per_token(&self) -> usize {
        self.n_layers * self.n_kv_heads * self.code_words() * 4
    }

    /// Parse a config object (manifest.json `config` entry).
    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        let get = |k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("config missing field {k}"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("unnamed")
                .to_string(),
            vocab: get("vocab")? as usize,
            d_model: get("d_model")? as usize,
            n_layers: get("n_layers")? as usize,
            n_heads: get("n_heads")? as usize,
            n_kv_heads: get("n_kv_heads")? as usize,
            head_dim: get("head_dim")? as usize,
            ffn_hidden: get("ffn_hidden")? as usize,
            rope_theta: get("rope_theta")? as f32,
            rbit: get("rbit")? as usize,
            dense_layers: get("dense_layers")? as usize,
        })
    }

    /// Serialize back to the manifest JSON shape.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("vocab", Json::num(self.vocab as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("n_kv_heads", Json::num(self.n_kv_heads as f64)),
            ("head_dim", Json::num(self.head_dim as f64)),
            ("ffn_hidden", Json::num(self.ffn_hidden as f64)),
            ("rope_theta", Json::num(self.rope_theta as f64)),
            ("rbit", Json::num(self.rbit as f64)),
            ("dense_layers", Json::num(self.dense_layers as f64)),
        ])
    }
}

/// Trained tiny-model presets (match python CONFIGS) and untrained
/// scale mirrors of the paper's evaluation models (perf sweeps only —
/// attention-layer shapes are what matters for memory traffic).
pub fn preset(name: &str) -> Option<ModelConfig> {
    let base = ModelConfig {
        name: name.to_string(),
        vocab: 128,
        d_model: 128,
        n_layers: 3,
        n_heads: 8,
        n_kv_heads: 8,
        head_dim: 16,
        ffn_hidden: 256,
        rope_theta: 10000.0,
        rbit: 128,
        dense_layers: 1,
    };
    match name {
        "hata-mha" => Some(base),
        "hata-gqa" => Some(ModelConfig { n_kv_heads: 2, ..base }),
        // Paper Table 4 mirrors: true head counts / head_dim, layer count
        // scaled down 8x (memory traffic per layer is the unit of Fig 5).
        // Mirrors use dense_layers = 0: the paper's dense-first-two-of-32
        // layers is an accuracy measure; with 8x fewer layers it would
        // distort the perf ratios the mirrors exist for.
        "mirror-llama2-7b" => Some(ModelConfig {
            vocab: 32000,
            d_model: 4096,
            n_layers: 4,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            ffn_hidden: 11008,
            dense_layers: 0,
            ..base
        }),
        "mirror-llama31-8b" => Some(ModelConfig {
            vocab: 32000,
            d_model: 4096,
            n_layers: 4,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            ffn_hidden: 14336,
            dense_layers: 0,
            ..base
        }),
        "mirror-qwen25-14b" => Some(ModelConfig {
            vocab: 32000,
            d_model: 5120,
            n_layers: 6,
            n_heads: 40,
            n_kv_heads: 8,
            head_dim: 128,
            ffn_hidden: 13824,
            dense_layers: 0,
            ..base
        }),
        "mirror-qwen25-32b" => Some(ModelConfig {
            vocab: 32000,
            d_model: 5120,
            n_layers: 8,
            n_heads: 40,
            n_kv_heads: 8,
            head_dim: 128,
            ffn_hidden: 27392,
            dense_layers: 0,
            ..base
        }),
        _ => None,
    }
}

/// Which attention/selection method the engine uses per request batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Full attention over the whole KV cache.
    Dense,
    /// Oracle: exact qk scores, then top-k (upper bound for all methods).
    ExactTopK,
    /// The paper: trained-hash Hamming scores, then top-k.
    Hata,
    /// Loki-style low-rank (first `channels` PCA dims of q/k).
    Loki,
    /// Quest-style block min/max upper-bound scores, block granularity.
    Quest,
    /// MagicPIG-style LSH sampling (random projections, K*L bits).
    MagicPig,
    /// StreamingLLM: attention sinks + recent window (compression).
    StreamingLlm,
    /// H2O: cumulative-attention heavy hitters + recent (compression).
    H2o,
    /// SnapKV: observation-window selected + recent (compression).
    SnapKv,
}

impl Method {
    /// Parse a CLI method name (accepts the short aliases printed by
    /// `hata --help`).
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "dense" => Method::Dense,
            "topk" | "exact-topk" | "exact" => Method::ExactTopK,
            "hata" => Method::Hata,
            "loki" => Method::Loki,
            "quest" => Method::Quest,
            "magicpig" | "mp" => Method::MagicPig,
            "streamingllm" | "sl" => Method::StreamingLlm,
            "h2o" => Method::H2o,
            "snapkv" | "s-kv" => Method::SnapKv,
            _ => return None,
        })
    }

    /// Canonical lowercase name (CLI value, table row label).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Dense => "dense",
            Method::ExactTopK => "topk",
            Method::Hata => "hata",
            Method::Loki => "loki",
            Method::Quest => "quest",
            Method::MagicPig => "magicpig",
            Method::StreamingLlm => "streamingllm",
            Method::H2o => "h2o",
            Method::SnapKv => "snapkv",
        }
    }

    /// Every method, in the paper's table column order.
    pub fn all() -> &'static [Method] {
        &[
            Method::Dense,
            Method::ExactTopK,
            Method::Hata,
            Method::Loki,
            Method::Quest,
            Method::MagicPig,
            Method::StreamingLlm,
            Method::H2o,
            Method::SnapKv,
        ]
    }
}

/// How the engine executes a batch step's work items across the
/// threadpool (`--exec`). Both modes run the identical per-item
/// routines on disjoint state, so they are bit-identical for every
/// (threads, batch, tile, method) combination; they differ only in
/// synchronization cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Barrier-per-stage reference path: each layer's stages run as
    /// consecutive [`crate::util::threadpool::ThreadPool::scatter`]
    /// calls, with a full-pool barrier between stages.
    Barrier,
    /// Dependency-driven work queue (default): the whole step becomes
    /// one [`crate::util::workqueue::TaskGraph`] per batch, and a
    /// sequence's next task starts the moment its own inputs are ready
    /// instead of waiting on the batch's slowest straggler.
    Queue,
}

impl ExecMode {
    /// Parse a CLI value (`queue` | `barrier`).
    pub fn parse(s: &str) -> Option<ExecMode> {
        Some(match s.to_ascii_lowercase().as_str() {
            "queue" | "q" => ExecMode::Queue,
            "barrier" | "scatter" | "b" => ExecMode::Barrier,
            _ => return None,
        })
    }

    /// Canonical lowercase name (CLI value, bench row label).
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Barrier => "barrier",
            ExecMode::Queue => "queue",
        }
    }
}

/// Serving engine parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Attention/selection method driving sparse decode.
    pub method: Method,
    /// Sparse token budget per decode step (0 = method default / dense).
    pub budget: usize,
    /// Max sequences decoded together per step.
    pub max_batch: usize,
    /// Max tokens a prefill chunk may process per scheduler step
    /// (`--prefill-chunk-budget`): long prompts stream through the step
    /// loop in pieces of this many tokens, interleaved with in-flight
    /// decode, so one long prefill never stalls everyone's TPOT.
    /// Bit-identical outputs for any value >= 1.
    pub prefill_chunk: usize,
    /// Max requests in flight across the serving front door
    /// (`--max-concurrent`): the router's admission semaphore blocks —
    /// or, for open-loop clients, sheds — submissions beyond this
    /// count. 0 = unbounded (the closed-loop default).
    pub max_concurrent: usize,
    /// Waiting/served batching policy ratio (`--waiting-served-ratio`):
    /// while live sequences are running, the scheduler defers admitting
    /// queued requests until `waiting >= ratio * running`, so prefill
    /// passes amortize over bigger admission batches instead of
    /// injecting one prompt at a time into a busy decode batch. 0.0
    /// (default) admits whenever a slot and KV pages are free.
    pub waiting_served_ratio: f64,
    /// Query rows per tiled-prefill attention work item: each prefill
    /// chunk fans (sequence, kv-head, query-tile) tiles of this many
    /// query tokens across the engine threadpool. Any value >= 1 is
    /// bit-identical to any other (and to the token-serial reference);
    /// it only shapes the fan-out granularity.
    pub prefill_tile: usize,
    /// KV pool capacity in tokens (across sequences).
    pub kv_capacity: usize,
    /// Physical KV block size in tokens (`--kv-block`): the paged
    /// cache's page granularity and the pool's accounting unit. Must
    /// be >= 1; bit-identical outputs for any value.
    pub kv_block: usize,
    /// Store KV in fixed-size physical blocks behind per-sequence
    /// block tables (`--paged`) instead of contiguous per-head
    /// regions. Enables copy-on-write prefix sharing and cheap
    /// preempt/resume; bit-identical to the contiguous layout.
    pub paged: bool,
    /// Run the residency tier (`--offload`): cold K/V blocks spill to a
    /// rate-limited slow-tier store and decode fetches back only the
    /// blocks its top-k selection needs, scoring the always-resident
    /// code cache first (paper Sec 5.3). Implies `paged`; bit-identical
    /// to the resident paged run.
    pub offload: bool,
    /// Device-resident K/V budget in tokens when offloading
    /// (`--offload-budget`): after each step, cold blocks beyond this
    /// many tokens are written back to the slow tier. 0 keeps only the
    /// append-target blocks resident (maximum offload pressure).
    pub offload_budget: usize,
    /// How many layers ahead the decode graph fetches the blocks a
    /// (sequence, head) selected last step (`--prefetch-depth`): layer
    /// L's fetch is released once layer L-depth's QKV finishes, so it
    /// overlaps layer L-1's attention at the default depth of 1
    /// (InfiniGen-style). 0 releases the fetch at the layer itself.
    pub prefetch_depth: usize,
    /// Loki channels (low-rank dims) when method == Loki.
    pub loki_channels: usize,
    /// Quest block size when method == Quest.
    pub quest_block: usize,
    /// MagicPIG bits per LSH table signature.
    pub magicpig_k: usize,
    /// MagicPIG LSH table count.
    pub magicpig_l: usize,
    /// StreamingLLM sink count.
    pub sinks: usize,
    /// SnapKV observation window.
    pub snapkv_window: usize,
    /// Worker threads for the engine's batched decode/prefill fan-out
    /// (1 = strictly serial; higher fans (sequence, kv-head) work items
    /// across the threadpool).
    pub threads: usize,
    /// Step executor: dependency-driven work queue (default) or the
    /// barrier-per-stage scatter reference path. Bit-identical outputs
    /// either way — this knob only trades synchronization overhead.
    pub exec_mode: ExecMode,
    /// Cache the decode task graph across steps (`--graph-cache`, on by
    /// default): the graph's shape depends only on (batch size, layers,
    /// kv heads), so steady-state decode steps reuse the cached
    /// structure and only rebind task payloads — the zero-allocation
    /// fast path. Off = rebuild the graph every token (the pre-cache
    /// reference behavior). Bit-identical outputs either way.
    pub graph_cache: bool,
    /// Softmax sampling temperature; 0 = greedy (argmax), the default so
    /// serving stays deterministic.
    pub temperature: f32,
    /// Base seed for per-request sampling RNG streams.
    pub seed: u64,
    /// f32 kernel tier (`--kernels`): scalar `Reference`, the
    /// bit-identical vectorized `Simd` default, or the `SimdFma`
    /// fast-math tier (see docs/PERFORMANCE.md §--kernels).
    pub kernels: crate::tensor::simd::KernelMode,
    /// KV storage dtype (`--kv-dtype`): f32 (default, bit-identical to
    /// the historical layout) or packed bf16/f16 rows that halve
    /// attention memory traffic and offload ledger bytes. Hash codes and
    /// selector side structures always hash the pre-quantization f32
    /// keys, so top-k selection is dtype-independent (see
    /// docs/PERFORMANCE.md §--kv-dtype for the accuracy contract).
    pub kv_dtype: crate::tensor::simd::KvDtype,
}

impl Default for ServeConfig {
    fn default() -> Self {
        // Paper Table 5 settings, scaled where noted in DESIGN.md.
        ServeConfig {
            method: Method::Hata,
            budget: 64,
            max_batch: 8,
            prefill_chunk: 512,
            max_concurrent: 0,
            waiting_served_ratio: 0.0,
            prefill_tile: 32,
            kv_capacity: 1 << 20,
            kv_block: crate::kvcache::pool::PAGE_TOKENS,
            paged: false,
            offload: false,
            offload_budget: 0,
            prefetch_depth: 1,
            loki_channels: 4, // paper: 32 of 128 dims; here 4 of 16 (same 25%)
            quest_block: 16,  // paper: 32; scaled to our shorter contexts
            magicpig_k: 10,
            magicpig_l: 150,
            sinks: 4,
            snapkv_window: 16,
            threads: 1,
            exec_mode: ExecMode::Queue,
            graph_cache: true,
            temperature: 0.0,
            seed: 0,
            kernels: crate::tensor::simd::KernelMode::default(),
            kv_dtype: crate::tensor::simd::KvDtype::F32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_roundtrips_json() {
        let c = preset("hata-gqa").unwrap();
        let j = c.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn group_and_words() {
        let c = preset("hata-gqa").unwrap();
        assert_eq!(c.group(), 4);
        assert_eq!(c.code_words(), 4);
        let m = preset("hata-mha").unwrap();
        assert_eq!(m.group(), 1);
    }

    #[test]
    fn kv_bytes_accounting() {
        let c = preset("hata-mha").unwrap();
        // 2 (K+V) * 3 layers * 8 kv heads * 16 dims * 4 bytes
        assert_eq!(c.kv_bytes_per_token(), 2 * 3 * 8 * 16 * 4);
        assert_eq!(c.code_bytes_per_token(), 3 * 8 * 4 * 4);
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(*m));
        }
        assert_eq!(Method::parse("nope"), None);
        assert_eq!(Method::parse("MP"), Some(Method::MagicPig));
    }

    #[test]
    fn unknown_preset_none() {
        assert!(preset("gpt5").is_none());
    }

    #[test]
    fn exec_mode_parse_roundtrip() {
        for m in [ExecMode::Barrier, ExecMode::Queue] {
            assert_eq!(ExecMode::parse(m.name()), Some(m));
        }
        assert_eq!(ExecMode::parse("scatter"), Some(ExecMode::Barrier));
        assert_eq!(ExecMode::parse("nope"), None);
    }

    #[test]
    fn mirror_models_have_paper_head_layout() {
        let l2 = preset("mirror-llama2-7b").unwrap();
        assert_eq!((l2.n_heads, l2.n_kv_heads, l2.head_dim), (32, 32, 128));
        let l31 = preset("mirror-llama31-8b").unwrap();
        assert_eq!((l31.n_heads, l31.n_kv_heads), (32, 8));
        assert_eq!(l31.group(), 4);
    }
}
