//! Artifact manifest: the contract between `python -m compile.aot` and the
//! Rust runtime. Parses `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::ModelConfig;
use crate::util::json::Json;

/// One lowered HLO graph and the static shape it was compiled for.
#[derive(Clone, Debug, PartialEq)]
pub struct HloEntry {
    /// Graph kind ("prefill", "decode_dense", "decode_hata").
    pub kind: String,
    /// Static token-capacity bucket the graph was lowered for.
    pub bucket: usize,
    /// top-k budget compiled into decode_hata graphs (0 otherwise).
    pub budget: usize,
    /// HLO text file path.
    pub path: PathBuf,
}

/// Everything exported for one model.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    /// Model shape parameters.
    pub config: ModelConfig,
    /// Weights .npz path.
    pub weights: PathBuf,
    /// rbit -> hash-weights npz path.
    pub hash_weights: Vec<(usize, PathBuf)>,
    /// Flat dotted-key parameter order shared with aot.py.
    pub param_order: Vec<String>,
    /// All lowered graphs.
    pub hlo: Vec<HloEntry>,
}

impl ModelArtifacts {
    /// Trained hash weights for a bit width, when exported.
    pub fn hash_weights_for(&self, rbit: usize) -> Option<&PathBuf> {
        self.hash_weights.iter().find(|(r, _)| *r == rbit).map(|(_, p)| p)
    }

    /// Smallest bucket >= needed length for a given graph kind.
    pub fn pick_bucket(&self, kind: &str, needed: usize) -> Option<&HloEntry> {
        self.hlo
            .iter()
            .filter(|e| e.kind == kind && e.bucket >= needed)
            .min_by_key(|e| e.bucket)
    }
}

/// The whole manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    /// Every exported model.
    pub models: Vec<ModelArtifacts>,
    /// Artifact directory all paths are relative to.
    pub root: PathBuf,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let models_obj = j
            .get("models")
            .and_then(|m| m.as_obj())
            .context("manifest missing models")?;
        let mut models = Vec::new();
        for (_, entry) in models_obj {
            let config = ModelConfig::from_json(
                entry.get("config").context("model missing config")?,
            )?;
            let weights = root.join(
                entry
                    .get("weights")
                    .and_then(|v| v.as_str())
                    .context("model missing weights")?,
            );
            let mut hash_weights = Vec::new();
            if let Some(hw) = entry.get("hash_weights").and_then(|v| v.as_obj()) {
                for (rbit, p) in hw {
                    hash_weights.push((
                        rbit.parse::<usize>().context("bad rbit key")?,
                        root.join(p.as_str().context("bad hash path")?),
                    ));
                }
            }
            hash_weights.sort_by_key(|(r, _)| *r);
            let param_order = entry
                .get("param_order")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default();
            let mut hlo = Vec::new();
            if let Some(arr) = entry.get("hlo").and_then(|v| v.as_arr()) {
                for e in arr {
                    hlo.push(HloEntry {
                        kind: e
                            .get("kind")
                            .and_then(|v| v.as_str())
                            .unwrap_or("")
                            .to_string(),
                        bucket: e.get("bucket").and_then(|v| v.as_usize()).unwrap_or(0),
                        budget: e.get("budget").and_then(|v| v.as_usize()).unwrap_or(0),
                        path: root.join(
                            e.get("path").and_then(|v| v.as_str()).unwrap_or(""),
                        ),
                    });
                }
            }
            models.push(ModelArtifacts { config, weights, hash_weights, param_order, hlo });
        }
        Ok(Manifest { models, root })
    }

    /// Artifacts of one model by config name.
    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .iter()
            .find(|m| m.config.name == name)
            .with_context(|| format!("manifest has no model {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> String {
        r#"{
          "models": {
            "m1": {
              "config": {"name":"m1","vocab":128,"d_model":128,"n_layers":3,
                         "n_heads":8,"n_kv_heads":2,"head_dim":16,
                         "ffn_hidden":256,"rope_theta":10000.0,"rbit":128,
                         "dense_layers":1},
              "weights": "m1.weights.npz",
              "hash_weights": {"128": "m1.hash_r128.npz", "64": "m1.hash_r64.npz"},
              "param_order": ["embed","final_norm"],
              "hlo": [
                {"kind":"prefill","bucket":256,"path":"m1.prefill.b256.hlo.txt"},
                {"kind":"decode_hata","bucket":256,"budget":64,"path":"d.hlo.txt"},
                {"kind":"decode_hata","bucket":1024,"budget":64,"path":"d2.hlo.txt"}
              ]
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_picks_buckets() {
        let dir = std::env::temp_dir().join(format!("hata_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let model = m.model("m1").unwrap();
        assert_eq!(model.config.n_kv_heads, 2);
        assert_eq!(model.hash_weights.len(), 2);
        assert!(model.hash_weights_for(64).is_some());
        assert!(model.hash_weights_for(999).is_none());
        let e = model.pick_bucket("decode_hata", 300).unwrap();
        assert_eq!(e.bucket, 1024);
        let e = model.pick_bucket("decode_hata", 10).unwrap();
        assert_eq!(e.bucket, 256);
        assert!(model.pick_bucket("decode_hata", 5000).is_none());
        assert!(m.model("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
