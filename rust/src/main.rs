//! `hata` CLI — leader entrypoint for the serving coordinator and the
//! table/figure regeneration commands (DESIGN.md §6).
//!
//! Subcommands:
//!   serve     run the continuous-batching engine over a synthetic load
//!   generate  one-shot generation from a prompt
//!   eval      regenerate accuracy tables/figures (--table N | --fig N)
//!   pjrt      run the AOT HLO artifacts through the PJRT runtime
//!   info      print model/artifact inventory

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use hata::bench::eval::{fidelity, task_accuracy};
use hata::bench::report::{fmt, Table};
use hata::bench::tasks::TaskKind;
use hata::config::manifest::Manifest;
use hata::config::{preset, ExecMode, Method, ServeConfig};
use hata::coordinator::request::Request;
use hata::coordinator::router::{Policy, Router};
use hata::kvcache::MethodAux;
use hata::model::{tokenizer, weights::Weights, Model};
use hata::tensor::simd::{KernelMode, KvDtype};
use hata::util::cli::Args;
use hata::util::rng::Rng;
use hata::util::stats::Summary;

const FLAGS: &[&str] = &[
    "model", "method", "budget", "ctx", "samples", "seed", "table", "fig",
    "requests", "workers", "threads", "temperature", "max-new", "prompt",
    "artifacts", "rbit", "verbose!", "random-weights!", "out", "prefill-tile",
    "exec", "graph-cache", "kernels", "kv-block", "kv-dtype", "paged!", "offload!",
    "offload-budget", "prefetch-depth", "max-concurrent",
    "waiting-served-ratio", "prefill-chunk-budget",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, FLAGS, true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("verbose") {
        hata::util::logger::set_level(hata::util::logger::Level::Debug);
    }
    let r = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("eval") => cmd_eval(&args),
        Some("pjrt") => cmd_pjrt(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: hata <serve|generate|eval|pjrt|info> [flags]
  --model NAME      model preset or manifest entry (default hata-mha)
  --method M        dense|topk|hata|loki|quest|magicpig|streamingllm|h2o|snapkv
  --budget K        sparse token budget (default 64)
  --ctx N           task context length (default 512)
  --samples N       samples per cell (default 10)
  --table N         regenerate table 1|2|6|7|8|10
  --fig N           regenerate figure 6|7|8
  --requests N      serve: number of synthetic requests
  --workers N       serve: router workers
  --max-concurrent N  serve: admission cap on requests in flight across
                    all workers (default 0 = unbounded); submission
                    blocks at the front door when full
  --waiting-served-ratio R  serve: defer admitting into a running batch
                    until waiting >= R * live (default 0 = admit
                    eagerly); batches admissions to amortize prefill
  --prefill-chunk-budget N  prompt tokens prefilled per request per step
                    (default 512), interleaved with decode in the same
                    step; bit-identical for any value >= 1
  --threads N       engine threadpool width (default 1 = serial)
  --prefill-tile N  query rows per tiled-prefill work item (default 32;
                    any value is bit-identical, it only shapes fan-out)
  --exec MODE       step executor: queue (dependency-driven work queue,
                    default) | barrier (scatter-per-stage reference);
                    outputs are bit-identical either way
  --graph-cache V   on (default) caches the decode task graph across
                    steps (rebuild only on batch-shape change; the
                    zero-allocation steady-state fast path) | off
                    rebuilds it every token; bit-identical either way
  --kernels MODE    f32 kernel tier: reference (scalar) | simd (default,
                    runtime AVX2/NEON dispatch, bit-identical to
                    reference) | simd-fma (fast-math FMA + poly exp,
                    ULP-bounded; see docs/PERFORMANCE.md)
  --kv-dtype D      KV storage dtype: f32 (default, bit-identical to the
                    historical layout) | bf16 | f16 — packed half rows
                    halve attention memory traffic and offload bytes;
                    hash codes are computed from pre-quantization keys,
                    so top-k selection matches the f32 run exactly and
                    only attention values carry bounded rounding error
                    (docs/PERFORMANCE.md)
  --paged           store KV in fixed-size physical blocks behind
                    per-sequence block tables: copy-on-write prefix
                    sharing + cheap preempt/resume, bit-identical to
                    the contiguous layout (default off)
  --kv-block N      physical KV block size in tokens (default 64);
                    any value >= 1 is bit-identical
  --offload         spill cold K/V blocks to a slow-tier store and fetch
                    back only the blocks decode's top-k selection needs
                    (codes stay device-resident; implies --paged;
                    bit-identical to the resident paged run)
  --offload-budget N  device-resident K/V token budget while offloading
                    (default 0 = keep only append-target blocks hot)
  --prefetch-depth N  layers of lookahead for the decode-graph block
                    prefetch (default 1 = fetch layer L during layer
                    L-1's attention, InfiniGen-style; 0 = fetch at the
                    layer itself)
  --temperature T   sampling temperature (default 0 = greedy)
  --random-weights  use random weights instead of artifacts (smoke mode)
  --artifacts DIR   artifact directory (default artifacts)";

/// Load a model: trained artifacts when available, random otherwise.
fn load_model(args: &Args, serve: &ServeConfig) -> Result<Model> {
    let name = args.str("model", "hata-mha");
    let dir = args.str("artifacts", "artifacts");
    let rbit = args.usize("rbit", 128)?;
    if !args.flag("random-weights") {
        if let Ok(manifest) = Manifest::load(&dir) {
            if let Ok(arts) = manifest.model(&name) {
                let mut cfg = arts.config.clone();
                cfg.rbit = rbit;
                let mut weights = Weights::load(&arts.weights, &cfg)?;
                if let Some(hw) = arts.hash_weights_for(rbit) {
                    weights.load_hash(hw, &cfg)?;
                } else if serve.method == Method::Hata {
                    bail!("no trained hash weights for rbit={rbit}");
                }
                let aux = MethodAux::build(&cfg, serve, None, 7);
                let mut model = Model::new(cfg, weights, aux);
                model.kernels = serve.kernels;
                return Ok(model);
            }
        }
        eprintln!("note: artifacts not found; falling back to random weights");
    }
    let cfg = preset(&name).with_context(|| format!("unknown preset {name}"))?;
    let mut rng = Rng::new(0);
    let weights = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, serve, None, 7);
    let mut model = Model::new(cfg, weights, aux);
    model.kernels = serve.kernels;
    Ok(model)
}

/// Parse an on/off CLI value (accepts true/false and 1/0 aliases).
fn parse_on_off(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Some(true),
        "off" | "false" | "0" => Some(false),
        _ => None,
    }
}

fn serve_config(args: &Args) -> Result<ServeConfig> {
    let method = Method::parse(&args.str("method", "hata")).context("bad --method")?;
    let base = ServeConfig::default();
    let exec_mode =
        ExecMode::parse(&args.str("exec", base.exec_mode.name())).context("bad --exec")?;
    let graph_cache = parse_on_off(&args.str("graph-cache", "on"))
        .context("bad --graph-cache (expected on|off)")?;
    let kernels =
        KernelMode::parse(&args.str("kernels", base.kernels.name())).context("bad --kernels")?;
    let kv_dtype =
        KvDtype::parse(&args.str("kv-dtype", base.kv_dtype.name())).context("bad --kv-dtype")?;
    Ok(ServeConfig {
        method,
        budget: args.usize("budget", 64)?,
        threads: args.usize("threads", 1)?,
        prefill_tile: args.usize("prefill-tile", base.prefill_tile)?,
        exec_mode,
        graph_cache,
        temperature: args.f64("temperature", 0.0)? as f32,
        seed: args.u64("seed", 0)?,
        kernels,
        kv_dtype,
        kv_block: args.usize("kv-block", base.kv_block)?,
        paged: args.flag("paged") || args.flag("offload"),
        offload: args.flag("offload"),
        offload_budget: args.usize("offload-budget", base.offload_budget)?,
        prefetch_depth: args.usize("prefetch-depth", base.prefetch_depth)?,
        max_concurrent: args.usize("max-concurrent", base.max_concurrent)?,
        waiting_served_ratio: args.f64("waiting-served-ratio", base.waiting_served_ratio)?,
        prefill_chunk: args.usize("prefill-chunk-budget", base.prefill_chunk)?,
        ..base
    })
}

fn cmd_generate(args: &Args) -> Result<()> {
    let serve = serve_config(args)?;
    let model = load_model(args, &serve)?;
    let prompt = args.str("prompt", "&qt=VK; the quick brown fox ?qt=");
    let max_new = args.usize("max-new", 8)?;
    let selector = hata::model::make_selector(&serve);
    let mut cache = hata::kvcache::SeqKvCache::new(&model.cfg, &serve);
    let mut state = hata::model::SeqState::new(&model.cfg);
    let mut scratch = hata::model::DecodeScratch::new(&model.cfg);
    let out = model.generate(
        &tokenizer::encode(&prompt),
        max_new,
        &serve,
        hata::model::sel_ref(&selector),
        &mut cache,
        &mut state,
        &mut scratch,
    );
    println!("prompt: {prompt}");
    println!("output: {}", tokenizer::decode(&out));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let serve = serve_config(args)?;
    let model = Arc::new(load_model(args, &serve)?);
    let n_requests = args.usize("requests", 16)?;
    let workers = args.usize("workers", 1)?;
    let ctx = args.usize("ctx", 256)?;
    let max_new = args.usize("max-new", 8)?;
    let corpus = hata::bench::tasks::Corpus::new(0);
    let mut rng = Rng::new(args.u64("seed", 0)?);
    let mut router = Router::new(Arc::clone(&model), serve.clone(), workers, Policy::LeastLoaded);
    let t0 = std::time::Instant::now();
    let mut streams = Vec::with_capacity(n_requests);
    for id in 0..n_requests {
        let (prompt, _) =
            hata::bench::tasks::make_task(TaskKind::Ns, &corpus, &mut rng, ctx, None);
        // submit_stream blocks at the admission gate under
        // --max-concurrent, so this loop doubles as a closed-loop client
        streams.push(router.submit_stream(Request {
            id: id as u64,
            prompt: tokenizer::encode(&prompt),
            max_new_tokens: max_new,
            stop_token: None,
            arrival: 0.0,
        }));
    }
    let mut gen = 0usize;
    let mut served = 0usize;
    let mut ttft = Summary::new();
    let mut tpot = Summary::new();
    for stream in streams {
        let out = stream.wait();
        if let Some(r) = out.response {
            served += 1;
            gen += r.tokens.len();
            ttft.add(r.ttft * 1e3);
            if r.tokens.len() > 1 {
                tpot.add((r.total_time - r.ttft) / (r.tokens.len() - 1) as f64 * 1e3);
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests ({} tokens generated) in {:.2}s -> {:.1} tok/s, method={}, budget={}",
        served,
        gen,
        wall,
        gen as f64 / wall,
        serve.method.name(),
        serve.budget
    );
    println!(
        "ttft p50={:.1}ms p99={:.1}ms | tpot mean={:.2}ms p99={:.2}ms",
        ttft.p50(),
        ttft.p99(),
        tpot.mean(),
        tpot.p99()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str("artifacts", "artifacts");
    match Manifest::load(&dir) {
        Ok(m) => {
            for model in &m.models {
                println!("model {}: {:?}", model.config.name, model.config);
                for (rbit, p) in &model.hash_weights {
                    println!("  hash rbit={rbit}: {}", p.display());
                }
                for e in &model.hlo {
                    println!("  hlo {} bucket={} budget={}", e.kind, e.bucket, e.budget);
                }
            }
        }
        Err(e) => println!("no artifacts ({e}); presets: hata-mha hata-gqa mirror-*"),
    }
    Ok(())
}

fn cmd_pjrt(args: &Args) -> Result<()> {
    let dir = args.str("artifacts", "artifacts");
    let name = args.str("model", "hata-mha");
    let manifest = Manifest::load(&dir)?;
    let arts = manifest.model(&name)?;
    let ctx = args.usize("ctx", 192)?;
    let max_new = args.usize("max-new", 6)?;
    let budget = args.usize("budget", 64)?;
    let pm = hata::runtime::PjrtModel::load(arts, ctx + max_new)?;
    let corpus = hata::bench::tasks::Corpus::new(0);
    let mut rng = Rng::new(1);
    let (prompt, ans) =
        hata::bench::tasks::make_task(TaskKind::Ns, &corpus, &mut rng, ctx, None);
    let toks = tokenizer::encode(&prompt);
    let dense = pm.generate(&toks, max_new, 0)?;
    let hata_out = pm.generate(&toks, max_new, budget)?;
    println!("task answer : {ans}");
    println!("pjrt dense  : {}", tokenizer::decode(&dense));
    println!("pjrt hata   : {}", tokenizer::decode(&hata_out));
    Ok(())
}

// ---------------------------------------------------------------- eval

/// Method columns shared by the table proxies (paper Tables 1/2).
fn table_methods() -> Vec<(Method, bool)> {
    vec![
        (Method::Dense, false),
        (Method::Loki, true),
        (Method::Quest, true),
        (Method::MagicPig, true),
        (Method::StreamingLlm, true),
        (Method::H2o, true),
        (Method::SnapKv, true),
        (Method::Hata, true),
    ]
}

fn cmd_eval(args: &Args) -> Result<()> {
    let table = args.usize("table", 0)?;
    let fig = args.usize("fig", 0)?;
    let samples = args.usize("samples", 10)?;
    let seed = args.u64("seed", 0)?;
    let out_dir = args.str("out", "bench_results");
    match (table, fig) {
        (1, _) | (2, _) | (6, _) | (7, _) | (8, _) | (10, _) => {
            eval_accuracy_table(args, table, samples, seed, &out_dir)
        }
        (_, 6) => eval_fig6(args, samples.max(1), seed, &out_dir),
        (_, 7) => eval_budget_ablation(args, samples, seed, &out_dir),
        (_, 8) => eval_rbit_ablation(args, samples, seed, &out_dir),
        _ => bail!("pass --table 1|2|6|7|8|10 or --fig 6|7|8"),
    }
}

fn eval_accuracy_table(
    args: &Args,
    table: usize,
    samples: usize,
    seed: u64,
    out: &str,
) -> Result<()> {
    // table -> (model, ctx, budget, kinds); see DESIGN.md §6
    let (default_model, ctx, budget, kinds): (&str, usize, usize, Vec<TaskKind>) = match table {
        1 | 6 | 8 => (
            "hata-mha",
            512,
            64,
            vec![TaskKind::Qa, TaskKind::Ns, TaskKind::Fwe, TaskKind::Vt],
        ),
        2 | 10 => ("hata-mha", 1024, 32, TaskKind::all().to_vec()),
        7 => ("hata-gqa", 512, 64, vec![TaskKind::Ns, TaskKind::Nmk, TaskKind::Qa]),
        _ => bail!("unknown table {table}"),
    };
    let model_name = args.str("model", default_model);
    let ctx = args.usize("ctx", ctx)?;
    let budget = args.usize("budget", budget)?;
    let mut header = vec!["Method".to_string()];
    header.extend(kinds.iter().map(|k| k.name().to_string()));
    header.push("AVG".into());
    header.push("recall@k".into());
    header.push("out_err".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "Table {table} proxy: accuracy on synthetic suite (model={model_name}, ctx={ctx}, budget={budget})"
        ),
        &header_refs,
    );
    let methods: Vec<(Method, bool)> = if table == 7 {
        vec![(Method::Dense, false), (Method::ExactTopK, true), (Method::Hata, true)]
    } else {
        table_methods()
    };
    for (method, uses_budget) in methods {
        let serve = ServeConfig {
            method,
            budget: if uses_budget { budget } else { 0 },
            ..Default::default()
        };
        let model = load_model_named(args, &model_name, &serve)?;
        let mut row = vec![method.name().to_string()];
        let mut sum = 0.0;
        for &kind in &kinds {
            let acc = task_accuracy(&model, &serve, kind, ctx, samples, seed, None);
            sum += acc;
            row.push(fmt(100.0 * acc));
        }
        row.push(fmt(100.0 * sum / kinds.len() as f64));
        if method != Method::Dense {
            let f = fidelity(&model, &serve, ctx.min(512), 3.min(samples), seed + 1);
            row.push(fmt(f.recall));
            row.push(fmt(f.output_err));
        } else {
            row.push("-".into());
            row.push("-".into());
        }
        t.row(row);
        eprintln!("[eval] {} done", method.name());
    }
    t.write_csv(out, &format!("table{table}"))?;
    println!("{}", t.render());
    Ok(())
}

fn load_model_named(args: &Args, name: &str, serve: &ServeConfig) -> Result<Model> {
    let mut argv = vec!["--model".to_string(), name.to_string()];
    if args.flag("random-weights") {
        argv.push("--random-weights".into());
    }
    argv.push("--artifacts".into());
    argv.push(args.str("artifacts", "artifacts"));
    let sub = Args::parse(&argv, FLAGS, false).unwrap();
    load_model(&sub, serve)
}

fn eval_fig6(args: &Args, samples: usize, seed: u64, out: &str) -> Result<()> {
    // Needle-in-a-haystack heatmap: ctx x depth for dense and hata.
    let ctxs = args.usize_list("ctx", &[128, 256, 512, 1024])?;
    let depths = [0.1, 0.3, 0.5, 0.7, 0.9];
    for method in [Method::Dense, Method::Hata] {
        let serve = ServeConfig {
            method,
            budget: if method == Method::Dense { 0 } else { 48 },
            ..Default::default()
        };
        let model = load_model(args, &serve)?;
        let mut t = Table::new(
            &format!("Fig 6 proxy: NIAH accuracy, method={}", method.name()),
            &["ctx", "d=0.1", "d=0.3", "d=0.5", "d=0.7", "d=0.9"],
        );
        for &ctx in &ctxs {
            let mut row = vec![ctx.to_string()];
            for &d in &depths {
                let acc =
                    task_accuracy(&model, &serve, TaskKind::Ns, ctx, samples, seed, Some(d));
                row.push(fmt(100.0 * acc));
            }
            t.row(row);
        }
        println!("{}", t.render());
        t.write_csv(out, &format!("fig6_{}", method.name()))?;
    }
    Ok(())
}

fn eval_budget_ablation(args: &Args, samples: usize, seed: u64, out: &str) -> Result<()> {
    let ctx = args.usize("ctx", 512)?;
    let budgets = args.usize_list("budget", &[8, 16, 32, 64, 128])?;
    let mut t = Table::new(
        &format!("Fig 7 proxy: token-budget ablation (ctx={ctx})"),
        &["budget", "hata", "quest", "loki", "recall_hata"],
    );
    for &b in &budgets {
        let mut row = vec![b.to_string()];
        for method in [Method::Hata, Method::Quest, Method::Loki] {
            let serve = ServeConfig { method, budget: b, ..Default::default() };
            let model = load_model(args, &serve)?;
            let acc = task_accuracy(&model, &serve, TaskKind::Ns, ctx, samples, seed, None);
            row.push(fmt(100.0 * acc));
        }
        let serve = ServeConfig { method: Method::Hata, budget: b, ..Default::default() };
        let model = load_model(args, &serve)?;
        let f = fidelity(&model, &serve, ctx, 3.min(samples), seed + 1);
        row.push(fmt(f.recall));
        t.row(row);
        eprintln!("[eval] budget={b} done");
    }
    t.write_csv(out, "fig7")?;
    println!("{}", t.render());
    Ok(())
}

fn eval_rbit_ablation(args: &Args, samples: usize, seed: u64, out: &str) -> Result<()> {
    let ctx = args.usize("ctx", 512)?;
    let rbits = args.usize_list("rbit", &[64, 128, 256])?;
    let budget = args.usize("budget", 48)?;
    let mut t = Table::new(
        &format!("Fig 8 proxy: hash-bit ablation (ctx={ctx}, budget={budget})"),
        &["rbit", "NS acc", "recall@k", "out_err"],
    );
    for &rbit in &rbits {
        let serve = ServeConfig { method: Method::Hata, budget, ..Default::default() };
        let mut argv = vec!["--rbit".to_string(), rbit.to_string()];
        if args.flag("random-weights") {
            argv.push("--random-weights".into());
        }
        argv.push("--artifacts".into());
        argv.push(args.str("artifacts", "artifacts"));
        argv.push("--model".into());
        argv.push(args.str("model", "hata-mha"));
        let sub = Args::parse(&argv, FLAGS, false).unwrap();
        let model = match load_model(&sub, &serve) {
            Ok(m) => m,
            Err(e) => {
                println!("rbit={rbit}: skipped ({e})");
                continue;
            }
        };
        let acc = task_accuracy(&model, &serve, TaskKind::Ns, ctx, samples, seed, None);
        let f = fidelity(&model, &serve, ctx, 3.min(samples), seed + 1);
        t.row(vec![rbit.to_string(), fmt(100.0 * acc), fmt(f.recall), fmt(f.output_err)]);
        eprintln!("[eval] rbit={rbit} done");
    }
    t.write_csv(out, "fig8")?;
    println!("{}", t.render());
    Ok(())
}
