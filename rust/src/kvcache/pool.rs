//! Page-accounting KV pool: vLLM-style admission bookkeeping.
//!
//! Physical storage lives in [`super::SeqKvCache`] vectors; this pool
//! tracks page ownership so the scheduler can admit/deny prefills and
//! detect memory pressure exactly the way a paged allocator would.

use std::collections::BTreeMap;

/// Tokens per KV page (the allocation granularity).
pub const PAGE_TOKENS: usize = 64;

/// Admission/accounting failures.
#[derive(Debug, thiserror::Error)]
pub enum PoolError {
    /// Not enough free pages for the requested growth.
    #[error("kv pool exhausted: need {need} pages, free {free}")]
    Exhausted {
        /// Pages the growth needed.
        need: usize,
        /// Pages currently free.
        free: usize,
    },
    /// Release of a sequence the pool never saw.
    #[error("unknown sequence {0}")]
    UnknownSeq(u64),
}

/// Token-capacity bookkeeping per sequence.
#[derive(Debug)]
pub struct KvPool {
    capacity_pages: usize,
    free_pages: usize,
    seqs: BTreeMap<u64, SeqAlloc>,
}

#[derive(Debug, Default, Clone)]
struct SeqAlloc {
    pages: usize,
    tokens: usize,
}

impl KvPool {
    /// Pool with `capacity_tokens / PAGE_TOKENS` pages.
    pub fn new(capacity_tokens: usize) -> Self {
        let pages = capacity_tokens / PAGE_TOKENS;
        KvPool { capacity_pages: pages, free_pages: pages, seqs: BTreeMap::new() }
    }

    /// Total capacity in tokens.
    pub fn capacity_tokens(&self) -> usize {
        self.capacity_pages * PAGE_TOKENS
    }

    /// Unreserved capacity in tokens.
    pub fn free_tokens(&self) -> usize {
        self.free_pages * PAGE_TOKENS
    }

    /// Fraction of pages reserved (0 = empty, 1 = full).
    pub fn utilization(&self) -> f64 {
        1.0 - self.free_pages as f64 / self.capacity_pages.max(1) as f64
    }

    /// Can `tokens` more tokens be appended to `seq` without exhaustion?
    pub fn can_grow(&self, seq: u64, tokens: usize) -> bool {
        let cur = self.seqs.get(&seq).cloned().unwrap_or_default();
        let need_pages = (cur.tokens + tokens).div_ceil(PAGE_TOKENS);
        need_pages.saturating_sub(cur.pages) <= self.free_pages
    }

    /// Reserve pages for `tokens` appended tokens of `seq`.
    pub fn grow(&mut self, seq: u64, tokens: usize) -> Result<(), PoolError> {
        let cur = self.seqs.entry(seq).or_default();
        let need_pages = (cur.tokens + tokens).div_ceil(PAGE_TOKENS);
        let extra = need_pages.saturating_sub(cur.pages);
        if extra > self.free_pages {
            return Err(PoolError::Exhausted { need: extra, free: self.free_pages });
        }
        self.free_pages -= extra;
        cur.pages = need_pages;
        cur.tokens += tokens;
        Ok(())
    }

    /// Release everything held by `seq` (on completion or preemption).
    pub fn release(&mut self, seq: u64) -> Result<(), PoolError> {
        let alloc = self.seqs.remove(&seq).ok_or(PoolError::UnknownSeq(seq))?;
        self.free_pages += alloc.pages;
        Ok(())
    }

    /// Tokens accounted to one sequence.
    pub fn seq_tokens(&self, seq: u64) -> usize {
        self.seqs.get(&seq).map(|a| a.tokens).unwrap_or(0)
    }

    /// Sequences currently holding pages.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_and_release_roundtrip() {
        let mut pool = KvPool::new(10 * PAGE_TOKENS);
        pool.grow(1, 100).unwrap();
        assert_eq!(pool.seq_tokens(1), 100);
        assert_eq!(pool.free_tokens(), (10 - 2) * PAGE_TOKENS);
        pool.grow(1, 28).unwrap(); // fits in the 2nd page
        assert_eq!(pool.free_tokens(), (10 - 2) * PAGE_TOKENS);
        pool.grow(1, 1).unwrap(); // 129 tokens -> 3rd page
        assert_eq!(pool.free_tokens(), (10 - 3) * PAGE_TOKENS);
        pool.release(1).unwrap();
        assert_eq!(pool.free_tokens(), 10 * PAGE_TOKENS);
        assert_eq!(pool.active_seqs(), 0);
    }

    #[test]
    fn exhaustion_detected() {
        let mut pool = KvPool::new(2 * PAGE_TOKENS);
        assert!(pool.can_grow(1, 2 * PAGE_TOKENS));
        assert!(!pool.can_grow(1, 2 * PAGE_TOKENS + 1));
        pool.grow(1, 2 * PAGE_TOKENS).unwrap();
        let err = pool.grow(2, 1).unwrap_err();
        assert!(matches!(err, PoolError::Exhausted { .. }));
    }

    #[test]
    fn release_unknown_errors() {
        let mut pool = KvPool::new(PAGE_TOKENS);
        assert!(matches!(pool.release(9), Err(PoolError::UnknownSeq(9))));
    }

    #[test]
    fn utilization_tracks() {
        let mut pool = KvPool::new(4 * PAGE_TOKENS);
        assert_eq!(pool.utilization(), 0.0);
        pool.grow(1, 2 * PAGE_TOKENS).unwrap();
        assert!((pool.utilization() - 0.5).abs() < 1e-9);
    }
}
