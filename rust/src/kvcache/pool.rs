//! Paged KV allocator: vLLM-style block bookkeeping with refcounted
//! copy-on-write prefix sharing.
//!
//! The pool owns the *identity* layer of the paged cache: it hands out
//! physical block ids, tracks per-sequence block tables, and refcounts
//! blocks shared across sequences (identical prompt prefixes registered
//! by token-chain hash). Physical storage for those ids lives in
//! [`super::BlockStore`]; the contiguous (non-paged) build keeps using
//! the pool purely as admission accounting, exactly as before.

use std::collections::{BTreeMap, HashMap};

/// Tokens per KV page (the default allocation granularity; override per
/// pool with [`KvPool::with_block`], surfaced as `--kv-block`).
pub const PAGE_TOKENS: usize = 64;

/// Admission/accounting failures.
#[derive(Debug, thiserror::Error)]
pub enum PoolError {
    /// Not enough free pages for the requested growth.
    #[error("kv pool exhausted: need {need} pages, free {free}")]
    Exhausted {
        /// Pages the growth needed.
        need: usize,
        /// Pages currently free.
        free: usize,
    },
    /// Release of a sequence the pool never saw.
    #[error("unknown sequence {0}")]
    UnknownSeq(u64),
}

/// Block allocator + per-sequence block tables + CoW prefix registry.
#[derive(Debug)]
pub struct KvPool {
    block_tokens: usize,
    capacity_pages: usize,
    /// Recycled block ids (LIFO).
    free: Vec<u32>,
    /// High-water mark: ids below this have been handed out at least once.
    next_fresh: u32,
    /// Per-physical-block reference count (0 = free / never used).
    refcount: Vec<u32>,
    /// Prefix chain-hash -> shared physical block.
    prefix_map: HashMap<(u64, u64), u32>,
    /// Reverse of `prefix_map` for cleanup on free.
    prefix_of: HashMap<u32, (u64, u64)>,
    seqs: BTreeMap<u64, SeqAlloc>,
}

#[derive(Debug, Default, Clone)]
struct SeqAlloc {
    tokens: usize,
    blocks: Vec<u32>,
}

impl KvPool {
    /// Pool with `capacity_tokens.div_ceil(PAGE_TOKENS)` pages (rounded
    /// *up*: a 63-token capacity is one page, not zero).
    pub fn new(capacity_tokens: usize) -> Self {
        Self::with_block(capacity_tokens, PAGE_TOKENS)
    }

    /// Pool with a custom block size in tokens (`--kv-block`).
    pub fn with_block(capacity_tokens: usize, block_tokens: usize) -> Self {
        let block_tokens = block_tokens.max(1);
        let pages = capacity_tokens.div_ceil(block_tokens);
        KvPool {
            block_tokens,
            capacity_pages: pages,
            free: Vec::new(),
            next_fresh: 0,
            refcount: Vec::new(),
            prefix_map: HashMap::new(),
            prefix_of: HashMap::new(),
            seqs: BTreeMap::new(),
        }
    }

    /// Tokens per block for this pool.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Total capacity in pages.
    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    /// Unreserved capacity in pages. Shared blocks count once, so prefix
    /// sharing *increases* this relative to the sum of sequence lengths.
    pub fn free_pages(&self) -> usize {
        self.capacity_pages - (self.next_fresh as usize - self.free.len())
    }

    /// Total capacity in tokens.
    pub fn capacity_tokens(&self) -> usize {
        self.capacity_pages * self.block_tokens
    }

    /// Unreserved capacity in tokens.
    pub fn free_tokens(&self) -> usize {
        self.free_pages() * self.block_tokens
    }

    /// Fraction of pages reserved (0 = empty, 1 = full). A zero-capacity
    /// pool is empty, not full.
    pub fn utilization(&self) -> f64 {
        if self.capacity_pages == 0 {
            return 0.0;
        }
        1.0 - self.free_pages() as f64 / self.capacity_pages as f64
    }

    /// Can `tokens` more tokens be appended to `seq` without exhaustion?
    pub fn can_grow(&self, seq: u64, tokens: usize) -> bool {
        let (cur_tokens, cur_blocks) =
            self.seqs.get(&seq).map(|a| (a.tokens, a.blocks.len())).unwrap_or((0, 0));
        let need_pages = (cur_tokens + tokens).div_ceil(self.block_tokens);
        need_pages.saturating_sub(cur_blocks) <= self.free_pages()
    }

    /// Reserve blocks for `tokens` appended tokens of `seq`, extending
    /// its block table with newly allocated physical ids. A failed grow
    /// changes nothing (no partial allocation, no phantom sequence).
    pub fn grow(&mut self, seq: u64, tokens: usize) -> Result<(), PoolError> {
        let free_pages = self.free_pages();
        let (cur_tokens, cur_blocks) =
            self.seqs.get(&seq).map(|a| (a.tokens, a.blocks.len())).unwrap_or((0, 0));
        let need_pages = (cur_tokens + tokens).div_ceil(self.block_tokens);
        let extra = need_pages.saturating_sub(cur_blocks);
        if extra > free_pages {
            return Err(PoolError::Exhausted { need: extra, free: free_pages });
        }
        for _ in 0..extra {
            let id = self.alloc_block();
            self.seqs.entry(seq).or_default().blocks.push(id);
        }
        let cur = self.seqs.entry(seq).or_default();
        cur.tokens += tokens;
        Ok(())
    }

    /// Pop a free id (or mint a fresh one) with refcount 1. Callers must
    /// have checked [`KvPool::free_pages`] first.
    fn alloc_block(&mut self) -> u32 {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                let id = self.next_fresh;
                self.next_fresh += 1;
                id
            }
        };
        if self.refcount.len() <= id as usize {
            self.refcount.resize(id as usize + 1, 0);
        }
        debug_assert_eq!(self.refcount[id as usize], 0, "allocated a live block");
        self.refcount[id as usize] = 1;
        id
    }

    /// Release everything held by `seq` (on completion or preemption).
    /// Shared blocks are decref'd; only the last holder frees them.
    pub fn release(&mut self, seq: u64) -> Result<(), PoolError> {
        let alloc = self.seqs.remove(&seq).ok_or(PoolError::UnknownSeq(seq))?;
        for id in alloc.blocks {
            self.decref(id);
        }
        Ok(())
    }

    fn decref(&mut self, id: u32) {
        let rc = &mut self.refcount[id as usize];
        debug_assert!(*rc > 0, "double free of block {id}");
        *rc -= 1;
        if *rc == 0 {
            if let Some(key) = self.prefix_of.remove(&id) {
                self.prefix_map.remove(&key);
            }
            self.free.push(id);
        }
    }

    /// The physical block table of `seq` (empty if unknown).
    pub fn seq_blocks(&self, seq: u64) -> &[u32] {
        self.seqs.get(&seq).map(|a| a.blocks.as_slice()).unwrap_or(&[])
    }

    /// `seq`'s append-target block — the last table entry, where its
    /// next token lands. The residency tier exempts these from eviction:
    /// appends must always write device-resident rows. `None` if the
    /// sequence is unknown or holds no blocks yet.
    pub fn seq_tail(&self, seq: u64) -> Option<u32> {
        self.seq_blocks(seq).last().copied()
    }

    /// Reference count of one physical block (0 = free).
    pub fn refcount(&self, block: u32) -> u32 {
        self.refcount.get(block as usize).copied().unwrap_or(0)
    }

    /// Try to replace `seq`'s block-table entry `idx` with an existing
    /// shared block carrying the same prefix chain-hash `key`. On a hit
    /// the sequence's own block is decref'd (usually freed) and the entry
    /// now aliases the shared block; on a miss the sequence's block is
    /// registered under `key` for future arrivals. Returns whether the
    /// entry now aliases a previously registered block (a "prefix hit").
    pub fn dedup_block(&mut self, seq: u64, idx: usize, key: (u64, u64)) -> bool {
        let mine = match self.seqs.get(&seq) {
            Some(a) if idx < a.blocks.len() => a.blocks[idx],
            _ => return false,
        };
        match self.prefix_map.get(&key).copied() {
            Some(shared) if shared != mine => {
                self.refcount[shared as usize] += 1;
                self.seqs.get_mut(&seq).unwrap().blocks[idx] = shared;
                self.decref(mine);
                true
            }
            Some(_) => false,
            None => {
                self.prefix_map.insert(key, mine);
                self.prefix_of.insert(mine, key);
                false
            }
        }
    }

    /// Copy-on-write: make `seq`'s block-table entry `idx` exclusively
    /// owned before a write. Returns `Ok(Some((src, dst)))` when a fresh
    /// block was allocated — the caller must copy the payload `src → dst`
    /// — and `Ok(None)` when the entry was already exclusive.
    pub fn ensure_writable(
        &mut self,
        seq: u64,
        idx: usize,
    ) -> Result<Option<(u32, u32)>, PoolError> {
        let cur = match self.seqs.get(&seq) {
            Some(a) if idx < a.blocks.len() => a.blocks[idx],
            _ => return Err(PoolError::UnknownSeq(seq)),
        };
        if self.refcount[cur as usize] <= 1 {
            return Ok(None);
        }
        if self.free_pages() == 0 {
            return Err(PoolError::Exhausted { need: 1, free: 0 });
        }
        let id = self.alloc_block();
        self.seqs.get_mut(&seq).unwrap().blocks[idx] = id;
        self.decref(cur);
        Ok(Some((cur, id)))
    }

    /// Fork `child` as a full CoW alias of `parent`: the child's table
    /// aliases every parent block (all refcounts bumped), so it costs no
    /// new pages until either side triggers [`KvPool::ensure_writable`].
    pub fn fork(&mut self, parent: u64, child: u64) -> Result<(), PoolError> {
        let src = self.seqs.get(&parent).ok_or(PoolError::UnknownSeq(parent))?.clone();
        for &id in &src.blocks {
            self.refcount[id as usize] += 1;
        }
        self.seqs.insert(child, src);
        Ok(())
    }

    /// Physical blocks minted so far (the id high-water mark). The paged
    /// [`super::BlockStore`] sizes its planes to cover exactly these ids,
    /// so storage grows with actual use, not pool capacity.
    pub fn minted_pages(&self) -> usize {
        self.next_fresh as usize
    }

    /// Tokens accounted to one sequence.
    pub fn seq_tokens(&self, seq: u64) -> usize {
        self.seqs.get(&seq).map(|a| a.tokens).unwrap_or(0)
    }

    /// Sequences currently holding pages.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    #[test]
    fn grow_and_release_roundtrip() {
        let mut pool = KvPool::new(10 * PAGE_TOKENS);
        pool.grow(1, 100).unwrap();
        assert_eq!(pool.seq_tokens(1), 100);
        assert_eq!(pool.free_tokens(), (10 - 2) * PAGE_TOKENS);
        pool.grow(1, 28).unwrap(); // fits in the 2nd page
        assert_eq!(pool.free_tokens(), (10 - 2) * PAGE_TOKENS);
        pool.grow(1, 1).unwrap(); // 129 tokens -> 3rd page
        assert_eq!(pool.free_tokens(), (10 - 3) * PAGE_TOKENS);
        assert_eq!(pool.seq_blocks(1).len(), 3);
        pool.release(1).unwrap();
        assert_eq!(pool.free_tokens(), 10 * PAGE_TOKENS);
        assert_eq!(pool.active_seqs(), 0);
    }

    #[test]
    fn exhaustion_detected() {
        let mut pool = KvPool::new(2 * PAGE_TOKENS);
        assert!(pool.can_grow(1, 2 * PAGE_TOKENS));
        assert!(!pool.can_grow(1, 2 * PAGE_TOKENS + 1));
        pool.grow(1, 2 * PAGE_TOKENS).unwrap();
        let err = pool.grow(2, 1).unwrap_err();
        assert!(matches!(err, PoolError::Exhausted { .. }));
    }

    #[test]
    fn release_unknown_errors() {
        let mut pool = KvPool::new(PAGE_TOKENS);
        assert!(matches!(pool.release(9), Err(PoolError::UnknownSeq(9))));
    }

    #[test]
    fn utilization_tracks() {
        let mut pool = KvPool::new(4 * PAGE_TOKENS);
        assert_eq!(pool.utilization(), 0.0);
        pool.grow(1, 2 * PAGE_TOKENS).unwrap();
        assert!((pool.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn non_multiple_capacity_rounds_up() {
        // regression: `new(63)` used to truncate to a zero-page pool
        let pool = KvPool::new(PAGE_TOKENS - 1);
        assert_eq!(pool.capacity_pages(), 1);
        assert!(pool.can_grow(1, 1));
        let pool = KvPool::new(PAGE_TOKENS + 1);
        assert_eq!(pool.capacity_pages(), 2);
    }

    #[test]
    fn zero_capacity_pool_reports_empty() {
        // regression: utilization used to report 1.0 for 0/0 pages
        let pool = KvPool::new(0);
        assert_eq!(pool.utilization(), 0.0);
        assert!(!pool.can_grow(1, 1));
    }

    #[test]
    fn block_ids_are_recycled() {
        let mut pool = KvPool::with_block(4 * 8, 8);
        pool.grow(1, 16).unwrap();
        let first: Vec<u32> = pool.seq_blocks(1).to_vec();
        assert_eq!(first, vec![0, 1]);
        pool.release(1).unwrap();
        pool.grow(2, 8).unwrap();
        // LIFO free list: the most recently freed id comes back first
        assert_eq!(pool.seq_blocks(2), &[1]);
        assert_eq!(pool.refcount(0), 0);
        assert_eq!(pool.refcount(1), 1);
    }

    #[test]
    fn dedup_shares_and_release_keeps_shared_alive() {
        let mut pool = KvPool::with_block(8 * 4, 4);
        pool.grow(1, 4).unwrap();
        pool.grow(2, 4).unwrap();
        let key = (0xabcd, 0x1234);
        assert!(!pool.dedup_block(1, 0, key), "first arrival registers");
        assert!(pool.dedup_block(2, 0, key), "second arrival hits");
        let shared = pool.seq_blocks(1)[0];
        assert_eq!(pool.seq_blocks(2)[0], shared);
        assert_eq!(pool.refcount(shared), 2);
        // seq 2's original block went back to the free list
        assert_eq!(pool.free_pages(), 8 - 1);
        pool.release(1).unwrap();
        assert_eq!(pool.refcount(shared), 1, "still held by seq 2");
        // a third arrival still hits the registry through seq 2's ref
        pool.grow(3, 4).unwrap();
        assert!(pool.dedup_block(3, 0, key));
        pool.release(2).unwrap();
        pool.release(3).unwrap();
        assert_eq!(pool.refcount(shared), 0);
        assert_eq!(pool.free_pages(), 8);
        // registry was cleaned: a fresh arrival re-registers, no hit
        pool.grow(4, 4).unwrap();
        assert!(!pool.dedup_block(4, 0, key));
    }

    #[test]
    fn cow_unshares_on_write() {
        let mut pool = KvPool::with_block(8 * 4, 4);
        pool.grow(1, 8).unwrap();
        pool.fork(1, 2).unwrap();
        let b0 = pool.seq_blocks(1)[0];
        assert_eq!(pool.refcount(b0), 2);
        // exclusive entries don't copy
        pool.grow(3, 4).unwrap();
        assert!(pool.ensure_writable(3, 0).unwrap().is_none());
        // shared entries do
        let (src, dst) = pool.ensure_writable(2, 0).unwrap().expect("copy");
        assert_eq!(src, b0);
        assert_ne!(dst, b0);
        assert_eq!(pool.refcount(b0), 1);
        assert_eq!(pool.refcount(dst), 1);
        assert_ne!(pool.seq_blocks(1)[0], pool.seq_blocks(2)[0]);
    }

    #[test]
    fn allocator_invariants_under_random_interleavings() {
        // free + Σ per-seq blocks == capacity at every step (no leaks, no
        // double frees), and can_grow ⇔ grow agreement — over randomized
        // grow/release interleavings without sharing.
        check(40, |rng: &mut Rng| {
            let bt = [1, 3, 4, 8][rng.below(4)];
            let cap_pages = 1 + rng.below(12);
            let mut pool = KvPool::with_block(cap_pages * bt, bt);
            let mut live: Vec<u64> = Vec::new();
            for step in 0..200u64 {
                if rng.below(3) == 0 && !live.is_empty() {
                    let seq = live.swap_remove(rng.below(live.len()));
                    pool.release(seq).unwrap();
                } else {
                    let seq = if !live.is_empty() && rng.below(2) == 0 {
                        live[rng.below(live.len())]
                    } else {
                        live.push(step + 1000);
                        step + 1000
                    };
                    let tokens = 1 + rng.below(3 * bt);
                    let fits = pool.can_grow(seq, tokens);
                    let grew = pool.grow(seq, tokens).is_ok();
                    prop_assert(fits == grew, "can_grow disagrees with grow")?;
                    if !grew && pool.seq_tokens(seq) == 0 {
                        live.retain(|&s| s != seq);
                        let _ = pool.release(seq);
                    }
                }
                let held: usize = live.iter().map(|&s| pool.seq_blocks(s).len()).sum();
                prop_assert(
                    pool.free_pages() + held == pool.capacity_pages(),
                    "pages leaked or double-freed",
                )?;
                let uniq: std::collections::HashSet<u32> =
                    live.iter().flat_map(|&s| pool.seq_blocks(s)).copied().collect();
                prop_assert(uniq.len() == held, "one block owned twice without sharing")?;
            }
            for seq in live {
                pool.release(seq).unwrap();
            }
            prop_assert(pool.free_pages() == pool.capacity_pages(), "drain leaked")
        });
    }
}
