//! Physical block storage for the paged KV cache.
//!
//! [`super::pool::KvPool`] owns block *identity* (ids, per-sequence block
//! tables, refcounts); this module owns the *bytes*: one [`BlockStore`]
//! per engine holds, for every (layer, kv-head) plane, a contiguous
//! K/V/code arena indexed by physical block id. A sequence's view of a
//! plane is its block table — logical token `t` lives at physical row
//! `table[t / block_tokens] * block_tokens + t % block_tokens` — so
//! growing a sequence, preempting it, or sharing a prompt prefix across
//! sequences never moves data, only table entries.
//!
//! ## Concurrency contract
//!
//! The store is shared (`Arc<BlockStore>`) across every sequence cache
//! and, through [`PagedRef`], across worker threads. Safety follows the
//! same discipline the engine already uses for `HeadHandle`/`RawSlice`
//! payloads in `model/mod.rs`:
//!
//! * [`BlockStore::ensure_blocks`] (the only reallocation point) is an
//!   `unsafe fn` called exclusively on the engine thread between model
//!   passes, while no worker holds a view.
//! * [`PagedRef`]s are captured on the engine thread during serial work
//!   item construction (after any `ensure_blocks`), so the plane
//!   pointers they carry stay valid for the whole pass.
//! * During a pass, workers write only rows of blocks exclusively owned
//!   by their own (sequence, plane) work item, and read only rows in
//!   their own sequence's table; shared (refcount > 1) CoW blocks are
//!   never written — appends land at `t >= prompt_len`, past every
//!   dedup-shared block (see `SeqKvCache::dedup_prefix`). Distinct work
//!   items therefore never touch overlapping addresses.

use std::cell::UnsafeCell;

use super::tier::TierController;
use crate::tensor::simd::KvDtype;

/// Unified read view of one (layer, kv-head) cache plane: either a
/// sequence's contiguous region (`bt` empty, rows are token-indexed) or
/// the shared paged plane plus the sequence's block table. Everything a
/// reader needs to resolve logical token rows, in either layout.
pub struct HeadRead<'a> {
    /// Key rows, `[rows, kv_elems]` row-major in *packed* storage form
    /// (whole plane when paged): `dh` f32 slots per row for f32 storage,
    /// `dh / 2` slots holding two half-precision values each otherwise.
    pub k: &'a [f32],
    /// Value rows, `[rows, kv_elems]` row-major, packed as `k`.
    pub v: &'a [f32],
    /// Packed key-code words, `[rows, words]`.
    pub codes: &'a [u64],
    /// Block table mapping logical block index -> physical block id;
    /// empty means the contiguous layout (physical row == token).
    pub bt: &'a [u32],
    /// Tokens per physical block (0 in the contiguous layout).
    pub block_tokens: usize,
    /// Storage dtype of the `k`/`v` rows.
    pub kv_dtype: KvDtype,
}

impl HeadRead<'_> {
    /// Physical row of logical token `t` under this view's layout.
    #[inline]
    pub fn row(&self, t: usize) -> usize {
        if self.bt.is_empty() {
            t
        } else {
            self.bt[t / self.block_tokens] as usize * self.block_tokens + t % self.block_tokens
        }
    }
}

/// Raw, copyable capture of one (plane, block table) pair: the paged
/// analogue of the plain `&mut HeadCache` inside `HeadMut`, carried by
/// `HeadMut`/`HeadHandle` so append and attention work items can run on
/// worker threads. Captured on the engine thread while workers are idle
/// (work items and task payloads are built serially); dereferenced only
/// inside a running work item under the module-level concurrency
/// contract.
#[derive(Clone, Copy)]
pub struct PagedRef {
    k: *mut f32,
    v: *mut f32,
    codes: *mut u64,
    /// Plane length in f32 elements (`k` and `v` are the same shape).
    kv_len: usize,
    /// Plane length in u64 code words.
    codes_len: usize,
    table: *const u32,
    table_len: usize,
    /// f32 storage slots per K/V row (`dh` for f32, `dh / 2` packed for
    /// the half dtypes).
    kv_elems: usize,
    kv_dtype: KvDtype,
    words: usize,
    block_tokens: usize,
    /// (layer, kv-head) plane index this ref was captured for.
    plane: usize,
    /// Residency-tier controller, null unless `--offload` is active.
    /// The engine's `Arc` keeps it alive for the whole run.
    tier: *const TierController,
}

// SAFETY: a PagedRef is addresses plus copies of shared scalars; every
// dereference is an `unsafe fn` whose caller must prove the access is
// ordered per the module-level contract (disjoint rows, no concurrent
// reallocation).
unsafe impl Send for PagedRef {}

impl PagedRef {
    /// Tokens per physical block.
    #[inline]
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Storage dtype of the K/V rows this ref addresses.
    #[inline]
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_dtype
    }

    /// Attach a residency-tier controller (done by `SeqKvCache` when the
    /// engine enabled `--offload`). The pointee must outlive every
    /// dereference of this ref — the engine's `Arc` guarantees it.
    pub fn attach_tier(&mut self, tier: *const TierController) {
        self.tier = tier;
    }

    /// True when a residency tier is attached (`--offload` runs only).
    #[inline]
    pub fn has_tier(&self) -> bool {
        !self.tier.is_null()
    }

    /// Demand-fetch every Host-resident block covering logical tokens
    /// `[0, len)` of this plane. No-op without a tier.
    ///
    /// # Safety
    /// As for [`PagedRef::table`]; the attached tier controller must be
    /// live (engine holds the `Arc` for the run).
    pub unsafe fn ensure_range_resident(&self, len: usize) {
        if self.tier.is_null() || len == 0 {
            return;
        }
        let blocks = &self.table()[..len.div_ceil(self.block_tokens)];
        (*self.tier).fetch_blocks(self.plane, blocks, false);
    }

    /// Demand-fetch the blocks holding the selected logical token
    /// `indices` of this plane. No-op without a tier.
    ///
    /// # Safety
    /// As for [`PagedRef::ensure_range_resident`]; every index must be
    /// covered by the table.
    pub unsafe fn ensure_selected_resident(&self, indices: &[u32], scratch: &mut Vec<u32>) {
        if self.tier.is_null() {
            return;
        }
        self.selected_blocks(indices, scratch);
        (*self.tier).fetch_blocks(self.plane, scratch, false);
    }

    /// Fetch previously recorded physical `blocks` of this plane ahead
    /// of demand (the layer-ahead prefetch task body). No-op without a
    /// tier.
    ///
    /// # Safety
    /// As for [`PagedRef::ensure_range_resident`]; the recorded ids must
    /// still be owned by (or shared with) this ref's sequence, which
    /// holds them at least until its next decode step.
    pub unsafe fn prefetch_blocks(&self, blocks: &[u32]) {
        if self.tier.is_null() {
            return;
        }
        (*self.tier).fetch_blocks(self.plane, blocks, true);
    }

    /// Resolve the deduplicated physical block ids covering logical
    /// token `indices` into `out` (cleared first). `indices` need not be
    /// sorted — selector output order is arbitrary.
    ///
    /// # Safety
    /// As for [`PagedRef::table`]; every index must be covered.
    pub unsafe fn selected_blocks(&self, indices: &[u32], out: &mut Vec<u32>) {
        out.clear();
        let table = self.table();
        for &t in indices {
            let b = table[t as usize / self.block_tokens];
            if !out.contains(&b) {
                out.push(b);
            }
        }
    }

    /// Resolve the physical block ids covering logical tokens `[0, len)`
    /// into `out` (cleared first) — the dense-attention analogue of
    /// [`PagedRef::selected_blocks`].
    ///
    /// # Safety
    /// As for [`PagedRef::table`]; `len` must be covered.
    pub unsafe fn range_blocks(&self, len: usize, out: &mut Vec<u32>) {
        out.clear();
        out.extend_from_slice(&self.table()[..len.div_ceil(self.block_tokens)]);
    }

    /// The sequence's block table.
    ///
    /// # Safety
    /// The table this ref was captured from must still be live and not
    /// concurrently mutated (tables are only rewritten on the engine
    /// thread between passes).
    #[inline]
    pub unsafe fn table<'a>(&self) -> &'a [u32] {
        std::slice::from_raw_parts(self.table, self.table_len)
    }

    /// Physical row of logical token `t`.
    ///
    /// # Safety
    /// As for [`PagedRef::table`]; additionally `t` must be covered by
    /// the table (`t / block_tokens < table.len()`).
    #[inline]
    pub unsafe fn phys_row(&self, t: usize) -> usize {
        let b = *self.table.add(t / self.block_tokens) as usize;
        b * self.block_tokens + t % self.block_tokens
    }

    /// Mutable K row of logical token `t` — *packed* storage form
    /// (`kv_elems` f32 slots; write half dtypes through
    /// [`crate::tensor::simd::pack_row`]).
    ///
    /// # Safety
    /// The caller must own token `t`'s block exclusively (its own
    /// sequence's unshared block, one work item per plane) and no reader
    /// of this row may be live — the append-before-attend ordering the
    /// engine's stage/graph structure provides.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn k_row_mut<'a>(&self, t: usize) -> &'a mut [f32] {
        let r = self.phys_row(t);
        debug_assert!((r + 1) * self.kv_elems <= self.kv_len);
        std::slice::from_raw_parts_mut(self.k.add(r * self.kv_elems), self.kv_elems)
    }

    /// Mutable V row of logical token `t` — packed storage form, as
    /// [`PagedRef::k_row_mut`].
    ///
    /// # Safety
    /// As for [`PagedRef::k_row_mut`].
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn v_row_mut<'a>(&self, t: usize) -> &'a mut [f32] {
        let r = self.phys_row(t);
        debug_assert!((r + 1) * self.kv_elems <= self.kv_len);
        std::slice::from_raw_parts_mut(self.v.add(r * self.kv_elems), self.kv_elems)
    }

    /// Mutable packed-code row of logical token `t`.
    ///
    /// # Safety
    /// As for [`PagedRef::k_row_mut`].
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn code_row_mut<'a>(&self, t: usize) -> &'a mut [u64] {
        let r = self.phys_row(t);
        debug_assert!((r + 1) * self.words <= self.codes_len);
        std::slice::from_raw_parts_mut(self.codes.add(r * self.words), self.words)
    }

    /// Materialize the full-plane read view plus the block table.
    ///
    /// # Safety
    /// No concurrent reallocation ([`BlockStore::ensure_blocks`]) and no
    /// concurrent write to any row this reader will resolve through its
    /// table — guaranteed by the module-level contract (each sequence
    /// reads only its own table's rows; shared blocks are read-only).
    pub unsafe fn read<'a>(&self) -> HeadRead<'a> {
        HeadRead {
            k: std::slice::from_raw_parts(self.k, self.kv_len),
            v: std::slice::from_raw_parts(self.v, self.kv_len),
            codes: std::slice::from_raw_parts(self.codes, self.codes_len),
            bt: std::slice::from_raw_parts(self.table, self.table_len),
            block_tokens: self.block_tokens,
            kv_dtype: self.kv_dtype,
        }
    }
}

/// Per-plane arenas, indexed `[plane][block * block_tokens + slot]`.
struct Planes {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    codes: Vec<Vec<u64>>,
    cap_blocks: usize,
}

/// The shared physical arena behind every paged [`super::SeqKvCache`]:
/// one K/V/code plane per (layer, kv-head), each a dense array of
/// fixed-size blocks. A physical block id addresses the same block slot
/// in *every* plane, so one [`super::pool::KvPool`] table entry relocates
/// a token's K, V and hash codes at once.
pub struct BlockStore {
    n_planes: usize,
    dh: usize,
    /// f32 storage slots per K/V row ([`KvDtype::elems`] of `dh`).
    kv_elems: usize,
    kv_dtype: KvDtype,
    words: usize,
    block_tokens: usize,
    inner: UnsafeCell<Planes>,
}

// SAFETY: all mutation goes through `unsafe fn`s (`ensure_blocks`,
// `copy_block`, and writes via `PagedRef`) whose contracts serialize
// access per the module-level concurrency story; safe accessors only
// read metadata or, for `blocks_equal`, rows the caller observes from
// the engine thread between passes.
unsafe impl Send for BlockStore {}
unsafe impl Sync for BlockStore {}

impl BlockStore {
    /// Empty store for `n_planes` (layer, kv-head) planes of `dh`-wide
    /// K/V rows stored as `kv_dtype` and `words` packed code words per
    /// token, in blocks of `block_tokens` tokens. Planes grow on demand
    /// via [`BlockStore::ensure_blocks`].
    pub fn new(
        n_planes: usize,
        dh: usize,
        words: usize,
        block_tokens: usize,
        kv_dtype: KvDtype,
    ) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        assert!(!kv_dtype.is_half() || dh % 2 == 0, "half kv dtypes need an even head_dim");
        BlockStore {
            n_planes,
            dh,
            kv_elems: kv_dtype.elems(dh),
            kv_dtype,
            words,
            block_tokens,
            inner: UnsafeCell::new(Planes {
                k: (0..n_planes).map(|_| Vec::new()).collect(),
                v: (0..n_planes).map(|_| Vec::new()).collect(),
                codes: (0..n_planes).map(|_| Vec::new()).collect(),
                cap_blocks: 0,
            }),
        }
    }

    /// Tokens per physical block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Per-head *logical* row width of the stored K/V rows (f32 values a
    /// row widens to, independent of storage dtype).
    pub fn dh(&self) -> usize {
        self.dh
    }

    /// f32 storage slots per K/V row (`dh` for f32 storage, `dh / 2`
    /// packed for the half dtypes) — the plane row stride.
    pub fn kv_elems(&self) -> usize {
        self.kv_elems
    }

    /// Storage dtype of the K/V planes.
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_dtype
    }

    /// Packed code words per token.
    pub fn words(&self) -> usize {
        self.words
    }

    /// (layer, kv-head) plane count.
    pub fn n_planes(&self) -> usize {
        self.n_planes
    }

    /// Physical blocks each plane currently holds rows for.
    pub fn cap_blocks(&self) -> usize {
        // SAFETY: metadata read; racing it requires a concurrent
        // `ensure_blocks`, whose contract forbids concurrent access.
        unsafe { (*self.inner.get()).cap_blocks }
    }

    /// Grow every plane to cover physical block ids `< n` (zero-filled
    /// rows). The only operation that moves plane storage.
    ///
    /// # Safety
    /// The caller must have exclusive access to the store: engine thread
    /// only, no worker running, and no live [`PagedRef`] or
    /// [`HeadRead`] view (all were captured before this pass or will be
    /// captured after this call).
    pub unsafe fn ensure_blocks(&self, n: usize) {
        let planes = &mut *self.inner.get();
        if n <= planes.cap_blocks {
            return;
        }
        let bt = self.block_tokens;
        for p in 0..self.n_planes {
            planes.k[p].resize(n * bt * self.kv_elems, 0.0);
            planes.v[p].resize(n * bt * self.kv_elems, 0.0);
            planes.codes[p].resize(n * bt * self.words, 0u64);
        }
        planes.cap_blocks = n;
    }

    /// Capture a [`PagedRef`] for one plane and one sequence's block
    /// table. Creating the ref is address arithmetic only; all
    /// dereferences are `unsafe fn`s with their own contracts. `table`
    /// must stay live (and unmoved) for as long as the ref is
    /// dereferenced — the per-sequence tables are reserved up front and
    /// rewritten only between passes.
    pub fn head_ref(&self, plane: usize, table: &[u32]) -> PagedRef {
        assert!(plane < self.n_planes, "plane {plane} out of range");
        // SAFETY: pointer extraction only; validity of later dereference
        // is the deref site's contract.
        let planes = unsafe { &mut *self.inner.get() };
        PagedRef {
            k: planes.k[plane].as_mut_ptr(),
            v: planes.v[plane].as_mut_ptr(),
            codes: planes.codes[plane].as_mut_ptr(),
            kv_len: planes.k[plane].len(),
            codes_len: planes.codes[plane].len(),
            table: table.as_ptr(),
            table_len: table.len(),
            kv_elems: self.kv_elems,
            kv_dtype: self.kv_dtype,
            words: self.words,
            block_tokens: self.block_tokens,
            plane,
            tier: std::ptr::null(),
        }
    }

    /// Raw K and V row storage of one block in one plane — the residency
    /// tier's spill/fetch data path (the code plane is deliberately not
    /// exposed: codes never leave the device).
    ///
    /// # Safety
    /// The caller must be the only thread touching these rows: either
    /// the engine thread between passes (eviction), or a worker holding
    /// the tier lock fetching a block that no task reads until the fetch
    /// reports it resident. `block < cap_blocks`, and no concurrent
    /// [`BlockStore::ensure_blocks`].
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn block_kv_mut(&self, plane: usize, block: u32) -> (&mut [f32], &mut [f32]) {
        let planes = &mut *self.inner.get();
        let n = self.block_tokens * self.kv_elems;
        let off = block as usize * n;
        let k = planes.k[plane][off..off + n].as_mut_ptr();
        let v = planes.v[plane][off..off + n].as_mut_ptr();
        (std::slice::from_raw_parts_mut(k, n), std::slice::from_raw_parts_mut(v, n))
    }

    /// Copy block `src`'s rows into block `dst` across every plane — the
    /// data half of a copy-on-write unshare
    /// ([`super::pool::KvPool::ensure_writable`]).
    ///
    /// # Safety
    /// As for [`BlockStore::ensure_blocks`]: engine thread only, no
    /// concurrent access. Both ids must be `< cap_blocks`.
    pub unsafe fn copy_block(&self, src: u32, dst: u32) {
        let planes = &mut *self.inner.get();
        let bt = self.block_tokens;
        let e = self.kv_elems;
        for p in 0..self.n_planes {
            let (s, d, n) = (src as usize * bt * e, dst as usize * bt * e, bt * e);
            planes.k[p].copy_within(s..s + n, d);
            planes.v[p].copy_within(s..s + n, d);
            let (s, d, n) =
                (src as usize * bt * self.words, dst as usize * bt * self.words, bt * self.words);
            planes.codes[p].copy_within(s..s + n, d);
        }
    }

    /// Bitwise equality of two blocks across every plane (K, V and
    /// codes) — the dedup debug check that prefix sharing never aliases
    /// divergent data. Engine-thread use between passes.
    pub fn blocks_equal(&self, a: u32, b: u32) -> bool {
        // SAFETY: shared read; callers observe from the engine thread
        // between passes (no concurrent writer), per the module contract.
        let planes = unsafe { &*self.inner.get() };
        let bt = self.block_tokens;
        let e = self.kv_elems;
        let (sa, sb, n) = (a as usize * bt * e, b as usize * bt * e, bt * e);
        let (ca, cb, m) =
            (a as usize * bt * self.words, b as usize * bt * self.words, bt * self.words);
        for p in 0..self.n_planes {
            let len = planes.k[p].len();
            if sa + n > len || sb + n > len {
                return false;
            }
            if planes.k[p][sa..sa + n] != planes.k[p][sb..sb + n]
                || planes.v[p][sa..sa + n] != planes.v[p][sb..sb + n]
                || planes.codes[p][ca..ca + m] != planes.codes[p][cb..cb + m]
            {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_blocks_grows_and_zero_fills() {
        let store = BlockStore::new(2, 4, 2, 8, KvDtype::F32);
        assert_eq!(store.cap_blocks(), 0);
        unsafe { store.ensure_blocks(3) };
        assert_eq!(store.cap_blocks(), 3);
        let table = [2u32, 0u32];
        let r = store.head_ref(1, &table);
        let rd = unsafe { r.read() };
        assert_eq!(rd.k.len(), 3 * 8 * 4);
        assert_eq!(rd.codes.len(), 3 * 8 * 2);
        assert!(rd.k.iter().all(|&x| x == 0.0));
        // logical token 0 lives in physical block 2, token 8 in block 0
        assert_eq!(rd.row(0), 2 * 8);
        assert_eq!(rd.row(9), 1);
        // shrinking requests are no-ops
        unsafe { store.ensure_blocks(1) };
        assert_eq!(store.cap_blocks(), 3);
    }

    #[test]
    fn paged_writes_land_at_table_rows() {
        let store = BlockStore::new(1, 2, 1, 4, KvDtype::F32);
        unsafe { store.ensure_blocks(2) };
        let table = [1u32, 0u32]; // logical blocks swapped
        let r = store.head_ref(0, &table);
        unsafe {
            r.k_row_mut(0).copy_from_slice(&[1.0, 2.0]); // phys row 4
            r.k_row_mut(5).copy_from_slice(&[3.0, 4.0]); // phys row 1
            r.code_row_mut(0)[0] = 7;
        }
        let rd = unsafe { r.read() };
        assert_eq!(&rd.k[4 * 2..5 * 2], &[1.0, 2.0]);
        assert_eq!(&rd.k[2..4], &[3.0, 4.0]);
        assert_eq!(rd.codes[4], 7);
        assert_eq!(rd.row(5), 1);
    }

    #[test]
    fn copy_block_and_equality() {
        let store = BlockStore::new(2, 2, 1, 4, KvDtype::F32);
        unsafe { store.ensure_blocks(3) };
        let table = [0u32];
        let r = store.head_ref(0, &table);
        unsafe {
            r.k_row_mut(1).copy_from_slice(&[5.0, 6.0]);
            r.v_row_mut(1).copy_from_slice(&[-5.0, -6.0]);
            r.code_row_mut(1)[0] = 42;
        }
        assert!(!store.blocks_equal(0, 2));
        unsafe { store.copy_block(0, 2) };
        assert!(store.blocks_equal(0, 2));
        assert!(store.blocks_equal(1, 1));
        // out-of-range ids compare unequal instead of panicking
        assert!(!store.blocks_equal(0, 9));
    }

    #[test]
    fn half_dtype_planes_use_packed_strides() {
        let store = BlockStore::new(2, 4, 1, 8, KvDtype::Bf16);
        assert_eq!(store.dh(), 4);
        assert_eq!(store.kv_elems(), 2);
        unsafe { store.ensure_blocks(3) };
        let table = [2u32, 0u32];
        let r = store.head_ref(1, &table);
        let rd = unsafe { r.read() };
        // half the f32 plane footprint for the same token capacity
        assert_eq!(rd.k.len(), 3 * 8 * 2);
        assert_eq!(rd.kv_dtype, KvDtype::Bf16);
        // rows are kv_elems long and land at packed strides
        unsafe {
            assert_eq!(r.k_row_mut(0).len(), 2);
            r.k_row_mut(0).copy_from_slice(&[1.0, 2.0]); // phys row 16
        }
        let rd = unsafe { r.read() };
        assert_eq!(&rd.k[2 * 8 * 2..2 * 8 * 2 + 2], &[1.0, 2.0]);
        // CoW copy moves packed rows intact
        unsafe { store.copy_block(2, 1) };
        assert!(store.blocks_equal(2, 1));
    }
}
