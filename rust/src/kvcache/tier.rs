//! Residency tiering for the paged KV cache: the runtime half of
//! HATA-off (paper Sec 5.3, Table 3).
//!
//! The analytical cost model in [`super::offload`] prices the paper's
//! scalability story; this module *runs* it. Every physical block of the
//! shared [`BlockStore`] carries a per-plane residency tier:
//!
//! * **Device** — rows live in the store's plane arena, readable by any
//!   attention work item (the default; freshly minted blocks start here);
//! * **Host** — rows were evicted to this controller's slow-tier arena
//!   and the device copy is poisoned with NaN, so any read that skips the
//!   fetch path corrupts logits and trips the bit-identity differential
//!   tests instead of silently passing.
//!
//! The compact key-code cache is **never** evicted: decode scores codes
//! on the always-resident plane, top-k selects, and only the selected
//! K/V blocks are fetched back (demand path), optionally one layer ahead
//! of their attention pass (prefetch path, InfiniGen-style). Evictions
//! happen on the engine thread between passes, write cold blocks back
//! under the pool's refcount/CoW rules (shared blocks spill once and are
//! fetched once for all holders), and never touch any live sequence's
//! tail block — the append target must stay writable on device.
//!
//! ## Concurrency contract
//!
//! All tier state sits behind one mutex. Worker threads call the fetch
//! entry points concurrently during a pass; a fetch copies rows
//! host→device *while holding the lock*, so a block observed `Device`
//! by any later lock holder is fully copied (mutex release/acquire
//! orders the memcpy before the read). Readers only resolve rows of
//! blocks their own ensure/prefetch call reported resident, which keeps
//! device-row reads data-race-free under the same row-disjointness
//! discipline `paged.rs` documents. Eviction, capacity growth and
//! allocation resets run on the engine thread between passes only.
//!
//! ## Accounting
//!
//! Every fetch pass is metered twice: a modeled [`TransferLedger`]
//! priced by the configured [`PcieModel`] (one scattered-row gather per
//! pass, matching the cost model's staging assumption), and measured
//! wall-clock seconds of the actual copies. `benches/table3_offload.rs`
//! runs this runtime beside the analytical model and reports the
//! prediction error between the two.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::paged::BlockStore;
use crate::simulator::pcie::{PcieModel, TransferLedger};

/// Snapshot of the tier controller's counters, threaded through
/// `Metrics::report` each engine step.
#[derive(Clone, Copy, Debug, Default)]
pub struct OffloadStats {
    /// Block-plane copies fetched host→device on the demand path (an
    /// attention work item needed rows that were not resident).
    pub demand_fetches: u64,
    /// Block-plane copies fetched host→device by prefetch tasks running
    /// ahead of their layer's attention.
    pub prefetch_fetches: u64,
    /// Residency checks that found the block-plane already on device.
    pub hits: u64,
    /// Blocks written back to the slow tier (all device planes at once).
    pub evictions: u64,
    /// Modeled host→device traffic (PCIe-priced gathers).
    pub fetch: TransferLedger,
    /// Modeled device→host write-back traffic.
    pub evict: TransferLedger,
    /// Measured wall-clock seconds spent in fetch copies.
    pub measured_fetch_s: f64,
    /// Measured wall-clock seconds spent in eviction copies.
    pub measured_evict_s: f64,
}

/// Per-block tier state.
struct BlockState {
    /// Per-plane flag: `true` when this (plane, block)'s K/V rows live
    /// in the slow-tier arena (device copy poisoned).
    host: Vec<bool>,
    /// Number of `true` entries in `host`.
    n_host: usize,
    /// Step counter at last allocation/fetch/hit — the LRU eviction key.
    last_touch: u64,
}

struct TierInner {
    blocks: Vec<BlockState>,
    /// Slow-tier K arena, `[plane][block * bt * kv_elems ..]` — same
    /// packed indexing as the device plane so spill/fetch are straight
    /// row copies of the stored (possibly half-precision) bits, and all
    /// byte accounting scales with the storage dtype automatically.
    slow_k: Vec<Vec<f32>>,
    /// Slow-tier V arena.
    slow_v: Vec<Vec<f32>>,
    stats: OffloadStats,
    step: u64,
    /// Eviction scratch (deduped live ids / LRU candidates).
    live_scratch: Vec<u32>,
    cand_scratch: Vec<(u64, u32)>,
}

/// Shared residency-tier controller for one [`BlockStore`]. The engine
/// owns one `Arc<TierController>` when `--offload` is active; sequence
/// caches attach it so every [`super::PagedRef`] captured for a pass can
/// reach the fetch path from worker threads.
pub struct TierController {
    store: Arc<BlockStore>,
    pcie: PcieModel,
    inner: Mutex<TierInner>,
}

impl TierController {
    /// Fresh controller: every block starts Device-resident; the slow
    /// tier grows with [`TierController::ensure_capacity`].
    pub fn new(store: Arc<BlockStore>, pcie: PcieModel) -> Self {
        let n_planes = store.n_planes();
        TierController {
            store,
            pcie,
            inner: Mutex::new(TierInner {
                blocks: Vec::new(),
                slow_k: (0..n_planes).map(|_| Vec::new()).collect(),
                slow_v: (0..n_planes).map(|_| Vec::new()).collect(),
                stats: OffloadStats::default(),
                step: 0,
                live_scratch: Vec::new(),
                cand_scratch: Vec::new(),
            }),
        }
    }

    /// The PCIe model pricing this controller's modeled ledgers.
    pub fn pcie(&self) -> PcieModel {
        self.pcie
    }

    /// Grow tier metadata and the slow arenas to cover physical block
    /// ids `< n`. Engine thread, between passes (pairs with
    /// [`BlockStore::ensure_blocks`]).
    pub fn ensure_capacity(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let n_planes = self.store.n_planes();
        while inner.blocks.len() < n {
            inner.blocks.push(BlockState {
                host: vec![false; n_planes],
                n_host: 0,
                last_touch: inner.step,
            });
        }
        let (bt, e) = (self.store.block_tokens(), self.store.kv_elems());
        for p in 0..n_planes {
            if inner.slow_k[p].len() < n * bt * e {
                inner.slow_k[p].resize(n * bt * e, 0.0);
                inner.slow_v[p].resize(n * bt * e, 0.0);
            }
        }
    }

    /// Advance the LRU clock one engine step.
    pub fn begin_step(&self) {
        self.inner.lock().unwrap().step += 1;
    }

    /// Reset `block` to Device across every plane without copying:
    /// called on the engine thread when the pool mints (or recycles) the
    /// block into a sequence's table, whose upcoming appends will write
    /// fresh rows. Without this, a recycled block still marked Host
    /// would later fetch stale slow-tier data over the new contents.
    pub fn note_allocated(&self, block: u32) {
        let mut g = self.inner.lock().unwrap();
        let step = g.step;
        if let Some(st) = g.blocks.get_mut(block as usize) {
            st.host.iter_mut().for_each(|h| *h = false);
            st.n_host = 0;
            st.last_touch = step;
        }
    }

    /// True when every plane of `block` is Device-resident (used to
    /// guard debug checks that compare device rows, e.g. the dedup
    /// `blocks_equal` assertion).
    pub fn is_fully_resident(&self, block: u32) -> bool {
        let g = self.inner.lock().unwrap();
        match g.blocks.get(block as usize) {
            Some(b) => b.n_host == 0,
            None => true,
        }
    }

    /// Cumulative counters since construction.
    pub fn stats(&self) -> OffloadStats {
        self.inner.lock().unwrap().stats
    }

    /// Fetch one plane of every block in `blocks` that is Host-resident
    /// (worker-callable; blocks may repeat — repeats after the first
    /// fetch count as hits). `prefetch` selects which counter the copies
    /// land in.
    pub fn fetch_blocks(&self, plane: usize, blocks: &[u32], prefetch: bool) {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let (bt, e) = (self.store.block_tokens(), self.store.kv_elems());
        let row_elems = bt * e;
        let t0 = Instant::now();
        let mut missing = 0u64;
        for &b in blocks {
            let Some(st) = inner.blocks.get_mut(b as usize) else { continue };
            st.last_touch = inner.step;
            if !st.host[plane] {
                inner.stats.hits += 1;
                continue;
            }
            let off = b as usize * row_elems;
            // SAFETY: tier lock held; no task reads these rows until a
            // fetch reports them resident, so this write is exclusive.
            unsafe {
                let (k, v) = self.store.block_kv_mut(plane, b);
                k.copy_from_slice(&inner.slow_k[plane][off..off + row_elems]);
                v.copy_from_slice(&inner.slow_v[plane][off..off + row_elems]);
            }
            st.host[plane] = false;
            st.n_host -= 1;
            missing += 1;
        }
        if missing > 0 {
            let bytes = missing as usize * 2 * row_elems * 4;
            // one staged gather per fetch pass: `missing` scattered K
            // and V row-groups packed host-side, then shipped together
            inner.stats.fetch.add_gather(&self.pcie, bytes, missing as usize * 2 * bt);
            inner.stats.measured_fetch_s += t0.elapsed().as_secs_f64();
            if prefetch {
                inner.stats.prefetch_fetches += missing;
            } else {
                inner.stats.demand_fetches += missing;
            }
        }
    }

    /// Demand-fetch every plane of every block in `table` — the prefill
    /// path (prefill attention reads the whole prefix) and the CoW
    /// unshare path (`copy_block` needs a current source). Engine thread.
    pub fn fetch_table_all_planes(&self, table: &[u32]) {
        for plane in 0..self.store.n_planes() {
            self.fetch_blocks(plane, table, false);
        }
    }

    /// Write back LRU-cold live blocks until at most `budget_blocks` of
    /// `live` remain Device-resident. `tails` (every live sequence's
    /// append-target block) are exempt, so the budget is a soft floor of
    /// `tails.len()`. Engine thread, between passes.
    pub fn evict_to_budget(&self, budget_blocks: usize, live: &[u32], tails: &[u32]) {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let n_planes = self.store.n_planes();
        inner.live_scratch.clear();
        inner.live_scratch.extend_from_slice(live);
        inner.live_scratch.sort_unstable();
        inner.live_scratch.dedup();
        let mut resident = 0usize;
        inner.cand_scratch.clear();
        for &b in &inner.live_scratch {
            let Some(st) = inner.blocks.get(b as usize) else { continue };
            if st.n_host < n_planes {
                resident += 1;
                if !tails.contains(&b) {
                    inner.cand_scratch.push((st.last_touch, b));
                }
            }
        }
        if resident <= budget_blocks {
            return;
        }
        inner.cand_scratch.sort_unstable();
        let row_elems = self.store.block_tokens() * self.store.kv_elems();
        let t0 = Instant::now();
        let mut evicted = 0usize;
        for i in 0..inner.cand_scratch.len() {
            if resident <= budget_blocks {
                break;
            }
            let b = inner.cand_scratch[i].1;
            let st = &mut inner.blocks[b as usize];
            let off = b as usize * row_elems;
            let mut spilled = 0usize;
            for plane in 0..n_planes {
                if st.host[plane] {
                    continue;
                }
                // SAFETY: engine thread between passes — no reader or
                // writer of any device row is live.
                unsafe {
                    let (k, v) = self.store.block_kv_mut(plane, b);
                    inner.slow_k[plane][off..off + row_elems].copy_from_slice(k);
                    inner.slow_v[plane][off..off + row_elems].copy_from_slice(v);
                    // poison: a read that bypasses the fetch path must
                    // corrupt results, not silently succeed
                    k.fill(f32::NAN);
                    v.fill(f32::NAN);
                }
                st.host[plane] = true;
                st.n_host += 1;
                spilled += 1;
            }
            resident -= 1;
            evicted += 1;
            inner.stats.evictions += 1;
            inner.stats.evict.add(&self.pcie, spilled * 2 * row_elems * 4);
        }
        if evicted > 0 {
            inner.stats.measured_evict_s += t0.elapsed().as_secs_f64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::simd::KvDtype;

    fn setup(n_planes: usize, blocks: usize) -> (Arc<BlockStore>, TierController) {
        let store = Arc::new(BlockStore::new(n_planes, 2, 1, 4, KvDtype::F32));
        unsafe { store.ensure_blocks(blocks) };
        let tier = TierController::new(store.clone(), PcieModel::gen4_x16());
        tier.ensure_capacity(blocks);
        (store, tier)
    }

    fn fill_block(store: &BlockStore, plane: usize, block: u32, val: f32) {
        let table = [block];
        let r = store.head_ref(plane, &table);
        for t in 0..4 {
            unsafe {
                r.k_row_mut(t).fill(val);
                r.v_row_mut(t).fill(-val);
            }
        }
    }

    fn read_first(store: &BlockStore, plane: usize, block: u32) -> f32 {
        let table = [block];
        let rd = unsafe { store.head_ref(plane, &table).read() };
        rd.k[rd.row(0) * 2]
    }

    #[test]
    fn evict_poisons_and_fetch_restores() {
        let (store, tier) = setup(2, 3);
        fill_block(&store, 0, 1, 7.0);
        fill_block(&store, 1, 1, 9.0);
        tier.evict_to_budget(0, &[1], &[]);
        assert!(read_first(&store, 0, 1).is_nan(), "device copy must be poisoned");
        assert!(!tier.is_fully_resident(1));
        tier.fetch_blocks(0, &[1], false);
        assert_eq!(read_first(&store, 0, 1), 7.0);
        assert!(!tier.is_fully_resident(1), "plane 1 still spilled");
        tier.fetch_blocks(1, &[1], false);
        assert_eq!(read_first(&store, 1, 1), 9.0);
        assert!(tier.is_fully_resident(1));
        let s = tier.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.demand_fetches, 2);
        assert_eq!(s.fetch.bytes, s.evict.bytes);
    }

    #[test]
    fn tails_survive_eviction_and_budget_holds() {
        let (_store, tier) = setup(1, 4);
        tier.evict_to_budget(1, &[0, 1, 2, 3], &[3]);
        // tail 3 exempt, one more block allowed by budget
        let resident: Vec<u32> = (0..4).filter(|&b| tier.is_fully_resident(b)).collect();
        assert!(resident.contains(&3));
        assert_eq!(resident.len(), 1, "budget=1: only the tail stays, {resident:?}");
    }

    #[test]
    fn lru_evicts_coldest_first() {
        let (_store, tier) = setup(1, 3);
        tier.begin_step();
        tier.fetch_blocks(0, &[2], false); // touch 2 at step 1 (hit)
        tier.begin_step();
        tier.fetch_blocks(0, &[0], false); // touch 0 at step 2
        tier.evict_to_budget(2, &[0, 1, 2], &[]);
        assert!(!tier.is_fully_resident(1), "block 1 is coldest");
        assert!(tier.is_fully_resident(0));
        assert!(tier.is_fully_resident(2));
    }

    #[test]
    fn recycled_block_does_not_fetch_stale_rows() {
        let (store, tier) = setup(1, 2);
        fill_block(&store, 0, 0, 5.0);
        tier.evict_to_budget(0, &[0], &[]);
        // block 0 freed and re-minted: new owner writes fresh rows
        tier.note_allocated(0);
        fill_block(&store, 0, 0, 11.0);
        tier.fetch_blocks(0, &[0], false);
        assert_eq!(read_first(&store, 0, 0), 11.0, "stale slow-tier data must not win");
        assert_eq!(tier.stats().hits, 1, "post-reset fetch is a hit");
    }

    #[test]
    fn hits_do_not_touch_the_ledger() {
        let (_store, tier) = setup(1, 2);
        tier.fetch_blocks(0, &[0, 1, 0], false);
        let s = tier.stats();
        assert_eq!(s.hits, 3);
        assert_eq!(s.demand_fetches, 0);
        assert_eq!(s.fetch.transfers, 0);
        assert_eq!(s.fetch.bytes, 0);
    }

    #[test]
    fn half_dtype_spill_fetch_halves_ledger_bytes_and_round_trips() {
        // same block geometry, half storage: spill + fetch move exactly
        // half the bytes, and the stored bits survive the round trip
        let run = |dt: KvDtype| {
            let store = Arc::new(BlockStore::new(1, 2, 1, 4, dt));
            unsafe { store.ensure_blocks(2) };
            let tier = TierController::new(store.clone(), PcieModel::gen4_x16());
            tier.ensure_capacity(2);
            fill_block(&store, 0, 0, 3.0);
            tier.evict_to_budget(0, &[0, 1], &[]);
            tier.fetch_blocks(0, &[0, 1], false);
            (tier.stats(), read_first(&store, 0, 0))
        };
        let (full, _) = run(KvDtype::F32);
        let (half, restored) = run(KvDtype::Bf16);
        assert!(full.evict.bytes > 0);
        assert_eq!(half.evict.bytes * 2, full.evict.bytes, "evict bytes must halve");
        assert_eq!(half.fetch.bytes * 2, full.fetch.bytes, "fetch bytes must halve");
        assert_eq!(half.evictions, full.evictions);
        assert_eq!(half.demand_fetches, full.demand_fetches);
        // spill/fetch are raw copies of the packed plane, so the stored
        // bits come back exactly as written
        assert_eq!(restored, 3.0);
    }
}
