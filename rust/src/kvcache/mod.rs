//! KV-cache management: per-sequence caches with the paper's extra
//! hash-code cache (Alg. 1 l.4-5), method side-structures maintained on
//! append, a page-accounting pool for admission control, and the
//! HATA-off tiered/offloaded variant.
//!
//! Storage is organised as one [`HeadCache`] region per (layer, kv-head),
//! so the batched decode path can split-borrow disjoint regions
//! ([`SeqKvCache::layer_heads_mut`]) and append to them from worker
//! threads concurrently — the ownership story the engine/model/attention
//! threadpool fan-out is built on. The tiled prefill path appends whole
//! token blocks per head ([`HeadMut::append_block`], then one
//! [`SeqKvCache::advance_len_by`]), with identical per-row arithmetic so
//! block decomposition never changes cache contents.
//!
//! Two storage layouts share every API above:
//!
//! * **contiguous** ([`SeqKvCache::new`]) — each head region owns
//!   grow-only `Vec`s, physical row == token index;
//! * **paged** ([`SeqKvCache::new_paged`]) — K/V/codes live in a shared
//!   [`BlockStore`] of fixed-size blocks and the sequence holds only a
//!   block table ([`pool::KvPool`] owns block identity, refcounts, and
//!   copy-on-write prefix sharing). Appends and reads resolve logical
//!   token `t` through the table; per-method side structures stay
//!   per-sequence (they are never shared, so they never page).
//!
//! Both layouts produce bit-identical attention results — enforced by
//! the rust/tests/paged.rs differential trace harness.

pub mod offload;
pub mod paged;
pub mod pool;
pub mod tier;

use std::sync::Arc;

pub use paged::{BlockStore, HeadRead, PagedRef};
use pool::KvPool;
use tier::TierController;

use crate::attention::Side;
use crate::config::{Method, ModelConfig, ServeConfig};
use crate::tensor::simd::{self, KvDtype};
use crate::util::rng::Rng;

/// One (layer, kv-head) cache region: K/V rows, the packed key-code
/// cache, and the per-method side structures maintained on append.
/// Layout: contiguous row-major token arrays, so the per-head decode hot
/// loop walks sequential memory. In the paged layout the `k`/`v`/`codes`
/// vectors stay empty (rows live in the shared [`BlockStore`]); the side
/// structures and the token counter are maintained here either way.
#[derive(Clone, Default)]
pub struct HeadCache {
    /// Tokens appended to this head (equals the row count in the
    /// contiguous layout; the append cursor in the paged layout).
    pub tokens: usize,
    /// Key rows, [len, kv_elems] row-major in *packed* storage form
    /// (contiguous layout only): `dh` f32 slots per row for f32 storage,
    /// `dh / 2` for the packed half dtypes.
    pub k: Vec<f32>,
    /// Value rows, [len, kv_elems] row-major, packed as `k` (contiguous
    /// layout only).
    pub v: Vec<f32>,
    /// Packed key hash codes, rbit/64 words per token (HATA; contiguous
    /// layout only).
    pub codes: Vec<u64>,
    /// Quest per-block elementwise key minima, [nblocks, dh].
    pub quest_min: Vec<f32>,
    /// Quest per-block elementwise key maxima, [nblocks, dh].
    pub quest_max: Vec<f32>,
    /// Loki PCA-projected keys, [len, channels].
    pub loki_kproj: Vec<f32>,
    /// MagicPIG LSH signatures, [len, L].
    pub mp_sigs: Vec<u16>,
}

impl HeadCache {
    fn bytes(&self) -> usize {
        (self.k.len() + self.v.len() + self.quest_min.len() + self.quest_max.len()
            + self.loki_kproj.len())
            * 4
            + self.codes.len() * 8
            + self.mp_sigs.len() * 2
    }
}

/// Split-borrow view of one head region plus the shared config scalars:
/// everything a worker thread needs to append a token's K/V/codes and
/// serve reads for that head, disjoint from every other head's view.
pub struct HeadMut<'a> {
    /// absolute head index (layer * n_kv + kv) — keys the aux tables
    pub head: usize,
    dh: usize,
    kv_dtype: KvDtype,
    quest_block: usize,
    loki_channels: usize,
    mp_k: usize,
    mp_l: usize,
    /// Paged layout: this head's plane in the shared [`BlockStore`] plus
    /// the sequence's block table. `None` = contiguous layout.
    paged: Option<PagedRef>,
    /// The underlying (layer, kv-head) cache region (side structures +
    /// token counter; also the K/V/code rows when contiguous).
    pub hc: &'a mut HeadCache,
}

impl HeadMut<'_> {
    /// Append one token's K/V for this head, maintaining the code cache
    /// and any enabled side structures. `hash_w` is the trained
    /// [dh, rbit] matrix for this head; `aux` carries the per-model
    /// method constants (Loki PCA, MagicPIG planes).
    ///
    /// `krow`/`vrow` are always logical f32 rows; half storage dtypes
    /// quantize here (the pipeline's single lossy step). Hash codes and
    /// every side structure are computed from the *pre-quantization*
    /// `krow`, so selection is identical across storage dtypes.
    pub fn append(
        &mut self,
        krow: &[f32],
        vrow: &[f32],
        hash_w: &[f32],
        rbit: usize,
        aux: &MethodAux,
    ) {
        debug_assert_eq!(krow.len(), self.dh);
        let dh = self.dh;
        let hc = &mut *self.hc;
        let t = hc.tokens;
        match &self.paged {
            // SAFETY: this work item exclusively owns this (sequence,
            // plane) append position: the engine builds at most one
            // append item per (sequence, layer, kv) and token `t` lands
            // in one of the sequence's own unshared blocks (appends sit
            // at `t >= prompt_len`, past every dedup-shared block), so
            // no other thread touches these rows (kvcache/paged.rs
            // module contract).
            Some(p) => unsafe {
                simd::pack_row(self.kv_dtype, krow, p.k_row_mut(t));
                simd::pack_row(self.kv_dtype, vrow, p.v_row_mut(t));
                if !hash_w.is_empty() {
                    crate::attention::hashenc::encode_fused_blocked_into(
                        krow,
                        hash_w,
                        rbit,
                        p.code_row_mut(t),
                    );
                }
            },
            None => {
                simd::pack_extend(self.kv_dtype, krow, &mut hc.k);
                simd::pack_extend(self.kv_dtype, vrow, &mut hc.v);
                if !hash_w.is_empty() {
                    crate::attention::hashenc::encode_fused_blocked(
                        krow,
                        hash_w,
                        rbit,
                        &mut hc.codes,
                    );
                }
            }
        }
        if self.quest_block > 0 {
            if t % self.quest_block == 0 {
                hc.quest_min.extend_from_slice(krow);
                hc.quest_max.extend_from_slice(krow);
            } else {
                let nb = hc.quest_min.len() / dh;
                let bmin = &mut hc.quest_min[(nb - 1) * dh..];
                for (m, &ki) in bmin.iter_mut().zip(krow) {
                    *m = m.min(ki);
                }
                let bmax = &mut hc.quest_max[(nb - 1) * dh..];
                for (m, &ki) in bmax.iter_mut().zip(krow) {
                    *m = m.max(ki);
                }
            }
        }
        if self.loki_channels > 0 {
            let pca = &aux.loki_pca[self.head];
            let r = self.loki_channels;
            for c in 0..r {
                let mut acc = 0.0;
                for (i, &ki) in krow.iter().enumerate() {
                    acc += ki * pca[i * r + c];
                }
                hc.loki_kproj.push(acc);
            }
        }
        if self.mp_l > 0 {
            let planes = &aux.mp_planes[self.head];
            for table in 0..self.mp_l {
                let mut sig = 0u16;
                for bit in 0..self.mp_k {
                    let p = &planes[(table * self.mp_k + bit) * dh..][..dh];
                    sig |= ((crate::tensor::ops::dot(krow, p) >= 0.0) as u16) << bit;
                }
                hc.mp_sigs.push(sig);
            }
        }
        hc.tokens = t + 1;
    }

    /// Append a whole block of tokens' K/V rows for this head in token
    /// order. `krows`/`vrows` are [len, stride] row-major with this
    /// head's dh-wide slice starting at `offset` in each row — exactly
    /// how the tiled prefill path lays out per-token projection rows.
    /// Per-row work (hash-code encode + side-structure maintenance) is
    /// [`HeadMut::append`], so the resulting cache is bit-identical to
    /// appending the same rows one decode step at a time; only the
    /// reservation is amortized over the block.
    #[allow(clippy::too_many_arguments)]
    pub fn append_block(
        &mut self,
        krows: &[f32],
        vrows: &[f32],
        stride: usize,
        offset: usize,
        hash_w: &[f32],
        rbit: usize,
        aux: &MethodAux,
    ) {
        let dh = self.dh;
        let rows = krows.len() / stride;
        if self.paged.is_none() {
            let e = self.kv_dtype.elems(dh);
            self.hc.k.reserve(rows * e);
            self.hc.v.reserve(rows * e);
            if !hash_w.is_empty() {
                self.hc.codes.reserve(rows * (rbit / 64));
            }
        }
        for r in 0..rows {
            let at = r * stride + offset;
            self.append(&krows[at..at + dh], &vrows[at..at + dh], hash_w, rbit, aux);
        }
    }

    /// Unified read view of this head's K/V/code rows in either layout.
    pub fn read(&self) -> HeadRead<'_> {
        match &self.paged {
            // SAFETY: `&self` proves no concurrent mutation through this
            // view, and the module contract (kvcache/paged.rs) rules out
            // reallocation or foreign writes to this sequence's rows
            // while the work item holding this HeadMut runs.
            Some(p) => unsafe { p.read() },
            None => HeadRead {
                k: &self.hc.k,
                v: &self.hc.v,
                codes: &self.hc.codes,
                bt: &[],
                block_tokens: 0,
                kv_dtype: self.kv_dtype,
            },
        }
    }

    /// Borrow the method side structures of this head.
    pub fn side<'b>(&'b self, hash_w: &'b [f32], aux: &'b MethodAux) -> Side<'b> {
        Side {
            hash_w,
            quest_min: &self.hc.quest_min,
            quest_max: &self.hc.quest_max,
            quest_block: self.quest_block,
            loki_kproj: &self.hc.loki_kproj,
            loki_pca: aux.loki_pca.get(self.head).map(|v| v.as_slice()).unwrap_or(&[]),
            loki_channels: self.loki_channels,
            mp_sigs: &self.hc.mp_sigs,
            mp_planes: aux.mp_planes.get(self.head).map(|v| v.as_slice()).unwrap_or(&[]),
            mp_k: self.mp_k,
            mp_l: self.mp_l,
        }
    }

    /// True when this head's paged ref carries a residency tier
    /// (`--offload` runs only).
    pub fn tier_active(&self) -> bool {
        self.paged.as_ref().is_some_and(|p| p.has_tier())
    }

    /// Demand-fetch every slow-tier block covering logical tokens
    /// `[0, len)` of this head's plane and record the block list in
    /// `out` — the full-range path (dense attention and exact top-k
    /// scoring read every cached row). No-op without a tier.
    pub fn ensure_range_resident(&self, len: usize, out: &mut Vec<u32>) {
        let Some(p) = self.paged.as_ref().filter(|p| p.has_tier()) else { return };
        // SAFETY: this HeadMut was captured under the paged module
        // contract (table live and unmoved for the pass); the tier
        // controller is kept alive by the engine's Arc for the run.
        unsafe {
            p.ensure_range_resident(len);
            p.range_blocks(len, out);
        }
    }

    /// Demand-fetch the slow-tier blocks holding the selected token
    /// `indices` and record the deduplicated block list in `out` — the
    /// top-k path: score always-resident codes first, then fetch only
    /// what selection chose. No-op without a tier.
    pub fn ensure_selected_resident(&self, indices: &[u32], out: &mut Vec<u32>) {
        let Some(p) = self.paged.as_ref().filter(|p| p.has_tier()) else { return };
        // SAFETY: as for [`HeadMut::ensure_range_resident`]; selector
        // output indices are all `< s` and therefore table-covered.
        unsafe { p.ensure_selected_resident(indices, out) };
    }
}

/// Address-based view of one (layer, kv-head) cache region for the
/// dependency-driven work-queue executor ([`crate::util::workqueue`]):
/// tasks for every layer exist simultaneously, so exclusivity comes from
/// graph edges rather than borrows, and each task re-materializes a
/// normal [`HeadMut`]/[`HeadCache`] view only while it runs.
///
/// Obtained from [`SeqKvCache::head_handles`]; the pointer stays valid
/// for the cache's lifetime (the per-head structs never move — only the
/// buffers inside them grow). Copyable so the append task and the
/// attention tasks of one head can each carry the same handle.
#[derive(Clone, Copy)]
pub struct HeadHandle {
    head: usize,
    dh: usize,
    kv_dtype: KvDtype,
    quest_block: usize,
    loki_channels: usize,
    mp_k: usize,
    mp_l: usize,
    paged: Option<PagedRef>,
    hc: *mut HeadCache,
}

// SAFETY: a HeadHandle is just an address plus copies of shared scalars;
// sending it between threads is safe because every dereference site is
// an `unsafe fn` whose caller must prove exclusive (head_mut) or shared
// (head_ref) access — in the workqueue path, via dependency edges.
unsafe impl Send for HeadHandle {}

impl HeadHandle {
    /// Absolute head index (layer * n_kv + kv) — keys the aux tables.
    pub fn index(&self) -> usize {
        self.head
    }

    /// Materialize the mutable append view of this head region.
    ///
    /// # Safety
    /// The caller must guarantee no other access to this head region is
    /// live for the returned view's lifetime — in the work-queue path,
    /// by being the only task for this (layer, kv) head and running
    /// after every task that reads it has completed (graph edges).
    pub unsafe fn head_mut(&self) -> HeadMut<'_> {
        HeadMut {
            head: self.head,
            dh: self.dh,
            kv_dtype: self.kv_dtype,
            quest_block: self.quest_block,
            loki_channels: self.loki_channels,
            mp_k: self.mp_k,
            mp_l: self.mp_l,
            paged: self.paged,
            hc: &mut *self.hc,
        }
    }

    /// Materialize a shared read view of this head region.
    ///
    /// # Safety
    /// The caller must guarantee no mutation of this head region is live
    /// for the returned borrow's lifetime — in the work-queue path, by
    /// depending on the head's append task (reads may then share freely).
    pub unsafe fn head_ref(&self) -> &HeadCache {
        &*self.hc
    }

    /// Prefetch previously recorded physical `blocks` of this head's
    /// plane from the slow tier (the decode graph's layer-ahead fetch
    /// task body). No-op unless a residency tier is attached.
    ///
    /// # Safety
    /// The handle's table must be live and unmoved (pass contract) and
    /// the recorded ids still owned by or shared with this sequence —
    /// true for a selection recorded at the previous decode step, since
    /// a live sequence's blocks are only released when it finishes.
    pub unsafe fn prefetch_blocks(&self, blocks: &[u32]) {
        if let Some(p) = &self.paged {
            p.prefetch_blocks(blocks);
        }
    }

    /// Materialize the unified K/V/code read view of this head region,
    /// resolving the paged layout's block indirection when active.
    ///
    /// # Safety
    /// As for [`HeadHandle::head_ref`]: no mutation of this head region
    /// (and, when paged, no [`BlockStore::ensure_blocks`]) may be live
    /// for the returned view's lifetime.
    pub unsafe fn read_view(&self) -> HeadRead<'_> {
        match &self.paged {
            Some(p) => p.read(),
            None => {
                let hc = &*self.hc;
                HeadRead {
                    k: &hc.k,
                    v: &hc.v,
                    codes: &hc.codes,
                    bt: &[],
                    block_tokens: 0,
                    kv_dtype: self.kv_dtype,
                }
            }
        }
    }
}

/// Paged-layout state of one sequence: the shared physical arena plus
/// this sequence's block table (mirrored from [`pool::KvPool`] by
/// [`SeqKvCache::sync_table`] so worker threads can resolve rows without
/// touching the pool).
struct PagedSeq {
    store: Arc<BlockStore>,
    table: Vec<u32>,
    /// Residency-tier controller, present when the engine enabled
    /// `--offload`; attached to every [`PagedRef`] captured from this
    /// sequence so worker-side fetches can reach it.
    tier: Option<Arc<TierController>>,
}

impl PagedSeq {
    fn head_ref(&self, h: usize) -> PagedRef {
        let mut r = self.store.head_ref(h, &self.table);
        if let Some(t) = &self.tier {
            r.attach_tier(Arc::as_ptr(t));
        }
        r
    }
}

/// All cached state for one sequence: K/V per (layer, kv-head), the packed
/// key-code cache, and per-method side structures.
pub struct SeqKvCache {
    /// Layer count (head regions are [layer][kv] ordered).
    pub n_layers: usize,
    /// KV heads per layer.
    pub n_kv: usize,
    /// Per-head *logical* dimension of the stored K/V rows.
    pub dh: usize,
    /// Packed code words per token (rbit / 64).
    pub words: usize,
    /// Storage dtype of the K/V rows (`--kv-dtype`).
    pub kv_dtype: KvDtype,
    len: usize,
    quest_block: usize,
    loki_channels: usize,
    mp_k: usize,
    mp_l: usize,
    paged: Option<PagedSeq>,
    heads: Vec<HeadCache>,
}

impl SeqKvCache {
    /// Empty cache sized for `cfg`, with the side structures demanded by
    /// `serve.method` enabled.
    pub fn new(cfg: &ModelConfig, serve: &ServeConfig) -> Self {
        let heads = cfg.n_layers * cfg.n_kv_heads;
        let enable_quest = serve.method == Method::Quest;
        let enable_loki = serve.method == Method::Loki;
        let enable_mp = serve.method == Method::MagicPig;
        assert!(
            !serve.kv_dtype.is_half() || cfg.head_dim % 2 == 0,
            "half kv dtypes need an even head_dim"
        );
        SeqKvCache {
            n_layers: cfg.n_layers,
            n_kv: cfg.n_kv_heads,
            dh: cfg.head_dim,
            words: cfg.rbit / 64,
            kv_dtype: serve.kv_dtype,
            len: 0,
            quest_block: if enable_quest { serve.quest_block } else { 0 },
            loki_channels: if enable_loki { serve.loki_channels } else { 0 },
            mp_k: if enable_mp { serve.magicpig_k } else { 0 },
            mp_l: if enable_mp { serve.magicpig_l } else { 0 },
            paged: None,
            heads: (0..heads).map(|_| HeadCache::default()).collect(),
        }
    }

    /// Empty *paged* cache: K/V/code rows live in the shared `store` and
    /// this sequence holds only a block table (kept in sync with the
    /// owning [`pool::KvPool`] via [`SeqKvCache::sync_table`]). Side
    /// structures stay per-sequence exactly as in the contiguous layout.
    ///
    /// Panics if the store's geometry does not match `cfg` or if `rbit`
    /// is not a multiple of 64 (paged code rows are written in place, so
    /// every token must own a whole number of words).
    pub fn new_paged(cfg: &ModelConfig, serve: &ServeConfig, store: Arc<BlockStore>) -> Self {
        assert_eq!(
            store.n_planes(),
            cfg.n_layers * cfg.n_kv_heads,
            "store plane count must match the model's (layer, kv-head) grid"
        );
        assert_eq!(store.dh(), cfg.head_dim, "store row width must match head_dim");
        assert_eq!(store.kv_dtype(), serve.kv_dtype, "store kv dtype must match serve config");
        assert_eq!(cfg.rbit % 64, 0, "paged cache requires rbit % 64 == 0");
        assert_eq!(store.words(), cfg.rbit / 64, "store code width must match rbit");
        let mut cache = Self::new(cfg, serve);
        cache.paged = Some(PagedSeq { store, table: Vec::new(), tier: None });
        cache
    }

    /// Attach the engine's residency-tier controller (`--offload`):
    /// every [`PagedRef`] captured from now on carries it, routing
    /// worker-side block fetches through the tier. Panics on a
    /// contiguous cache — offload requires the paged layout.
    pub fn attach_tier(&mut self, tier: Arc<TierController>) {
        let p = self.paged.as_mut().expect("attach_tier requires the paged layout");
        p.tier = Some(tier);
    }

    /// True when this cache uses the paged layout.
    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// This sequence's block table (empty when contiguous).
    pub fn block_table(&self) -> &[u32] {
        self.paged.as_ref().map(|p| p.table.as_slice()).unwrap_or(&[])
    }

    /// Mirror the pool's block list for this sequence into the local
    /// table (no-op when contiguous). Engine-thread only, between passes:
    /// worker-held [`PagedRef`]s alias this table's storage, so it must
    /// not be resized while a pass runs — callers reserve via
    /// [`SeqKvCache::reserve`] and sync before capturing work items.
    pub fn sync_table(&mut self, blocks: &[u32]) {
        if let Some(p) = &mut self.paged {
            p.table.clear();
            p.table.extend_from_slice(blocks);
        }
    }

    fn paged_ref(&self, h: usize) -> Option<PagedRef> {
        self.paged.as_ref().map(|p| p.head_ref(h))
    }

    /// Absolute head index (layer * n_kv + kv) keying the aux tables.
    #[inline]
    pub fn head_index(&self, layer: usize, kv: usize) -> usize {
        layer * self.n_kv + kv
    }

    /// Cached tokens (same for every head region).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True before the first token is appended.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn head_view(&mut self, h: usize) -> HeadMut<'_> {
        let paged = self.paged_ref(h);
        HeadMut {
            head: h,
            dh: self.dh,
            kv_dtype: self.kv_dtype,
            quest_block: self.quest_block,
            loki_channels: self.loki_channels,
            mp_k: self.mp_k,
            mp_l: self.mp_l,
            paged,
            hc: &mut self.heads[h],
        }
    }

    /// Mutable view of one (layer, kv) head region.
    pub fn head_mut(&mut self, layer: usize, kv: usize) -> HeadMut<'_> {
        let h = self.head_index(layer, kv);
        self.head_view(h)
    }

    /// Disjoint mutable views of every kv head in one layer — the split
    /// borrow the batched decode path hands to worker threads.
    pub fn layer_heads_mut(&mut self, layer: usize) -> Vec<HeadMut<'_>> {
        let (dh, qb, lc, mk, ml, nkv) =
            (self.dh, self.quest_block, self.loki_channels, self.mp_k, self.mp_l, self.n_kv);
        let dt = self.kv_dtype;
        let base = layer * nkv;
        let paged = &self.paged;
        self.heads[base..base + nkv]
            .iter_mut()
            .enumerate()
            .map(|(kv, hc)| HeadMut {
                head: base + kv,
                dh,
                kv_dtype: dt,
                quest_block: qb,
                loki_channels: lc,
                mp_k: mk,
                mp_l: ml,
                paged: paged.as_ref().map(|p| p.head_ref(base + kv)),
                hc,
            })
            .collect()
    }

    /// Stable raw handles to every (layer, kv) head region at once —
    /// the work-queue analogue of [`Self::layer_heads_mut`]. Where the
    /// barrier path re-borrows one layer's heads per scatter stage, the
    /// dependency-graph path builds tasks for *all* layers up front, so
    /// it takes addresses instead of borrows and re-materializes a
    /// short-lived view inside each task ([`HeadHandle::head_mut`] /
    /// [`HeadHandle::head_ref`]), with graph edges supplying the
    /// exclusivity the borrow checker normally would.
    ///
    /// Handles are ordered layer-major (`layer * n_kv + kv`), matching
    /// [`Self::head_index`]. They stay valid until this cache is moved
    /// or dropped — the `heads` vector itself is never resized, only the
    /// buffers inside each [`HeadCache`] grow.
    pub fn head_handles(&mut self) -> Vec<HeadHandle> {
        let (dh, qb, lc, mk, ml) =
            (self.dh, self.quest_block, self.loki_channels, self.mp_k, self.mp_l);
        let dt = self.kv_dtype;
        let paged = &self.paged;
        self.heads
            .iter_mut()
            .enumerate()
            .map(|(h, hc)| HeadHandle {
                head: h,
                dh,
                kv_dtype: dt,
                quest_block: qb,
                loki_channels: lc,
                mp_k: mk,
                mp_l: ml,
                paged: paged.as_ref().map(|p| p.head_ref(h)),
                hc,
            })
            .collect()
    }

    /// Raw handle to a single (layer, kv) head region — the
    /// one-at-a-time variant of [`Self::head_handles`], used by the
    /// cached decode graph's per-step payload rebind
    /// (`Model::bind_decode_tasks`) so the steady-state path never
    /// allocates a handle vector. Same validity contract as
    /// [`Self::head_handles`].
    pub fn head_handle(&mut self, layer: usize, kv: usize) -> HeadHandle {
        let h = self.head_index(layer, kv);
        let paged = self.paged_ref(h);
        HeadHandle {
            head: h,
            dh: self.dh,
            kv_dtype: self.kv_dtype,
            quest_block: self.quest_block,
            loki_channels: self.loki_channels,
            mp_k: self.mp_k,
            mp_l: self.mp_l,
            paged,
            hc: &mut self.heads[h],
        }
    }

    /// Pre-reserve every head region's buffers (K/V rows, packed code
    /// words, and whichever side structures are enabled) for a total of
    /// `tokens` cached tokens, so steady-state appends up to that length
    /// never reallocate. Useful for callers that know a sequence's
    /// prompt + generation budget up front — and required by the
    /// zero-allocation decode-step guarantee (rust/tests/alloc.rs).
    ///
    /// In the paged layout the K/V/code reservation becomes a block-table
    /// reservation instead (the rows live in the shared [`BlockStore`]);
    /// side structures reserve identically in both layouts.
    pub fn reserve(&mut self, tokens: usize) {
        fn reserve_total<T>(v: &mut Vec<T>, total: usize) {
            if v.capacity() < total {
                // capacity < total implies len <= capacity < total, so
                // the subtraction cannot underflow
                v.reserve(total - v.len());
            }
        }
        let paged = self.paged.is_some();
        if let Some(p) = &mut self.paged {
            let bt = p.store.block_tokens();
            // +1 block of slack so a trailing partial block never forces
            // a mid-pass table reallocation (PagedRefs alias the table)
            reserve_total(&mut p.table, tokens.div_ceil(bt) + 1);
        }
        let dh = self.dh;
        let e = self.kv_dtype.elems(dh);
        for hc in &mut self.heads {
            if !paged {
                reserve_total(&mut hc.k, tokens * e);
                reserve_total(&mut hc.v, tokens * e);
                reserve_total(&mut hc.codes, tokens * self.words);
            }
            if self.quest_block > 0 {
                let blocks = tokens.div_ceil(self.quest_block);
                reserve_total(&mut hc.quest_min, blocks * dh);
                reserve_total(&mut hc.quest_max, blocks * dh);
            }
            if self.loki_channels > 0 {
                reserve_total(&mut hc.loki_kproj, tokens * self.loki_channels);
            }
            if self.mp_l > 0 {
                reserve_total(&mut hc.mp_sigs, tokens * self.mp_l);
            }
        }
    }

    /// Record one fully-appended token (call once after all layers/heads
    /// of a step appended through [`Self::head_mut`]/[`Self::layer_heads_mut`]).
    pub fn advance_len(&mut self) {
        self.len += 1;
    }

    /// Record `n` fully-appended tokens at once — the tiled prefill path
    /// appends a whole chunk per head ([`HeadMut::append_block`]) before
    /// bumping the sequence length.
    pub fn advance_len_by(&mut self, n: usize) {
        self.len += n;
    }

    /// Append one token's K/V for a given (layer, kv) head, maintaining
    /// the code cache and any enabled side structures. The sequence
    /// length bumps automatically when the last (layer, kv) head is
    /// appended.
    ///
    /// Convenience wrapper over [`Self::head_mut`] + [`Self::advance_len`]
    /// (the decode paths use those directly); do not mix the two
    /// protocols on one cache or `len` double-counts.
    #[allow(clippy::too_many_arguments)]
    pub fn append(
        &mut self,
        layer: usize,
        kv: usize,
        krow: &[f32],
        vrow: &[f32],
        hash_w: &[f32],
        rbit: usize,
        aux: &MethodAux,
    ) {
        let h = self.head_index(layer, kv);
        let last = h == self.heads.len() - 1;
        self.head_view(h).append(krow, vrow, hash_w, rbit, aux);
        if last {
            self.len += 1;
        }
    }

    /// Key rows of one head region, [len, kv_elems] row-major in packed
    /// storage form. Contiguous layout only (a paged head's rows live in
    /// the [`BlockStore`] — use [`Self::read_view`] or
    /// [`Self::k_logical`]; the latter also widens half storage).
    pub fn k_slice(&self, layer: usize, kv: usize) -> &[f32] {
        debug_assert!(self.paged.is_none(), "k_slice on a paged cache; use read_view");
        &self.heads[self.head_index(layer, kv)].k
    }

    /// Value rows of one head region, packed storage form as
    /// [`Self::k_slice`]. Contiguous layout only.
    pub fn v_slice(&self, layer: usize, kv: usize) -> &[f32] {
        debug_assert!(self.paged.is_none(), "v_slice on a paged cache; use read_view");
        &self.heads[self.head_index(layer, kv)].v
    }

    /// Packed key-code words of one head region. Contiguous layout only
    /// (see [`Self::k_slice`]).
    pub fn codes_slice(&self, layer: usize, kv: usize) -> &[u64] {
        debug_assert!(self.paged.is_none(), "codes_slice on a paged cache; use read_view");
        &self.heads[self.head_index(layer, kv)].codes
    }

    /// Unified read view of one head's K/V/code rows in either layout.
    pub fn read_view(&self, layer: usize, kv: usize) -> HeadRead<'_> {
        let h = self.head_index(layer, kv);
        match self.paged_ref(h) {
            // SAFETY: `&self` proves no live mutation of this cache (so
            // no table rewrite), and the module contract rules out
            // concurrent store reallocation while any borrow is live.
            Some(p) => unsafe { p.read() },
            None => {
                let hc = &self.heads[h];
                HeadRead {
                    k: &hc.k,
                    v: &hc.v,
                    codes: &hc.codes,
                    bt: &[],
                    block_tokens: 0,
                    kv_dtype: self.kv_dtype,
                }
            }
        }
    }

    /// One head's key rows gathered into logical token order and widened
    /// to f32 — layout- and dtype-independent, for tests and
    /// differential comparisons.
    pub fn k_logical(&self, layer: usize, kv: usize) -> Vec<f32> {
        let rd = self.read_view(layer, kv);
        let e = self.kv_dtype.elems(self.dh);
        let mut out = Vec::with_capacity(self.len * self.dh);
        for t in 0..self.len {
            let r = rd.row(t);
            simd::widen_extend(self.kv_dtype, &rd.k[r * e..(r + 1) * e], &mut out);
        }
        out
    }

    /// One head's value rows in logical token order, widened to f32 (see
    /// [`Self::k_logical`]).
    pub fn v_logical(&self, layer: usize, kv: usize) -> Vec<f32> {
        let rd = self.read_view(layer, kv);
        let e = self.kv_dtype.elems(self.dh);
        let mut out = Vec::with_capacity(self.len * self.dh);
        for t in 0..self.len {
            let r = rd.row(t);
            simd::widen_extend(self.kv_dtype, &rd.v[r * e..(r + 1) * e], &mut out);
        }
        out
    }

    /// One head's packed code words in logical token order (see
    /// [`Self::k_logical`]). Empty when the method never encoded codes
    /// in the contiguous layout; the paged plane always has storage, so
    /// compare codes only for hash methods.
    pub fn codes_logical(&self, layer: usize, kv: usize) -> Vec<u64> {
        let rd = self.read_view(layer, kv);
        let w = self.words;
        let mut out = Vec::with_capacity(self.len * w);
        if w == 0 || rd.codes.is_empty() {
            return out;
        }
        for t in 0..self.len {
            let r = rd.row(t);
            out.extend_from_slice(&rd.codes[r * w..(r + 1) * w]);
        }
        out
    }

    /// Register this sequence's fully-prefilled prompt blocks in the
    /// pool's prefix registry, aliasing any block another sequence
    /// already holds for the identical token chain (copy-on-write prefix
    /// sharing). Call once, engine-thread, after the final prefill chunk;
    /// only blocks *fully covered* by the prompt participate, so every
    /// shared block sits strictly below the append cursor and is never
    /// written again. Returns the number of prefix hits (blocks now
    /// stored once instead of twice). No-op for contiguous caches.
    pub fn dedup_prefix(&mut self, pool: &mut KvPool, id: u64, prompt: &[u32]) -> usize {
        let Some(p) = &self.paged else { return 0 };
        let bt = p.store.block_tokens();
        let full_blocks = prompt.len() / bt;
        // 128-bit token-chain hash: block i's key digests tokens
        // [0, (i+1)*bt), so equal keys mean equal prompts up to and
        // including the block — position sensitivity for free.
        let mut h1: u64 = 0xcbf29ce484222325; // FNV-1a offset basis
        let mut h2: u64 = 0x9e3779b97f4a7c15;
        let mut hits = 0usize;
        debug_assert_eq!(prompt.chunks_exact(bt).len(), full_blocks);
        for (idx, chunk) in prompt.chunks_exact(bt).enumerate() {
            for &tok in chunk {
                h1 = (h1 ^ u64::from(tok)).wrapping_mul(0x100000001b3);
                h2 = (h2 ^ u64::from(tok).wrapping_mul(0xc6a4a7935bd1e995))
                    .rotate_left(31)
                    .wrapping_mul(0xc6a4a7935bd1e995);
            }
            let mine = pool.seq_blocks(id).get(idx).copied();
            if pool.dedup_block(id, idx, (h1, h2)) {
                hits += 1;
                if cfg!(debug_assertions) {
                    let (Some(mine), Some(&shared)) = (mine, pool.seq_blocks(id).get(idx)) else {
                        unreachable!("dedup hit on a missing block-table entry")
                    };
                    // device rows are poison once a block spilled to the
                    // slow tier — only compare when both sides are whole
                    let comparable = match &p.tier {
                        Some(t) => t.is_fully_resident(mine) && t.is_fully_resident(shared),
                        None => true,
                    };
                    debug_assert!(
                        !comparable || p.store.blocks_equal(mine, shared),
                        "prefix hash collision: block contents diverge"
                    );
                }
            }
        }
        self.sync_table(pool.seq_blocks(id));
        hits
    }

    /// Copy-on-write unshare of one block-table entry before an in-place
    /// write: allocates a private copy if (and only if) the entry is
    /// shared, copies the payload, and re-syncs the local table. Returns
    /// whether a copy happened. Engine-thread, between passes.
    pub fn make_writable(
        &mut self,
        pool: &mut KvPool,
        id: u64,
        idx: usize,
    ) -> Result<bool, pool::PoolError> {
        let Some(p) = &self.paged else { return Ok(false) };
        let copied = match pool.ensure_writable(id, idx)? {
            Some((src, dst)) => {
                // the dst id may be freshly minted — make sure the planes
                // cover it before copying
                // SAFETY: engine thread between passes (method contract):
                // no worker holds a view.
                unsafe {
                    p.store.ensure_blocks(pool.minted_pages());
                }
                if let Some(t) = &p.tier {
                    // copy_block reads device rows: restore a spilled
                    // source first, and mark the (possibly recycled)
                    // private copy freshly device-resident
                    t.ensure_capacity(pool.minted_pages());
                    t.fetch_table_all_planes(&[src]);
                    t.note_allocated(dst);
                }
                // SAFETY: as above.
                unsafe {
                    p.store.copy_block(src, dst);
                }
                true
            }
            None => false,
        };
        self.sync_table(pool.seq_blocks(id));
        Ok(copied)
    }

    /// Fork this paged cache into a CoW child sequence: the pool aliases
    /// every parent block ([`pool::KvPool::fork`]), side structures are
    /// cloned, and the child's table mirrors the shared blocks — zero
    /// pages until a write triggers [`Self::make_writable`].
    pub fn fork_paged(
        &self,
        pool: &mut KvPool,
        parent: u64,
        child: u64,
    ) -> Result<SeqKvCache, pool::PoolError> {
        let p = self.paged.as_ref().expect("fork_paged on a contiguous cache");
        pool.fork(parent, child)?;
        let mut cache = SeqKvCache {
            n_layers: self.n_layers,
            n_kv: self.n_kv,
            dh: self.dh,
            words: self.words,
            kv_dtype: self.kv_dtype,
            len: self.len,
            quest_block: self.quest_block,
            loki_channels: self.loki_channels,
            mp_k: self.mp_k,
            mp_l: self.mp_l,
            paged: Some(PagedSeq {
                store: Arc::clone(&p.store),
                table: Vec::new(),
                tier: p.tier.clone(),
            }),
            heads: self.heads.clone(),
        };
        cache.sync_table(pool.seq_blocks(child));
        Ok(cache)
    }

    /// Borrow the method side structures for one head.
    pub fn side<'a>(&'a self, layer: usize, kv: usize, hash_w: &'a [f32], aux: &'a MethodAux) -> Side<'a> {
        let h = self.head_index(layer, kv);
        let hc = &self.heads[h];
        Side {
            hash_w,
            quest_min: &hc.quest_min,
            quest_max: &hc.quest_max,
            quest_block: self.quest_block,
            loki_kproj: &hc.loki_kproj,
            loki_pca: aux.loki_pca.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            loki_channels: self.loki_channels,
            mp_sigs: &hc.mp_sigs,
            mp_planes: aux.mp_planes.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            mp_k: self.mp_k,
            mp_l: self.mp_l,
        }
    }

    /// Total bytes held (K + V + codes + side structures).
    pub fn bytes(&self) -> usize {
        self.heads.iter().map(|h| h.bytes()).sum()
    }
}

/// Per-model constants the side structures need (shared across sequences):
/// Loki PCA matrices and MagicPIG hyperplanes, per (layer, kv) head.
#[derive(Default)]
pub struct MethodAux {
    /// Loki PCA projection per head, each [dh, channels] row-major.
    pub loki_pca: Vec<Vec<f32>>,
    /// MagicPIG hyperplanes per head, each [L * K, dh] row-major.
    pub mp_planes: Vec<Vec<f32>>,
}

impl MethodAux {
    /// Build for the configured method. Loki PCA comes from artifacts when
    /// available (trained); `identity_fallback` uses the raw first channels
    /// (equivalent to SparQ-style truncation) when no PCA export exists.
    pub fn build(cfg: &ModelConfig, serve: &ServeConfig, pca: Option<Vec<Vec<f32>>>, seed: u64) -> Self {
        let heads = cfg.n_layers * cfg.n_kv_heads;
        let mut aux = MethodAux::default();
        if serve.method == Method::Loki {
            aux.loki_pca = pca.unwrap_or_else(|| {
                let r = serve.loki_channels;
                let mut id = vec![0.0f32; cfg.head_dim * r];
                for c in 0..r.min(cfg.head_dim) {
                    id[c * r + c] = 1.0;
                }
                vec![id; heads]
            });
        }
        if serve.method == Method::MagicPig {
            let mut rng = Rng::new(seed);
            aux.mp_planes = (0..heads)
                .map(|_| rng.normal_vec(serve.magicpig_l * serve.magicpig_k * cfg.head_dim))
                .collect();
        }
        aux
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn cfg_serve(method: Method) -> (ModelConfig, ServeConfig) {
        let cfg = preset("hata-gqa").unwrap();
        let serve = ServeConfig { method, ..Default::default() };
        (cfg, serve)
    }

    fn append_token(cache: &mut SeqKvCache, cfg: &ModelConfig, aux: &MethodAux, hash_w: &[f32], val: f32) {
        let krow = vec![val; cfg.head_dim];
        let vrow = vec![-val; cfg.head_dim];
        for layer in 0..cfg.n_layers {
            for kv in 0..cfg.n_kv_heads {
                cache.append(layer, kv, &krow, &vrow, hash_w, cfg.rbit, aux);
            }
        }
    }

    #[test]
    fn append_grows_all_heads_and_len() {
        let (cfg, serve) = cfg_serve(Method::Hata);
        let aux = MethodAux::default();
        let hash_w = vec![0.5; cfg.head_dim * cfg.rbit];
        let mut cache = SeqKvCache::new(&cfg, &serve);
        for t in 0..5 {
            append_token(&mut cache, &cfg, &aux, &hash_w, t as f32);
        }
        assert_eq!(cache.len(), 5);
        assert_eq!(cache.k_slice(2, 1).len(), 5 * cfg.head_dim);
        assert_eq!(cache.codes_slice(0, 0).len(), 5 * cfg.rbit / 64);
        assert!(cache.bytes() > 0);
    }

    #[test]
    fn quest_block_minmax_maintained() {
        let (cfg, serve) = cfg_serve(Method::Quest);
        let aux = MethodAux::build(&cfg, &serve, None, 0);
        let mut cache = SeqKvCache::new(&cfg, &serve);
        let block = serve.quest_block;
        // two blocks: values 0..block have max block-1
        for t in 0..(2 * block) {
            append_token(&mut cache, &cfg, &aux, &[], t as f32);
        }
        let side = cache.side(0, 0, &[], &aux);
        assert_eq!(side.quest_min.len(), 2 * cfg.head_dim);
        assert_eq!(side.quest_min[0], 0.0);
        assert_eq!(side.quest_max[0], (block - 1) as f32);
        assert_eq!(side.quest_min[cfg.head_dim], block as f32);
        assert_eq!(side.quest_max[cfg.head_dim], (2 * block - 1) as f32);
    }

    #[test]
    fn loki_identity_fallback_projects_first_channels() {
        let (cfg, serve) = cfg_serve(Method::Loki);
        let aux = MethodAux::build(&cfg, &serve, None, 0);
        let mut cache = SeqKvCache::new(&cfg, &serve);
        append_token(&mut cache, &cfg, &aux, &[], 3.0);
        let side = cache.side(1, 0, &[], &aux);
        assert_eq!(side.loki_kproj.len(), serve.loki_channels);
        // identity fallback keeps the raw first channels
        assert!(side.loki_kproj.iter().all(|&x| x == 3.0));
    }

    #[test]
    fn magicpig_signatures_deterministic() {
        let (cfg, serve) = cfg_serve(Method::MagicPig);
        let aux = MethodAux::build(&cfg, &serve, None, 7);
        let aux2 = MethodAux::build(&cfg, &serve, None, 7);
        let mut c1 = SeqKvCache::new(&cfg, &serve);
        let mut c2 = SeqKvCache::new(&cfg, &serve);
        append_token(&mut c1, &cfg, &aux, &[], 1.5);
        append_token(&mut c2, &cfg, &aux2, &[], 1.5);
        assert_eq!(c1.side(0, 0, &[], &aux).mp_sigs, c2.side(0, 0, &[], &aux2).mp_sigs);
        assert_eq!(c1.side(0, 0, &[], &aux).mp_sigs.len(), serve.magicpig_l);
    }

    #[test]
    fn reserve_prevents_append_reallocation() {
        for method in [Method::Hata, Method::Quest, Method::Loki, Method::MagicPig] {
            let (cfg, serve) = cfg_serve(method);
            let aux = MethodAux::build(&cfg, &serve, None, 0);
            let hash_w = vec![0.5; cfg.head_dim * cfg.rbit];
            let mut plain = SeqKvCache::new(&cfg, &serve);
            let mut reserved = SeqKvCache::new(&cfg, &serve);
            let tokens = 40;
            reserved.reserve(tokens);
            // snapshot pointers: appends within the reservation must not move
            let k_ptr = reserved.heads[0].k.as_ptr();
            for t in 0..tokens {
                append_token(&mut plain, &cfg, &aux, &hash_w, t as f32);
                append_token(&mut reserved, &cfg, &aux, &hash_w, t as f32);
            }
            assert_eq!(reserved.heads[0].k.as_ptr(), k_ptr, "{method:?} reallocated");
            for layer in 0..cfg.n_layers {
                for kv in 0..cfg.n_kv_heads {
                    assert_eq!(plain.k_slice(layer, kv), reserved.k_slice(layer, kv), "{method:?}");
                    assert_eq!(
                        plain.codes_slice(layer, kv),
                        reserved.codes_slice(layer, kv),
                        "{method:?}"
                    );
                }
            }
            assert_eq!(plain.len(), reserved.len());
        }
    }

    #[test]
    fn single_head_handle_matches_bulk_handles() {
        let (cfg, serve) = cfg_serve(Method::Hata);
        let mut cache = SeqKvCache::new(&cfg, &serve);
        let bulk = cache.head_handles();
        for layer in 0..cfg.n_layers {
            for kv in 0..cfg.n_kv_heads {
                let one = cache.head_handle(layer, kv);
                let h = layer * cfg.n_kv_heads + kv;
                assert_eq!(one.index(), bulk[h].index());
                assert_eq!(one.hc, bulk[h].hc, "same region address");
            }
        }
    }

    #[test]
    fn disabled_side_structures_stay_empty() {
        let (cfg, serve) = cfg_serve(Method::Dense);
        let aux = MethodAux::default();
        let mut cache = SeqKvCache::new(&cfg, &serve);
        append_token(&mut cache, &cfg, &aux, &[], 1.0);
        let side = cache.side(0, 0, &[], &aux);
        assert!(side.quest_min.is_empty());
        assert!(side.loki_kproj.is_empty());
        assert!(side.mp_sigs.is_empty());
    }

    #[test]
    fn block_append_matches_per_token_append() {
        // append_block over [len, n_kv * dh] projection rows must build
        // the exact same cache (codes + side structures) as per-token
        // appends — the invariant the tiled prefill path rests on
        for method in [Method::Hata, Method::Quest, Method::Loki, Method::MagicPig] {
            let (cfg, serve) = cfg_serve(method);
            let aux = MethodAux::build(&cfg, &serve, None, 3);
            let hash_w = if method == Method::Hata {
                vec![0.25; cfg.head_dim * cfg.rbit]
            } else {
                Vec::new()
            };
            let len = 2 * serve.quest_block + 3;
            let stride = cfg.n_kv_heads * cfg.head_dim;
            let krows: Vec<f32> = (0..len * stride).map(|i| (i as f32).sin()).collect();
            let vrows: Vec<f32> = (0..len * stride).map(|i| (i as f32).cos()).collect();
            let mut serial = SeqKvCache::new(&cfg, &serve);
            let mut block = SeqKvCache::new(&cfg, &serve);
            for t in 0..len {
                for layer in 0..cfg.n_layers {
                    for kv in 0..cfg.n_kv_heads {
                        let at = t * stride + kv * cfg.head_dim;
                        serial.head_mut(layer, kv).append(
                            &krows[at..at + cfg.head_dim],
                            &vrows[at..at + cfg.head_dim],
                            &hash_w,
                            cfg.rbit,
                            &aux,
                        );
                    }
                }
                serial.advance_len();
            }
            for layer in 0..cfg.n_layers {
                for (kv, mut head) in block.layer_heads_mut(layer).into_iter().enumerate() {
                    head.append_block(
                        &krows,
                        &vrows,
                        stride,
                        kv * cfg.head_dim,
                        &hash_w,
                        cfg.rbit,
                        &aux,
                    );
                }
            }
            block.advance_len_by(len);
            assert_eq!(serial.len(), block.len(), "{method:?}");
            for layer in 0..cfg.n_layers {
                for kv in 0..cfg.n_kv_heads {
                    assert_eq!(serial.k_slice(layer, kv), block.k_slice(layer, kv), "{method:?}");
                    assert_eq!(serial.v_slice(layer, kv), block.v_slice(layer, kv), "{method:?}");
                    assert_eq!(
                        serial.codes_slice(layer, kv),
                        block.codes_slice(layer, kv),
                        "{method:?}"
                    );
                    let a = serial.side(layer, kv, &hash_w, &aux);
                    let b = block.side(layer, kv, &hash_w, &aux);
                    assert_eq!(a.quest_min, b.quest_min, "{method:?}");
                    assert_eq!(a.quest_max, b.quest_max, "{method:?}");
                    assert_eq!(a.loki_kproj, b.loki_kproj, "{method:?}");
                    assert_eq!(a.mp_sigs, b.mp_sigs, "{method:?}");
                }
            }
            assert_eq!(serial.bytes(), block.bytes(), "{method:?}");
        }
    }

    /// Paged test fixture: a tiny-block pool + store + paged cache for
    /// one sequence, with the pool/store/table kept in sync the way the
    /// engine does (grow, ensure, sync before each append).
    fn paged_fixture(
        cfg: &ModelConfig,
        serve: &ServeConfig,
        bt: usize,
    ) -> (pool::KvPool, Arc<BlockStore>, SeqKvCache) {
        let pool = pool::KvPool::with_block(64 * bt, bt);
        let planes = cfg.n_layers * cfg.n_kv_heads;
        let store =
            Arc::new(BlockStore::new(planes, cfg.head_dim, cfg.rbit / 64, bt, serve.kv_dtype));
        let cache = SeqKvCache::new_paged(cfg, serve, Arc::clone(&store));
        (pool, store, cache)
    }

    fn grow_synced(
        pool: &mut pool::KvPool,
        store: &BlockStore,
        cache: &mut SeqKvCache,
        id: u64,
        tokens: usize,
    ) {
        pool.grow(id, tokens).unwrap();
        // SAFETY: single-threaded test, no live views
        unsafe { store.ensure_blocks(pool.minted_pages()) };
        cache.sync_table(pool.seq_blocks(id));
    }

    #[test]
    fn paged_append_matches_contiguous_logically() {
        // the tentpole invariant at cache level: appending the same rows
        // through the paged layout (tiny blocks, shuffled physical order)
        // yields bit-identical logical K/V/codes and side structures
        for method in [Method::Dense, Method::Hata, Method::Quest, Method::Loki, Method::MagicPig] {
            let (cfg, serve) = cfg_serve(method);
            let aux = MethodAux::build(&cfg, &serve, None, 5);
            let hash_w = if method == Method::Hata {
                vec![0.25; cfg.head_dim * cfg.rbit]
            } else {
                Vec::new()
            };
            let mut flat = SeqKvCache::new(&cfg, &serve);
            let (mut pool, store, mut paged) = paged_fixture(&cfg, &serve, 4);
            let len = 11; // crosses block boundaries, ends mid-block
            for t in 0..len {
                grow_synced(&mut pool, &store, &mut paged, 7, 1);
                let val = (t as f32).sin();
                append_token(&mut flat, &cfg, &aux, &hash_w, val);
                append_token(&mut paged, &cfg, &aux, &hash_w, val);
            }
            assert_eq!(flat.len(), paged.len(), "{method:?}");
            assert!(paged.is_paged() && !flat.is_paged());
            for layer in 0..cfg.n_layers {
                for kv in 0..cfg.n_kv_heads {
                    assert_eq!(flat.k_slice(layer, kv), paged.k_logical(layer, kv), "{method:?}");
                    assert_eq!(flat.v_slice(layer, kv), paged.v_logical(layer, kv), "{method:?}");
                    if method == Method::Hata {
                        assert_eq!(
                            flat.codes_slice(layer, kv),
                            paged.codes_logical(layer, kv),
                            "{method:?}"
                        );
                    }
                    let a = flat.side(layer, kv, &hash_w, &aux);
                    let b = paged.side(layer, kv, &hash_w, &aux);
                    assert_eq!(a.quest_min, b.quest_min, "{method:?}");
                    assert_eq!(a.quest_max, b.quest_max, "{method:?}");
                    assert_eq!(a.loki_kproj, b.loki_kproj, "{method:?}");
                    assert_eq!(a.mp_sigs, b.mp_sigs, "{method:?}");
                }
            }
            // the unified read view resolves the same rows
            let rd = paged.read_view(0, 0);
            assert_eq!(rd.block_tokens, 4);
            assert_eq!(rd.bt, pool.seq_blocks(7));
            let flat_rd = flat.read_view(0, 0);
            assert!(flat_rd.bt.is_empty());
            assert_eq!(flat_rd.row(5), 5);
        }
    }

    #[test]
    fn half_dtype_append_quantizes_once_and_matches_across_layouts() {
        // contiguous and paged half-precision caches must hold the same
        // quantized rows, codes must come from the pre-quantization f32
        // keys (== the f32 run's codes), and re-quantizing the widened
        // rows must be the identity (quantize-once contract)
        for dtype in [KvDtype::Bf16, KvDtype::F16] {
            let (cfg, mut serve) = cfg_serve(Method::Hata);
            serve.kv_dtype = dtype;
            let serve_f32 = ServeConfig { method: Method::Hata, ..Default::default() };
            let aux = MethodAux::default();
            let hash_w = vec![0.5; cfg.head_dim * cfg.rbit];
            let mut full = SeqKvCache::new(&cfg, &serve_f32);
            let mut flat = SeqKvCache::new(&cfg, &serve);
            let (mut pool, store, mut paged) = paged_fixture(&cfg, &serve, 4);
            for t in 0..11 {
                grow_synced(&mut pool, &store, &mut paged, 3, 1);
                let val = (t as f32).sin() * 3.0;
                append_token(&mut full, &cfg, &aux, &hash_w, val);
                append_token(&mut flat, &cfg, &aux, &hash_w, val);
                append_token(&mut paged, &cfg, &aux, &hash_w, val);
            }
            // packed footprint is half the f32 one
            assert_eq!(flat.heads[0].k.len() * 2, full.heads[0].k.len(), "{dtype:?}");
            for layer in 0..cfg.n_layers {
                for kv in 0..cfg.n_kv_heads {
                    let fk = flat.k_logical(layer, kv);
                    assert_eq!(fk, paged.k_logical(layer, kv), "{dtype:?}");
                    assert_eq!(flat.v_logical(layer, kv), paged.v_logical(layer, kv), "{dtype:?}");
                    // codes hash pre-quantization keys: identical to f32
                    assert_eq!(
                        flat.codes_slice(layer, kv),
                        &full.codes_logical(layer, kv)[..],
                        "{dtype:?}"
                    );
                    // widened rows re-quantize to the same stored bits
                    let mut requant = Vec::new();
                    for row in fk.chunks_exact(cfg.head_dim) {
                        simd::pack_extend(dtype, row, &mut requant);
                    }
                    let stored = &flat.heads[flat.head_index(layer, kv)].k;
                    let eq = requant.iter().zip(stored).all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(eq, "{dtype:?} widen/requantize must be the identity");
                }
            }
        }
    }

    #[test]
    fn dedup_prefix_shares_full_prompt_blocks() {
        let (cfg, serve) = cfg_serve(Method::Hata);
        let aux = MethodAux::default();
        let hash_w = vec![0.5; cfg.head_dim * cfg.rbit];
        let bt = 4;
        let prompt: Vec<u32> = (0..10u32).collect(); // 2 full blocks + 2 tokens
        let (mut pool, store, mut a) = paged_fixture(&cfg, &serve, bt);
        let mut b = SeqKvCache::new_paged(&cfg, &serve, Arc::clone(&store));
        for (id, cache) in [(1u64, &mut a), (2u64, &mut b)] {
            for &tok in &prompt {
                grow_synced(&mut pool, &store, cache, id, 1);
                append_token(cache, &cfg, &aux, &hash_w, tok as f32);
            }
        }
        assert_eq!(a.dedup_prefix(&mut pool, 1, &prompt), 0, "first arrival registers");
        assert_eq!(b.dedup_prefix(&mut pool, 2, &prompt), 2, "second arrival hits full blocks");
        assert_eq!(pool.seq_blocks(1)[..2], pool.seq_blocks(2)[..2]);
        assert_ne!(pool.seq_blocks(1)[2], pool.seq_blocks(2)[2], "partial block stays private");
        assert_eq!(pool.refcount(pool.seq_blocks(1)[0]), 2);
        assert_eq!(b.block_table(), pool.seq_blocks(2), "table resynced after dedup");
        // logical contents are untouched by the aliasing
        assert_eq!(a.k_logical(0, 0), b.k_logical(0, 0));
        // appends past the prompt land in private blocks and never
        // diverge the shared prefix
        grow_synced(&mut pool, &store, &mut b, 2, bt);
        a.sync_table(pool.seq_blocks(1));
        append_token(&mut b, &cfg, &aux, &hash_w, 99.0);
        assert_eq!(a.k_logical(0, 0), b.k_logical(0, 0)[..a.len() * cfg.head_dim]);
    }

    #[test]
    fn fork_is_cow_and_make_writable_unshares() {
        let (cfg, serve) = cfg_serve(Method::Dense);
        let aux = MethodAux::default();
        let bt = 4;
        let (mut pool, store, mut parent) = paged_fixture(&cfg, &serve, bt);
        for t in 0..(2 * bt) {
            grow_synced(&mut pool, &store, &mut parent, 1, 1);
            append_token(&mut parent, &cfg, &aux, &[], t as f32);
        }
        let free_before = pool.free_pages();
        let mut child = parent.fork_paged(&mut pool, 1, 2).unwrap();
        assert_eq!(pool.free_pages(), free_before, "fork costs zero pages");
        assert_eq!(child.k_logical(0, 0), parent.k_logical(0, 0));
        assert_eq!(pool.refcount(pool.seq_blocks(1)[0]), 2);
        // unshare block 0 of the child, then scribble on it
        assert!(child.make_writable(&mut pool, 2, 0).unwrap());
        assert!(!child.make_writable(&mut pool, 2, 0).unwrap(), "already exclusive");
        assert_ne!(pool.seq_blocks(1)[0], pool.seq_blocks(2)[0]);
        let before = parent.k_logical(0, 0);
        {
            let head = child.head_mut(0, 0);
            let row: Vec<f32> = vec![123.0; cfg.head_dim];
            let p = head.paged.unwrap();
            // SAFETY: single-threaded test; token 0's row belongs to the
            // child's freshly unshared private block
            unsafe { p.k_row_mut(0).copy_from_slice(&row) };
        }
        assert_eq!(parent.k_logical(0, 0), before, "CoW never mutates the shared block");
        assert_eq!(child.k_logical(0, 0)[..cfg.head_dim], vec![123.0; cfg.head_dim]);
    }

    #[test]
    fn split_borrow_append_matches_serial_append() {
        // appending through layer_heads_mut + advance_len must build the
        // exact same cache as the serial append() path
        let (cfg, serve) = cfg_serve(Method::Quest);
        let aux = MethodAux::build(&cfg, &serve, None, 0);
        let mut serial = SeqKvCache::new(&cfg, &serve);
        let mut split = SeqKvCache::new(&cfg, &serve);
        for t in 0..20 {
            append_token(&mut serial, &cfg, &aux, &[], t as f32);
            let krow = vec![t as f32; cfg.head_dim];
            let vrow = vec![-(t as f32); cfg.head_dim];
            for layer in 0..cfg.n_layers {
                for mut head in split.layer_heads_mut(layer) {
                    head.append(&krow, &vrow, &[], cfg.rbit, &aux);
                }
            }
            split.advance_len();
        }
        assert_eq!(serial.len(), split.len());
        for layer in 0..cfg.n_layers {
            for kv in 0..cfg.n_kv_heads {
                assert_eq!(serial.k_slice(layer, kv), split.k_slice(layer, kv));
                assert_eq!(serial.v_slice(layer, kv), split.v_slice(layer, kv));
                let a = serial.side(layer, kv, &[], &aux);
                let b = split.side(layer, kv, &[], &aux);
                assert_eq!(a.quest_min, b.quest_min);
                assert_eq!(a.quest_max, b.quest_max);
            }
        }
        assert_eq!(serial.bytes(), split.bytes());
    }
}
