//! KV-cache management: per-sequence caches with the paper's extra
//! hash-code cache (Alg. 1 l.4-5), method side-structures maintained on
//! append, a page-accounting pool for admission control, and the
//! HATA-off tiered/offloaded variant.

pub mod offload;
pub mod pool;

use crate::attention::Side;
use crate::config::{Method, ModelConfig, ServeConfig};
use crate::util::rng::Rng;

/// All cached state for one sequence: K/V per (layer, kv-head), the packed
/// key-code cache, and per-method side structures.
///
/// Layout: per (layer, kv) contiguous row-major token arrays, so the
/// per-head decode hot loop walks sequential memory.
pub struct SeqKvCache {
    pub n_layers: usize,
    pub n_kv: usize,
    pub dh: usize,
    pub words: usize,
    len: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    codes: Vec<Vec<u64>>,
    // Quest block summaries
    quest_block: usize,
    quest_min: Vec<Vec<f32>>,
    quest_max: Vec<Vec<f32>>,
    // Loki projected keys
    loki_channels: usize,
    loki_kproj: Vec<Vec<f32>>,
    // MagicPIG signatures
    mp_k: usize,
    mp_l: usize,
    mp_sigs: Vec<Vec<u16>>,
}

impl SeqKvCache {
    pub fn new(cfg: &ModelConfig, serve: &ServeConfig) -> Self {
        let heads = cfg.n_layers * cfg.n_kv_heads;
        let enable_quest = serve.method == Method::Quest;
        let enable_loki = serve.method == Method::Loki;
        let enable_mp = serve.method == Method::MagicPig;
        SeqKvCache {
            n_layers: cfg.n_layers,
            n_kv: cfg.n_kv_heads,
            dh: cfg.head_dim,
            words: cfg.rbit / 64,
            len: 0,
            k: vec![Vec::new(); heads],
            v: vec![Vec::new(); heads],
            codes: vec![Vec::new(); heads],
            quest_block: if enable_quest { serve.quest_block } else { 0 },
            quest_min: vec![Vec::new(); if enable_quest { heads } else { 0 }],
            quest_max: vec![Vec::new(); if enable_quest { heads } else { 0 }],
            loki_channels: if enable_loki { serve.loki_channels } else { 0 },
            loki_kproj: vec![Vec::new(); if enable_loki { heads } else { 0 }],
            mp_k: if enable_mp { serve.magicpig_k } else { 0 },
            mp_l: if enable_mp { serve.magicpig_l } else { 0 },
            mp_sigs: vec![Vec::new(); if enable_mp { heads } else { 0 }],
        }
    }

    #[inline]
    pub fn head_index(&self, layer: usize, kv: usize) -> usize {
        layer * self.n_kv + kv
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one token's K/V for a given (layer, kv) head, maintaining
    /// the code cache and any enabled side structures.
    /// `hash_w` is the trained [dh, rbit] matrix for this head; `aux`
    /// carries the per-model method constants (Loki PCA, MagicPIG planes).
    #[allow(clippy::too_many_arguments)]
    pub fn append(
        &mut self,
        layer: usize,
        kv: usize,
        krow: &[f32],
        vrow: &[f32],
        hash_w: &[f32],
        rbit: usize,
        aux: &MethodAux,
    ) {
        let h = self.head_index(layer, kv);
        debug_assert_eq!(krow.len(), self.dh);
        self.k[h].extend_from_slice(krow);
        self.v[h].extend_from_slice(vrow);
        if !hash_w.is_empty() {
            crate::attention::hashenc::encode_fused_blocked(krow, hash_w, rbit, &mut self.codes[h]);
        }
        if self.quest_block > 0 {
            let t = self.k[h].len() / self.dh - 1;
            if t % self.quest_block == 0 {
                self.quest_min[h].extend_from_slice(krow);
                self.quest_max[h].extend_from_slice(krow);
            } else {
                let nb = self.quest_min[h].len() / self.dh;
                let bmin = &mut self.quest_min[h][(nb - 1) * self.dh..];
                let bmax = &mut self.quest_max[h][(nb - 1) * self.dh..];
                for i in 0..self.dh {
                    bmin[i] = bmin[i].min(krow[i]);
                    bmax[i] = bmax[i].max(krow[i]);
                }
            }
        }
        if self.loki_channels > 0 {
            let pca = &aux.loki_pca[h];
            let r = self.loki_channels;
            for c in 0..r {
                let mut acc = 0.0;
                for i in 0..self.dh {
                    acc += krow[i] * pca[i * r + c];
                }
                self.loki_kproj[h].push(acc);
            }
        }
        if self.mp_l > 0 {
            let planes = &aux.mp_planes[h];
            for table in 0..self.mp_l {
                let mut sig = 0u16;
                for bit in 0..self.mp_k {
                    let p = &planes[(table * self.mp_k + bit) * self.dh..][..self.dh];
                    sig |= ((crate::tensor::ops::dot(krow, p) >= 0.0) as u16) << bit;
                }
                self.mp_sigs[h].push(sig);
            }
        }
        // bump global length once per full token (after the last head)
        if h == self.n_layers * self.n_kv - 1 {
            self.len += 1;
        }
    }

    pub fn k_slice(&self, layer: usize, kv: usize) -> &[f32] {
        &self.k[self.head_index(layer, kv)]
    }

    pub fn v_slice(&self, layer: usize, kv: usize) -> &[f32] {
        &self.v[self.head_index(layer, kv)]
    }

    pub fn codes_slice(&self, layer: usize, kv: usize) -> &[u64] {
        &self.codes[self.head_index(layer, kv)]
    }

    /// Borrow the method side structures for one head.
    pub fn side<'a>(&'a self, layer: usize, kv: usize, hash_w: &'a [f32], aux: &'a MethodAux) -> Side<'a> {
        let h = self.head_index(layer, kv);
        Side {
            hash_w,
            quest_min: self.quest_min.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            quest_max: self.quest_max.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            quest_block: self.quest_block,
            loki_kproj: self.loki_kproj.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            loki_pca: aux.loki_pca.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            loki_channels: self.loki_channels,
            mp_sigs: self.mp_sigs.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            mp_planes: aux.mp_planes.get(h).map(|v| v.as_slice()).unwrap_or(&[]),
            mp_k: self.mp_k,
            mp_l: self.mp_l,
        }
    }

    /// Total bytes held (K + V + codes + side structures).
    pub fn bytes(&self) -> usize {
        let f = |vs: &[Vec<f32>]| vs.iter().map(|v| v.len() * 4).sum::<usize>();
        let c: usize = self.codes.iter().map(|v| v.len() * 8).sum();
        let s: usize = self.mp_sigs.iter().map(|v| v.len() * 2).sum();
        f(&self.k) + f(&self.v) + c + f(&self.quest_min) + f(&self.quest_max) + f(&self.loki_kproj) + s
    }
}

/// Per-model constants the side structures need (shared across sequences):
/// Loki PCA matrices and MagicPIG hyperplanes, per (layer, kv) head.
#[derive(Default)]
pub struct MethodAux {
    pub loki_pca: Vec<Vec<f32>>,
    pub mp_planes: Vec<Vec<f32>>,
}

impl MethodAux {
    /// Build for the configured method. Loki PCA comes from artifacts when
    /// available (trained); `identity_fallback` uses the raw first channels
    /// (equivalent to SparQ-style truncation) when no PCA export exists.
    pub fn build(cfg: &ModelConfig, serve: &ServeConfig, pca: Option<Vec<Vec<f32>>>, seed: u64) -> Self {
        let heads = cfg.n_layers * cfg.n_kv_heads;
        let mut aux = MethodAux::default();
        if serve.method == Method::Loki {
            aux.loki_pca = pca.unwrap_or_else(|| {
                let r = serve.loki_channels;
                let mut id = vec![0.0f32; cfg.head_dim * r];
                for c in 0..r.min(cfg.head_dim) {
                    id[c * r + c] = 1.0;
                }
                vec![id; heads]
            });
        }
        if serve.method == Method::MagicPig {
            let mut rng = Rng::new(seed);
            aux.mp_planes = (0..heads)
                .map(|_| rng.normal_vec(serve.magicpig_l * serve.magicpig_k * cfg.head_dim))
                .collect();
        }
        aux
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn cfg_serve(method: Method) -> (ModelConfig, ServeConfig) {
        let cfg = preset("hata-gqa").unwrap();
        let serve = ServeConfig { method, ..Default::default() };
        (cfg, serve)
    }

    fn append_token(cache: &mut SeqKvCache, cfg: &ModelConfig, aux: &MethodAux, hash_w: &[f32], val: f32) {
        let krow = vec![val; cfg.head_dim];
        let vrow = vec![-val; cfg.head_dim];
        for layer in 0..cfg.n_layers {
            for kv in 0..cfg.n_kv_heads {
                cache.append(layer, kv, &krow, &vrow, hash_w, cfg.rbit, aux);
            }
        }
    }

    #[test]
    fn append_grows_all_heads_and_len() {
        let (cfg, serve) = cfg_serve(Method::Hata);
        let aux = MethodAux::default();
        let hash_w = vec![0.5; cfg.head_dim * cfg.rbit];
        let mut cache = SeqKvCache::new(&cfg, &serve);
        for t in 0..5 {
            append_token(&mut cache, &cfg, &aux, &hash_w, t as f32);
        }
        assert_eq!(cache.len(), 5);
        assert_eq!(cache.k_slice(2, 1).len(), 5 * cfg.head_dim);
        assert_eq!(cache.codes_slice(0, 0).len(), 5 * cfg.rbit / 64);
        assert!(cache.bytes() > 0);
    }

    #[test]
    fn quest_block_minmax_maintained() {
        let (cfg, serve) = cfg_serve(Method::Quest);
        let aux = MethodAux::build(&cfg, &serve, None, 0);
        let mut cache = SeqKvCache::new(&cfg, &serve);
        let block = serve.quest_block;
        // two blocks: values 0..block have max block-1
        for t in 0..(2 * block) {
            append_token(&mut cache, &cfg, &aux, &[], t as f32);
        }
        let side = cache.side(0, 0, &[], &aux);
        assert_eq!(side.quest_min.len(), 2 * cfg.head_dim);
        assert_eq!(side.quest_min[0], 0.0);
        assert_eq!(side.quest_max[0], (block - 1) as f32);
        assert_eq!(side.quest_min[cfg.head_dim], block as f32);
        assert_eq!(side.quest_max[cfg.head_dim], (2 * block - 1) as f32);
    }

    #[test]
    fn loki_identity_fallback_projects_first_channels() {
        let (cfg, serve) = cfg_serve(Method::Loki);
        let aux = MethodAux::build(&cfg, &serve, None, 0);
        let mut cache = SeqKvCache::new(&cfg, &serve);
        append_token(&mut cache, &cfg, &aux, &[], 3.0);
        let side = cache.side(1, 0, &[], &aux);
        assert_eq!(side.loki_kproj.len(), serve.loki_channels);
        // identity fallback keeps the raw first channels
        assert!(side.loki_kproj.iter().all(|&x| x == 3.0));
    }

    #[test]
    fn magicpig_signatures_deterministic() {
        let (cfg, serve) = cfg_serve(Method::MagicPig);
        let aux = MethodAux::build(&cfg, &serve, None, 7);
        let aux2 = MethodAux::build(&cfg, &serve, None, 7);
        let mut c1 = SeqKvCache::new(&cfg, &serve);
        let mut c2 = SeqKvCache::new(&cfg, &serve);
        append_token(&mut c1, &cfg, &aux, &[], 1.5);
        append_token(&mut c2, &cfg, &aux2, &[], 1.5);
        assert_eq!(c1.side(0, 0, &[], &aux).mp_sigs, c2.side(0, 0, &[], &aux2).mp_sigs);
        assert_eq!(c1.side(0, 0, &[], &aux).mp_sigs.len(), serve.magicpig_l);
    }

    #[test]
    fn disabled_side_structures_stay_empty() {
        let (cfg, serve) = cfg_serve(Method::Dense);
        let aux = MethodAux::default();
        let mut cache = SeqKvCache::new(&cfg, &serve);
        append_token(&mut cache, &cfg, &aux, &[], 1.0);
        let side = cache.side(0, 0, &[], &aux);
        assert!(side.quest_min.is_empty());
        assert!(side.loki_kproj.is_empty());
        assert!(side.mp_sigs.is_empty());
    }
}
