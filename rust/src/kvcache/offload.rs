//! HATA-off: KV-cache offloading with top-k prefetch (paper Sec 5.3,
//! Table 3), plus a MagicPIG-style CPU-scoring comparator.
//!
//! Tiering: the full K/V cache lives in the HOST tier; only the compact
//! key-code cache (rbit/8 bytes per token per head) stays DEVICE-resident.
//! A decode step scores codes on-device, top-k selects, then fetches just
//! the selected rows over the modeled PCIe link — overlapping the fetch of
//! layer L+1 with the attention compute of layer L (InfiniGen-style
//! prefetching, which the paper credits for HATA-off's decode speedup).
//!
//! MagicPIG's design instead keeps scoring on the CPU with ~1500-bit LSH
//! signatures: no row fetch, but (a) 12x larger signature traffic and (b)
//! attention compute at CPU rates. Both cost models are exercised by
//! `benches/table3_offload.rs`.

use crate::config::ModelConfig;
use crate::simulator::pcie::{PcieModel, TransferLedger};

/// Device-side compute rates used for the modeled comparison; the GPU rate
/// reflects the paper's 149.7 TFLOPS card on bandwidth-bound attention
/// (2 TB/s HBM), the CPU rate a 48-thread host (~100 GB/s, ~2 TFLOPS).
#[derive(Clone, Copy, Debug)]
pub struct OffloadRates {
    /// Device (GPU) memory bandwidth, bytes/s.
    pub dev_bw: f64,
    /// Host (CPU) memory bandwidth, bytes/s.
    pub host_bw: f64,
    /// PCIe link model.
    pub pcie: PcieModel,
}

impl OffloadRates {
    /// The paper's Table 3 testbed constants.
    pub fn paper_testbed() -> Self {
        OffloadRates { dev_bw: 2.0e12, host_bw: 100.0e9, pcie: PcieModel::gen4_x16() }
    }
}

/// Accounting result for a whole request (prefill + N decode steps).
#[derive(Clone, Copy, Debug, Default)]
pub struct OffloadReport {
    /// Modeled prefill seconds (compute overlapped with offload stream).
    pub prefill_seconds: f64,
    /// Modeled decode seconds across all steps.
    pub decode_seconds: f64,
    /// Bytes and seconds that crossed the PCIe link.
    pub ledger: TransferLedger,
}

impl OffloadReport {
    /// Prefill + decode seconds.
    pub fn total(&self) -> f64 {
        self.prefill_seconds + self.decode_seconds
    }
}

fn kv_bytes_per_token(cfg: &ModelConfig) -> usize {
    cfg.kv_bytes_per_token()
}

/// HATA-off cost model: prefill computes on device and streams K/V out to
/// host; decode scores device-resident codes, fetches top-k rows/layer
/// with cross-layer prefetch overlap.
pub fn hata_off(cfg: &ModelConfig, rates: &OffloadRates, prefill_len: usize, decode_len: usize, budget: usize) -> OffloadReport {
    let mut rep = OffloadReport::default();
    let kv_tok = kv_bytes_per_token(cfg);
    // ---- prefill: attention compute (bandwidth model, causal ~ s^2/2
    // traffic capped by flash tiling to ~2 passes) + KV offload stream
    let kv_total = prefill_len * kv_tok;
    let attn_passes = 2.0; // flash-style: read K,V once per q tile wave
    let compute = attn_passes * kv_total as f64 / rates.dev_bw
        + code_bytes(cfg, prefill_len) as f64 / rates.dev_bw;
    let mut ledger = TransferLedger::default();
    ledger.add(&rates.pcie, kv_total);
    // offload stream overlaps prefill compute
    rep.prefill_seconds = TransferLedger::overlapped(compute, ledger.seconds);
    // ---- decode: per step, per layer: score codes on device, fetch 2*k
    // rows, attend on device; fetches overlap the previous layer's attend.
    // Host-side packing (InfiniGen-style): the 48-thread host packs the
    // selected rows into a contiguous staging buffer (read+write at host
    // bandwidth), then ONE DMA per layer ships it — per-row DMA latency
    // would otherwise dominate and no real implementation pays it.
    let per_head_rows = budget.min(prefill_len);
    for step in 0..decode_len {
        let s = prefill_len + step;
        let score = code_bytes(cfg, s) as f64 / rates.dev_bw;
        let row_bytes = 2 * per_head_rows * cfg.head_dim * 4 * cfg.n_kv_heads;
        let pack = 2.0 * row_bytes as f64 / rates.host_bw;
        let mut l = TransferLedger::default();
        l.add(&rates.pcie, row_bytes);
        for _layer in 0..cfg.n_layers {
            ledger.add(&rates.pcie, row_bytes);
        }
        let fetch = pack + l.seconds;
        let attend = row_bytes as f64 / rates.dev_bw;
        // Prefetch overlap pipelines layer L+1's pack+DMA behind layer L's
        // attend, but the pipeline has ends: layer 0's fetch has no prior
        // attend to hide behind, and the last layer's attend runs after the
        // final fetch. fill + (n-1) overlapped stages + drain:
        let step_s = fetch + (cfg.n_layers - 1) as f64 * attend.max(fetch) + attend;
        rep.decode_seconds += score + step_s;
    }
    rep.ledger = ledger;
    rep
}

/// MagicPIG-style cost model: prefill additionally builds ~1500-bit LSH
/// signatures and ships K/V to host; decode scores signatures and computes
/// attention on the CPU (sampled tokens), shipping only the query and the
/// attention output across PCIe.
pub fn magicpig_off(cfg: &ModelConfig, rates: &OffloadRates, prefill_len: usize, decode_len: usize, budget: usize) -> OffloadReport {
    let mut rep = OffloadReport::default();
    let kv_tok = kv_bytes_per_token(cfg);
    let sig_bytes_per_tok = 1500 / 8 * cfg.n_layers * cfg.n_kv_heads; // paper Sec 5.3
    // prefill: device attention + signature build (memory-bound on device,
    // 1500 projections of 128-d vectors per head-token) + KV offload
    let kv_total = prefill_len * kv_tok;
    let sig_flops = 2.0 * (prefill_len * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * 1500) as f64;
    let sig_time = sig_flops / (rates.dev_bw * 10.0) // ~10 flop/byte arithmetic intensity
        + (prefill_len * sig_bytes_per_tok) as f64 / rates.dev_bw;
    let compute = 2.0 * kv_total as f64 / rates.dev_bw + sig_time;
    let mut ledger = TransferLedger::default();
    ledger.add(&rates.pcie, kv_total + prefill_len * sig_bytes_per_tok);
    rep.prefill_seconds = TransferLedger::overlapped(compute, ledger.seconds);
    // decode: CPU scores signatures over s tokens + CPU attention on k rows
    let per_head_rows = budget.min(prefill_len);
    for step in 0..decode_len {
        let s = prefill_len + step;
        let score = (s * sig_bytes_per_tok) as f64 / rates.host_bw;
        let attend = (2 * per_head_rows * cfg.head_dim * 4 * cfg.n_kv_heads * cfg.n_layers) as f64
            / rates.host_bw;
        // query down + output up per layer, tiny but latency-bound: the
        // ledger records the same 2*n_layers DMAs the time term charges,
        // so ledger.transfers/ledger.seconds agree with decode_seconds.
        let mut l = TransferLedger::default();
        for _layer in 0..cfg.n_layers {
            l.add(&rates.pcie, cfg.d_model * 4); // query down
            l.add(&rates.pcie, cfg.d_model * 4); // output up
        }
        ledger.merge(&l);
        rep.decode_seconds += score + attend + l.seconds;
    }
    rep.ledger = ledger;
    rep
}

fn code_bytes(cfg: &ModelConfig, tokens: usize) -> usize {
    tokens * cfg.code_bytes_per_token()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn hata_off_beats_magicpig_shape() {
        // Table 3 shape: HATA-off faster in both phases on the
        // Llama2-mirror at 36K prefill / 500 decode.
        let cfg = preset("mirror-llama2-7b").unwrap();
        let rates = OffloadRates::paper_testbed();
        let budget = (36_000.0 * 0.0156) as usize;
        let h = hata_off(&cfg, &rates, 36_000, 500, budget);
        let m = magicpig_off(&cfg, &rates, 36_000, 500, budget);
        assert!(h.prefill_seconds < m.prefill_seconds, "prefill {} vs {}", h.prefill_seconds, m.prefill_seconds);
        assert!(h.decode_seconds < m.decode_seconds, "decode {} vs {}", h.decode_seconds, m.decode_seconds);
        assert!(h.total() < m.total());
    }

    #[test]
    fn decode_cost_grows_with_len() {
        let cfg = preset("mirror-llama31-8b").unwrap();
        let rates = OffloadRates::paper_testbed();
        let a = hata_off(&cfg, &rates, 10_000, 100, 256).decode_seconds;
        let b = hata_off(&cfg, &rates, 10_000, 200, 256).decode_seconds;
        assert!(b > 1.9 * a);
    }

    #[test]
    fn ledger_counts_offloaded_bytes() {
        let cfg = preset("hata-mha").unwrap();
        let rates = OffloadRates::paper_testbed();
        let rep = hata_off(&cfg, &rates, 1000, 10, 64);
        // at least the full prefill KV must have crossed the link
        assert!(rep.ledger.bytes >= (1000 * cfg.kv_bytes_per_token()) as u64);
    }

    #[test]
    fn hata_off_decode_charges_pipeline_fill_and_drain() {
        // The prefetch pipeline can only hide a fetch behind a *prior*
        // layer's attend: layer 0's fetch and the last layer's attend
        // stick out of the overlap. One decode step must therefore cost
        // exactly fetch + (L-1)*max(attend, fetch) + attend on top of
        // the code-scoring term — not L*max(attend, fetch), which the
        // old accounting charged (off by one fill + one drain).
        let cfg = preset("mirror-llama2-7b").unwrap();
        let rates = OffloadRates::paper_testbed();
        let (prefill, budget) = (36_000, 561);
        let rep = hata_off(&cfg, &rates, prefill, 1, budget);
        let row_bytes = 2 * budget * cfg.head_dim * 4 * cfg.n_kv_heads;
        let score = prefill * cfg.code_bytes_per_token();
        let score = score as f64 / rates.dev_bw;
        let pack = 2.0 * row_bytes as f64 / rates.host_bw;
        let fetch = pack + rates.pcie.transfer_time(row_bytes);
        let attend = row_bytes as f64 / rates.dev_bw;
        let expect = score + fetch + (cfg.n_layers - 1) as f64 * attend.max(fetch) + attend;
        assert!(
            (rep.decode_seconds - expect).abs() < 1e-12,
            "decode step accounting drifted: {} vs {expect}",
            rep.decode_seconds
        );
        let fully_overlapped = score + cfg.n_layers as f64 * attend.max(fetch);
        assert!(rep.decode_seconds > fully_overlapped, "ends of the pipeline must stick out");
    }

    #[test]
    fn magicpig_ledger_agrees_with_charged_time() {
        // Satellite fix: the ledger used to record ONE merged DMA per
        // step while decode_seconds charged 2*n_layers DMA latencies.
        // Both sides must now see the same transfers, so the modeled
        // decode PCIe seconds are exactly recomputable from the ledger.
        let cfg = preset("hata-mha").unwrap();
        let rates = OffloadRates::paper_testbed();
        let steps = 7;
        let rep = magicpig_off(&cfg, &rates, 500, steps, 32);
        let mut prefill_only = TransferLedger::default();
        let sig_bytes_per_tok = 1500 / 8 * cfg.n_layers * cfg.n_kv_heads;
        prefill_only.add(&rates.pcie, 500 * cfg.kv_bytes_per_token() + 500 * sig_bytes_per_tok);
        let decode_transfers = rep.ledger.transfers - prefill_only.transfers;
        assert_eq!(
            decode_transfers,
            (2 * cfg.n_layers * steps) as u64,
            "ledger must record every per-layer query/output DMA"
        );
        let per_step = 2 * cfg.n_layers;
        let per_step_s = per_step as f64 * rates.pcie.transfer_time(cfg.d_model * 4);
        let decode_link_s = rep.ledger.seconds - prefill_only.seconds;
        assert!(
            (decode_link_s - steps as f64 * per_step_s).abs() < 1e-12,
            "ledger seconds must match the latency charged into decode_seconds"
        );
    }
}
