//! # HATA — Hash-Aware Top-k Attention
//!
//! Rust + JAX + Pallas reproduction of *"HATA: Trainable and
//! Hardware-Efficient Hash-Aware Top-k Attention for Scalable Large Model
//! Inference"* (ACL Findings 2025).
//!
//! This crate is Layer 3 of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): hash encoding,
//!   Hamming scoring, fused gather + sparse attention. Build-time only.
//! * **L2** — JAX model (`python/compile/model.py`): transformer fwd with
//!   the HATA decode step, AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: the serving coordinator (router, continuous
//!   batcher, prefill/decode scheduler, KV-cache + hash-code cache
//!   manager), the native CPU inference engine, every baseline top-k /
//!   compression method the paper compares against, and the PJRT runtime
//!   that loads the AOT artifacts. Python is never on the request path.
//!
//! `docs/ARCHITECTURE.md` has the module map and the life-of-a-request
//! walkthrough for both the batched decode path and the block-tiled
//! prefill path; `README.md` has the build/run quickstart.
//!
//! Documentation is a build gate: CI runs `cargo doc --no-deps` with
//! `RUSTDOCFLAGS="-D warnings"`, and the `missing_docs` lint below makes
//! an undocumented public item (or a broken intra-doc link) fail it.
#![warn(missing_docs)]

pub mod util;
pub mod config;
pub mod tensor;
pub mod model;
pub mod attention;
pub mod kvcache;
pub mod coordinator;
pub mod runtime;
pub mod simulator;
pub mod bench;
