//! Table/CSV emitters: every bench and eval prints an aligned text table
//! (the paper-table shape) and appends a CSV under `bench_results/`.

use std::fmt::Write as _;
use std::path::Path;

/// Aligned text table builder.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (c, w) in cells.iter().zip(&widths) {
                let _ = write!(out, "{c:>w$}  ", w = w);
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Append as CSV (with header when the file is new).
    pub fn write_csv(&self, dir: impl AsRef<Path>, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir.as_ref())?;
        let path = dir.as_ref().join(format!("{name}.csv"));
        let mut body = String::new();
        body.push_str(&self.header.join(","));
        body.push('\n');
        for row in &self.rows {
            body.push_str(&row.join(","));
            body.push('\n');
        }
        std::fs::write(path, body)
    }
}

/// Header labels matching the cells produced by [`roofline_cells`].
pub const ROOFLINE_HEADER: [&str; 5] = ["GB/s", "roof_GB/s", "GFLOP/s", "roof_GFLOP/s", "%roof"];

/// Shared roofline columns for the float kernel benches (microbench,
/// fig9): measured GB/s and GFLOP/s for the kernel's known traffic and
/// work, the `simulator::roofline` bound for the same counts, and the
/// fraction of that bound achieved. Keeps every bench printing bounds
/// from the one model instead of hand-rolled constants.
pub fn roofline_cells(
    est: &crate::simulator::roofline::KernelEstimate,
    measured_s: f64,
) -> Vec<String> {
    let gbs = est.hbm_bytes / measured_s / 1e9;
    let gflops = est.flops / measured_s / 1e9;
    let roof_gbs = est.hbm_bytes / est.seconds / 1e9;
    let roof_gflops = est.flops / est.seconds / 1e9;
    let pct = 100.0 * est.seconds / measured_s;
    vec![fmt(gbs), fmt(roof_gbs), fmt(gflops), fmt(roof_gflops), fmt(pct)]
}

/// Header labels matching the cells produced by [`int_roofline_cells`].
pub const INT_ROOFLINE_HEADER: [&str; 5] = ["GB/s", "roof_GB/s", "GOP/s", "roof_GOP/s", "%roof"];

/// Roofline columns for integer/bit-op kernels (the Hamming scorer):
/// same shape as [`roofline_cells`] with the ALU work read from the
/// estimate's VPU slot, so XOR+popcount throughput prints as GOP/s next
/// to its own `simulator::roofline::int_kernel` bound rather than a
/// GFLOP/s column that would always read zero.
pub fn int_roofline_cells(
    est: &crate::simulator::roofline::KernelEstimate,
    measured_s: f64,
) -> Vec<String> {
    let gbs = est.hbm_bytes / measured_s / 1e9;
    let gops = est.vpu_ops / measured_s / 1e9;
    let roof_gbs = est.hbm_bytes / est.seconds / 1e9;
    let roof_gops = est.vpu_ops / est.seconds / 1e9;
    let pct = 100.0 * est.seconds / measured_s;
    vec![fmt(gbs), fmt(roof_gbs), fmt(gops), fmt(roof_gops), fmt(pct)]
}

/// Format a float with sensible precision for table cells.
pub fn fmt(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["method", "acc"]);
        t.row(vec!["hata".into(), "0.97".into()]);
        t.row(vec!["dense".into(), "1.00".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("hata"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_written() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join(format!("hata_csv_{}", std::process::id()));
        t.write_csv(&dir, "t").unwrap();
        let body = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert_eq!(body, "a,b\n1,2\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn roofline_cells_match_header_and_bound() {
        let dev = crate::simulator::roofline::Device::cpu();
        // 1 GB of traffic, trivial flops: bound = bandwidth time
        let est = crate::simulator::roofline::float_kernel(&dev, 1e9, 1.0);
        // measured at exactly the bound -> GB/s equals roof, %roof = 100
        let cells = roofline_cells(&est, est.seconds);
        assert_eq!(cells.len(), ROOFLINE_HEADER.len());
        assert_eq!(cells[0], cells[1]);
        assert_eq!(cells[4], "100");
        // measured 2x slower -> half the roof
        let slow = roofline_cells(&est, est.seconds * 2.0);
        assert_eq!(slow[4], "50.00");
    }

    #[test]
    fn int_roofline_cells_match_header_and_bound() {
        let dev = crate::simulator::roofline::Device::cpu();
        let est = crate::simulator::roofline::int_kernel(&dev, 1e9, 1.0);
        let cells = int_roofline_cells(&est, est.seconds);
        assert_eq!(cells.len(), INT_ROOFLINE_HEADER.len());
        assert_eq!(cells[0], cells[1]);
        assert_eq!(cells[4], "100");
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(f64::NAN), "-");
        assert_eq!(fmt(123.4), "123");
        assert_eq!(fmt(1.234), "1.23");
        assert_eq!(fmt(0.1234), "0.1234");
    }
}
