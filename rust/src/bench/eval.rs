//! Accuracy + fidelity evaluation of every attention method over the
//! synthetic task suite — the machinery behind the Table 1/2/6-10 proxies
//! and Figures 6/7/8.

use crate::config::{Method, ServeConfig};
use crate::kvcache::SeqKvCache;
use crate::model::{make_selector, sel_ref, tokenizer, DecodeScratch, Model, SeqState};
use crate::util::rng::Rng;

use super::tasks::{make_task, Corpus, TaskKind};

/// Exact-match accuracy of one method on one task kind.
#[allow(clippy::too_many_arguments)]
pub fn task_accuracy(
    model: &Model,
    serve: &ServeConfig,
    kind: TaskKind,
    ctx: usize,
    n_samples: usize,
    seed: u64,
    depth: Option<f64>,
) -> f64 {
    let corpus = Corpus::new(0);
    let mut rng = Rng::new(seed);
    let selector = make_selector(serve);
    let mut hits = 0usize;
    let mut scratch = DecodeScratch::new(&model.cfg);
    for _ in 0..n_samples {
        let (prompt, answer) = make_task(kind, &corpus, &mut rng, ctx, depth);
        let toks = tokenizer::encode(&prompt);
        let mut cache = SeqKvCache::new(&model.cfg, serve);
        let mut state = SeqState::new(&model.cfg);
        let out = model.generate(
            &toks,
            answer.len(),
            serve,
            sel_ref(&selector),
            &mut cache,
            &mut state,
            &mut scratch,
        );
        if tokenizer::decode(&out) == answer {
            hits += 1;
        }
    }
    hits as f64 / n_samples as f64
}

/// Fidelity metrics of a selection method against exact attention, on
/// real Q/K states harvested from the trained model over task prompts.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fidelity {
    /// fraction of the true top-k keys the method selected
    pub recall: f64,
    /// mean relative L2 error of the sparse attention output vs dense
    pub output_err: f64,
}

/// Measure selection recall + attention-output error at one decode
/// position per sample (the final query token of a task prompt).
pub fn fidelity(
    model: &Model,
    serve: &ServeConfig,
    ctx: usize,
    n_samples: usize,
    seed: u64,
) -> Fidelity {
    use crate::attention::compute::{dense_attention, exact_group_scores, sparse_attention_fused};
    use crate::attention::topk::topk_quickselect;
    use crate::attention::{AttnInputs, MethodState, Scratch};

    let corpus = Corpus::new(0);
    let mut rng = Rng::new(seed);
    let selector = make_selector(serve);
    let mut scratch = DecodeScratch::new(&model.cfg);
    let mut recall_sum = 0.0;
    let mut err_sum = 0.0;
    let mut count = 0usize;
    let cfg = &model.cfg;
    for i in 0..n_samples {
        let (prompt, _) = make_task(TaskKind::Ns, &corpus, &mut rng, ctx, None);
        let toks = tokenizer::encode(&prompt);
        let mut cache = SeqKvCache::new(cfg, serve);
        let mut state = SeqState::new(cfg);
        // prefill everything but the final token; then run one step to
        // have fresh q against the full cache
        model.prefill(&toks[..toks.len() - 1], &mut cache, &mut state, serve, &mut scratch);
        let pos = toks.len() - 1;
        let dense_serve = ServeConfig { budget: 0, ..serve.clone() };
        model.decode_step(
            toks[pos],
            pos,
            &mut cache,
            &mut state,
            &dense_serve,
            None,
            &mut scratch,
        );
        // fidelity on the LAST layer's heads (most selective, per paper)
        let li = cfg.n_layers - 1;
        for kv in 0..cfg.n_kv_heads {
            let group = cfg.group();
            // reconstruct the step's queries: scratch.q holds last layer
            let inp = AttnInputs {
                q: &scratch.q[kv * group * cfg.head_dim..(kv + 1) * group * cfg.head_dim],
                group,
                dh: cfg.head_dim,
                k: cache.k_slice(li, kv),
                v: cache.v_slice(li, kv),
                codes: cache.codes_slice(li, kv),
                words: cfg.rbit / 64,
                rbit: cfg.rbit,
                s: cache.len(),
                pos: cache.len() - 1,
                bt: &[],
                block_tokens: 0,
                kv_dtype: cache.kv_dtype,
                kernels: model.kernels,
                side: cache.side(li, kv, model.weights.hash_head(li, kv), &model.aux),
            };
            let budget = serve.budget.min(inp.s);
            let mut sel_scratch = Scratch::default();
            // truth: exact aggregated scores top-k
            let mut truth = Vec::new();
            exact_group_scores(&inp, &mut sel_scratch.scores);
            topk_quickselect(&sel_scratch.scores, budget, &mut sel_scratch.perm, &mut truth);
            // method selection
            let mut st = MethodState::default();
            // H2O/SnapKV need engine-maintained state: reuse actual state
            let st_ref = &mut state.per_head[li * cfg.n_kv_heads + kv];
            let indices: Vec<u32> = if let Some(sel) = selector.as_deref() {
                sel.select(&inp, if matches!(serve.method, Method::H2o | Method::SnapKv) { st_ref } else { &mut st }, budget, &mut sel_scratch);
                sel_scratch.indices.clone()
            } else {
                (0..inp.s as u32).collect()
            };
            let tset: std::collections::BTreeSet<u32> = truth.iter().copied().collect();
            let hit = indices.iter().filter(|i| tset.contains(i)).count();
            recall_sum += hit as f64 / budget.max(1) as f64;
            // output error
            let mut dense_out = vec![0.0f32; group * cfg.head_dim];
            let mut sparse_out = vec![0.0f32; group * cfg.head_dim];
            let mut probs = Vec::new();
            dense_attention(model.kernels, &inp, &mut probs, &mut dense_out);
            sparse_attention_fused(model.kernels, &inp, &indices, &mut probs, &mut sparse_out);
            let num: f32 = dense_out
                .iter()
                .zip(&sparse_out)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let den: f32 = dense_out.iter().map(|a| a * a).sum();
            err_sum += (num / den.max(1e-12)).sqrt() as f64;
            count += 1;
        }
        let _ = i;
    }
    Fidelity { recall: recall_sum / count as f64, output_err: err_sum / count as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::kvcache::MethodAux;
    use crate::model::weights::Weights;

    fn model() -> Model {
        let cfg = preset("hata-mha").unwrap();
        let mut rng = Rng::new(0);
        let weights = Weights::random(&cfg, &mut rng);
        Model::new(cfg, weights, MethodAux::default())
    }

    #[test]
    fn exact_topk_fidelity_is_perfect() {
        let m = model();
        let serve = ServeConfig { method: Method::ExactTopK, budget: 24, ..Default::default() };
        let f = fidelity(&m, &serve, 128, 2, 1);
        assert!(f.recall > 0.999, "recall {}", f.recall);
        assert!(f.output_err < 0.5, "err {}", f.output_err);
    }

    #[test]
    fn dense_fidelity_recall_full() {
        let m = model();
        let serve = ServeConfig { method: Method::Dense, budget: 24, ..Default::default() };
        let f = fidelity(&m, &serve, 96, 2, 1);
        // dense "selects" everything -> recall 1, error 0
        assert!(f.recall >= 1.0);
        assert!(f.output_err < 1e-5);
    }

    #[test]
    fn hata_random_hash_beats_nothing_sanity() {
        // untrained random hash on a random model: recall should still be
        // far above the random-selection baseline budget/s
        let m = model();
        let serve = ServeConfig { method: Method::Hata, budget: 16, ..Default::default() };
        let f = fidelity(&m, &serve, 160, 3, 2);
        assert!(f.recall > 16.0 / 160.0, "recall {}", f.recall);
    }

    #[test]
    fn task_accuracy_runs_on_untrained_model() {
        // untrained model: accuracy ~0, but the pipeline must not panic
        let m = model();
        let serve = ServeConfig { method: Method::Hata, budget: 16, ..Default::default() };
        let acc = task_accuracy(&m, &serve, TaskKind::Ns, 96, 2, 3, None);
        assert!((0.0..=1.0).contains(&acc));
    }
}
