//! Bench harness (criterion is unavailable offline — see DESIGN.md §2):
//! warmup + timed iterations + summary, plus the decode-layer micro
//! fixture shared by the Fig 5 / Fig 9 benches.

use crate::attention::{AttnInputs, Side};
use crate::tensor::simd::{KernelMode, KvDtype};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::time_iters;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Timed iterations (after warmup).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// Fastest iteration seconds.
    pub min_s: f64,
}

/// Run a closure with warmup and report stats.
pub fn bench(name: &str, warmup: usize, iters: usize, f: impl FnMut()) -> BenchResult {
    let samples = time_iters(warmup, iters, f);
    let mut s = Summary::new();
    for &x in &samples {
        s.add(x);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        p50_s: s.p50(),
        min_s: s.min(),
    }
}

impl BenchResult {
    /// One aligned human-readable report line.
    pub fn line(&self) -> String {
        format!(
            "{:40} {:>10.3} ms/iter (p50 {:>10.3}, min {:>10.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.min_s * 1e3,
            self.iters
        )
    }
}

/// Synthetic single-(layer, kv-head) decode fixture: random K/V/codes at a
/// given context length — the unit under test in Fig 5 and Fig 9.
pub struct LayerFixture {
    /// Head dimension.
    pub dh: usize,
    /// GQA query heads per KV head.
    pub group: usize,
    /// Hash code bits.
    pub rbit: usize,
    /// Context length.
    pub s: usize,
    /// Query rows, [group, dh].
    pub q: Vec<f32>,
    /// Key cache, [s, dh].
    pub k: Vec<f32>,
    /// Value cache, [s, dh].
    pub v: Vec<f32>,
    /// Packed key codes.
    pub codes: Vec<u64>,
    /// Hash projection, [dh, rbit].
    pub hash_w: Vec<f32>,
    /// Quest block minima.
    pub quest_min: Vec<f32>,
    /// Quest block maxima.
    pub quest_max: Vec<f32>,
    /// Quest tokens per block.
    pub quest_block: usize,
    /// Loki projected keys.
    pub loki_kproj: Vec<f32>,
    /// Loki projection matrix.
    pub loki_pca: Vec<f32>,
    /// Loki retained channels.
    pub loki_channels: usize,
}

impl LayerFixture {
    /// Random fixture at context length `s` (deterministic in `seed`).
    pub fn new(s: usize, dh: usize, group: usize, rbit: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let k = rng.normal_vec(s * dh);
        let v = rng.normal_vec(s * dh);
        let q = rng.normal_vec(group * dh);
        let hash_w: Vec<f32> = rng.normal_vec(dh * rbit);
        let codes = crate::attention::hashenc::encode_rows(&k, dh, &hash_w, rbit);
        // quest blocks
        let quest_block = 16;
        let nb = s.div_ceil(quest_block);
        let mut quest_min = vec![f32::INFINITY; nb * dh];
        let mut quest_max = vec![f32::NEG_INFINITY; nb * dh];
        for t in 0..s {
            let b = t / quest_block;
            for i in 0..dh {
                quest_min[b * dh + i] = quest_min[b * dh + i].min(k[t * dh + i]);
                quest_max[b * dh + i] = quest_max[b * dh + i].max(k[t * dh + i]);
            }
        }
        // loki: identity projection over first quarter channels
        let loki_channels = (dh / 4).max(1);
        let mut loki_pca = vec![0.0f32; dh * loki_channels];
        for c in 0..loki_channels {
            loki_pca[c * loki_channels + c] = 1.0;
        }
        let mut loki_kproj = Vec::with_capacity(s * loki_channels);
        for t in 0..s {
            for c in 0..loki_channels {
                loki_kproj.push(k[t * dh + c]);
            }
        }
        LayerFixture {
            dh,
            group,
            rbit,
            s,
            q,
            k,
            v,
            codes,
            hash_w,
            quest_min,
            quest_max,
            quest_block,
            loki_kproj,
            loki_pca,
            loki_channels,
        }
    }

    /// Borrow the fixture as a selector/kernel input.
    pub fn inputs(&self) -> AttnInputs<'_> {
        AttnInputs {
            q: &self.q,
            group: self.group,
            dh: self.dh,
            k: &self.k,
            v: &self.v,
            codes: &self.codes,
            words: self.rbit / 64,
            rbit: self.rbit,
            s: self.s,
            pos: self.s - 1,
            bt: &[],
            block_tokens: 0,
            kv_dtype: KvDtype::F32,
            kernels: KernelMode::default(),
            side: Side {
                hash_w: &self.hash_w,
                quest_min: &self.quest_min,
                quest_max: &self.quest_max,
                quest_block: self.quest_block,
                loki_kproj: &self.loki_kproj,
                loki_pca: &self.loki_pca,
                loki_channels: self.loki_channels,
                mp_sigs: &[],
                mp_planes: &[],
                mp_k: 0,
                mp_l: 0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s * 1.5 + 1e-9);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn fixture_shapes_consistent() {
        let f = LayerFixture::new(500, 16, 4, 128, 0);
        assert_eq!(f.k.len(), 500 * 16);
        assert_eq!(f.codes.len(), 500 * 2);
        let inp = f.inputs();
        assert_eq!(inp.s, 500);
        assert_eq!(inp.side.quest_min.len(), 500usize.div_ceil(16) * 16);
    }
}
