//! Benchmark + evaluation harness: workload generators that mirror
//! `python/compile/data.py`, accuracy evaluation over the task suite
//! (Tables 1/2/6-10 proxies, Figs 6-8), fidelity metrics (top-k recall,
//! attention-output error), and table/CSV emitters.

pub mod eval;
pub mod harness;
pub mod report;
pub mod tasks;
