//! RULER-style synthetic long-context tasks — Rust mirror of
//! `python/compile/data.py` (same byte grammar; golden-pinned by
//! rust/tests/parity.rs against `<model>.goldens.npz`).
//!
//! Grammar: needle `&<k>=<v>;` (k: 2 lowercase, v: 2 uppercase), query
//! `?<k>=` with expected continuation `<v>;`, variable-tracking alias
//! `&<k2>=<k1>;`, filler from a seeded word chain.

use crate::util::rng::Rng;

/// Needle key length (lowercase chars).
pub const KEY_LEN: usize = 2;
/// Needle value length (uppercase chars).
pub const VAL_LEN: usize = 2;

/// Task kinds mirroring RULER's categories (DESIGN.md §6, Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// single needle (NS1-3 collapse to depth-parameterized NS)
    Ns,
    /// multi-key: 4 needles, query one
    Nmk,
    /// multi-value: same key announced twice (first binding wins)
    Nmv,
    /// multi-query (we score the first query)
    Nmq,
    /// variable tracking: alias chain
    Vt,
    /// frequent-word: thrice-repeated binding
    Fwe,
    /// QA-style single fact
    Qa,
}

impl TaskKind {
    /// Every task kind, in table column order.
    pub fn all() -> &'static [TaskKind] {
        &[
            TaskKind::Ns,
            TaskKind::Nmk,
            TaskKind::Nmv,
            TaskKind::Nmq,
            TaskKind::Vt,
            TaskKind::Fwe,
            TaskKind::Qa,
        ]
    }

    /// Short table label.
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Ns => "NS",
            TaskKind::Nmk => "NMK",
            TaskKind::Nmv => "NMV",
            TaskKind::Nmq => "NMQ",
            TaskKind::Vt => "VT",
            TaskKind::Fwe => "FWE",
            TaskKind::Qa => "QA",
        }
    }
}

/// Seeded filler-text source (word chain over lowercase words).
pub struct Corpus {
    words: Vec<String>,
    next: Vec<[usize; 8]>,
}

impl Corpus {
    /// Build the word chain deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let n_words = 512;
        let words: Vec<String> = (0..n_words)
            .map(|_| {
                let len = 2 + rng.below(6);
                (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
            })
            .collect();
        let next = (0..n_words)
            .map(|_| {
                let mut row = [0usize; 8];
                for r in row.iter_mut() {
                    *r = rng.below(n_words);
                }
                row
            })
            .collect();
        Corpus { words, next }
    }

    /// Exactly `n_chars` of filler text.
    pub fn text(&self, rng: &mut Rng, n_chars: usize) -> String {
        let mut out = String::with_capacity(n_chars + 8);
        let mut w = rng.below(self.words.len());
        while out.len() < n_chars {
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(&self.words[w]);
            w = self.next[w][rng.below(8)];
        }
        out.truncate(n_chars);
        out
    }
}

fn key(rng: &mut Rng) -> String {
    (0..KEY_LEN).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

fn val(rng: &mut Rng) -> String {
    (0..VAL_LEN).map(|_| (b'A' + rng.below(26) as u8) as char).collect()
}

fn distinct_keys(rng: &mut Rng, n: usize) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    while out.len() < n {
        let k = key(rng);
        if !out.contains(&k) {
            out.push(k);
        }
    }
    out
}

/// Place needles at fractional depths in filler; returns (prompt, answer).
fn assemble(
    corpus: &Corpus,
    rng: &mut Rng,
    ctx: usize,
    needles: &[String],
    depths: &[f64],
    query: &str,
    answer: &str,
) -> (String, String) {
    let needle_len: usize = needles.iter().map(|s| s.len()).sum();
    let filler = ctx
        .checked_sub(needle_len + query.len())
        .expect("context too small for task");
    let text = corpus.text(rng, filler);
    let mut offs: Vec<usize> = depths.iter().map(|d| (d * filler as f64) as usize).collect();
    offs.sort_unstable();
    let mut prompt = String::with_capacity(ctx);
    let mut prev = 0;
    for (off, ndl) in offs.iter().zip(needles) {
        prompt.push_str(&text[prev..*off]);
        prompt.push_str(ndl);
        prev = *off;
    }
    prompt.push_str(&text[prev..]);
    prompt.push_str(query);
    (prompt, answer.to_string())
}

/// Generate one (prompt, expected_continuation). `depth` in [0,1] or None
/// for random.
pub fn make_task(
    kind: TaskKind,
    corpus: &Corpus,
    rng: &mut Rng,
    ctx: usize,
    depth: Option<f64>,
) -> (String, String) {
    let d = depth.unwrap_or_else(|| 0.05 + 0.9 * rng.f64());
    match kind {
        TaskKind::Ns | TaskKind::Qa => {
            let (k, v) = (key(rng), val(rng));
            assemble(corpus, rng, ctx, &[format!("&{k}={v};")], &[d], &format!("?{k}="), &format!("{v};"))
        }
        TaskKind::Nmk => {
            let keys = distinct_keys(rng, 4);
            let vals: Vec<String> = (0..4).map(|_| val(rng)).collect();
            let needles: Vec<String> =
                keys.iter().zip(&vals).map(|(k, v)| format!("&{k}={v};")).collect();
            let mut depths: Vec<f64> = (0..4).map(|_| 0.05 + 0.9 * rng.f64()).collect();
            depths.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let pick = rng.below(4);
            assemble(corpus, rng, ctx, &needles, &depths, &format!("?{}=", keys[pick]), &format!("{};", vals[pick]))
        }
        TaskKind::Nmv => {
            let k = key(rng);
            let (v1, v2) = (val(rng), val(rng));
            let needles = vec![format!("&{k}={v1};"), format!("&{k}+{v2};")];
            let mut depths = vec![0.05 + 0.9 * rng.f64(), 0.05 + 0.9 * rng.f64()];
            depths.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assemble(corpus, rng, ctx, &needles, &depths, &format!("?{k}="), &format!("{v1};"))
        }
        TaskKind::Nmq => {
            let keys = distinct_keys(rng, 3);
            let vals: Vec<String> = (0..3).map(|_| val(rng)).collect();
            let needles: Vec<String> =
                keys.iter().zip(&vals).map(|(k, v)| format!("&{k}={v};")).collect();
            let mut depths: Vec<f64> = (0..3).map(|_| 0.05 + 0.9 * rng.f64()).collect();
            depths.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q0 = rng.below(3);
            assemble(corpus, rng, ctx, &needles, &depths, &format!("?{}=", keys[q0]), &format!("{};", vals[q0]))
        }
        TaskKind::Vt => {
            let keys = distinct_keys(rng, 2);
            let (k1, k2) = (&keys[0], &keys[1]);
            let v = val(rng);
            let needles = vec![format!("&{k1}={v};"), format!("&{k2}={k1};")];
            let mut depths = vec![0.05 + 0.9 * rng.f64(), 0.05 + 0.9 * rng.f64()];
            depths.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assemble(corpus, rng, ctx, &needles, &depths, &format!("?{k2}="), &format!("{k1};"))
        }
        TaskKind::Fwe => {
            let hot = val(rng);
            let needles = vec![format!("&fwe={hot};"); 3];
            let mut depths = vec![
                0.05 + 0.9 * rng.f64(),
                0.05 + 0.9 * rng.f64(),
                0.05 + 0.9 * rng.f64(),
            ];
            depths.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assemble(corpus, rng, ctx, &needles, &depths, "?fwe=", &format!("{hot};"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_has_exact_context_length() {
        let corpus = Corpus::new(0);
        let mut rng = Rng::new(1);
        for &kind in TaskKind::all() {
            let (prompt, ans) = make_task(kind, &corpus, &mut rng, 300, None);
            assert_eq!(prompt.len(), 300, "{kind:?}");
            assert_eq!(ans.len(), VAL_LEN + 1, "{kind:?}");
            assert!(prompt.is_ascii());
        }
    }

    #[test]
    fn needle_present_and_answer_consistent() {
        let corpus = Corpus::new(0);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let (prompt, ans) = make_task(TaskKind::Ns, &corpus, &mut rng, 256, Some(0.5));
            // query "?kk=" is the suffix; the needle "&kk=VV;" must exist
            let k = &prompt[prompt.len() - KEY_LEN - 1..prompt.len() - 1];
            let needle = format!("&{k}={}", ans);
            assert!(prompt.contains(&needle), "prompt lacks {needle:?}");
        }
    }

    #[test]
    fn depth_controls_position() {
        let corpus = Corpus::new(0);
        let mut rng = Rng::new(3);
        let (early, _) = make_task(TaskKind::Ns, &corpus, &mut rng, 400, Some(0.05));
        let (late, _) = make_task(TaskKind::Ns, &corpus, &mut rng, 400, Some(0.9));
        let pos_early = early.find('&').unwrap();
        let pos_late = late.find('&').unwrap();
        assert!(pos_early < 60, "{pos_early}");
        assert!(pos_late > 300, "{pos_late}");
    }

    #[test]
    fn vt_answer_is_intermediate_key() {
        let corpus = Corpus::new(0);
        let mut rng = Rng::new(4);
        let (prompt, ans) = make_task(TaskKind::Vt, &corpus, &mut rng, 300, None);
        // answer must be a lowercase key + ';'
        assert!(ans[..KEY_LEN].bytes().all(|b| b.is_ascii_lowercase()));
        assert!(prompt.contains(&format!("&{}=", &ans[..KEY_LEN])));
    }

    #[test]
    fn deterministic_given_seed() {
        let corpus = Corpus::new(7);
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        assert_eq!(
            make_task(TaskKind::Nmk, &corpus, &mut a, 350, None),
            make_task(TaskKind::Nmk, &corpus, &mut b, 350, None)
        );
    }
}
