//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python -m compile.aot` and executes them on the CPU PJRT client.
//!
//! This is the proof of the three-layer contract: the L2 JAX graphs (with
//! the L1 Pallas kernels inlined by interpret-mode lowering) run from Rust
//! with no Python anywhere near the process. Static shapes come bucketed;
//! [`PjrtModel::generate`] picks the smallest bucket that fits and masks
//! the tail via the graph's `cur_len` scalar.
//!
//! The real implementation needs the `xla` bindings, which are not in the
//! offline crate set; it compiles only under `RUSTFLAGS="--cfg pjrt"`
//! (add the `xla` dependency locally when enabling it). Otherwise a stub
//! [`PjrtModel`] keeps the CLI/test surface intact and reports the
//! runtime as unavailable at load time.

#[cfg(not(pjrt))]
mod stub {
    use anyhow::{bail, Result};

    use crate::config::manifest::ModelArtifacts;

    /// Stub compiled without `--cfg pjrt`: same surface, fails at load.
    pub struct PjrtModel {
        /// Static token-capacity bucket of the loaded graphs.
        pub bucket: usize,
        /// Top-k budget compiled into the HATA decode graph.
        pub hata_budget: usize,
    }

    impl PjrtModel {
        /// Always fails: the `xla` bindings are not compiled in.
        pub fn load(_arts: &ModelArtifacts, _needed: usize) -> Result<PjrtModel> {
            bail!(
                "PJRT runtime unavailable: built without `--cfg pjrt` \
                 (xla bindings are not in the offline crate set)"
            )
        }

        /// Always fails: the `xla` bindings are not compiled in.
        pub fn generate(&self, _prompt: &[u32], _n_new: usize, _budget: usize) -> Result<Vec<u32>> {
            bail!("PJRT runtime unavailable: built without `--cfg pjrt`")
        }
    }
}

#[cfg(not(pjrt))]
pub use stub::PjrtModel;

#[cfg(pjrt)]
mod pjrt_impl {
    use std::path::Path;

    use anyhow::{anyhow, bail, Context, Result};

    use crate::config::manifest::ModelArtifacts;
    use crate::config::ModelConfig;
    use crate::tensor::io::TensorStore;

    /// Thin wrapper over the PJRT CPU client.
    pub struct PjrtRuntime {
        pub client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            Ok(PjrtRuntime { client: xla::PjRtClient::cpu().map_err(wrap)? })
        }

        /// Load + compile one HLO text file.
        pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(wrap)
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client.compile(&comp).map_err(wrap).context("compiling HLO")
        }
    }

    fn wrap(e: xla::Error) -> anyhow::Error {
        anyhow!("xla: {e}")
    }

    /// A generation-capable model running entirely on AOT artifacts.
    pub struct PjrtModel {
        pub cfg: ModelConfig,
        runtime: PjrtRuntime,
        /// weight literals in aot.py param_order, then hash_w
        weights: Vec<xla::Literal>,
        hash_w: xla::Literal,
        prefill: xla::PjRtLoadedExecutable,
        decode_dense: xla::PjRtLoadedExecutable,
        decode_hata: Option<xla::PjRtLoadedExecutable>,
        pub bucket: usize,
        pub hata_budget: usize,
    }

    impl PjrtModel {
        /// Load weights + graphs for one model from the manifest, choosing
        /// the smallest bucket >= `needed` tokens.
        pub fn load(arts: &ModelArtifacts, needed: usize) -> Result<PjrtModel> {
            let runtime = PjrtRuntime::cpu()?;
            let cfg = arts.config.clone();
            let pre = arts
                .pick_bucket("prefill", needed)
                .with_context(|| format!("no prefill bucket >= {needed}"))?;
            let bucket = pre.bucket;
            let dd = arts
                .hlo
                .iter()
                .find(|e| e.kind == "decode_dense" && e.bucket == bucket)
                .context("no decode_dense for bucket")?;
            let dh = arts.hlo.iter().find(|e| e.kind == "decode_hata" && e.bucket == bucket);
            let store = TensorStore::load(&arts.weights)?;
            let mut weights = Vec::new();
            for name in &arts.param_order {
                let t = store.f32(name)?;
                weights.push(literal_f32(t.data(), t.shape())?);
            }
            let hash_path = arts
                .hash_weights_for(cfg.rbit)
                .with_context(|| format!("no hash weights rbit={}", cfg.rbit))?;
            let hstore = TensorStore::load(hash_path)?;
            let ht = hstore.f32("hash_w")?;
            let hash_w = literal_f32(ht.data(), ht.shape())?;
            Ok(PjrtModel {
                prefill: runtime.load_hlo(&pre.path)?,
                decode_dense: runtime.load_hlo(&dd.path)?,
                decode_hata: dh.map(|e| runtime.load_hlo(&e.path)).transpose()?,
                hata_budget: dh.map(|e| e.budget).unwrap_or(0),
                cfg,
                runtime,
                weights,
                hash_w,
                bucket,
            })
        }

        /// Greedy generation. `budget > 0` uses the HATA decode graph.
        pub fn generate(&self, prompt: &[u32], n_new: usize, budget: usize) -> Result<Vec<u32>> {
            let cfg = &self.cfg;
            if prompt.len() + n_new > self.bucket {
                bail!("bucket {} too small for {} tokens", self.bucket, prompt.len() + n_new);
            }
            if budget > 0 && self.decode_hata.is_none() {
                bail!("no decode_hata graph in artifacts");
            }
            // ---- prefill
            let mut toks_padded = vec![0i32; self.bucket];
            for (i, &t) in prompt.iter().enumerate() {
                toks_padded[i] = t as i32;
            }
            let tokens_lit =
                xla::Literal::vec1(&toks_padded).reshape(&[self.bucket as i64]).map_err(wrap)?;
            let len_lit = xla::Literal::scalar(prompt.len() as i32);
            let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
            args.push(&self.hash_w);
            args.push(&tokens_lit);
            args.push(&len_lit);
            let res = self.prefill.execute::<&xla::Literal>(&args).map_err(wrap)?;
            let tuple = res[0][0].to_literal_sync().map_err(wrap)?;
            let mut parts = tuple.to_tuple().map_err(wrap)?;
            anyhow::ensure!(parts.len() == 4, "prefill returns 4 outputs");
            let mut kc = parts.remove(1);
            let mut vc = parts.remove(1);
            let mut cc = parts.remove(1);
            let logits = parts.remove(0);
            let mut next = argmax_lit(&logits)?;
            // prefill emits caches sized [L, KV, bucket, *] already
            let mut out = Vec::with_capacity(n_new);
            let _ = cfg;
            // ---- decode loop
            for step in 0..n_new {
                out.push(next);
                let pos = prompt.len() + step;
                let tok_lit = xla::Literal::scalar(next as i32);
                let pos_lit = xla::Literal::scalar(pos as i32);
                let exe =
                    if budget > 0 { self.decode_hata.as_ref().unwrap() } else { &self.decode_dense };
                let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
                args.push(&self.hash_w);
                args.push(&tok_lit);
                args.push(&pos_lit);
                args.push(&kc);
                args.push(&vc);
                args.push(&cc);
                let res = exe.execute::<&xla::Literal>(&args).map_err(wrap)?;
                let tuple = res[0][0].to_literal_sync().map_err(wrap)?;
                let mut parts = tuple.to_tuple().map_err(wrap)?;
                anyhow::ensure!(parts.len() == 4, "decode returns 4 outputs");
                let logits = parts.remove(0);
                kc = parts.remove(0);
                vc = parts.remove(0);
                cc = parts.remove(0);
                next = argmax_lit(&logits)?;
            }
            Ok(out)
        }
    }

    fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data).reshape(&dims).map_err(wrap)
    }

    fn argmax_lit(logits: &xla::Literal) -> Result<u32> {
        let v: Vec<f32> = logits.to_vec().map_err(wrap)?;
        Ok(crate::tensor::ops::argmax(&v) as u32)
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn cpu_client_comes_up() {
            let rt = PjrtRuntime::cpu().unwrap();
            assert!(rt.client.device_count() >= 1);
        }

        #[test]
        fn literal_roundtrip() {
            let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
            let v: Vec<f32> = l.to_vec().unwrap();
            assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0]);
        }

        #[test]
        fn load_missing_hlo_errors() {
            let rt = PjrtRuntime::cpu().unwrap();
            assert!(rt.load_hlo(Path::new("/nonexistent.hlo.txt")).is_err());
        }
    }
}

#[cfg(pjrt)]
pub use pjrt_impl::{PjrtModel, PjrtRuntime};
