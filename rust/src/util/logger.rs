//! Leveled stderr logger with monotonic timestamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, ordered (`Debug` lowest).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Verbose diagnostics (off by default).
    Debug = 0,
    /// Normal operational messages (the default threshold).
    Info = 1,
    /// Recoverable anomalies (stalls, fallbacks).
    Warn = 2,
    /// Failures.
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global minimum level that gets printed.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Would a message at `level` be printed?
pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Print one message to stderr with a monotonic timestamp (prefer the
/// `log_info!`/`log_debug!`/`log_warn!` macros).
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{secs:9.3}s {tag} {target}] {msg}");
}

/// Log a formatted message at [`Level::Info`].
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, format_args!($($arg)*))
    };
}

/// Log a formatted message at [`Level::Debug`].
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, format_args!($($arg)*))
    };
}

/// Log a formatted message at [`Level::Warn`].
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
