//! Mini property-testing driver (no proptest in the offline crate set).
//!
//! A property is a closure over a seeded [`crate::util::rng::Rng`]; the
//! driver runs it across many derived seeds and reports the first failing
//! seed, which reproduces deterministically:
//!
//! ```ignore
//! check(100, |rng| {
//!     let n = 1 + rng.below(50);
//!     let xs = rng.normal_vec(n);
//!     prop_assert(sorted(&sort(xs)), "sort output is sorted")
//! });
//! ```

use super::rng::Rng;

/// Result of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Approximate float comparison for property bodies.
pub fn prop_close(a: f32, b: f32, tol: f32, what: &str) -> PropResult {
    let denom = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `prop` for `cases` derived seeds; panic with the failing seed.
pub fn check(cases: u64, prop: impl Fn(&mut Rng) -> PropResult) {
    check_seeded(0xC0FFEE, cases, prop)
}

/// As [`check`] with an explicit base seed (to pin a regression).
pub fn check_seeded(base: u64, cases: u64, prop: impl Fn(&mut Rng) -> PropResult) {
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(50, |rng| {
            let a = rng.below(100);
            prop_assert(a < 100, "below() in range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |rng| {
            let a = rng.below(100);
            prop_assert(a < 50, "intentionally flaky")
        });
    }

    #[test]
    fn prop_close_tolerates_small_error() {
        assert!(prop_close(1.0, 1.0 + 1e-7, 1e-5, "x").is_ok());
        assert!(prop_close(1.0, 1.1, 1e-5, "x").is_err());
    }

    #[test]
    fn failing_seed_reproduces() {
        // find the failing seed, then assert the same seed fails again
        let mut failed_seed = None;
        for case in 0..1000u64 {
            let seed = 7u64.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
            let mut rng = Rng::new(seed);
            if rng.below(10) == 3 {
                failed_seed = Some(seed);
                break;
            }
        }
        let seed = failed_seed.expect("some seed should hit 3");
        let mut rng = Rng::new(seed);
        assert_eq!(rng.below(10), 3);
    }
}
