//! Minimal scoped thread pool (no rayon in the offline crate set).
//!
//! Sized from `std::thread::available_parallelism`; on the single-core CI
//! image this degenerates to inline execution, which keeps benches honest
//! (no fake parallel speedups) while the code path still exercises the
//! pool on multi-core machines.
//!
//! The serving hot path fans per-(sequence, kv-head) decode work — and,
//! since the block-tiled prefill refactor, per-(sequence, tile)
//! projection/MLP and per-(sequence, kv-head, query-tile) prefill
//! attention work — across the pool's *persistent* workers (no per-step
//! thread spawns), handing each worker exclusive use of one scratch
//! arena. Two executors drive that fan-out: [`ThreadPool::scatter`]
//! (one stage at a time, full-pool barrier per stage — the `--exec
//! barrier` reference path) and the dependency-driven
//! [`crate::util::workqueue::TaskGraph`] (`--exec queue`, the default),
//! which runs on the same pool via [`ThreadPool::execute`].
//! [`ThreadPool::for_each_index`] remains for borrowed one-shot fan-outs
//! that do not need worker-local state.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-width pool of persistent worker threads fed over one shared
/// channel; see the module docs for the fan-out patterns it backs.
pub struct ThreadPool {
    workers: Vec<std::thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

/// Completion latch shared between one `scatter` call's jobs.
struct Latch {
    next: AtomicUsize,
    remaining: AtomicUsize,
    panicked: AtomicBool,
    done: Mutex<bool>,
    cv: Condvar,
}

impl ThreadPool {
    /// Spawn a pool of `threads.max(1)` persistent workers.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    /// Pool sized from `std::thread::available_parallelism`.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    /// Worker count.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Queue one fire-and-forget job on the pool.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool closed");
    }

    /// Run `f(i)` for i in 0..n in parallel and wait for completion.
    ///
    /// Uses `std::thread::scope` (not the pool's queue) so borrowed
    /// closures work without `'static` bounds; the pool's size only
    /// decides the fan-out. Degenerates to inline execution on one core.
    pub fn for_each_index<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let width = self.size().min(n);
        if width <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..width {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Fan `items` across the pool's persistent workers, giving each
    /// worker exclusive use of one `states` arena: every item is handed
    /// to `f(index, &mut items[index], &mut states[worker])` exactly
    /// once. Blocks until all items are processed.
    ///
    /// Execution order is unspecified, but which worker runs an item
    /// cannot affect results as long as `f` fully overwrites whatever it
    /// reads from the worker arena — the same contract the serial decode
    /// loop already places on its reused scratch. Runs inline (and in
    /// index order) when the pool, `states`, or `items` has a single
    /// entry, so `threads = 1` engines stay strictly serial.
    ///
    /// Panics in `f` are caught on the worker, the fan-out drains, and
    /// the panic is re-raised here (instead of poisoning the pool).
    pub fn scatter<T, S, F>(&self, items: &mut [T], states: &mut [S], f: F)
    where
        T: Send,
        S: Send,
        F: Fn(usize, &mut T, &mut S) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let width = self.size().min(n).min(states.len());
        if width <= 1 {
            let s = states.first_mut().expect("scatter: states must be non-empty");
            for (i, t) in items.iter_mut().enumerate() {
                f(i, t, s);
            }
            return;
        }
        let latch = Latch {
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(width),
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            cv: Condvar::new(),
        };
        let items_addr = items.as_mut_ptr() as usize;
        let states_addr = states.as_mut_ptr() as usize;
        let latch_ref = &latch;
        let f_ref = &f;
        for w in 0..width {
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                // SAFETY: `w` is unique per job, so this is the only
                // &mut into states[w] for the whole fan-out.
                let s = unsafe { &mut *(states_addr as *mut S).add(w) };
                loop {
                    let i = latch_ref.next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // SAFETY: the atomic counter yields each index to
                    // exactly one worker, so this &mut aliases nothing.
                    let t = unsafe { &mut *(items_addr as *mut T).add(i) };
                    let guarded = AssertUnwindSafe(|| f_ref(i, t, &mut *s));
                    if std::panic::catch_unwind(guarded).is_err() {
                        latch_ref.panicked.store(true, Ordering::SeqCst);
                        break;
                    }
                }
                if latch_ref.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    // notify while holding the lock: the waiter may only
                    // observe done=true (and then destroy the latch) after
                    // this worker's final access to it
                    let mut done = latch_ref.done.lock().unwrap();
                    *done = true;
                    latch_ref.cv.notify_all();
                }
            });
            // SAFETY: the job borrows `f`, `latch` and the item/state
            // slices, all of which outlive this call: we block on the
            // latch below until every job has signalled completion, so
            // the 'static erasure can never be observed.
            let job: Job = unsafe { std::mem::transmute(job) };
            self.tx.as_ref().unwrap().send(job).expect("pool closed");
        }
        let mut done = latch.done.lock().unwrap();
        while !*done {
            done = latch.cv.wait(done).unwrap();
        }
        drop(done);
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool::scatter: a worker job panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..32 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn for_each_index_covers_range() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_index(64, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn single_thread_pool_inline() {
        let pool = ThreadPool::new(1);
        let mut data = vec![0usize; 8];
        let ptr = data.as_mut_ptr() as usize;
        pool.for_each_index(8, |i| unsafe {
            *(ptr as *mut usize).add(i) = i * 2;
        });
        assert_eq!(data, vec![0, 2, 4, 6, 8, 10, 12, 14]);
    }

    #[test]
    fn scatter_processes_each_item_once_with_worker_state() {
        let pool = ThreadPool::new(4);
        let mut items: Vec<usize> = vec![0; 100];
        let mut states: Vec<usize> = vec![0; 4];
        pool.scatter(&mut items, &mut states, |i, it, s| {
            *it += i + 1;
            *s += 1;
        });
        assert!(items.iter().enumerate().all(|(i, &v)| v == i + 1));
        assert_eq!(states.iter().sum::<usize>(), 100);
    }

    #[test]
    fn scatter_inline_when_single_state() {
        let pool = ThreadPool::new(4);
        let mut items = vec![1usize; 8];
        let mut states = vec![0usize];
        pool.scatter(&mut items, &mut states, |_, it, s| {
            *it *= 2;
            *s += 1;
        });
        assert_eq!(states[0], 8);
        assert!(items.iter().all(|&v| v == 2));
    }

    #[test]
    fn scatter_empty_items_is_noop() {
        let pool = ThreadPool::new(2);
        let mut items: Vec<usize> = Vec::new();
        let mut states = vec![0usize; 2];
        pool.scatter(&mut items, &mut states, |_, _, s| *s += 1);
        assert_eq!(states, vec![0, 0]);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
