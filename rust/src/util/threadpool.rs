//! Minimal scoped thread pool (no rayon in the offline crate set).
//!
//! Sized from `std::thread::available_parallelism`; on the single-core CI
//! image this degenerates to inline execution, which keeps benches honest
//! (no fake parallel speedups) while the code path still exercises the
//! pool on multi-core machines.
//!
//! The serving hot path fans per-(sequence, kv-head) decode work — and,
//! since the block-tiled prefill refactor, per-(sequence, tile)
//! projection/MLP and per-(sequence, kv-head, query-tile) prefill
//! attention work — across the pool's *persistent* workers (no per-step
//! thread spawns), handing each worker exclusive use of one scratch
//! arena. Two executors drive that fan-out: [`ThreadPool::scatter`]
//! (one stage at a time, full-pool barrier per stage — the `--exec
//! barrier` reference path) and the dependency-driven
//! [`crate::util::workqueue::TaskGraph`] (`--exec queue`, the default).
//! Both now dispatch through [`ThreadPool::broadcast`], which hands one
//! shared borrowed closure to the first `width` workers **without any
//! heap allocation** — no boxed jobs, no channel nodes — which is what
//! lets a warmed-up steady-state decode step run allocation-free (see
//! rust/tests/alloc.rs). [`ThreadPool::for_each_index`] remains for
//! borrowed one-shot fan-outs that do not need worker-local state, and
//! [`ThreadPool::execute`] for fire-and-forget boxed jobs off the hot
//! path.

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One pending broadcast: a type-erased pointer to the caller's closure
/// plus the monomorphized trampoline that re-types and invokes it with
/// the worker's id. Plain data — posting it allocates nothing.
#[derive(Clone, Copy)]
struct BcastJob {
    /// `&F` erased to an address (valid until the broadcast completes —
    /// the caller blocks until every participant has finished).
    data: usize,
    /// `trampoline::<F>`: re-types `data` and calls `(*data)(worker)`.
    call: unsafe fn(usize, usize),
}

/// Re-type the erased closure address and invoke it for one worker.
///
/// # Safety
/// `data` must be a live `&F` for the duration of the call — guaranteed
/// by [`ThreadPool::broadcast`] blocking until every participant exits.
unsafe fn trampoline<F: Fn(usize) + Sync>(data: usize, worker: usize) {
    (*(data as *const F))(worker)
}

/// Worker-visible pool state behind the shared mutex.
struct PoolState {
    /// Fire-and-forget boxed jobs ([`ThreadPool::execute`]).
    jobs: VecDeque<Job>,
    /// Bumped once per broadcast; workers run the current broadcast job
    /// at most once by comparing against their last-seen epoch.
    epoch: u64,
    /// Workers with id < width participate in the current broadcast.
    width: usize,
    /// The current broadcast job (stale after completion; never re-run
    /// because the epoch only matches once per worker).
    bcast: Option<BcastJob>,
    /// Participants that have not finished the current broadcast yet.
    remaining: usize,
    /// A broadcast participant panicked (re-raised on the caller).
    panicked: bool,
    /// Pool is shutting down; workers exit once the queue drains.
    shutdown: bool,
}

/// State + condvars shared between the pool handle and its workers.
struct PoolShared {
    state: Mutex<PoolState>,
    /// Wakes workers when jobs or a broadcast arrive.
    work_cv: Condvar,
    /// Wakes the broadcast caller when the last participant finishes.
    done_cv: Condvar,
}

/// Fixed-width pool of persistent worker threads with stable worker ids;
/// see the module docs for the fan-out patterns it backs.
pub struct ThreadPool {
    workers: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<PoolShared>,
    /// Serializes whole broadcasts (one fan-out at a time per pool).
    bcast_lock: Mutex<()>,
}

impl ThreadPool {
    /// Spawn a pool of `threads.max(1)` persistent workers.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                epoch: 0,
                width: 0,
                bcast: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|id| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, id))
            })
            .collect();
        ThreadPool { workers, shared, bcast_lock: Mutex::new(()) }
    }

    /// Pool sized from `std::thread::available_parallelism`.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    /// Worker count.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Queue one fire-and-forget boxed job on the pool (not part of the
    /// allocation-free hot path — use [`ThreadPool::broadcast`] there).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.shared.state.lock().unwrap();
        st.jobs.push_back(Box::new(job));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Run `f(worker_id)` exactly once on each of the first
    /// `width.min(size)` workers and block until all of them return.
    /// The closure is passed by reference and invoked through a
    /// monomorphized trampoline, so posting the fan-out performs **no
    /// heap allocation** — the property the steady-state decode step's
    /// zero-allocation guarantee (rust/tests/alloc.rs) rests on.
    ///
    /// Worker ids are stable for the pool's lifetime, so `f` can index a
    /// per-worker arena slice with them. Broadcasts are serialized per
    /// pool; concurrent callers take turns. Panics in `f` are caught on
    /// the worker, the fan-out drains, and the panic is re-raised here.
    ///
    /// Must not be called from a pool worker thread (e.g. from inside an
    /// [`ThreadPool::execute`] job or another broadcast): the calling
    /// worker would be a required participant of its own fan-out and the
    /// call would deadlock. Guarded by a debug assertion.
    pub fn broadcast<F: Fn(usize) + Sync>(&self, width: usize, f: &F) {
        debug_assert!(
            !IN_POOL_WORKER.with(|w| w.get()),
            "ThreadPool::broadcast called from a pool worker thread (would deadlock)"
        );
        let width = width.min(self.size());
        if width == 0 {
            return;
        }
        let _turn = self.bcast_lock.lock().unwrap();
        let mut st = self.shared.state.lock().unwrap();
        st.epoch = st.epoch.wrapping_add(1);
        st.width = width;
        st.bcast = Some(BcastJob { data: f as *const F as usize, call: trampoline::<F> });
        st.remaining = width;
        st.panicked = false;
        self.shared.work_cv.notify_all();
        while st.remaining > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        let panicked = st.panicked;
        drop(st);
        if panicked {
            panic!("ThreadPool::broadcast: a worker job panicked");
        }
    }

    /// Run `f(i)` for i in 0..n in parallel and wait for completion.
    ///
    /// Uses `std::thread::scope` (not the pool's workers) so borrowed
    /// closures work without worker-arena bookkeeping; the pool's size
    /// only decides the fan-out. Degenerates to inline execution on one
    /// core.
    pub fn for_each_index<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let width = self.size().min(n);
        if width <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..width {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Fan `items` across the pool's persistent workers, giving each
    /// worker exclusive use of one `states` arena: every item is handed
    /// to `f(index, &mut items[index], &mut states[worker])` exactly
    /// once. Blocks until all items are processed.
    ///
    /// Execution order is unspecified, but which worker runs an item
    /// cannot affect results as long as `f` fully overwrites whatever it
    /// reads from the worker arena — the same contract the serial decode
    /// loop already places on its reused scratch. Runs inline (and in
    /// index order) when the pool, `states`, or `items` has a single
    /// entry, so `threads = 1` engines stay strictly serial.
    ///
    /// Panics in `f` are caught on the worker, the fan-out drains, and
    /// the panic is re-raised here (instead of poisoning the pool).
    pub fn scatter<T, S, F>(&self, items: &mut [T], states: &mut [S], f: F)
    where
        T: Send,
        S: Send,
        F: Fn(usize, &mut T, &mut S) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let width = self.size().min(n).min(states.len());
        if width <= 1 {
            let s = states.first_mut().expect("scatter: states must be non-empty");
            for (i, t) in items.iter_mut().enumerate() {
                f(i, t, s);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let items_addr = items.as_mut_ptr() as usize;
        let states_addr = states.as_mut_ptr() as usize;
        let f_ref = &f;
        // SAFETY notes for the worker closure: `w` is unique per
        // participant, so states[w] has exactly one &mut for the whole
        // fan-out, and the atomic counter yields each item index to
        // exactly one worker. `broadcast` blocks until every participant
        // returns, so the borrows of `f`, the counter and both slices
        // outlive every use.
        self.broadcast(width, &|w: usize| {
            let s = unsafe { &mut *(states_addr as *mut S).add(w) };
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let t = unsafe { &mut *(items_addr as *mut T).add(i) };
                let guarded = AssertUnwindSafe(|| f_ref(i, t, &mut *s));
                if std::panic::catch_unwind(guarded).is_err() {
                    panicked.store(true, Ordering::SeqCst);
                    break;
                }
            }
        });
        if panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool::scatter: a worker job panicked");
        }
    }
}

thread_local! {
    /// True on pool worker threads — lets [`ThreadPool::broadcast`]
    /// debug-assert against the self-deadlocking reentrant case.
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// What a woken worker decided to do next.
enum Step {
    Bcast(BcastJob),
    Job(Job),
    Exit,
}

/// Persistent worker: interleave broadcast participation (when this
/// worker's id is within the broadcast width) with boxed-job draining.
fn worker_loop(shared: &PoolShared, id: usize) {
    IN_POOL_WORKER.with(|w| w.set(true));
    let mut seen = 0u64;
    loop {
        let step = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if id < st.width {
                        break Step::Bcast(st.bcast.expect("broadcast job set with epoch"));
                    }
                    // not a participant in this epoch: fall through
                }
                if let Some(j) = st.jobs.pop_front() {
                    break Step::Job(j);
                }
                if st.shutdown {
                    break Step::Exit;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        match step {
            Step::Bcast(b) => {
                // SAFETY: the broadcast caller blocks until `remaining`
                // hits zero, so the closure behind `b.data` is live.
                let guarded = AssertUnwindSafe(|| unsafe { (b.call)(b.data, id) });
                let ok = std::panic::catch_unwind(guarded).is_ok();
                let mut st = shared.state.lock().unwrap();
                if !ok {
                    st.panicked = true;
                }
                st.remaining -= 1;
                if st.remaining == 0 {
                    shared.done_cv.notify_all();
                }
            }
            Step::Job(j) => j(),
            Step::Exit => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..32 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn broadcast_runs_once_per_participant() {
        let pool = ThreadPool::new(4);
        for width in [1usize, 2, 4, 9] {
            let hits: Vec<AtomicUsize> = (0..pool.size()).map(|_| AtomicUsize::new(0)).collect();
            pool.broadcast(width, &|w| {
                hits[w].fetch_add(1, Ordering::SeqCst);
            });
            let expect = width.min(pool.size());
            for (w, h) in hits.iter().enumerate() {
                let want = usize::from(w < expect);
                assert_eq!(h.load(Ordering::SeqCst), want, "width {width} worker {w}");
            }
        }
    }

    #[test]
    fn broadcast_serializes_and_repeats() {
        // back-to-back broadcasts must each run exactly once per worker,
        // including workers that skipped a narrower earlier epoch
        let pool = ThreadPool::new(3);
        let total = AtomicUsize::new(0);
        pool.broadcast(1, &|_| {
            total.fetch_add(1, Ordering::SeqCst);
        });
        pool.broadcast(3, &|_| {
            total.fetch_add(10, Ordering::SeqCst);
        });
        pool.broadcast(2, &|_| {
            total.fetch_add(100, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 1 + 30 + 200);
    }

    #[test]
    fn broadcast_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.broadcast(2, &|w| {
                if w == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "broadcast must re-raise worker panics");
        // pool still works afterwards
        let ran = AtomicUsize::new(0);
        pool.broadcast(2, &|_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn for_each_index_covers_range() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_index(64, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn single_thread_pool_inline() {
        let pool = ThreadPool::new(1);
        let mut data = vec![0usize; 8];
        let ptr = data.as_mut_ptr() as usize;
        pool.for_each_index(8, |i| unsafe {
            *(ptr as *mut usize).add(i) = i * 2;
        });
        assert_eq!(data, vec![0, 2, 4, 6, 8, 10, 12, 14]);
    }

    #[test]
    fn scatter_processes_each_item_once_with_worker_state() {
        let pool = ThreadPool::new(4);
        let mut items: Vec<usize> = vec![0; 100];
        let mut states: Vec<usize> = vec![0; 4];
        pool.scatter(&mut items, &mut states, |i, it, s| {
            *it += i + 1;
            *s += 1;
        });
        assert!(items.iter().enumerate().all(|(i, &v)| v == i + 1));
        assert_eq!(states.iter().sum::<usize>(), 100);
    }

    #[test]
    fn scatter_inline_when_single_state() {
        let pool = ThreadPool::new(4);
        let mut items = vec![1usize; 8];
        let mut states = vec![0usize];
        pool.scatter(&mut items, &mut states, |_, it, s| {
            *it *= 2;
            *s += 1;
        });
        assert_eq!(states[0], 8);
        assert!(items.iter().all(|&v| v == 2));
    }

    #[test]
    fn scatter_empty_items_is_noop() {
        let pool = ThreadPool::new(2);
        let mut items: Vec<usize> = Vec::new();
        let mut states = vec![0usize; 2];
        pool.scatter(&mut items, &mut states, |_, _, s| *s += 1);
        assert_eq!(states, vec![0, 0]);
    }

    #[test]
    fn scatter_panic_propagates() {
        let pool = ThreadPool::new(2);
        let mut items = vec![0usize; 8];
        let mut states = vec![(); 2];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scatter(&mut items, &mut states, |i, _, _| {
                if i == 3 {
                    panic!("scatter boom");
                }
            });
        }));
        assert!(r.is_err());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
