//! Minimal scoped thread pool (no rayon in the offline crate set).
//!
//! Sized from `std::thread::available_parallelism`; on the single-core CI
//! image this degenerates to inline execution, which keeps benches honest
//! (no fake parallel speedups) while the code path still exercises the
//! pool on multi-core machines.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<std::thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool closed");
    }

    /// Run `f(i)` for i in 0..n in parallel and wait for completion.
    ///
    /// Uses `std::thread::scope` (not the pool's queue) so borrowed
    /// closures work without `'static` bounds; the pool's size only
    /// decides the fan-out. Degenerates to inline execution on one core.
    pub fn for_each_index<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let width = self.size().min(n);
        if width <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..width {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..32 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn for_each_index_covers_range() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_index(64, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn single_thread_pool_inline() {
        let pool = ThreadPool::new(1);
        let mut data = vec![0usize; 8];
        let ptr = data.as_mut_ptr() as usize;
        pool.for_each_index(8, |i| unsafe {
            *(ptr as *mut usize).add(i) = i * 2;
        });
        assert_eq!(data, vec![0, 2, 4, 6, 8, 10, 12, 14]);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
