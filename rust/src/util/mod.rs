//! From-scratch substrates.
//!
//! The build image ships no crates.io index beyond a tiny vendored set
//! (see DESIGN.md §2), so the usual ecosystem pieces — serde, clap, rand,
//! criterion, rayon — are reimplemented here at the scale this project
//! needs. Each submodule carries its own unit tests.

pub mod cli;
pub mod json;
pub mod logger;
pub mod pt;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;
pub mod workqueue;
