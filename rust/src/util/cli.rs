//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands. Unknown flags are an error, which catches typos in
//! bench scripts early.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args and typed flag access.
#[derive(Debug, Default)]
pub struct Args {
    /// First bare argument when subcommands are enabled.
    pub subcommand: Option<String>,
    /// Remaining bare arguments.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    known: Vec<String>,
}

/// Argument parsing failures (reported with the offending flag).
#[derive(Debug, thiserror::Error)]
pub enum CliError {
    /// Flag is not in the spec (likely a typo).
    #[error("unknown flag --{0}")]
    Unknown(String),
    /// Non-boolean flag appeared without a value.
    #[error("flag --{0} expects a value")]
    MissingValue(String),
    /// Value failed to parse as the requested type.
    #[error("flag --{0}: cannot parse {1:?}")]
    BadValue(String, String),
}

impl Args {
    /// Parse `argv[1..]`. `spec` lists known flag names; names ending in
    /// `!` are boolean (take no value).
    pub fn parse(argv: &[String], spec: &[&str], with_subcommand: bool) -> Result<Args, CliError> {
        let mut out = Args::default();
        out.known = spec.iter().map(|s| s.trim_end_matches('!').to_string()).collect();
        let boolset: Vec<&str> = spec
            .iter()
            .filter(|s| s.ends_with('!'))
            .map(|s| s.trim_end_matches('!'))
            .collect();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let (key, inline) = match name.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (name.to_string(), None),
                };
                if !out.known.contains(&key) {
                    return Err(CliError::Unknown(key));
                }
                if boolset.contains(&key.as_str()) {
                    out.flags.insert(key, inline.unwrap_or_else(|| "true".into()));
                } else if let Some(v) = inline {
                    out.flags.insert(key, v);
                } else if let Some(v) = it.next() {
                    out.flags.insert(key, v.clone());
                } else {
                    return Err(CliError::MissingValue(key));
                }
            } else if with_subcommand && out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    /// String flag with a default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// String flag, `None` when absent.
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    /// `usize` flag with a default.
    pub fn usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.to_string(), v.clone())),
        }
    }

    /// `u64` flag with a default.
    pub fn u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.to_string(), v.clone())),
        }
    }

    /// `f64` flag with a default.
    pub fn f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.to_string(), v.clone())),
        }
    }

    /// Boolean flag: present (or `--key=true`)?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Comma-separated list of usize, e.g. `--lens 1024,2048`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| CliError::BadValue(key.to_string(), v.clone()))
                })
                .collect(),
        }
    }

    /// Comma-separated list of f64, e.g. `--offered-load 10,25,50`.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| CliError::BadValue(key.to_string(), v.clone()))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = Args::parse(
            &argv(&["serve", "--model", "hata-mha", "--verbose", "file.txt"]),
            &["model", "verbose!"],
            true,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.str("model", ""), "hata-mha");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&argv(&["--k=32"]), &["k"], false).unwrap();
        assert_eq!(a.usize("k", 0).unwrap(), 32);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(
            Args::parse(&argv(&["--nope"]), &["k"], false),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            Args::parse(&argv(&["--k"]), &["k"], false),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&argv(&["--lens", "1,2,3"]), &["lens"], false).unwrap();
        assert_eq!(a.usize_list("lens", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.usize_list("other", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn f64_list_parsing() {
        let a = Args::parse(&argv(&["--load", "0.5, 10,25"]), &["load"], false).unwrap();
        assert_eq!(a.f64_list("load", &[]).unwrap(), vec![0.5, 10.0, 25.0]);
        assert_eq!(a.f64_list("other", &[1.5]).unwrap(), vec![1.5]);
        assert!(Args::parse(&argv(&["--load", "x"]), &["load"], false)
            .unwrap()
            .f64_list("load", &[])
            .is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&[]), &["x"], false).unwrap();
        assert_eq!(a.usize("x", 7).unwrap(), 7);
        assert_eq!(a.f64("x", 0.5).unwrap(), 0.5);
        assert!(!a.flag("x"));
    }
}
